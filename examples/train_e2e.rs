//! End-to-end validation (DESIGN.md §5 E2E): REAL chunk-managed training
//! of a GPT model through the three-layer stack.
//!
//! * L3 (this binary + the patrickstar crate): chunk layout, Access/
//!   Release state machine, LRU eviction between the capacity-accounted
//!   "GPU" pool and host memory, grad-reuses-param-chunk, chunk-wise ADAM.
//! * L2: the JAX GPT fwd/bwd lowered to `artifacts/train_step.hlo.txt`.
//! * L1: the Pallas kernels (attention core, layernorm, fused chunk ADAM)
//!   inside those artifacts, lowered with interpret=True.
//!
//! Trains on the synthetic corpus and prints the loss curve; the loss
//! must drop well below the unigram entropy, proving the whole stack
//! (including chunk eviction on every step) computes correct gradients.
//!
//! Run `make artifacts` first, then:
//!   cargo run --release --example train_e2e -- [steps] [gpu_mb]

use anyhow::Result;
use patrickstar::train::{Trainer, TrainerConfig};
use patrickstar::util::human_bytes;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize =
        args.get(1).and_then(|s| s.parse().ok()).unwrap_or(120);
    let gpu_mb: u64 = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);

    let cfg = TrainerConfig {
        artifacts_dir: "artifacts".into(),
        gpu_bytes: gpu_mb << 20,
        lr: 1e-3,
        ..Default::default()
    };
    let mut trainer = Trainer::new(cfg)?;
    let man = trainer.manifest().clone();
    println!(
        "model: {:.2}M params ({} layers x hidden {}, vocab {}, seq {}), \
         chunk {} elems, GPU pool {}",
        man.n_params as f64 / 1e6,
        man.layers,
        man.hidden,
        man.vocab,
        man.seq,
        man.chunk_elems,
        human_bytes(gpu_mb << 20),
    );

    let report = trainer.train(steps, 10)?;

    // Loss-curve summary: first/median/last.
    let n = report.losses.len();
    println!("\nloss curve (every ~{} steps):", (n / 12).max(1));
    for (i, loss) in report.losses.iter().enumerate() {
        if i % (n / 12).max(1) == 0 || i == n - 1 {
            println!("  step {i:4}  loss {loss:.4}");
        }
    }
    let first = report.losses[0];
    let last = report.losses[n - 1];
    println!(
        "\nfirst {first:.4} -> last {last:.4}  (uniform = ln(vocab) = \
         {:.3})",
        (man.vocab as f64).ln()
    );
    println!(
        "chunk traffic: {} cpu->gpu, {} gpu->cpu, {} evictions \
         (eviction > 0 proves the GPU pool was under real pressure)",
        human_bytes(report.cpu_to_gpu_bytes),
        human_bytes(report.gpu_to_cpu_bytes),
        report.evictions,
    );
    println!(
        "mean step time {:.2}s over {} steps",
        report.step_secs.iter().sum::<f64>() / n as f64,
        n
    );
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("E2E OK: loss decreased through the full three-layer stack");
    Ok(())
}
