//! Reproduce Fig. 16 + Table 4: iteration time breakdown for the
//! optimization ablations (Base / OSC / SP) on the paper's six cases.
//!
//! Run with: `cargo run --release --example breakdown`

use anyhow::Result;
use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, OptimizationPlan};
use patrickstar::model::GptSpec;
use patrickstar::sim::Phase;
use patrickstar::util::Table;

fn main() -> Result<()> {
    // Paper's six cases: SuperPod 10B & 50B, YARD 12B, each on 1 & 8 GPU.
    let cases = [
        (ClusterPreset::superpod(), "10B", 1u32),
        (ClusterPreset::superpod(), "10B", 8),
        (ClusterPreset::superpod(), "50B", 1),
        (ClusterPreset::superpod(), "50B", 8),
        (ClusterPreset::yard(), "12B", 1),
        (ClusterPreset::yard(), "12B", 8),
    ];
    let plans = [
        ("Base", OptimizationPlan::default()),
        ("OSC", OptimizationPlan::os_on_cpu()),
        ("SP", OptimizationPlan::static_partition()),
    ];
    let mut table4 = Table::new(&["case", "margin(+)/spill(-)"]);
    for (cluster, model, gpus) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, 8, gpus);
        println!("\n=== {} {} {}g (batch 8) ===", cluster.name, model, gpus);
        let mut t = Table::new(&["plan", "total", "fwd+bwd", "adam",
                                 "allgather", "reduce-sc", "cpu->gpu",
                                 "gpu->cpu", "adam-move"]);
        for (label, opt) in plans {
            match Engine::new(cluster, task).with_opt(opt).run() {
                Ok(r) => {
                    let g = |p| format!("{:.2}", r.breakdown.get(p));
                    t.row(vec![
                        format!("{gpus}g{label}"),
                        format!("{:.2}s", r.iter_time_s),
                        g(Phase::FwdBwd),
                        g(Phase::Adam),
                        g(Phase::AllGather),
                        g(Phase::ReduceScatter),
                        g(Phase::CpuToGpu),
                        g(Phase::GpuToCpu),
                        g(Phase::AdamMove),
                    ]);
                    if label == "Base" {
                        table4.row(vec![
                            format!("{} {} {}g", cluster.name, model, gpus),
                            format!("{:+}", r.placement.margin_or_spill()),
                        ]);
                    }
                }
                Err(e) => {
                    t.row(vec![
                        format!("{gpus}g{label}"),
                        format!("infeasible: {e}"),
                        "-".into(), "-".into(), "-".into(), "-".into(),
                        "-".into(), "-".into(), "-".into(),
                    ]);
                }
            }
        }
        print!("{}", t.render());
    }
    println!("\n=== Table 4: margin space / spilling (Base plan) ===");
    print!("{}", table4.render());
    println!(
        "paper Table 4: SPod 10B 1g:+2 8g:+6 | SPod 50B 1g:-20 8g:+1 | \
         YARD 12B 1g:-1 8g:+5"
    );
    Ok(())
}
