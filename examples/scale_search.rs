//! Reproduce Fig. 13 + Fig. 19: max model scale per system per GPU count
//! on all cluster presets, plus the 700$-PC experiment (Sec. 9.2.5).
//!
//! Run with: `cargo run --release --example scale_search`

use anyhow::Result;
use patrickstar::config::{ClusterPreset, SystemKind};
use patrickstar::scale::max_model_scale;
use patrickstar::util::Table;

fn scale_row(
    t: &mut Table,
    system: SystemKind,
    cluster: ClusterPreset,
    gpus: u32,
) {
    match max_model_scale(system, cluster, gpus) {
        Some(p) => {
            let r = p.best.unwrap();
            t.row(vec![
                cluster.name.into(),
                format!("{gpus}g"),
                system.name(),
                p.model.into(),
                format!("{:.1}", r.tflops_per_gpu),
            ]);
        }
        None => {
            t.row(vec![
                cluster.name.into(),
                format!("{gpus}g"),
                system.name(),
                "-".into(),
                "-".into(),
            ]);
        }
    }
}

fn main() -> Result<()> {
    println!("=== Fig. 13: max model scale (bar: 30/50 Tflops) ===");
    let mut t = Table::new(&["cluster", "gpus", "system", "max model",
                             "tflops/GPU"]);
    for cluster in [ClusterPreset::yard(), ClusterPreset::superpod()] {
        for gpus in [1u32, 2, 4, 8] {
            for system in [
                SystemKind::PyTorchDdp,
                SystemKind::DeepSpeedDp,
                SystemKind::DeepSpeedMp(gpus.min(8)),
                SystemKind::PatrickStar,
            ] {
                if matches!(system, SystemKind::DeepSpeedMp(1)) {
                    continue;
                }
                scale_row(&mut t, system, cluster, gpus);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "paper: YARD 8g — PyTorch 1B, DeepSpeed-DP 4B, DeepSpeed-MP 8B, \
         PatrickStar 18B; SuperPod 8g — DeepSpeed 30B, PatrickStar 68B"
    );

    println!("\n=== Fig. 19: 120 GB CPU memory, 8x V100 ===");
    let mut t = Table::new(&["cluster", "gpus", "system", "max model",
                             "tflops/GPU"]);
    for system in [SystemKind::DeepSpeedDp, SystemKind::DeepSpeedMp(8),
                   SystemKind::PatrickStar] {
        scale_row(&mut t, system, ClusterPreset::yard_120gb(), 8);
    }
    print!("{}", t.render());
    println!("paper: PatrickStar 8B @ 48.78 Tflops, DeepSpeed-MP 4B");

    println!("\n=== Sec. 9.2.5: the 700$ PC (RTX 2060 8GB + 16GB DRAM) ===");
    let mut t = Table::new(&["cluster", "gpus", "system", "max model",
                             "tflops/GPU"]);
    for system in [SystemKind::PyTorchDdp, SystemKind::DeepSpeedDp,
                   SystemKind::PatrickStar] {
        scale_row(&mut t, system, ClusterPreset::pc(), 1);
    }
    print!("{}", t.render());
    println!("paper: PatrickStar 0.7B @ 18.46 Tflops; baselines 0.11B");
    Ok(())
}
