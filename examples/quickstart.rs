//! Quickstart: the PatrickStar public API in ~60 lines.
//!
//! 1. Pick a paper model and cluster preset.
//! 2. Run the chunk-size search (Sec. 9.1).
//! 3. Simulate one training iteration and print the Fig. 16-style
//!    breakdown.
//! 4. Compare against the DeepSpeed baseline on the same task.
//!
//! Run with: `cargo run --release --example quickstart`

use anyhow::Result;
use patrickstar::baselines::run_system;
use patrickstar::chunk::search_chunk_size;
use patrickstar::config::{ClusterPreset, SystemKind, TrainTask};
use patrickstar::model::GptSpec;

fn main() -> Result<()> {
    let cluster = ClusterPreset::yard(); // 8x V100-32GB, 240 GB DRAM
    let model = GptSpec::by_name("10B").expect("model in Table 2");

    // --- chunk size search (paper Table 3) -----------------------------
    let budget = cluster.cpu_mem + cluster.n_gpus as u64 * cluster.gpu_mem;
    let search = search_chunk_size(&model.tensor_specs(), budget)
        .expect("feasible chunk size");
    println!(
        "chunk search: best {} elems, utilization {:.1}%",
        search.best.chunk_elems,
        100.0 * search.best.utilization
    );

    // --- one PatrickStar iteration on 8 GPUs ---------------------------
    let task = TrainTask::new(model, 16, 8);
    let ps = run_system(SystemKind::PatrickStar, cluster, task)?;
    println!("\n--- PatrickStar ---\n{}", ps.render());

    // --- DeepSpeed on the same task ------------------------------------
    match run_system(SystemKind::DeepSpeedDp, cluster, task) {
        Ok(ds) => {
            println!("--- DeepSpeed-DP ---\n{}", ds.render());
            println!(
                "speedup: {:.2}x (paper reports 1.08-1.47x on YARD)",
                ds.iter_time_s / ps.iter_time_s
            );
        }
        Err(e) => println!(
            "--- DeepSpeed-DP ---\ninfeasible on this task: {e}\n\
             (PatrickStar trains it anyway — the paper's Fig. 10 story)"
        ),
    }
    Ok(())
}
