import os
import sys

# Tests import the build-time package as `compile.*`; make that work no
# matter which directory pytest is launched from.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
