"""AOT compile path: lower the L2 model + L1 kernels to HLO text artifacts.

Python runs exactly once, here.  Outputs (under artifacts/):

    train_step.hlo.txt   (tokens, targets, *params) -> (loss, *grads)
    eval_loss.hlo.txt    (tokens, targets, *params) -> (loss,)
    adam_step.hlo.txt    (hp[8], p[c], m[c], v[c], g[c]) -> (p', m', v')
                         c = chunk_elems; body is the Pallas chunk_adam kernel
    manifest.json        model config, param order/shapes, chunk size,
                         artifact inventory — the rust<->python contract

Interchange is HLO *text*, not serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
behind the rust `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import adam as K


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step(cfg: M.GptConfig, with_grads: bool = True) -> str:
    tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)
    params = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape in M.param_order(cfg)
    ]
    if with_grads:
        fn = M.train_step_flat(cfg)
    else:
        order = [n for n, _ in M.param_order(cfg)]

        def fn(tokens, targets, *flat):
            return (M.loss_fn(cfg, dict(zip(order, flat)), tokens, targets),)

    return to_hlo_text(jax.jit(fn).lower(tok, tok, *params))


def lower_adam_step(chunk_elems: int, block: int) -> str:
    hp = jax.ShapeDtypeStruct((K.HP_LEN,), jnp.float32)
    buf = jax.ShapeDtypeStruct((chunk_elems,), jnp.float32)

    def fn(hp, p, m, v, g):
        return K.chunk_adam(hp, p, m, v, g, block=block)

    return to_hlo_text(jax.jit(fn).lower(hp, buf, buf, buf, buf))


def is_embedding(name: str) -> bool:
    """Embedding parameters are CPU-pinned and not chunk-orchestrated
    (paper Sec. 8.2: 'embedding parameters are not managed by chunk')."""
    return name in ("wte", "wpe")


def pick_chunk_elems(cfg: M.GptConfig, target: int) -> int:
    """Round target up so the largest chunk-managed tensor fits in one chunk.

    Mirrors the constraint of the paper's mapping schema (Sec. 6.1): a
    tensor never spans two chunks, so chunk size >= max tensor size.
    Embedding tensors are excluded — they are CPU-pinned (Sec. 8.2).  The
    rust side performs the full fragmentation-minimizing search (paper
    Table 3); at AOT time we only need a feasible, 64-aligned size for the
    e2e model because the kernel signature bakes it in.
    """
    biggest = max(
        int(math.prod(shape))
        for name, shape in M.param_order(cfg)
        if not is_embedding(name)
    )
    elems = max(target, biggest)
    return ((elems + 63) // 64) * 64


def write(path: str, text: str) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    print(f"wrote {path}  ({len(text)} chars, sha256:{digest})")
    return {"path": os.path.basename(path), "bytes": len(text),
            "sha256_16": digest}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output dir "
                    "(or a single .hlo.txt path for --only)")
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--chunk-elems", type=int, default=1 << 16,
                    help="target chunk size in f32 elements (rounded up to "
                    "fit the largest tensor, 64-aligned)")
    ap.add_argument("--adam-block", type=int, default=K.DEFAULT_BLOCK)
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference model instead")
    args = ap.parse_args(argv)

    cfg = M.GptConfig(
        vocab=args.vocab, seq=args.seq, hidden=args.hidden,
        layers=args.layers, heads=args.heads, batch=args.batch,
        use_pallas=not args.no_pallas,
    )
    out = args.out
    if out.endswith(".txt"):
        out = os.path.dirname(out) or "."
    os.makedirs(out, exist_ok=True)

    chunk_elems = pick_chunk_elems(cfg, args.chunk_elems)
    arts = {}
    print(f"model: {cfg.n_params()/1e6:.2f}M params, "
          f"chunk_elems={chunk_elems}", file=sys.stderr)
    arts["train_step"] = write(
        os.path.join(out, "train_step.hlo.txt"), lower_train_step(cfg))
    arts["eval_loss"] = write(
        os.path.join(out, "eval_loss.hlo.txt"),
        lower_train_step(cfg, with_grads=False))
    arts["adam_step"] = write(
        os.path.join(out, "adam_step.hlo.txt"),
        lower_adam_step(chunk_elems, args.adam_block))

    manifest = {
        "model": {
            "vocab": cfg.vocab, "seq": cfg.seq, "hidden": cfg.hidden,
            "layers": cfg.layers, "heads": cfg.heads, "batch": cfg.batch,
            "use_pallas": cfg.use_pallas, "n_params": cfg.n_params(),
        },
        "params": [
            {"name": n, "shape": list(s), "numel": int(math.prod(s)),
             "embedding": is_embedding(n)}
            for n, s in M.param_order(cfg)
        ],
        "chunk_elems": chunk_elems,
        "adam_hp_len": K.HP_LEN,
        "artifacts": arts,
    }
    mpath = os.path.join(out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
