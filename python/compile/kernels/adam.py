"""L1 Pallas kernel: chunk-granular fused ADAM update.

This is PatrickStar's parameter-updating hot-spot expressed as a Pallas
kernel.  In the paper (Sec. 6.2, Sec. 8.2) the ADAM stage operates on whole
chunks: the param fp32 / momentum / variance chunk lists share offsets, and
grad fp16 chunks are converted to fp32 on the fly.  Here the chunk *is* the
kernel's input buffer, and BlockSpec tiles it into VMEM-sized slabs — the
HBM<->VMEM schedule mirrors, one level down the memory hierarchy, the
CPU<->GPU chunk schedule the paper performs with its chunk manager.

TPU adaptation note (DESIGN.md §2): on a real TPU this is a memory-bound
elementwise kernel; with the default block of 16384 f32 elements the VMEM
working set is 5 slabs x 64 KiB = 320 KiB, far under the ~16 MiB VMEM
budget, leaving room for double buffering.  On this testbed it is lowered
with interpret=True so the same code runs on the CPU PJRT client.

Hyper-parameters travel in a single f32[8] scalar vector so the lowered HLO
has a stable, chunk-size-independent signature:

    hp = [lr, beta1, beta2, eps, weight_decay, step, _, _]
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Layout of the hyper-parameter vector (kept in sync with rust/src/train/).
HP_LEN = 8
HP_LR, HP_BETA1, HP_BETA2, HP_EPS, HP_WD, HP_STEP = 0, 1, 2, 3, 4, 5

DEFAULT_BLOCK = 16384


def _adam_block_kernel(hp_ref, p_ref, m_ref, v_ref, g_ref,
                       po_ref, mo_ref, vo_ref):
    """Pallas body: fused ADAM on one VMEM block of a chunk."""
    lr = hp_ref[HP_LR]
    beta1 = hp_ref[HP_BETA1]
    beta2 = hp_ref[HP_BETA2]
    eps = hp_ref[HP_EPS]
    wd = hp_ref[HP_WD]
    step = hp_ref[HP_STEP]

    p = p_ref[...]
    g = g_ref[...] + wd * p
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    # Bias correction: step >= 1.  Computed per block; scalar math only.
    bc1 = 1.0 - jnp.power(beta1, step)
    bc2 = 1.0 - jnp.power(beta2, step)
    m_hat = m / bc1
    v_hat = v / bc2
    po_ref[...] = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=("block",))
def chunk_adam(hp, p, m, v, g, *, block=DEFAULT_BLOCK):
    """Fused ADAM over a flat f32 chunk.

    Args:
        hp: f32[HP_LEN] hyper-parameter vector (see module docstring).
        p, m, v, g: f32[n] param fp32 / momentum / variance / grad chunks.
        block: VMEM tile size; the chunk is processed in ceil(n/block)
            grid steps.  n must be a multiple of block unless n < block,
            in which case a single whole-chunk block is used (chunk sizes
            produced by the rust chunk-size search are always multiples
            of 64, so the alignment precondition holds in practice).

    Returns:
        (p_new, m_new, v_new), each f32[n].
    """
    n = p.shape[0]
    if n <= block or n % block != 0:
        block = n
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    hp_spec = pl.BlockSpec((HP_LEN,), lambda i: (0,))
    out_shape = [jax.ShapeDtypeStruct((n,), jnp.float32)] * 3
    return tuple(
        pl.pallas_call(
            _adam_block_kernel,
            grid=grid,
            in_specs=[hp_spec, spec, spec, spec, spec],
            out_specs=[spec, spec, spec],
            out_shape=out_shape,
            interpret=True,
        )(hp, p, m, v, g)
    )


def make_hp(lr, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.0, step=1):
    """Pack ADAM hyper-parameters into the f32[HP_LEN] vector."""
    vec = [lr, beta1, beta2, eps, weight_decay, float(step)] + [0.0] * (
        HP_LEN - 6
    )
    return jnp.asarray(vec, dtype=jnp.float32)
