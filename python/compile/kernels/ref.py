"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has an exact reference implementation
here.  pytest (python/tests/) sweeps shapes/dtypes with hypothesis and
asserts allclose(kernel, ref).  These functions are also used directly by
the reference model to build a completely Pallas-free model for
end-to-end numerical comparison.
"""

from __future__ import annotations

import jax.numpy as jnp


def adam_ref(p, m, v, g, *, lr, beta1, beta2, eps, weight_decay, step):
    """Reference fused ADAM update on a flat chunk.

    Mirrors the paper's chunk-granular parameter update (Sec. 6.2): the
    optimizer states (param fp32 / momentum / variance) live in chunk lists
    with identical offsets, so the update is a pure elementwise map over
    four equally-shaped flat buffers.

    Returns (p_new, m_new, v_new).
    """
    g = g + weight_decay * p
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * g * g
    # Bias correction (step counts from 1).
    m_hat = m_new / (1.0 - beta1**step)
    v_hat = v_new / (1.0 - beta2**step)
    p_new = p - lr * m_hat / (jnp.sqrt(v_hat) + eps)
    return p_new, m_new, v_new


def layernorm_ref(x, gamma, beta, *, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def attention_core_ref(q, k, v, *, causal=True, scale=None):
    """Reference attention core: softmax(scale * Q K^T + mask) V.

    q, k, v: [heads, seq, head_dim] (batch folded into heads by the caller).
    """
    hd = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        s = q.shape[-2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, jnp.finfo(logits.dtype).min)
    probs = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", probs, v)


def softmax_xent_ref(logits, targets):
    """Reference mean softmax cross-entropy.

    logits: [N, vocab]; targets: int32 [N].
    """
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits), axis=-1))
    gold = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
