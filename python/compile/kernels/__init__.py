# L1: Pallas kernels for the paper's compute hot-spots.
#  - adam.chunk_adam    : chunk-granular fused ADAM (PatrickStar Sec. 6.2/8.2)
#  - layers.layernorm   : memory-bound elementwise norm (custom-VJP Pallas)
#  - layers.attention_core : MXU-oriented attention core (custom-VJP Pallas)
#  - ref                : pure-jnp oracles for all of the above
from . import adam, layers, ref  # noqa: F401
