"""L1 Pallas kernels for the transformer blocks: LayerNorm + attention core.

The paper's FWD/BWD compute is a GPT-2 stack; its hot spots on the
accelerator are the attention core (GEMM + softmax, MXU-bound) and the
pervasive LayerNorms (memory-bound).  Both are written as Pallas kernels so
that they lower into the same HLO module as the surrounding jnp graph and
are exercised by the rust PJRT runtime on every training step.

Reverse mode: interpret-mode pallas_call is not linearizable by JAX's
autodiff in this environment, so both ops carry `jax.custom_vjp` whose
*backward passes are themselves Pallas kernels*.  The attention backward
recomputes the softmax from Q/K/V instead of saving the probability matrix
(flash-attention-style rematerialization) — the same memory/compute trade
the paper applies at chunk level with activation checkpointing (Sec. 3.3).

TPU adaptation (DESIGN.md §2): each grid step holds one (batch*head)
[seq, head_dim] Q/K/V tile plus one [seq, seq] logits tile in VMEM; the
matmuls in the bodies target the MXU.  interpret=True makes the same code
run on CPU PJRT here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30
_LN_EPS = 1e-5


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

def _ln_fwd_kernel(x_ref, g_ref, b_ref, o_ref, xhat_ref, rstd_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    rstd = 1.0 / jnp.sqrt(var + eps)
    xhat = (x - mu) * rstd
    o_ref[...] = xhat * g_ref[...] + b_ref[...]
    xhat_ref[...] = xhat
    rstd_ref[...] = rstd


def _ln_bwd_kernel(dy_ref, xhat_ref, rstd_ref, g_ref, dx_ref):
    dy = dy_ref[...]
    xhat = xhat_ref[...]
    rstd = rstd_ref[...]
    wdy = dy * g_ref[...]
    m1 = jnp.mean(wdy, axis=-1, keepdims=True)
    m2 = jnp.mean(wdy * xhat, axis=-1, keepdims=True)
    dx_ref[...] = (wdy - m1 - xhat * m2) * rstd


# Rows per grid step.  PERF (EXPERIMENTS.md §Perf L1): one row per step
# lowers interpret-mode pallas to a `rows`-iteration XLA while loop —
# 512 iterations of tiny work per layernorm call dominated the e2e step
# time.  Tiling LN_BLOCK_ROWS rows per step keeps the VMEM tile small
# (128 x hidden x 4 B = 256 KB at hidden 512) while cutting the loop
# trip count 128x.
LN_BLOCK_ROWS = 128


def _ln_rows_block(rows: int) -> int:
    if rows % LN_BLOCK_ROWS == 0:
        return LN_BLOCK_ROWS
    return rows  # fall back to a single whole-input block


def _ln_fwd(x, gamma, beta):
    rows, hidden = x.shape
    br = _ln_rows_block(rows)
    grid = (rows // br,)
    row = pl.BlockSpec((br, hidden), lambda i: (i, 0))
    vec = pl.BlockSpec((hidden,), lambda i: (0,))
    scal = pl.BlockSpec((br, 1), lambda i: (i, 0))
    y, xhat, rstd = pl.pallas_call(
        functools.partial(_ln_fwd_kernel, eps=_LN_EPS),
        grid=grid,
        in_specs=[row, vec, vec],
        out_specs=[row, row, scal],
        out_shape=[
            jax.ShapeDtypeStruct((rows, hidden), x.dtype),
            jax.ShapeDtypeStruct((rows, hidden), x.dtype),
            jax.ShapeDtypeStruct((rows, 1), x.dtype),
        ],
        interpret=True,
    )(x, gamma, beta)
    return y, (xhat, rstd, gamma)


def _ln_bwd(res, dy):
    xhat, rstd, gamma = res
    rows, hidden = dy.shape
    br = _ln_rows_block(rows)
    grid = (rows // br,)
    row = pl.BlockSpec((br, hidden), lambda i: (i, 0))
    vec = pl.BlockSpec((hidden,), lambda i: (0,))
    scal = pl.BlockSpec((br, 1), lambda i: (i, 0))
    dx = pl.pallas_call(
        _ln_bwd_kernel,
        grid=grid,
        in_specs=[row, row, scal, vec],
        out_specs=row,
        out_shape=jax.ShapeDtypeStruct((rows, hidden), dy.dtype),
        interpret=True,
    )(dy, xhat, rstd, gamma)
    # Parameter grads are plain cross-row reductions; XLA fuses these.
    dgamma = jnp.sum(dy * xhat, axis=0)
    dbeta = jnp.sum(dy, axis=0)
    return dx, dgamma, dbeta


@jax.custom_vjp
def layernorm(x, gamma, beta):
    """Pallas LayerNorm over the last axis of x: f32[rows, hidden]."""
    return _ln_fwd(x, gamma, beta)[0]


layernorm.defvjp(_ln_fwd, _ln_bwd)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

def _softmax_qk(q, k, *, scale, causal):
    """[seq, seq] probabilities for one head; MXU matmul + masked softmax."""
    logits = jnp.dot(q, k.T) * scale
    if causal:
        s = logits.shape[0]
        rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        logits = jnp.where(rows >= cols, logits, _NEG_INF)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits)
    return p / jnp.sum(p, axis=-1, keepdims=True)


def _attn_fwd_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal):
    p = _softmax_qk(q_ref[0], k_ref[0], scale=scale, causal=causal)
    o_ref[0] = jnp.dot(p, v_ref[0])


def _attn_bwd_kernel(q_ref, k_ref, v_ref, do_ref, dq_ref, dk_ref, dv_ref,
                     *, scale, causal):
    """Recompute-probabilities backward for one head (flash-style)."""
    q, k, v, do = q_ref[0], k_ref[0], v_ref[0], do_ref[0]
    p = _softmax_qk(q, k, scale=scale, causal=causal)
    dv_ref[0] = jnp.dot(p.T, do)
    dp = jnp.dot(do, v.T)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq_ref[0] = jnp.dot(ds, k) * scale
    dk_ref[0] = jnp.dot(ds.T, q) * scale


def _attn_call(kernel, n_out, q, k, v, *extra, causal):
    heads, seq, hd = q.shape
    scale = 1.0 / float(hd) ** 0.5
    spec = pl.BlockSpec((1, seq, hd), lambda h: (h, 0, 0))
    shape = jax.ShapeDtypeStruct((heads, seq, hd), q.dtype)
    out_specs = [spec] * n_out if n_out > 1 else spec
    out_shape = [shape] * n_out if n_out > 1 else shape
    return pl.pallas_call(
        functools.partial(kernel, scale=scale, causal=causal),
        grid=(heads,),
        in_specs=[spec] * (3 + len(extra)),
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(q, k, v, *extra)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention_core(q, k, v, causal=True):
    """Pallas attention core: softmax(scale * Q K^T + mask) V.

    q, k, v: f32[heads, seq, head_dim] (batch folded into heads).
    """
    return _attn_call(_attn_fwd_kernel, 1, q, k, v, causal=causal)


def _attn_fwd(q, k, v, causal):
    return attention_core(q, k, v, causal), (q, k, v)


def _attn_bwd(causal, res, do):
    q, k, v = res
    dq, dk, dv = _attn_call(
        _attn_bwd_kernel, 3, q, k, v, do, causal=causal)
    return dq, dk, dv


attention_core.defvjp(_attn_fwd, _attn_bwd)
