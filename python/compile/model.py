"""L2: GPT-2-like transformer fwd/bwd in JAX, calling the L1 Pallas kernels.

This is the compute graph that PatrickStar trains.  The model mirrors the
paper's workload (Sec. 9.1: GPT-2-like stacks, varied by hidden dim and
layer count) at a scale the CPU PJRT backend can actually train end to end.

The module is build-time only: aot.py lowers `train_step` (fwd + bwd) and
the chunk ADAM kernel to HLO text; the rust L3 coordinator loads those
artifacts and never touches python again.

Parameter naming convention (must stay in sync with rust/src/train/):
parameters are emitted in model-definition order, exactly the order the
paper's chunk layout algorithm consumes them (Sec. 6.1 "in the order of
model initialization").  `param_order(cfg)` is the single source of truth
and is serialized into artifacts/manifest.json.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import layers as pk
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class GptConfig:
    """Model-related configuration (paper Table 2 analogue, scaled down)."""

    vocab: int = 4096
    seq: int = 128
    hidden: int = 256
    layers: int = 4
    heads: int = 4
    batch: int = 4
    use_pallas: bool = True  # False -> pure-jnp reference path (oracle)

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    def n_params(self) -> int:
        return sum(int(math.prod(s)) for _, s in param_order(self))


def param_order(cfg: GptConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every parameter, in model-definition order."""
    h, v, s = cfg.hidden, cfg.vocab, cfg.seq
    out: List[Tuple[str, Tuple[int, ...]]] = [
        ("wte", (v, h)),
        ("wpe", (s, h)),
    ]
    for i in range(cfg.layers):
        p = f"h{i}."
        out += [
            (p + "ln1.g", (h,)),
            (p + "ln1.b", (h,)),
            (p + "attn.wqkv", (h, 3 * h)),
            (p + "attn.bqkv", (3 * h,)),
            (p + "attn.wo", (h, h)),
            (p + "attn.bo", (h,)),
            (p + "ln2.g", (h,)),
            (p + "ln2.b", (h,)),
            (p + "mlp.wi", (h, 4 * h)),
            (p + "mlp.bi", (4 * h,)),
            (p + "mlp.wo", (4 * h, h)),
            (p + "mlp.bo", (h,)),
        ]
    out += [("lnf.g", (h,)), ("lnf.b", (h,))]
    # lm head is tied to wte (GPT-2 convention) -> no extra parameter.
    return out


def init_params(cfg: GptConfig, key) -> Dict[str, jax.Array]:
    """GPT-2 style init: N(0, 0.02), residual projections scaled by depth."""
    params: Dict[str, jax.Array] = {}
    for i, (name, shape) in enumerate(param_order(cfg)):
        key, sub = jax.random.split(key)
        if name.endswith((".b", ".bqkv", ".bi", ".bo")):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("attn.wo", "mlp.wo")):
                std = 0.02 / math.sqrt(2 * cfg.layers)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def _layernorm(cfg: GptConfig, x2d, g, b):
    if cfg.use_pallas:
        return pk.layernorm(x2d, g, b)
    return kref.layernorm_ref(x2d, g, b)


def _attention(cfg: GptConfig, q, k, v):
    if cfg.use_pallas:
        return pk.attention_core(q, k, v, causal=True)
    return kref.attention_core_ref(q, k, v, causal=True)


def _block(cfg: GptConfig, params: Dict[str, jax.Array], i: int, x):
    """One pre-LN transformer block.  x: [B, S, H]."""
    b, s, h = x.shape
    p = f"h{i}."
    y = _layernorm(cfg, x.reshape(b * s, h), params[p + "ln1.g"],
                   params[p + "ln1.b"]).reshape(b, s, h)
    qkv = y @ params[p + "attn.wqkv"] + params[p + "attn.bqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def heads(t):  # [B,S,H] -> [B*nh, S, hd]
        return (t.reshape(b, s, cfg.heads, cfg.head_dim)
                 .transpose(0, 2, 1, 3)
                 .reshape(b * cfg.heads, s, cfg.head_dim))

    att = _attention(cfg, heads(q), heads(k), heads(v))
    att = (att.reshape(b, cfg.heads, s, cfg.head_dim)
              .transpose(0, 2, 1, 3)
              .reshape(b, s, h))
    x = x + att @ params[p + "attn.wo"] + params[p + "attn.bo"]

    y = _layernorm(cfg, x.reshape(b * s, h), params[p + "ln2.g"],
                   params[p + "ln2.b"]).reshape(b, s, h)
    y = jax.nn.gelu(y @ params[p + "mlp.wi"] + params[p + "mlp.bi"])
    return x + y @ params[p + "mlp.wo"] + params[p + "mlp.bo"]


def forward(cfg: GptConfig, params: Dict[str, jax.Array], tokens):
    """Logits for tokens i32[B, S] -> f32[B, S, vocab]."""
    b, s = tokens.shape
    x = params["wte"][tokens] + params["wpe"][jnp.arange(s)]
    for i in range(cfg.layers):
        x = _block(cfg, params, i, x)
    x = _layernorm(cfg, x.reshape(b * s, cfg.hidden), params["lnf.g"],
                   params["lnf.b"]).reshape(b, s, cfg.hidden)
    return x @ params["wte"].T  # tied lm head


def loss_fn(cfg: GptConfig, params: Dict[str, jax.Array], tokens, targets):
    """Mean next-token cross-entropy.  tokens/targets: i32[B, S]."""
    logits = forward(cfg, params, tokens)
    n = cfg.batch * cfg.seq
    return kref.softmax_xent_ref(
        logits.reshape(n, cfg.vocab), targets.reshape(n)
    )


def train_step(cfg: GptConfig):
    """Returns f(params_dict, tokens, targets) -> (loss, grads_dict)."""

    def step(params, tokens, targets):
        return jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(
            params
        )

    return step


def train_step_flat(cfg: GptConfig):
    """Flat-signature step for AOT lowering.

    f(tokens i32[B,S], targets i32[B,S], *params in param_order)
      -> (loss f32[], *grads in param_order)

    The flat order is the contract with the rust runtime: rust feeds chunk
    slices as PJRT literals positionally and reads grads back positionally.
    """
    order = param_order(cfg)
    names = [n for n, _ in order]

    def step(tokens, targets, *flat):
        params = dict(zip(names, flat))
        loss, grads = train_step(cfg)(params, tokens, targets)
        return (loss, *[grads[n] for n in names])

    return step
