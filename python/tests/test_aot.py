"""pytest: AOT pipeline — HLO text validity and manifest contract."""

import json
import math
import os

import pytest

from compile import aot
from compile import model as M

TINY = M.GptConfig(vocab=64, seq=8, hidden=16, layers=1, heads=2, batch=1)


class TestPickChunkElems:
    def test_fits_largest_nonembedding_tensor(self):
        c = aot.pick_chunk_elems(TINY, 1)
        biggest = max(
            math.prod(s) for n, s in M.param_order(TINY)
            if not aot.is_embedding(n))
        assert c >= biggest

    def test_alignment(self):
        for target in (1, 100, 4097, 1 << 16):
            assert aot.pick_chunk_elems(TINY, target) % 64 == 0

    def test_monotone_in_target(self):
        assert (aot.pick_chunk_elems(TINY, 1 << 20)
                >= aot.pick_chunk_elems(TINY, 1))

    def test_embeddings_are_flagged(self):
        assert aot.is_embedding("wte") and aot.is_embedding("wpe")
        assert not aot.is_embedding("h0.attn.wqkv")


def entry_params(text: str) -> int:
    """Count parameter() instructions in the ENTRY computation only
    (nested while/grid computations also contain parameter() lines)."""
    entry = text[text.index("ENTRY"):]
    return entry.count("parameter(")


class TestLowering:
    def test_adam_step_hlo(self):
        text = aot.lower_adam_step(256, 128)
        assert "ENTRY" in text
        # 5 inputs: hp + 4 chunk buffers.
        assert entry_params(text) == 5

    def test_train_step_hlo_has_all_params(self):
        text = aot.lower_train_step(TINY)
        n_inputs = 2 + len(M.param_order(TINY))  # tokens, targets, params
        assert "ENTRY" in text
        assert entry_params(text) == n_inputs

    def test_eval_loss_hlo(self):
        text = aot.lower_train_step(TINY, with_grads=False)
        assert "ENTRY" in text
        assert entry_params(text) == 2 + len(M.param_order(TINY))


class TestEndToEndEmit(object):
    def test_main_writes_artifacts(self, tmp_path):
        out = str(tmp_path)
        aot.main([
            "--out", out, "--vocab", "64", "--seq", "8", "--hidden", "16",
            "--layers", "1", "--heads", "2", "--batch", "1",
            "--chunk-elems", "256",
        ])
        names = {"train_step.hlo.txt", "eval_loss.hlo.txt",
                 "adam_step.hlo.txt", "manifest.json"}
        assert names <= set(os.listdir(out))
        man = json.load(open(os.path.join(out, "manifest.json")))
        assert man["model"]["n_params"] == TINY.n_params()
        assert man["chunk_elems"] % 64 == 0
        numels = [p["numel"] for p in man["params"]]
        assert sum(numels) == TINY.n_params()
        # Parameter order in the manifest is the rust<->python contract.
        assert [p["name"] for p in man["params"]] == [
            n for n, _ in M.param_order(TINY)]
        # Embeddings flagged for CPU pinning.
        emb = {p["name"] for p in man["params"] if p["embedding"]}
        assert emb == {"wte", "wpe"}
