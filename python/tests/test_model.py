"""pytest: L2 model — shapes, numerics, Pallas-model vs reference-model.

The strongest signal here is `test_pallas_model_matches_ref_model`: the
full GPT forward+backward built on Pallas kernels must agree with the same
model built purely on jnp oracles, for both loss value and every gradient.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

SMALL = M.GptConfig(vocab=128, seq=16, hidden=32, layers=2, heads=2, batch=2)
SMALL_REF = M.GptConfig(vocab=128, seq=16, hidden=32, layers=2, heads=2,
                        batch=2, use_pallas=False)


def batch_for(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (cfg.batch, cfg.seq), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    return toks, tgts


class TestParamOrder:
    def test_deterministic(self):
        assert M.param_order(SMALL) == M.param_order(SMALL)

    def test_counts(self):
        # 2 embeddings + 12 per layer + 2 final-LN; lm head is tied.
        assert len(M.param_order(SMALL)) == 2 + 12 * SMALL.layers + 2

    def test_n_params_formula(self):
        """n_params matches the analytic GPT-2 formula."""
        cfg = SMALL
        h, v, s, L = cfg.hidden, cfg.vocab, cfg.seq, cfg.layers
        per_layer = (2 * h            # ln1
                     + 3 * h * h + 3 * h  # qkv
                     + h * h + h      # proj
                     + 2 * h          # ln2
                     + 4 * h * h + 4 * h  # mlp in
                     + 4 * h * h + h)     # mlp out
        want = v * h + s * h + L * per_layer + 2 * h
        assert cfg.n_params() == want

    def test_all_names_unique(self):
        names = [n for n, _ in M.param_order(SMALL)]
        assert len(names) == len(set(names))


class TestForward:
    def test_logit_shape(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        toks, _ = batch_for(SMALL)
        logits = M.forward(SMALL, params, toks)
        assert logits.shape == (SMALL.batch, SMALL.seq, SMALL.vocab)

    def test_loss_finite_and_near_uniform_at_init(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(0))
        toks, tgts = batch_for(SMALL)
        loss = M.loss_fn(SMALL, params, toks, tgts)
        assert np.isfinite(float(loss))
        # Init logits are near zero -> loss ~ log(vocab).
        assert abs(float(loss) - np.log(SMALL.vocab)) < 0.5

    def test_causality_of_full_model(self):
        """Changing future tokens must not change past logits."""
        params = M.init_params(SMALL, jax.random.PRNGKey(1))
        toks, _ = batch_for(SMALL)
        cut = SMALL.seq // 2
        toks2 = toks.at[:, cut:].set((toks[:, cut:] + 1) % SMALL.vocab)
        l1 = M.forward(SMALL, params, toks)
        l2 = M.forward(SMALL, params, toks2)
        np.testing.assert_allclose(l1[:, :cut], l2[:, :cut],
                                   rtol=1e-4, atol=1e-5)


class TestPallasVsRefModel:
    def test_pallas_model_matches_ref_model(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(2))
        toks, tgts = batch_for(SMALL, 3)
        loss_p, grads_p = M.train_step(SMALL)(params, toks, tgts)
        loss_r, grads_r = M.train_step(SMALL_REF)(params, toks, tgts)
        np.testing.assert_allclose(loss_p, loss_r, rtol=1e-5)
        for name in grads_p:
            np.testing.assert_allclose(
                grads_p[name], grads_r[name], rtol=5e-3, atol=1e-5,
                err_msg=f"grad mismatch for {name}")

    def test_flat_step_matches_dict_step(self):
        params = M.init_params(SMALL, jax.random.PRNGKey(4))
        toks, tgts = batch_for(SMALL, 5)
        names = [n for n, _ in M.param_order(SMALL)]
        flat = [params[n] for n in names]
        out = M.train_step_flat(SMALL)(toks, tgts, *flat)
        loss_d, grads_d = M.train_step(SMALL)(params, toks, tgts)
        np.testing.assert_allclose(out[0], loss_d, rtol=1e-6)
        for i, name in enumerate(names):
            np.testing.assert_allclose(out[1 + i], grads_d[name],
                                       rtol=1e-5, atol=1e-7)


class TestTrainingSanity:
    def test_loss_decreases_with_sgd(self):
        """A few plain-SGD steps on a fixed batch reduce the loss."""
        cfg = SMALL
        params = M.init_params(cfg, jax.random.PRNGKey(6))
        toks, tgts = batch_for(cfg, 7)
        step = jax.jit(M.train_step(cfg))
        first = None
        for _ in range(8):
            loss, grads = step(params, toks, tgts)
            if first is None:
                first = float(loss)
            params = {k: params[k] - 0.05 * grads[k] for k in params}
        assert float(loss) < first - 0.1
