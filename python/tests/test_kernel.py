"""pytest: Pallas kernels vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes/dtypes/hyper-parameters; every property asserts
allclose(kernel, ref) with tolerances appropriate for f32 accumulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from compile.kernels import adam as K
from compile.kernels import layers as pk
from compile.kernels import ref

SET = settings(
    max_examples=25,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def arr(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape,
                                     jnp.float32)


# ---------------------------------------------------------------------------
# chunk ADAM
# ---------------------------------------------------------------------------

class TestChunkAdam:
    @SET
    @given(
        n=st.sampled_from([64, 192, 1024, 4096, 16384]),
        block=st.sampled_from([64, 256, 1024, 16384]),
        lr=st.floats(1e-5, 1e-1),
        wd=st.sampled_from([0.0, 0.01, 0.1]),
        step=st.integers(1, 1000),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, block, lr, wd, step, seed):
        keys = [seed * 4 + i for i in range(4)]
        p, g = arr(keys[0], (n,)), arr(keys[3], (n,))
        m, v = arr(keys[1], (n,), 0.1), jnp.abs(arr(keys[2], (n,), 0.1))
        hp = K.make_hp(lr, weight_decay=wd, step=step)
        pn, mn, vn = K.chunk_adam(hp, p, m, v, g, block=block)
        pr, mr, vr = ref.adam_ref(p, m, v, g, lr=lr, beta1=0.9, beta2=0.999,
                                  eps=1e-8, weight_decay=wd, step=step)
        np.testing.assert_allclose(mn, mr, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(vn, vr, rtol=1e-5, atol=1e-6)
        # p tolerance is looser: the kernel computes beta**step in f32
        # (the ref uses python f64), and sqrt(v_hat) near zero amplifies
        # that rounding.
        np.testing.assert_allclose(pn, pr, rtol=2e-3, atol=1e-5)

    def test_zero_grad_moves_little(self):
        """With g=0, wd=0: m,v decay; p only moves by the decayed-moment
        term, which is 0 when m=v=0."""
        n = 256
        p = arr(0, (n,))
        z = jnp.zeros((n,))
        hp = K.make_hp(1e-3, step=1)
        pn, mn, vn = K.chunk_adam(hp, p, z, z, z)
        np.testing.assert_allclose(pn, p, atol=1e-7)
        np.testing.assert_allclose(mn, z)
        np.testing.assert_allclose(vn, z)

    def test_variance_nonnegative(self):
        n = 512
        p, m, g = arr(1, (n,)), arr(2, (n,)), arr(3, (n,), 5.0)
        v = jnp.abs(arr(4, (n,)))
        hp = K.make_hp(1e-2, step=7)
        _, _, vn = K.chunk_adam(hp, p, m, v, g)
        assert bool(jnp.all(vn >= 0))

    def test_non_multiple_block_falls_back_to_whole_chunk(self):
        n = 100  # not a multiple of any default block
        p, m, v, g = (arr(i, (n,)) for i in range(4))
        v = jnp.abs(v)
        hp = K.make_hp(1e-3, step=2)
        pn, _, _ = K.chunk_adam(hp, p, m, v, g, block=64)
        pr, _, _ = ref.adam_ref(p, m, v, g, lr=1e-3, beta1=0.9, beta2=0.999,
                                eps=1e-8, weight_decay=0.0, step=2)
        np.testing.assert_allclose(pn, pr, rtol=1e-4, atol=1e-6)

    def test_descends_on_quadratic(self):
        """End-to-end sanity: ADAM on f(p)=||p||^2/2 decreases the loss."""
        n = 128
        p = arr(9, (n,), 2.0)
        m = jnp.zeros((n,))
        v = jnp.zeros((n,))
        losses = []
        for step in range(1, 30):
            g = p  # grad of ||p||^2 / 2
            hp = K.make_hp(5e-2, step=step)
            p, m, v = K.chunk_adam(hp, p, m, v, g)
            losses.append(float(jnp.sum(p * p)) / 2)
        assert losses[-1] < losses[0] * 0.5


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

class TestLayerNorm:
    @SET
    @given(
        rows=st.integers(1, 64),
        hidden=st.sampled_from([8, 32, 64, 256]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, rows, hidden, seed):
        x = arr(seed, (rows, hidden), 3.0)
        g = arr(seed + 1, (hidden,))
        b = arr(seed + 2, (hidden,))
        np.testing.assert_allclose(
            pk.layernorm(x, g, b), ref.layernorm_ref(x, g, b),
            rtol=1e-4, atol=1e-5)

    def test_normalizes(self):
        x = arr(3, (16, 128), 10.0)
        y = pk.layernorm(x, jnp.ones(128), jnp.zeros(128))
        np.testing.assert_allclose(jnp.mean(y, axis=-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(jnp.std(y, axis=-1), 1.0, atol=1e-3)

    @SET
    @given(rows=st.integers(2, 16), hidden=st.sampled_from([16, 64]),
           seed=st.integers(0, 2**31 - 1))
    def test_vjp_matches_ref(self, rows, hidden, seed):
        x = arr(seed, (rows, hidden))
        g = arr(seed + 1, (hidden,))
        b = arr(seed + 2, (hidden,))
        f = lambda *a: jnp.sum(jnp.sin(pk.layernorm(*a)))
        fr = lambda *a: jnp.sum(jnp.sin(ref.layernorm_ref(*a)))
        for got, want in zip(jax.grad(f, (0, 1, 2))(x, g, b),
                             jax.grad(fr, (0, 1, 2))(x, g, b)):
            np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Attention core
# ---------------------------------------------------------------------------

class TestAttention:
    @SET
    @given(
        heads=st.integers(1, 8),
        seq=st.sampled_from([4, 16, 33, 64]),
        hd=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, heads, seq, hd, causal, seed):
        q = arr(seed, (heads, seq, hd))
        k = arr(seed + 1, (heads, seq, hd))
        v = arr(seed + 2, (heads, seq, hd))
        np.testing.assert_allclose(
            pk.attention_core(q, k, v, causal),
            ref.attention_core_ref(q, k, v, causal=causal),
            rtol=1e-4, atol=1e-5)

    def test_causality(self):
        """Output at position t must not depend on inputs at positions > t."""
        q = arr(0, (2, 16, 8))
        k = arr(1, (2, 16, 8))
        v = arr(2, (2, 16, 8))
        out = pk.attention_core(q, k, v, True)
        k2 = k.at[:, 8:, :].set(99.0)
        v2 = v.at[:, 8:, :].set(-99.0)
        out2 = pk.attention_core(q, k2, v2, True)
        np.testing.assert_allclose(out[:, :8], out2[:, :8], rtol=1e-5)

    def test_rows_are_convex_combinations(self):
        """Non-causal attention output lies in the convex hull of V rows."""
        q = arr(5, (1, 8, 4), 0.5)
        k = arr(6, (1, 8, 4), 0.5)
        v = arr(7, (1, 8, 4))
        out = pk.attention_core(q, k, v, False)
        assert bool(jnp.all(out <= jnp.max(v, axis=1, keepdims=True) + 1e-5))
        assert bool(jnp.all(out >= jnp.min(v, axis=1, keepdims=True) - 1e-5))

    @SET
    @given(seq=st.sampled_from([8, 32]), seed=st.integers(0, 2**31 - 1))
    def test_vjp_matches_ref(self, seq, seed):
        q = arr(seed, (2, seq, 8))
        k = arr(seed + 1, (2, seq, 8))
        v = arr(seed + 2, (2, seq, 8))
        f = lambda *a: jnp.sum(jnp.cos(pk.attention_core(*a, True)))
        fr = lambda *a: jnp.sum(jnp.cos(
            ref.attention_core_ref(*a, causal=True)))
        for got, want in zip(jax.grad(f, (0, 1, 2))(q, k, v),
                             jax.grad(fr, (0, 1, 2))(q, k, v)):
            np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
