//! End-to-end integration over the REAL PJRT runtime: chunk-managed
//! training steps through the JAX/Pallas artifacts.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a notice) when artifacts/ is absent so plain
//! `cargo test` works in a fresh checkout.  The whole file is gated on
//! the `pjrt` feature (the xla bindings are not in the offline cache).
#![cfg(feature = "pjrt")]

use patrickstar::chunk::ChunkKind;
use patrickstar::train::{Trainer, TrainerConfig};

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn mk_trainer(gpu_mb: u64) -> Trainer {
    Trainer::new(TrainerConfig {
        artifacts_dir: "artifacts".into(),
        gpu_bytes: gpu_mb << 20,
        cpu_bytes: 4 << 30,
        lr: 1e-3,
        weight_decay: 0.01,
        seed: 7,
        ..Default::default()
    })
    .expect("trainer init")
}

#[test]
fn e2e_two_steps_reduce_loss_on_fixed_batch() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut t = mk_trainer(12);
    let mut corpus = t.corpus(1);
    let (toks, tgts) = corpus.next_batch();
    // Repeating the same batch must drive its loss down monotonically
    // after the first couple of ADAM steps.
    let l0 = t.step(&toks, &tgts).unwrap();
    let mut prev = l0;
    for _ in 0..3 {
        prev = t.step(&toks, &tgts).unwrap();
    }
    assert!(prev < l0, "fixed-batch loss {l0} -> {prev} did not drop");
    assert!(l0.is_finite() && prev.is_finite());
}

#[test]
fn e2e_eviction_under_tiny_gpu_pool_still_correct() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // A GPU pool that fits only ~3 chunks forces eviction on every
    // access; numerics must be identical to a roomy pool.
    let mut tight = mk_trainer(7);
    let mut roomy = mk_trainer(512);
    let (toks, tgts) = tight.corpus(2).next_batch();
    let l_tight = tight.step(&toks, &tgts).unwrap();
    let l_roomy = roomy.step(&toks, &tgts).unwrap();
    assert!(
        (l_tight - l_roomy).abs() < 1e-5,
        "eviction changed numerics: {l_tight} vs {l_roomy}"
    );
    assert!(
        tight.mgr().stats.evictions > 0,
        "tight pool must actually evict"
    );
    assert!(tight.mgr().stats.gpu_to_cpu_bytes
            > roomy.mgr().stats.gpu_to_cpu_bytes);
}

#[test]
fn e2e_eval_matches_before_after_update() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut t = mk_trainer(16);
    let (toks, tgts) = t.corpus(3).next_batch();
    let before = t.eval(&toks, &tgts).unwrap();
    let step_loss = t.step(&toks, &tgts).unwrap();
    let after = t.eval(&toks, &tgts).unwrap();
    // eval before the update equals the training loss on that batch
    // (same params, same inputs, eval_loss vs train_step fwd).
    assert!(
        (before - step_loss).abs() < 1e-4,
        "eval {before} != step loss {step_loss}"
    );
    // and the update moved the parameters.
    assert!(after != before, "params did not change");
    assert!(after < before, "one ADAM step should reduce this loss");
}

#[test]
fn e2e_grad_reuses_param_chunk_space() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // Paper Fig. 6: there is no grad fp16 chunk list — after a step the
    // fp16 chunk payload holds the *updated parameters* (grads were
    // written over them, then ADAM wrote params back).  Verify the fp16
    // payload equals the fp32 master copy.
    let mut t = mk_trainer(64);
    let (toks, tgts) = t.corpus(4).next_batch();
    t.step(&toks, &tgts).unwrap();
    let fp16_list = t.mgr().reg.list(ChunkKind::ParamFp16);
    let mut checked = 0;
    for p16 in fp16_list {
        let p32 = t.mgr().reg.os_chunks_for(p16)[0];
        let a = t.mgr().payload(p16).unwrap();
        let b = t.mgr().payload(p32).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-6, "fp16/fp32 divergence");
        }
        checked += 1;
    }
    assert!(checked > 4, "expected several chunks, got {checked}");
}

#[test]
fn e2e_four_chunk_lists_only_14_bytes_per_param() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let t = mk_trainer(16);
    let reg = &t.mgr().reg;
    // Accounting invariant (Sec. 6.1): 14 bytes per chunked parameter.
    let stats = reg.stats();
    let managed: u64 = stats.capacity_elems;
    assert_eq!(reg.model_data_bytes(), managed / 4 * 14);
}
