//! Property tests for the collective-stream overlap (ISSUE 2
//! satellite): for random model/cluster configs, turning the collective
//! stream on never changes all-gather/reduce-scatter byte volume — the
//! pipeline moves collectives on the clock, never on the wire — and the
//! numeric `RealCollectives` results are identical with overlap on/off.

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, EngineReport, OptimizationPlan};
use patrickstar::dp::RealCollectives;
use patrickstar::model::GptSpec;
use patrickstar::util::quickcheck::forall;
use patrickstar::util::Rng;

fn run(task: TrainTask, opt: OptimizationPlan) -> Result<EngineReport, String> {
    Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run()
        .map_err(|e| format!("engine: {e}"))
}

#[test]
fn property_collective_overlap_preserves_wire_volume() {
    forall(
        5,
        |rng| {
            let model = ["1B", "2B", "4B"][rng.range(0, 3)];
            let batch = [4u64, 8, 16][rng.range(0, 3)];
            let gpus = [2u32, 4, 8][rng.range(0, 3)];
            let lookahead = [1u32, 2, 4][rng.range(0, 3)];
            (model, batch, gpus, lookahead)
        },
        |&(model, batch, gpus, lookahead)| {
            let task =
                TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
            let serial = run(task, OptimizationPlan::default())?;
            let over = run(
                task,
                OptimizationPlan {
                    group_lookahead: lookahead,
                    ..OptimizationPlan::collectives_pipelined()
                },
            )?;
            if over.allgather_bytes != serial.allgather_bytes {
                return Err(format!(
                    "{model}/{gpus}g/b{batch}/la{lookahead}: allgather \
                     volume changed: {} != {}",
                    over.allgather_bytes, serial.allgather_bytes
                ));
            }
            if over.reduce_scatter_bytes != serial.reduce_scatter_bytes {
                return Err(format!(
                    "{model}/{gpus}g/b{batch}/la{lookahead}: \
                     reduce-scatter volume changed: {} != {}",
                    over.reduce_scatter_bytes, serial.reduce_scatter_bytes
                ));
            }
            // The stream may only hide collective time, never add wall
            // time: issue order is schedule order (FIFO), so a demand
            // gather never queues behind a less-urgent one.
            if over.iter_time_s > serial.iter_time_s * (1.0 + 1e-9) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch}/la{lookahead}: overlap \
                     slower: {} > {}",
                    over.iter_time_s, serial.iter_time_s
                ));
            }
            // Work accounting (phase clocks) nets out identically when
            // nothing was cancelled: same gathers, same wire time.
            if over.gather_cancels == 0 {
                let d = (over.breakdown.get(patrickstar::sim::Phase::AllGather)
                    - serial.breakdown.get(patrickstar::sim::Phase::AllGather))
                    .abs();
                if d > 1e-9 {
                    return Err(format!(
                        "{model}/{gpus}g/b{batch}/la{lookahead}: \
                         allgather phase work drifted by {d}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn property_real_collectives_identical_with_overlap_on_off() {
    // `RealCollectives` has no overlap code path by design — the way
    // overlap *could* perturb real collective numerics is by changing
    // the chunk layout (chunk size, volumes) that shapes the rank
    // buffers.  So: run the engine in both modes, derive the buffer
    // shapes from each run's own report, and push seeded gradients
    // through the real reduce-scatter.  Any layout or volume drift
    // between the modes changes the shapes and fails the comparison.
    forall(
        3,
        |rng| {
            let model = ["1B", "2B"][rng.range(0, 2)];
            let gpus = [2u32, 4][rng.range(0, 2)];
            let seed = rng.range(0, 1 << 30) as u64;
            (model, gpus, seed)
        },
        |&(model, gpus, seed)| {
            let task =
                TrainTask::new(GptSpec::by_name(model).unwrap(), 8, gpus);
            let off = run(task, OptimizationPlan::default())?;
            let on = run(task, OptimizationPlan::collectives_pipelined())?;
            let p = gpus as usize;
            // Buffer length derived from each mode's engine output:
            // identical modes => identical shapes => identical numbers.
            let shape = |r: &EngineReport| {
                (r.chunk_elems % 97 + 3) as usize
                    + (r.allgather_bytes % 13) as usize
            };
            let gen_contribs = |len: usize| {
                let mut r = Rng::new(seed);
                let c: Vec<Vec<Vec<f32>>> = (0..p)
                    .map(|_| {
                        (0..p)
                            .map(|_| {
                                (0..len).map(|_| r.normal_f32(1.0)).collect()
                            })
                            .collect()
                    })
                    .collect();
                c
            };
            let contribs_off = gen_contribs(shape(&off));
            let contribs_on = gen_contribs(shape(&on));
            let rs_off = RealCollectives::reduce_scatter_avg(&contribs_off);
            let rs_on = RealCollectives::reduce_scatter_avg(&contribs_on);
            if rs_off != rs_on {
                return Err("reduce_scatter_avg diverged on/off".into());
            }
            let ag_off = RealCollectives::all_gather(&contribs_off[0]);
            let ag_on = RealCollectives::all_gather(&contribs_on[0]);
            if ag_off != ag_on {
                return Err("all_gather diverged on/off".into());
            }
            Ok(())
        },
    );
}

#[test]
fn collective_stream_actually_issues_lookahead_gathers() {
    // Deterministic sanity on one multi-GPU config: the pipeline really
    // runs (gathers issued ahead), hides collective time, and the
    // engine's own exposed/overlapped split is consistent.
    let task = TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 4);
    let serial =
        Engine::new(ClusterPreset::yard(), task).run().unwrap();
    let over = Engine::new(ClusterPreset::yard(), task)
        .with_opt(OptimizationPlan::collectives_pipelined())
        .run()
        .unwrap();
    assert!(over.gather_prefetches > 0, "no lookahead gathers issued");
    assert!(
        over.breakdown.overlapped_collective_s > 0.0,
        "nothing overlapped"
    );
    let serial_coll = serial.breakdown.critical_collective_s();
    assert!(
        over.breakdown.exposed_collective_s < serial_coll,
        "exposed collective time did not drop: {} !< {}",
        over.breakdown.exposed_collective_s,
        serial_coll
    );
    assert_eq!(over.allgather_bytes, serial.allgather_bytes);
    assert_eq!(over.reduce_scatter_bytes, serial.reduce_scatter_bytes);
}
