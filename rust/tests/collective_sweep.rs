//! Multi-rank sweep (ISSUE 2 satellite): nproc ∈ {1, 2, 4, 8}.
//!
//! * a single rank has zero collective cost, stream on or off;
//! * exposed collective time is monotonically non-increasing as the
//!   group lookahead grows;
//! * the engine's chunk-level gather/reduce-scatter accounting matches
//!   the closed-form schedule count exactly, and the paper's
//!   per-iteration volume formula (`patrickstar_iter_bytes`,
//!   6(p-1)/p·M) still holds at chunk granularity.

use patrickstar::chunk::ChunkRegistry;
use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::dp::{CollectiveCost, CommGroups};
use patrickstar::engine::{Engine, EngineReport, OptimizationPlan};
use patrickstar::model::GptSpec;
use patrickstar::sim::Phase;

fn run(gpus: u32, opt: OptimizationPlan) -> EngineReport {
    let task = TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, gpus);
    Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run()
        .unwrap()
}

#[test]
fn single_rank_has_zero_collective_cost() {
    for opt in [
        OptimizationPlan::default(),
        OptimizationPlan::collectives_pipelined(),
    ] {
        let r = run(1, opt);
        assert_eq!(r.allgather_bytes, 0);
        assert_eq!(r.reduce_scatter_bytes, 0);
        assert_eq!(r.breakdown.get(Phase::AllGather), 0.0);
        assert_eq!(r.breakdown.get(Phase::ReduceScatter), 0.0);
        assert_eq!(r.breakdown.exposed_collective_s, 0.0);
        assert_eq!(r.breakdown.overlapped_collective_s, 0.0);
        assert_eq!(r.gather_prefetches, 0);
    }
}

#[test]
fn exposed_collective_time_monotone_in_group_lookahead() {
    for gpus in [2u32, 4, 8] {
        let serial = run(gpus, OptimizationPlan::default());
        let serial_coll = serial.breakdown.critical_collective_s();
        let mut prev = f64::INFINITY;
        let mut deepest = f64::INFINITY;
        for la in [0u32, 1, 2, 4] {
            let r = run(
                gpus,
                OptimizationPlan {
                    group_lookahead: la,
                    ..OptimizationPlan::collectives_pipelined()
                },
            );
            let exposed = r.breakdown.exposed_collective_s;
            assert!(
                exposed <= serial_coll * (1.0 + 1e-9),
                "{gpus}g la={la}: exposed {exposed} above serial \
                 {serial_coll}"
            );
            assert!(
                exposed <= prev * (1.0 + 1e-9) + 1e-12,
                "{gpus}g: exposed collective time not monotone: \
                 la={la} gives {exposed} > previous {prev}"
            );
            prev = exposed;
            deepest = exposed;
            // Volume is lookahead-invariant.
            assert_eq!(r.allgather_bytes, serial.allgather_bytes,
                       "{gpus}g la={la}");
            assert_eq!(r.reduce_scatter_bytes, serial.reduce_scatter_bytes,
                       "{gpus}g la={la}");
        }
        // Depth must actually help on these collective-heavy configs,
        // not just not hurt.
        assert!(
            deepest < serial_coll,
            "{gpus}g: lookahead 4 hid nothing ({deepest} !< {serial_coll})"
        );
    }
}

#[test]
fn chunk_level_volume_matches_schedule_and_paper_formula() {
    for gpus in [2u32, 4, 8] {
        let r = run(gpus, OptimizationPlan::default());
        let nproc = gpus as usize;
        // The fp16 chunk-list length, rebuilt from the same layout the
        // engine used (`placement.total_fp16_chunks` is the rank-local
        // share, not the list).
        let spec = GptSpec::by_name("4B").unwrap();
        let reg =
            ChunkRegistry::build(&spec.tensor_specs(), r.chunk_elems)
                .unwrap();
        let list_len = reg.list_len;
        let groups = CommGroups::new(list_len, nproc);
        let chunk_bytes = 2 * r.chunk_elems; // fp16
        let cc = CollectiveCost::new(
            ClusterPreset::yard().net.nvlink,
            nproc,
        );
        // Schedule count: every group with a remote member is gathered
        // once in FWD and once in BWD; every group reduce-scatters its
        // grads once.
        let eligible = (0..groups.n_groups())
            .filter(|&g| groups.members(g).len() >= 2)
            .count() as u64;
        let expected_ag =
            2 * eligible * cc.allgather_op(chunk_bytes).bytes;
        let expected_rs = groups.n_groups() as u64
            * cc.reduce_scatter_op(chunk_bytes).bytes;
        assert_eq!(r.allgather_bytes, expected_ag, "{gpus}g allgather");
        assert_eq!(r.reduce_scatter_bytes, expected_rs,
                   "{gpus}g reduce-scatter");
        // Paper Sec. 7: total per-rank wire volume = 6(p-1)/p·M.  At
        // chunk granularity M is the chunked parameter count; ragged
        // tail groups and the FWD/BWD/RS 2:1 split leave a small gap.
        let m_chunked = list_len as u64 * r.chunk_elems;
        let formula = cc.patrickstar_iter_bytes(m_chunked);
        let total = (r.allgather_bytes + r.reduce_scatter_bytes) as f64;
        let rel = (total - formula).abs() / formula;
        assert!(
            rel < 0.15,
            "{gpus}g: volume {total} vs formula {formula} ({:.1}% off)",
            100.0 * rel
        );
    }
}
