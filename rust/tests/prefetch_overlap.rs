//! Integration + property tests for the warm-up-guided prefetch pipeline
//! (ISSUE 1): in-flight/pinned chunks are invisible to every eviction
//! policy, the pipeline reorders but never multiplies PCIe traffic, and
//! the overlap-off ablation keeps the serial flat-clock contract.

use patrickstar::chunk::{ChunkKind, ChunkManager, ChunkRegistry,
                         TensorSpec};
use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, EngineReport, OptimizationPlan};
use patrickstar::evict::{EvictionPolicy, FifoPolicy, LfuPolicy, LruPolicy,
                         OptPolicy};
use patrickstar::mem::{Device, HeterogeneousSpace};
use patrickstar::model::GptSpec;
use patrickstar::sim::Phase;
use patrickstar::tensor::TensorState;
use patrickstar::tracer::MemTracer;
use patrickstar::util::quickcheck::forall;
use patrickstar::util::Rng;

// ---------------------------------------------------------------------
// Property: pinned and in-flight chunks are never eviction victims
// ---------------------------------------------------------------------

/// A randomized manager state: chunks resident on both devices with
/// random tensor states, a random pinned subset, and a random prefetched
/// (in-flight) subset.
struct Case {
    mgr: ChunkManager,
    tracer: MemTracer,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let pinned: Vec<u32> = self
            .mgr
            .reg
            .chunks
            .iter()
            .filter(|c| c.pinned)
            .map(|c| c.id.0)
            .collect();
        write!(f, "Case {{ chunks: {}, pinned: {:?} }}",
               self.mgr.reg.chunks.len(), pinned)
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    let n_tensors = 2 * rng.range(3, 9); // 3..8 fp16 chunks
    let specs: Vec<TensorSpec> = (0..n_tensors)
        .map(|i| TensorSpec {
            name: format!("t{i}"),
            numel: 50,
            embedding: false,
        })
        .collect();
    let reg = ChunkRegistry::build(&specs, 100).unwrap();
    // Room for everything: residency is decided by the random walk
    // below, not by pressure.
    let mut mgr =
        ChunkManager::new(reg, HeterogeneousSpace::new(1 << 20, 1 << 20));
    let mut tracer = MemTracer::new(mgr.reg.chunks.len());
    let mut pol = FifoPolicy::default();
    let fp16 = mgr.reg.list(ChunkKind::ParamFp16);
    for (i, &c) in fp16.iter().enumerate() {
        let dev = if rng.range(0, 2) == 0 {
            Device::Gpu(0)
        } else {
            Device::Cpu
        };
        mgr.alloc_payload(c, dev).unwrap();
        tracer.record_chunk_use(c, rng.range(0, 50) as u32);
        // Random tensor states (legal transitions from FREE only).
        for ti in 0..2usize {
            let t = mgr.reg.tensor_index(ChunkKind::ParamFp16, 2 * i + ti);
            match rng.range(0, 3) {
                0 => {} // stays FREE
                1 => {
                    mgr.reg.tensors[t].set_state(TensorState::Hold).unwrap();
                }
                _ => {
                    mgr.reg.tensors[t]
                        .set_state(TensorState::Compute)
                        .unwrap();
                }
            }
        }
        if rng.range(0, 4) == 0 {
            mgr.pin(c);
        }
    }
    tracer.finish_warmup();
    // Prefetch a random subset of the CPU-resident movable chunks.
    for &c in &fp16 {
        if rng.range(0, 2) == 0 {
            mgr.prefetch_to(c, Device::Gpu(0), 1 << 20, &mut pol, 0,
                            &|_| true)
                .unwrap();
        }
    }
    mgr.drain_events();
    Case { mgr, tracer }
}

#[test]
fn property_no_policy_ever_picks_pinned_or_inflight() {
    forall(150, gen_case, |case| {
        let mgr = &case.mgr;
        for device in [Device::Gpu(0), Device::Cpu] {
            let cands = mgr.eviction_candidates(device);
            for &c in &cands {
                if mgr.chunk(c).pinned {
                    return Err(format!("pinned {c:?} in candidates"));
                }
                if mgr.is_inflight(c) {
                    return Err(format!("in-flight {c:?} in candidates"));
                }
                if mgr
                    .chunk(c)
                    .tensors
                    .iter()
                    .any(|t| {
                        mgr.reg.tensors[t.0 as usize].state
                            == TensorState::Compute
                    })
                {
                    return Err(format!("COMPUTE {c:?} in candidates"));
                }
            }
            // Every policy must pick from the candidate set (or refuse).
            let mut lru = LruPolicy::default();
            let mut fifo = FifoPolicy::default();
            let mut lfu = LfuPolicy::default();
            let mut opt = OptPolicy { tracer: &case.tracer };
            let policies: [&mut dyn EvictionPolicy; 4] =
                [&mut opt, &mut lru, &mut fifo, &mut lfu];
            for p in policies {
                if let Some(v) = p.pick(&cands, &mgr.reg.chunks, 25) {
                    if !cands.contains(&v) {
                        return Err(format!(
                            "{} picked {v:?} outside candidates",
                            p.name()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Property: the pipeline reorders transfers, it never multiplies them
// ---------------------------------------------------------------------

fn volume(r: &EngineReport) -> u64 {
    r.move_stats.cpu_to_gpu_bytes + r.move_stats.gpu_to_cpu_bytes
}

#[test]
fn property_prefetch_never_increases_transfer_volume() {
    forall(
        6,
        |rng| {
            let model = ["1B", "2B", "4B"][rng.range(0, 3)];
            let batch = [4u64, 8, 16][rng.range(0, 3)];
            let gpus = [1u32, 2, 4][rng.range(0, 3)];
            (model, batch, gpus)
        },
        |&(model, batch, gpus)| {
            let task =
                TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
            let run = |opt| {
                Engine::new(ClusterPreset::yard(), task)
                    .with_opt(opt)
                    .run()
                    .map_err(|e| format!("engine: {e}"))
            };
            let serial = run(OptimizationPlan::default())?;
            let piped = run(OptimizationPlan::pipelined())?;
            if volume(&piped) > volume(&serial) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch}: pipeline moved {} B > \
                     serial {} B",
                    volume(&piped),
                    volume(&serial)
                ));
            }
            if piped.iter_time_s > serial.iter_time_s * (1.0 + 1e-9) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch}: pipeline slower: {} > {}",
                    piped.iter_time_s, serial.iter_time_s
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Deterministic spill-heavy config: the acceptance-criteria shape
// ---------------------------------------------------------------------

#[test]
fn pipeline_wins_materially_on_spilled_model() {
    // 12B on one V100 streams spilled fp16 chunks every iteration; the
    // pipeline must cut iteration time without adding traffic.
    let task = TrainTask::new(GptSpec::by_name("12B").unwrap(), 8, 1);
    let serial = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    let piped = Engine::new(ClusterPreset::yard(), task)
        .with_opt(OptimizationPlan::pipelined())
        .run()
        .unwrap();
    assert!(volume(&piped) <= volume(&serial));
    assert!(piped.move_stats.prefetches > 0);
    assert!(
        piped.breakdown.overlapped_transfer_s
            > piped.breakdown.exposed_transfer_s,
        "most transfer time should be hidden: exposed {} overlapped {}",
        piped.breakdown.exposed_transfer_s,
        piped.breakdown.overlapped_transfer_s
    );
    assert!(
        piped.iter_time_s < serial.iter_time_s,
        "no win: {} vs {}",
        piped.iter_time_s,
        serial.iter_time_s
    );
}

// ---------------------------------------------------------------------
// The overlap-off ablation keeps the serial contract
// ---------------------------------------------------------------------

#[test]
fn serial_ablation_reproduces_flat_breakdown() {
    let task = TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 1);
    let r = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    let sum: f64 = Phase::ALL.iter().map(|&p| r.breakdown.get(p)).sum();
    assert!((sum - r.iter_time_s).abs() < 1e-9, "sum {sum} != total {}",
            r.iter_time_s);
    assert_eq!(r.breakdown.overlapped_transfer_s, 0.0);
    assert_eq!(r.move_stats.prefetches, 0);
    // Determinism: running the same serial config twice is bit-identical
    // (the pipeline ablation's baseline is reproducible).
    let r2 = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    assert_eq!(r.iter_time_s, r2.iter_time_s);
    assert_eq!(volume(&r), volume(&r2));
}
