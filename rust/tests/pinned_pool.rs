//! Pinned staging-pool integration tests (ISSUE 3): the pool changes
//! *when* copies run and which curve bills them — never how many bytes
//! cross PCIe or the wire — and a disabled pool reproduces the
//! single-curve pipeline bit-for-bit.

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, EngineReport, OptimizationPlan};
use patrickstar::model::GptSpec;
use patrickstar::util::quickcheck::forall;

fn pcie_volume(r: &EngineReport) -> u64 {
    r.move_stats.cpu_to_gpu_bytes + r.move_stats.gpu_to_cpu_bytes
}

fn coll_volume(r: &EngineReport) -> u64 {
    r.allgather_bytes + r.reduce_scatter_bytes
}

fn run(task: TrainTask, opt: OptimizationPlan) -> EngineReport {
    Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run()
        .unwrap()
}

fn trace(task: TrainTask, opt: OptimizationPlan) -> Vec<String> {
    let (_, t) = Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run_traced()
        .unwrap();
    t
}

// ---------------------------------------------------------------------
// Pool 0 (disabled) is the single-curve model, bit-for-bit
// ---------------------------------------------------------------------

/// An effectively unbounded pool grants every acquire, so every copy is
/// charged at the pinned rate and every issue decision matches the
/// disabled pool exactly: the per-moment timeline must be bit-identical.
/// This pins the ISSUE 3 acceptance criterion from the other side —
/// the new routing machinery at "no contention" IS the old single-curve
/// code path.
#[test]
fn unbounded_pool_is_bit_identical_to_disabled() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
    for base in [
        OptimizationPlan::pipelined(),
        OptimizationPlan::fully_pipelined(),
    ] {
        let off = trace(task, OptimizationPlan { pinned_buffers: 0, ..base });
        let unbounded = trace(
            task,
            OptimizationPlan { pinned_buffers: 1 << 20, ..base },
        );
        assert_eq!(
            off, unbounded,
            "unbounded pool drifted from the single-curve timeline"
        );
    }
}

/// With the pool disabled nothing may be billed on the pageable curve
/// and nothing may be throttled.
#[test]
fn disabled_pool_never_bills_pageable() {
    let task = TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 1);
    for opt in [
        OptimizationPlan::default(),
        OptimizationPlan::overlap_only(),
        OptimizationPlan::pipelined(),
    ] {
        let r = run(task, opt);
        assert_eq!(r.breakdown.pageable_copy_s, 0.0);
        assert_eq!(r.move_stats.pinned_waits, 0);
    }
}

/// In serial mode async copies complete the instant they are charged,
/// so their buffer leases expire immediately: a finite pool can never
/// fill up and the serial timeline is bit-identical at every pool size.
#[test]
fn serial_timeline_is_pool_size_invariant() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
    let base = trace(task, OptimizationPlan::default());
    for pool in [1u32, 4] {
        let with_pool = trace(
            task,
            OptimizationPlan {
                pinned_buffers: pool,
                ..OptimizationPlan::default()
            },
        );
        assert_eq!(base, with_pool, "serial trace drifted at pool={pool}");
    }
}

// ---------------------------------------------------------------------
// Property: the pool changes timing, never transfer volume
// ---------------------------------------------------------------------

/// Mirrors the PR 1/PR 2 volume-invariance suites: a pool of any size
/// re-prices and re-times copies but never *adds* PCIe traffic over the
/// serial schedule (throttled prefetches simply become the demand
/// fetches serial would have issued), and the collective wire volume is
/// bit-for-bit the serial schedule's (cancelled lookahead gathers are
/// credited back; every group is still gathered exactly once per
/// trigger).
#[test]
fn property_pool_never_changes_transfer_volume() {
    forall(
        4,
        |rng| {
            let model = ["1B", "2B", "4B"][rng.range(0, 3)];
            let batch = [4u64, 8][rng.range(0, 2)];
            let gpus = [1u32, 2][rng.range(0, 2)];
            let pool = [1u32, 2, 4, 8][rng.range(0, 4)];
            (model, batch, gpus, pool)
        },
        |&(model, batch, gpus, pool)| {
            let task =
                TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
            let serial = run(task, OptimizationPlan::default());
            let pooled = run(
                task,
                OptimizationPlan {
                    pinned_buffers: pool,
                    ..OptimizationPlan::fully_pipelined()
                },
            );
            if pcie_volume(&pooled) > pcie_volume(&serial) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch} pool={pool}: pool added \
                     PCIe traffic: {} > serial {}",
                    pcie_volume(&pooled),
                    pcie_volume(&serial)
                ));
            }
            if coll_volume(&pooled) != coll_volume(&serial) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch} pool={pool}: collective \
                     volume changed: {} != serial {}",
                    coll_volume(&pooled),
                    coll_volume(&serial)
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Contention on a spill-heavy config: throttling is real and monotone
// ---------------------------------------------------------------------

// ---------------------------------------------------------------------
// Per-direction sub-pools (ISSUE 4 satellite)
// ---------------------------------------------------------------------

/// `--pinned-buffers N` keeps meaning *total*: an explicit `N:N` split
/// (each direction may use the whole pool) is the identity spelling of
/// the unsplit default, bit-for-bit, on every pipeline shape.
#[test]
fn full_split_is_bit_identical_to_unsplit_pool() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
    for pool in [1u32, 4] {
        let base = OptimizationPlan {
            pinned_buffers: pool,
            ..OptimizationPlan::fully_pipelined()
        };
        let unsplit = trace(task, base);
        let split = trace(
            task,
            OptimizationPlan { pinned_split: Some((pool, pool)), ..base },
        );
        assert_eq!(
            unsplit, split,
            "N:N split drifted from the shared pool at size {pool}"
        );
    }
}

/// A directional split re-prices and re-times copies like any pool
/// configuration — it never adds PCIe or collective traffic.
#[test]
fn split_pool_never_changes_transfer_volume() {
    let task = TrainTask::new(GptSpec::by_name("12B").unwrap(), 8, 1);
    let serial = run(task, OptimizationPlan::default());
    for split in [(3u32, 1u32), (1, 3), (2, 2)] {
        let r = run(
            task,
            OptimizationPlan {
                pinned_buffers: 4,
                pinned_split: Some(split),
                ..OptimizationPlan::fully_pipelined()
            },
        );
        assert!(
            pcie_volume(&r) <= pcie_volume(&serial),
            "split {split:?} added PCIe traffic"
        );
        assert_eq!(coll_volume(&r), coll_volume(&serial));
    }
}

#[test]
fn tiny_pool_throttles_and_degrades_on_spilled_model() {
    // 12B on one V100 streams spilled fp16 chunks every iteration — the
    // config the PR 1 pipeline wins materially on.  A 1-buffer pool must
    // visibly throttle that pipeline (waits observed) and cannot beat
    // the uncontended (unbounded == disabled) pool.
    let task = TrainTask::new(GptSpec::by_name("12B").unwrap(), 8, 1);
    let free = run(task, OptimizationPlan::pipelined());
    let tight = run(
        task,
        OptimizationPlan {
            pinned_buffers: 1,
            ..OptimizationPlan::pipelined()
        },
    );
    assert!(
        tight.move_stats.pinned_waits > 0,
        "a 1-buffer pool on a spill config must throttle the window"
    );
    assert!(
        tight.iter_time_s >= free.iter_time_s * (1.0 - 1e-9),
        "contended pool beat the uncontended pipeline: {} < {}",
        tight.iter_time_s,
        free.iter_time_s
    );
    // The pool throttles and re-prices copies; it never adds traffic
    // over the serial schedule.
    let serial = run(task, OptimizationPlan::default());
    assert!(pcie_volume(&tight) <= pcie_volume(&serial));
    assert!(pcie_volume(&free) <= pcie_volume(&serial));
}
