//! The lint gate (ISSUE 8): plain `cargo test` fails if `src/` picks
//! up a determinism or layering violation, so the contract holds even
//! where CI's dedicated `lint` job is not wired up.

use std::path::Path;

use patrickstar::lint::lint_tree;

#[test]
fn src_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("walk src/");
    // Sanity: the walk really covered the crate, not an empty dir.
    assert!(
        report.files > 30,
        "only {} files scanned under {} — wrong root?",
        report.files,
        root.display(),
    );
    if !report.findings.is_empty() {
        let mut msg = format!(
            "{} lint finding(s) — fix or add a reviewed \
             `// lint:allow(<rule>): <reason>`:\n",
            report.findings.len()
        );
        for f in &report.findings {
            msg.push_str(&format!("  {f}\n"));
        }
        panic!("{msg}");
    }
}
