//! Golden-trace regression tests (ISSUE 2 satellite): the full
//! per-moment stream timeline of one small config is serialized into
//! `tests/golden/` and compared bit-for-bit, so future stream, eviction
//! or collective changes cannot silently drift the simulated clock.
//!
//! Every line is a moment index plus the hex-encoded f64 bits of every
//! stream frontier, exposure accumulator and per-phase clock — any
//! 1-ulp change anywhere in the schedule shows up as a textual diff
//! (run the suite with `--nocapture` to see it).
//!
//! Bootstrap: on a machine where the golden file does not exist yet,
//! the test writes it and instead asserts run-to-run bit-for-bit
//! determinism, so the first run is still a real check.  Regenerate
//! deliberately with `GOLDEN_UPDATE=1 cargo test golden`.
//!
//! CI runs with `GOLDEN_STRICT=1` (ISSUE 3 satellite): there a missing
//! golden file is a hard failure, not a bootstrap — a fresh CI checkout
//! silently regenerating the reference would regression-check nothing.
//! The `.txt` files under `tests/golden/` must be generated once on a
//! toolchain machine and committed.

use std::fs;
use std::path::PathBuf;

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, OptimizationPlan};
use patrickstar::model::GptSpec;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The reference config: small enough to run in seconds, 2 GPUs so the
/// distributed gather/reduce-scatter path is in the trace.
fn task() -> TrainTask {
    TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2)
}

fn trace_for(opt: OptimizationPlan) -> Vec<String> {
    let (_, trace) = Engine::new(ClusterPreset::yard(), task())
        .with_opt(opt)
        .run_traced()
        .expect("engine run");
    assert!(!trace.is_empty(), "trace must not be empty");
    trace
}

/// First differing line, printed in full so `--nocapture` CI logs show
/// exactly where the clock drifted.
fn diff_report(want: &[String], got: &[String]) -> String {
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        if w != g {
            return format!(
                "first divergence at line {}:\n  golden: {}\n  got:    {}",
                i + 1,
                w,
                g
            );
        }
    }
    format!(
        "line count changed: golden {} lines, got {}",
        want.len(),
        got.len()
    )
}

fn check_golden(name: &str, opt: OptimizationPlan) {
    let got = trace_for(opt);
    // Bit-for-bit determinism is a precondition for a golden trace to
    // mean anything — assert it on every run, not just bootstrap.
    let again = trace_for(opt);
    assert!(
        got == again,
        "non-deterministic trace for {name}:\n{}",
        diff_report(&got, &again)
    );
    let path = golden_dir().join(format!("{name}.txt"));
    let update = std::env::var("GOLDEN_UPDATE").is_ok();
    // CI must regression-check, never self-seed: with GOLDEN_STRICT set
    // a missing golden file fails loudly instead of bootstrapping.
    let strict = std::env::var("GOLDEN_STRICT")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    if strict && !update {
        assert!(
            path.exists(),
            "golden trace {} missing under GOLDEN_STRICT — generate it \
             on a toolchain machine (GOLDEN_UPDATE=1 cargo test golden) \
             and commit it",
            path.display()
        );
    }
    if update || !path.exists() {
        fs::create_dir_all(golden_dir()).expect("mkdir tests/golden");
        fs::write(&path, got.join("\n") + "\n").expect("write golden");
        println!(
            "golden trace {} {} ({} lines)",
            path.display(),
            if update { "updated" } else { "bootstrapped" },
            got.len()
        );
        return;
    }
    let want: Vec<String> = fs::read_to_string(&path)
        .expect("read golden")
        .lines()
        .map(str::to_string)
        .collect();
    assert!(
        want == got,
        "stream timeline drifted from {} — if intentional, regenerate \
         with GOLDEN_UPDATE=1\n{}",
        path.display(),
        diff_report(&want, &got)
    );
}

#[test]
fn golden_trace_serial() {
    check_golden("trace_1b_2g_serial", OptimizationPlan::default());
}

#[test]
fn golden_trace_pipelined() {
    // Everything on: chunk prefetch, copy streams, collective stream.
    check_golden("trace_1b_2g_pipelined", OptimizationPlan::fully_pipelined());
}

#[test]
fn golden_trace_adaptive() {
    // The ISSUE 4 cell: pinned pipeline with feedback-sized windows and
    // the negotiated headroom ledger.  The controller reads only the
    // (deterministic) stream timeline, so its trace is as bit-stable as
    // the static ones.
    check_golden("trace_1b_2g_adaptive", OptimizationPlan::adaptive_pipeline());
}

#[test]
fn traced_run_reports_exactly_like_untraced() {
    // Tracing must be a pure observer: the report (times, volumes,
    // placement) is bit-identical with and without it.
    let e = Engine::new(ClusterPreset::yard(), task());
    let plain = e.run().unwrap();
    let (traced, _) = e.run_traced().unwrap();
    assert_eq!(plain.iter_time_s, traced.iter_time_s);
    assert_eq!(plain.allgather_bytes, traced.allgather_bytes);
    assert_eq!(
        plain.move_stats.cpu_to_gpu_bytes,
        traced.move_stats.cpu_to_gpu_bytes
    );
    assert_eq!(plain.gpu_peak, traced.gpu_peak);
}
