//! Golden-trace regression tests (ISSUE 2 satellite): the full
//! per-moment stream timeline of one small config is serialized into
//! `tests/golden/` and compared bit-for-bit, so future stream, eviction
//! or collective changes cannot silently drift the simulated clock.
//!
//! Every line is a moment index plus the hex-encoded f64 bits of every
//! stream frontier, exposure accumulator and per-phase clock — any
//! 1-ulp change anywhere in the schedule shows up as a textual diff
//! (run the suite with `--nocapture` to see it).
//!
//! Bootstrap: on a machine where the golden file does not exist yet,
//! the test writes it and instead asserts run-to-run bit-for-bit
//! determinism, so the first run is still a real check.  Regenerate
//! deliberately with `GOLDEN_UPDATE=1 cargo test golden`.
//!
//! CI runs with `GOLDEN_STRICT=1` (ISSUE 3 satellite): there a missing
//! golden file is a hard failure, not a bootstrap — a fresh CI checkout
//! silently regenerating the reference would regression-check nothing.
//! The `.txt` files under `tests/golden/` must be generated once on a
//! toolchain machine and committed.

use std::fs;
use std::path::PathBuf;

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{ChaosPlan, Engine, OptimizationPlan};
use patrickstar::model::GptSpec;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The reference config: small enough to run in seconds, 2 GPUs so the
/// distributed gather/reduce-scatter path is in the trace.
fn task() -> TrainTask {
    TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2)
}

fn trace_for(opt: OptimizationPlan) -> Vec<String> {
    trace_for_on(ClusterPreset::yard(), task(), opt)
}

fn trace_for_on(
    cluster: ClusterPreset,
    task: TrainTask,
    opt: OptimizationPlan,
) -> Vec<String> {
    let (_, trace) = Engine::new(cluster, task)
        .with_opt(opt)
        .run_traced()
        .expect("engine run");
    assert!(!trace.is_empty(), "trace must not be empty");
    trace
}

/// First differing line, printed in full so `--nocapture` CI logs show
/// exactly where the clock drifted.
fn diff_report(want: &[String], got: &[String]) -> String {
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        if w != g {
            return format!(
                "first divergence at line {}:\n  golden: {}\n  got:    {}",
                i + 1,
                w,
                g
            );
        }
    }
    format!(
        "line count changed: golden {} lines, got {}",
        want.len(),
        got.len()
    )
}

fn check_golden(name: &str, opt: OptimizationPlan) {
    check_golden_on(name, ClusterPreset::yard(), task(), opt);
}

fn check_golden_on(
    name: &str,
    cluster: ClusterPreset,
    task: TrainTask,
    opt: OptimizationPlan,
) {
    let got = trace_for_on(cluster, task, opt);
    // Bit-for-bit determinism is a precondition for a golden trace to
    // mean anything — assert it on every run, not just bootstrap.
    let again = trace_for_on(cluster, task, opt);
    assert!(
        got == again,
        "non-deterministic trace for {name}:\n{}",
        diff_report(&got, &again)
    );
    let path = golden_dir().join(format!("{name}.txt"));
    let update = std::env::var("GOLDEN_UPDATE").is_ok();
    // CI must regression-check, never self-seed: with GOLDEN_STRICT set
    // a missing golden file fails loudly instead of bootstrapping.
    let strict = std::env::var("GOLDEN_STRICT")
        .map(|v| v != "0" && !v.is_empty())
        .unwrap_or(false);
    if strict && !update {
        assert!(
            path.exists(),
            "golden trace {} missing under GOLDEN_STRICT — generate it \
             on a toolchain machine (GOLDEN_UPDATE=1 cargo test golden) \
             and commit it",
            path.display()
        );
    }
    if update || !path.exists() {
        fs::create_dir_all(golden_dir()).expect("mkdir tests/golden");
        fs::write(&path, got.join("\n") + "\n").expect("write golden");
        println!(
            "golden trace {} {} ({} lines)",
            path.display(),
            if update { "updated" } else { "bootstrapped" },
            got.len()
        );
        return;
    }
    let want: Vec<String> = fs::read_to_string(&path)
        .expect("read golden")
        .lines()
        .map(str::to_string)
        .collect();
    assert!(
        want == got,
        "stream timeline drifted from {} — if intentional, regenerate \
         with GOLDEN_UPDATE=1\n{}",
        path.display(),
        diff_report(&want, &got)
    );
}

#[test]
fn golden_trace_serial() {
    check_golden("trace_1b_2g_serial", OptimizationPlan::default());
}

#[test]
fn golden_trace_pipelined() {
    // Everything on: chunk prefetch, copy streams, collective stream.
    check_golden("trace_1b_2g_pipelined", OptimizationPlan::fully_pipelined());
}

#[test]
fn golden_trace_adaptive() {
    // The ISSUE 4 cell: pinned pipeline with feedback-sized windows and
    // the negotiated headroom ledger.  The controller reads only the
    // (deterministic) stream timeline, so its trace is as bit-stable as
    // the static ones.
    check_golden("trace_1b_2g_adaptive", OptimizationPlan::adaptive_pipeline());
}

/// ISSUE 7 golden: the 3-tier schedule on the RAM-starved NVME-LAB box.
/// One GPU, pinned pipeline, 64 GB NVMe grant — the 1B model cannot fit
/// CPU+GPU there, so every iteration crosses the NVMe lane and its
/// two-hop staged copies are pinned into the reference timeline
/// (snapshot lines carry the nvme frontier, so any drift in the NVMe
/// link curve or the staging sequence shows up as a textual diff).
#[test]
fn golden_trace_nvme() {
    let plan = OptimizationPlan {
        nvme_gb: 64,
        ..OptimizationPlan::pinned_pipeline()
    };
    check_golden_on(
        "trace_1b_1g_nvme",
        ClusterPreset::nvme_lab(),
        TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 1),
        plan,
    );
}

/// NVMe chaos lane determinism: same seed, same jittered 3-tier
/// schedule, byte for byte — report and trace (the satellite-4 replay
/// contract for the new fault lane).
#[test]
fn nvme_chaos_runs_replay_byte_identically() {
    let plan = OptimizationPlan {
        nvme_gb: 64,
        ..OptimizationPlan::pinned_pipeline()
    };
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 1);
    let go = |seed: u64| {
        Engine::new(ClusterPreset::nvme_lab(), task)
            .with_opt(plan)
            .with_chaos(ChaosPlan::all(seed))
            .run_traced()
            .expect("chaotic 3-tier run")
    };
    let (r1, t1) = go(0xC0FFEE);
    let (r2, t2) = go(0xC0FFEE);
    assert_eq!(t1, t2, "same-seed NVMe chaos trace not replayable");
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"),
               "same-seed NVMe chaos report not replayable");
    assert!(r1.chaos.is_some());
    // A different seed must still converge to a valid run (faults are
    // perturbations, not schedule corruption).
    let (r3, _) = go(0xBEEF);
    assert!(r3.iter_time_s > 0.0);
}

#[test]
fn traced_run_reports_exactly_like_untraced() {
    // Tracing must be a pure observer: the report (times, volumes,
    // placement) is bit-identical with and without it.
    let e = Engine::new(ClusterPreset::yard(), task());
    let plain = e.run().unwrap();
    let (traced, _) = e.run_traced().unwrap();
    assert_eq!(plain.iter_time_s, traced.iter_time_s);
    assert_eq!(plain.allgather_bytes, traced.allgather_bytes);
    assert_eq!(
        plain.move_stats.cpu_to_gpu_bytes,
        traced.move_stats.cpu_to_gpu_bytes
    );
    assert_eq!(plain.gpu_peak, traced.gpu_peak);
}
