//! Adaptive lookahead integration + property tests (ISSUE 4).
//!
//! The contract, mirroring the PR 1/PR 2/PR 3 suites:
//!
//! * window bounds — the controller's windows never exceed the static
//!   caps nor the pool bound (unit-property in `engine::adaptive`; here
//!   the *engine-level* telemetry is checked against the caps);
//! * volume — adaptive mode re-times transfers, it never adds PCIe
//!   traffic over the serial schedule, and collective wire volume stays
//!   bit-for-bit serial;
//! * identity — with `adaptive_lookahead` off (and the pinned split at
//!   its unsplit default) every timeline is bit-identical to the PR 3
//!   code paths: the ledger without earmarks IS the old budget, the
//!   unsplit pool IS the old pool.  The committed golden traces pin
//!   this across PRs; these tests pin it within the build.

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{Engine, EngineReport, OptimizationPlan};
use patrickstar::model::GptSpec;
use patrickstar::util::quickcheck::forall;

fn pcie_volume(r: &EngineReport) -> u64 {
    r.move_stats.cpu_to_gpu_bytes + r.move_stats.gpu_to_cpu_bytes
}

fn coll_volume(r: &EngineReport) -> u64 {
    r.allgather_bytes + r.reduce_scatter_bytes
}

fn run(task: TrainTask, opt: OptimizationPlan) -> EngineReport {
    Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run()
        .unwrap()
}

fn trace(task: TrainTask, opt: OptimizationPlan) -> Vec<String> {
    let (_, t) = Engine::new(ClusterPreset::yard(), task)
        .with_opt(opt)
        .run_traced()
        .unwrap();
    t
}

// ---------------------------------------------------------------------
// Window bounds at the engine level
// ---------------------------------------------------------------------

#[test]
fn adaptive_windows_stay_under_their_caps() {
    let task = TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 2);
    let opt = OptimizationPlan::adaptive_pipeline();
    let r = run(task, opt);
    assert!(r.adaptive_lookahead);
    assert!(r.avg_chunk_lookahead > 0.0, "chunk lane sized nothing");
    assert!(
        r.avg_chunk_lookahead <= opt.lookahead as f64,
        "avg chunk window {} exceeds cap {}",
        r.avg_chunk_lookahead,
        opt.lookahead
    );
    assert!(r.avg_group_lookahead >= 1.0);
    assert!(
        r.avg_group_lookahead <= opt.group_lookahead as f64,
        "avg group window {} exceeds cap {}",
        r.avg_group_lookahead,
        opt.group_lookahead
    );
    // Static mode reports no adaptive telemetry.
    let s = run(task, OptimizationPlan::pinned_pipeline());
    assert!(!s.adaptive_lookahead);
}

// ---------------------------------------------------------------------
// Property (b): adaptive mode never adds traffic over serial
// ---------------------------------------------------------------------

#[test]
fn property_adaptive_never_increases_transfer_volume() {
    forall(
        4,
        |rng| {
            let model = ["1B", "2B", "4B"][rng.range(0, 3)];
            let batch = [4u64, 8][rng.range(0, 2)];
            let gpus = [1u32, 2][rng.range(0, 2)];
            let pool = [0u32, 2, 4][rng.range(0, 3)];
            (model, batch, gpus, pool)
        },
        |&(model, batch, gpus, pool)| {
            let task =
                TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
            let serial = run(task, OptimizationPlan::default());
            let adaptive = run(
                task,
                OptimizationPlan {
                    pinned_buffers: pool,
                    ..OptimizationPlan::adaptive_pipeline()
                },
            );
            if pcie_volume(&adaptive) > pcie_volume(&serial) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch} pool={pool}: adaptive \
                     added PCIe traffic: {} > serial {}",
                    pcie_volume(&adaptive),
                    pcie_volume(&serial)
                ));
            }
            if coll_volume(&adaptive) != coll_volume(&serial) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch} pool={pool}: adaptive \
                     changed collective volume: {} != serial {}",
                    coll_volume(&adaptive),
                    coll_volume(&serial)
                ));
            }
            if adaptive.iter_time_s > serial.iter_time_s * (1.0 + 1e-9) {
                return Err(format!(
                    "{model}/{gpus}g/b{batch} pool={pool}: adaptive \
                     slower than serial: {} > {}",
                    adaptive.iter_time_s, serial.iter_time_s
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Property (c): adaptive off is bit-identical to the PR 3 paths
// ---------------------------------------------------------------------

#[test]
fn adaptive_off_timelines_are_bit_identical_to_static_paths() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
    for base in [
        OptimizationPlan::default(),
        OptimizationPlan::pipelined(),
        OptimizationPlan::fully_pipelined(),
        OptimizationPlan::pinned_pipeline(),
    ] {
        // The PR 3 plan spelled through the new plan struct with every
        // new knob at its neutral value must trace identically — the
        // ledger with no earmarks and the unsplit pool ARE the old
        // code paths.
        assert!(!base.adaptive_lookahead && base.pinned_split.is_none());
        let a = trace(task, base);
        let b = trace(task, base);
        assert_eq!(a, b, "static trace must be deterministic");
        // Spelling the unsplit pool explicitly (`N:N`) changes nothing.
        let split = OptimizationPlan {
            pinned_split: Some((base.pinned_buffers, base.pinned_buffers)),
            ..base
        };
        let c = trace(task, split);
        assert_eq!(a, c, "explicit N:N split drifted from unsplit");
    }
}

#[test]
fn adaptive_runs_are_deterministic() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
    let a = trace(task, OptimizationPlan::adaptive_pipeline());
    let b = trace(task, OptimizationPlan::adaptive_pipeline());
    assert_eq!(a, b, "adaptive trace must be bit-deterministic");
}

// ---------------------------------------------------------------------
// The headline: adaptive competes with the static windows
// ---------------------------------------------------------------------

#[test]
fn adaptive_not_worse_than_default_static_on_spill_config() {
    // 12B on one V100 streams spilled fp16 chunks every iteration —
    // the transfer-bound config the pipeline exists for.  The adaptive
    // window must stay within a whisker of the default static pipeline
    // (the bench sweep in `cargo bench -- adaptive_lookahead` holds it
    // to the *best* static pair; CI gates the regression at 5%).
    let task = TrainTask::new(GptSpec::by_name("12B").unwrap(), 8, 1);
    let static_def = run(task, OptimizationPlan::pinned_pipeline());
    let adaptive = run(task, OptimizationPlan::adaptive_pipeline());
    assert!(adaptive.move_stats.prefetches > 0, "lane never fired");
    assert!(
        adaptive.iter_time_s <= static_def.iter_time_s * 1.05,
        "adaptive {} vs static default {}",
        adaptive.iter_time_s,
        static_def.iter_time_s
    );
}

#[test]
fn adaptive_group_window_competes_on_collective_config() {
    // 8-GPU config where the collective lane carries the win: the
    // adaptive group window (cap 4) must hide at least as much
    // collective time as the default static gla=1, within tolerance.
    let task = TrainTask::new(GptSpec::by_name("8B").unwrap(), 8, 4);
    let static_def = run(task, OptimizationPlan::pinned_pipeline());
    let adaptive = run(task, OptimizationPlan::adaptive_pipeline());
    assert!(adaptive.gather_prefetches > 0, "no lookahead gathers");
    assert!(
        adaptive.iter_time_s <= static_def.iter_time_s * 1.05,
        "adaptive {} vs static default {}",
        adaptive.iter_time_s,
        static_def.iter_time_s
    );
    assert_eq!(
        coll_volume(&adaptive),
        coll_volume(&static_def),
        "wire volume must not depend on the window policy"
    );
}
