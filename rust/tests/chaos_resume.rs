//! Chaos fault-injection integration suite (ISSUE 6).
//!
//! The kill-and-resume golden tests live next to the engine
//! (`engine/mod.rs`) because they drive sessions below the public API;
//! this file locks the *whole-engine* chaos contracts:
//!
//! * **Wire-volume invariance** — injected mid-flight aborts cancel
//!   in-flight gathers and prefetches, but every cancel credits its
//!   volume back, so the collective wire bytes of a chaos-battered
//!   pipelined run equal the serial plan's bit-for-bit (u64 equality,
//!   no tolerance).
//! * **Fault counters** — a hostile plan actually injects, and the
//!   counters reach the report.
//! * **Robustness sweep** — every pipeline cell survives a hostile
//!   fault plan without panicking or producing a nonsensical report.
//!
//! (The chaos-off passthrough and same-seed replay contracts live in
//! `tests/session_equivalence.rs`.)

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{ChaosPlan, Engine, EngineReport,
                          OptimizationPlan};
use patrickstar::model::GptSpec;
use patrickstar::util::quickcheck::forall;

fn run(
    plan: OptimizationPlan,
    chaos: Option<ChaosPlan>,
    gpus: u32,
) -> EngineReport {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, gpus);
    let mut e = Engine::new(ClusterPreset::yard(), task).with_opt(plan);
    if let Some(c) = chaos {
        e = e.with_chaos(c);
    }
    e.run().expect("engine run")
}

/// A plan hostile enough that cancels actually happen: every lane on,
/// firing an order of magnitude above the default rate.
fn hostile(seed: u64) -> ChaosPlan {
    ChaosPlan { rate: 0.5, intensity: 2.0, ..ChaosPlan::all(seed) }
}

#[test]
fn property_chaos_cancels_preserve_collective_wire_volume() {
    // The serial plan issues every collective on demand and cancels
    // nothing — its wire volume is the ground truth.
    let serial = run(OptimizationPlan::default(), None, 4);
    assert!(serial.allgather_bytes > 0);
    forall(
        6,
        |rng| rng.next_u64(),
        |&seed| {
            let chaotic = run(
                OptimizationPlan::pinned_pipeline(),
                Some(hostile(seed)),
                4,
            );
            if chaotic.allgather_bytes != serial.allgather_bytes {
                return Err(format!(
                    "allgather volume drifted under chaos (seed {seed}): \
                     {} != {}",
                    chaotic.allgather_bytes, serial.allgather_bytes
                ));
            }
            if chaotic.reduce_scatter_bytes != serial.reduce_scatter_bytes
            {
                return Err(format!(
                    "reduce-scatter volume drifted under chaos (seed \
                     {seed}): {} != {}",
                    chaotic.reduce_scatter_bytes,
                    serial.reduce_scatter_bytes
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn hostile_chaos_injects_and_the_report_carries_the_counters() {
    let r = run(OptimizationPlan::pinned_pipeline(), Some(hostile(7)), 4);
    let st = r.chaos.expect("chaos run must report fault counters");
    assert!(st.copy_slowdowns > 0, "jitter lane never fired: {st:?}");
    assert!(st.collective_stretches > 0,
            "straggler lane never fired: {st:?}");
    assert!(st.aborts > 0, "abort lane never fired: {st:?}");
    // A chaos-free run keeps the report clean.
    let clean = run(OptimizationPlan::pinned_pipeline(), None, 4);
    assert_eq!(clean.chaos, None);
}

#[test]
fn every_pipeline_cell_survives_hostile_chaos() {
    for (label, plan) in [
        ("base", OptimizationPlan::default()),
        ("overlap", OptimizationPlan::overlap_only()),
        ("pipelined", OptimizationPlan::pipelined()),
        ("collectives", OptimizationPlan::collectives_pipelined()),
        ("pinned", OptimizationPlan::pinned_pipeline()),
        ("adaptive", OptimizationPlan::adaptive_pipeline()),
    ] {
        for gpus in [1u32, 4] {
            let r = run(plan, Some(hostile(13)), gpus);
            assert!(r.iter_time_s > 0.0, "{label}/{gpus}: zero iter time");
            assert!(r.iter_time_s.is_finite(),
                    "{label}/{gpus}: non-finite iter time");
            assert!(r.chaos.is_some(), "{label}/{gpus}: counters missing");
            assert_eq!(r.move_stats.lease_leaks, 0,
                       "{label}/{gpus}: chaos leaked a pinned lease");
        }
    }
}
