//! Integration tests across the simulator stack: engine + baselines +
//! scale + collectives + config, exercising whole-system behaviours the
//! unit tests cannot.

use patrickstar::baselines::run_system;
use patrickstar::config::{ClusterPreset, SystemKind, TrainTask};
use patrickstar::dp::{CollectiveCost, RealCollectives};
use patrickstar::engine::{Engine, EvictKind, OptimizationPlan};
use patrickstar::model::{ActivationPlan, GptSpec};
use patrickstar::scale::max_model_scale;
use patrickstar::sim::Phase;
use patrickstar::util::quickcheck::forall;
use patrickstar::util::Json;

fn yard_task(model: &str, batch: u64, gpus: u32) -> TrainTask {
    TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus)
}

// ---------------------------------------------------------------------
// Headline shapes (paper Sec. 9.2)
// ---------------------------------------------------------------------

#[test]
fn paper_headline_yard_scale_ratios() {
    // Fig. 13 YARD 8 GPUs: PatrickStar 18B vs DeepSpeed-DP 4B (>=3x),
    // PyTorch 1B (>=12x).
    let ps = max_model_scale(SystemKind::PatrickStar,
                             ClusterPreset::yard(), 8).unwrap();
    let ds = max_model_scale(SystemKind::DeepSpeedDp,
                             ClusterPreset::yard(), 8).unwrap();
    let pt = max_model_scale(SystemKind::PyTorchDdp,
                             ClusterPreset::yard(), 8).unwrap();
    let n = |p: &patrickstar::scale::Probe| {
        GptSpec::by_name(p.model).unwrap().n_params()
    };
    assert_eq!(ps.model, "18B");
    assert!(n(&ps) >= 3 * n(&ds), "PS {} vs DS {}", ps.model, ds.model);
    assert!(n(&ps) >= 12 * n(&pt), "PS {} vs PT {}", ps.model, pt.model);
}

#[test]
fn paper_headline_superpod_scale_ratios() {
    // Fig. 13 SuperPod 8 GPUs: PatrickStar 68B ~ 2.27x DeepSpeed 30B.
    let ps = max_model_scale(SystemKind::PatrickStar,
                             ClusterPreset::superpod(), 8).unwrap();
    let ds = max_model_scale(SystemKind::DeepSpeedDp,
                             ClusterPreset::superpod(), 8).unwrap();
    assert_eq!(ps.model, "68B");
    assert_eq!(ds.model, "30B");
}

#[test]
fn patrickstar_throughput_beats_deepspeed_across_models() {
    // Figs. 14/15: PatrickStar >= DeepSpeed-DP wherever both run.
    for model in ["1B", "2B", "4B"] {
        for gpus in [1u32, 8] {
            let task = yard_task(model, 16, gpus);
            let ps = run_system(SystemKind::PatrickStar,
                                ClusterPreset::yard(), task);
            let ds = run_system(SystemKind::DeepSpeedDp,
                                ClusterPreset::yard(), task);
            if let (Ok(ps), Ok(ds)) = (ps, ds) {
                assert!(
                    ps.tflops_per_gpu >= ds.tflops_per_gpu,
                    "{model}/{gpus}g: ps {} < ds {}",
                    ps.tflops_per_gpu,
                    ds.tflops_per_gpu
                );
            }
        }
    }
}

#[test]
fn patrickstar_trains_where_deepspeed_crashes() {
    // Fig. 10: 8B on YARD single GPU — DeepSpeed's host-side footprint
    // exceeds 240 GB; PatrickStar evicts chunks and proceeds.
    let task = yard_task("8B", 8, 1);
    assert!(run_system(SystemKind::DeepSpeedDp, ClusterPreset::yard(),
                       task).is_err());
    let ps = run_system(SystemKind::PatrickStar, ClusterPreset::yard(),
                        task).unwrap();
    assert!(ps.tflops_per_gpu > 10.0);
}

#[test]
fn throughput_robust_to_model_scale() {
    // Sec. 9.2.3: 18B throughput is >= 80% of 1B throughput on 8 GPUs
    // (paper: 94%).
    let best = |model| {
        patrickstar::scale::best_over_batches(
            SystemKind::PatrickStar,
            ClusterPreset::yard(),
            GptSpec::by_name(model).unwrap(),
            8,
        )
        .best
        .unwrap()
        .tflops_per_gpu
    };
    let t1 = best("1B");
    let t18 = best("18B");
    assert!(t18 > 0.8 * t1, "18B {t18} vs 1B {t1}");
}

// ---------------------------------------------------------------------
// Optimization ablations (Fig. 16)
// ---------------------------------------------------------------------

#[test]
fn ablation_ordering_base_beats_sp_and_osc() {
    let task = yard_task("12B", 8, 8);
    let run = |opt| {
        Engine::new(ClusterPreset::yard(), task)
            .with_opt(opt)
            .run()
            .unwrap()
            .iter_time_s
    };
    let base = run(OptimizationPlan::default());
    let osc = run(OptimizationPlan::os_on_cpu());
    let sp = run(OptimizationPlan::static_partition());
    assert!(base <= osc + 1e-9, "base {base} vs osc {osc}");
    assert!(base < sp, "base {base} vs sp {sp}");
    // The paper's 10B/8g case shows ~6.9x for Base vs SP; require a
    // material gap here too.
    assert!(sp / base > 1.5, "sp/base only {:.2}", sp / base);
}

#[test]
fn opt_eviction_moves_no_more_than_history_policies() {
    let task = yard_task("12B", 8, 1);
    let moved = |evict| {
        let opt = OptimizationPlan { eviction: evict, ..Default::default() };
        let r = Engine::new(ClusterPreset::yard(), task)
            .with_opt(opt)
            .run()
            .unwrap();
        r.move_stats.cpu_to_gpu_bytes + r.move_stats.gpu_to_cpu_bytes
    };
    let opt = moved(EvictKind::Opt);
    for other in [EvictKind::Lru, EvictKind::Fifo, EvictKind::Lfu] {
        let m = moved(other);
        assert!(
            opt <= m,
            "OPT moved {opt} B > {other:?} moved {m} B"
        );
    }
}

// ---------------------------------------------------------------------
// Communication invariants (Sec. 7)
// ---------------------------------------------------------------------

#[test]
fn wire_volume_matches_6_over_p_formula() {
    // The engine's measured all-gather + reduce-scatter bytes per rank
    // must equal 6(p-1)/p x chunked-params within chunk rounding.
    let task = yard_task("4B", 8, 8);
    let r = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    let m = GptSpec::by_name("4B").unwrap();
    let chunked_params = m.n_params() - m.embedding_params();
    let expect = 6.0 * 7.0 / 8.0 * chunked_params as f64;
    let got = (r.allgather_bytes + r.reduce_scatter_bytes) as f64;
    let ratio = got / expect;
    assert!(
        (0.9..1.25).contains(&ratio),
        "wire bytes {got:.3e} vs formula {expect:.3e} (ratio {ratio:.3})"
    );
}

#[test]
fn collective_bandwidth_beats_broadcast_baseline() {
    let cc = CollectiveCost::new(
        patrickstar::mem::Interconnect::v100_node().nvlink, 8);
    // Same payload: chunked all-gather vs per-tensor broadcast.
    let chunk = 256u64 << 20;
    let ag = cc.allgather_time(chunk);
    let bc = cc.broadcast_time(chunk, 512 << 10);
    assert!(bc > ag, "broadcast {bc} must exceed chunked allgather {ag}");
}

#[test]
fn multi_rank_reduce_scatter_numeric_equivalence() {
    // Spawn real threads, each contributing chunk data; reduce-scatter
    // must equal the sequential average.
    use std::sync::Arc;
    let nproc = 4usize;
    let len = 1024usize;
    let contribs: Vec<Vec<Vec<f32>>> = (0..nproc)
        .map(|r| {
            (0..nproc)
                .map(|g| {
                    (0..len).map(|i| (r * 31 + g * 7 + i) as f32).collect()
                })
                .collect()
        })
        .collect();
    let shared = Arc::new(contribs);
    let handles: Vec<_> = (0..nproc)
        .map(|rank| {
            let c = Arc::clone(&shared);
            std::thread::spawn(move || {
                let out = RealCollectives::reduce_scatter_avg(&c);
                out[rank].clone()
            })
        })
        .collect();
    for (rank, h) in handles.into_iter().enumerate() {
        let got = h.join().unwrap();
        for (i, &g) in got.iter().enumerate() {
            let want: f32 = (0..nproc)
                .map(|r| (r * 31 + rank * 7 + i) as f32)
                .sum::<f32>()
                / nproc as f32;
            assert!((g - want).abs() < 1e-4, "rank {rank} elem {i}");
        }
    }
}

// ---------------------------------------------------------------------
// Memory invariants
// ---------------------------------------------------------------------

#[test]
fn gpu_peak_never_exceeds_capacity() {
    for (cluster, model, gpus) in [
        (ClusterPreset::yard(), "4B", 1u32),
        (ClusterPreset::yard(), "12B", 8),
        (ClusterPreset::superpod(), "30B", 8),
    ] {
        let task = TrainTask::new(GptSpec::by_name(model).unwrap(), 8, gpus);
        let r = Engine::new(cluster, task).run().unwrap();
        assert!(
            r.gpu_peak <= cluster.gpu_mem,
            "{model}/{gpus}g: chunk peak {} > GPU {}",
            r.gpu_peak,
            cluster.gpu_mem
        );
        assert!(r.cpu_peak <= cluster.cpu_mem);
    }
}

#[test]
fn batch_size_only_affects_nonmodel_side() {
    // Raising batch must not change chunked model bytes, only the
    // non-model peak (the decoupling DeepSpeed lacks, Sec. 4).
    let r8 = Engine::new(ClusterPreset::yard(), yard_task("4B", 8, 1))
        .run()
        .unwrap();
    let r32 = Engine::new(ClusterPreset::yard(), yard_task("4B", 32, 1))
        .run()
        .unwrap();
    assert_eq!(r8.chunk_elems, r32.chunk_elems);
    assert!(r32.non_model_peak > r8.non_model_peak);
}

#[test]
fn property_engine_time_composition() {
    // Random feasible small tasks: every phase non-negative and total =
    // sum of phases.
    forall(
        8,
        |rng| {
            let models = ["1B", "2B", "4B"];
            let model = models[rng.range(0, models.len())];
            let batch = [4u64, 8, 16][rng.range(0, 3)];
            let gpus = [1u32, 2, 4, 8][rng.range(0, 4)];
            (model, batch, gpus)
        },
        |&(model, batch, gpus)| {
            let task = yard_task(model, batch, gpus);
            let r = Engine::new(ClusterPreset::yard(), task)
                .run()
                .map_err(|e| format!("engine failed: {e}"))?;
            let sum: f64 =
                Phase::ALL.iter().map(|&p| r.breakdown.get(p)).sum();
            if (sum - r.iter_time_s).abs() > 1e-9 {
                return Err(format!("sum {sum} != total {}", r.iter_time_s));
            }
            if r.tflops_per_gpu <= 0.0 {
                return Err("non-positive throughput".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Config plumbing
// ---------------------------------------------------------------------

#[test]
fn task_json_roundtrip_drives_engine() {
    let j = Json::parse(
        r#"{"model": "1B", "batch": 8, "gpus": 2, "plan": "ckpt"}"#,
    )
    .unwrap();
    let task = TrainTask::from_json(&j).unwrap();
    assert_eq!(task.plan, ActivationPlan::Checkpointing);
    let r = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    assert_eq!(r.n_gpus, 2);
}

#[test]
fn activation_offload_helps_when_memory_tight() {
    // 8B batch 32 on one V100: plain checkpointing's boundary
    // activations crowd out chunks; offload trades PCIe time for space.
    let base = yard_task("8B", 32, 1);
    let off = base.with_plan(ActivationPlan::CheckpointingOffload);
    let r_off = Engine::new(ClusterPreset::yard(), off).run().unwrap();
    assert!(r_off.breakdown.get(Phase::ActOffload) > 0.0);
    match Engine::new(ClusterPreset::yard(), base).run() {
        Ok(r_ck) => {
            // If both run, offload must show lower non-model peak.
            assert!(r_off.non_model_peak < r_ck.non_model_peak);
        }
        Err(_) => {} // plain ckpt infeasible: offload rescued the task
    }
}
