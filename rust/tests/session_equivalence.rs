//! Session/engine equivalence suite (ISSUE 5 satellite).
//!
//! The `TrainingSession`/`ExecutionBackend` split must be a pure
//! refactor of the old `Engine::run` monolith.  Three layers pin that:
//!
//! 1. **Trait neutrality** — driving a `SimBackend` (including through
//!    `&mut dyn ExecutionBackend`, the worst case for accidental
//!    re-pricing or reordering) is bit-identical to driving the raw
//!    `StreamTimeline`, for arbitrary operation sequences.
//! 2. **Whole-engine determinism and observer purity** — across
//!    randomized `OptimizationPlan`s (every toggle), model sizes and
//!    nproc ∈ {1, 2, 4, 8}, `TrainingSession` over `SimBackend`
//!    produces byte-identical `EngineReport`s and traces run-to-run,
//!    and tracing never perturbs the report.
//! 3. **Cross-refactor anchoring** — the committed golden traces
//!    (`tests/golden/*.txt`, `GOLDEN_STRICT=1` in CI) compare today's
//!    session against the recorded pre-refactor schedules bit-for-bit;
//!    this file covers the configurations the three golden files
//!    don't.

use patrickstar::config::{ClusterPreset, TrainTask};
use patrickstar::engine::{ChaosBackend, ChaosPlan, Engine, EngineReport,
                          EvictKind, ExecutionBackend, OptimizationPlan,
                          SimBackend};
use patrickstar::model::GptSpec;
use patrickstar::sim::{CopyDir, CopyRoute, Phase, StreamTimeline};
use patrickstar::util::quickcheck::forall;
use patrickstar::util::Rng;

// ---------------------------------------------------------------------
// 1. Trait neutrality
// ---------------------------------------------------------------------

/// One random backend operation, mirrored onto both substrates.
#[derive(Clone, Copy, Debug)]
enum Op {
    Execute(f64),
    DemandCopy(f64, CopyDir),
    IssueCopy(f64, CopyDir, CopyRoute),
    DemandColl(f64),
    IssueColl(f64),
    SyncCopies,
    SyncColl,
}

fn gen_ops(rng: &mut Rng) -> (bool, Vec<Op>) {
    let overlap = rng.range(0, 2) == 1;
    let n = rng.range(1, 40);
    let mut ops = Vec::with_capacity(n);
    for _ in 0..n {
        let secs = rng.range(1, 1000) as f64 / 300.0;
        let dir = if rng.range(0, 2) == 0 {
            CopyDir::H2D
        } else {
            CopyDir::D2H
        };
        let route = if rng.range(0, 2) == 0 {
            CopyRoute::Pinned
        } else {
            CopyRoute::Pageable
        };
        ops.push(match rng.range(0, 7) {
            0 => Op::Execute(secs),
            1 => Op::DemandCopy(secs, dir),
            2 => Op::IssueCopy(secs, dir, route),
            3 => Op::DemandColl(secs),
            4 => Op::IssueColl(secs),
            5 => Op::SyncCopies,
            _ => Op::SyncColl,
        });
    }
    (overlap, ops)
}

#[test]
fn property_sim_backend_dispatch_matches_raw_timeline() {
    let net = ClusterPreset::yard().net;
    forall(200, gen_ops, |&(overlap, ref ops)| {
        let mut raw = StreamTimeline::new(overlap);
        let mut sim = SimBackend::new(overlap, net, 2);
        let be: &mut dyn ExecutionBackend = &mut sim;
        // Completion times issued so far, to exercise the sync paths.
        let mut raw_copy_done = 0.0f64;
        let mut be_copy_done = 0.0f64;
        let mut raw_coll_done = 0.0f64;
        let mut be_coll_done = 0.0f64;
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Op::Execute(s) => {
                    raw.charge(Phase::FwdBwd, s);
                    be.execute_moment(Phase::FwdBwd, s);
                }
                Op::DemandCopy(s, d) => {
                    raw.demand_copy(Phase::CpuToGpu, s, d, 0.0);
                    be.demand_copy(Phase::CpuToGpu, s, d, 0.0);
                }
                Op::IssueCopy(s, d, r) => {
                    raw_copy_done =
                        raw.async_copy_on(Phase::GpuToCpu, s, d, 0.0, r);
                    be_copy_done =
                        be.issue_copy(Phase::GpuToCpu, s, d, 0.0, r);
                }
                Op::DemandColl(s) => {
                    raw.demand_collective(Phase::AllGather, s);
                    be.demand_collective(Phase::AllGather, s);
                }
                Op::IssueColl(s) => {
                    raw_coll_done =
                        raw.async_collective(Phase::ReduceScatter, s);
                    be_coll_done =
                        be.issue_collective(Phase::ReduceScatter, s);
                }
                Op::SyncCopies => {
                    raw.wait_until(raw_copy_done);
                    be.sync_until(be_copy_done);
                }
                Op::SyncColl => {
                    raw.wait_collective(raw_coll_done);
                    be.sync_collective(be_coll_done);
                }
            }
            if raw.snapshot() != be.snapshot() {
                return Err(format!(
                    "snapshot diverged at op {i} ({op:?}, overlap \
                     {overlap})\n  raw: {}\n  sim: {}",
                    raw.snapshot(),
                    be.snapshot()
                ));
            }
        }
        if raw_copy_done.to_bits() != be_copy_done.to_bits()
            || raw_coll_done.to_bits() != be_coll_done.to_bits()
        {
            return Err("completion times diverged".into());
        }
        if raw.makespan().to_bits() != be.makespan().to_bits() {
            return Err("makespan diverged".into());
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// 2. Whole-engine determinism + observer purity over random plans
// ---------------------------------------------------------------------

fn random_plan(rng: &mut Rng) -> OptimizationPlan {
    let overlap_collectives = rng.range(0, 2) == 1;
    let overlap = overlap_collectives || rng.range(0, 2) == 1;
    let pinned_buffers = [0u32, 1, 2, 4][rng.range(0, 4)];
    OptimizationPlan {
        use_tracer: rng.range(0, 4) != 0, // mostly on; SP cell too
        device_aware_os: rng.range(0, 4) != 0,
        eviction: [EvictKind::Opt, EvictKind::Lru, EvictKind::Fifo,
                   EvictKind::Lfu][rng.range(0, 4)],
        prefetch: rng.range(0, 2) == 1,
        overlap,
        lookahead: rng.range(1, 64) as u32,
        overlap_collectives,
        group_lookahead: rng.range(1, 4) as u32,
        pinned_buffers,
        pinned_split: if pinned_buffers >= 2 && rng.range(0, 2) == 1 {
            Some((rng.range(1, pinned_buffers as usize + 1) as u32,
                  rng.range(1, pinned_buffers as usize + 1) as u32))
        } else {
            None
        },
        adaptive_lookahead: rng.range(0, 2) == 1,
        nvme_gb: 0,
        nvme_gbps: 0.0,
    }
}

fn run_traced_for(
    plan: OptimizationPlan,
    model: &str,
    batch: u64,
    gpus: u32,
) -> (EngineReport, Vec<String>) {
    let task = TrainTask::new(GptSpec::by_name(model).unwrap(), batch,
                              gpus);
    Engine::new(ClusterPreset::yard(), task)
        .with_opt(plan)
        .run_traced()
        .expect("engine run")
}

#[test]
fn property_session_reports_and_traces_are_deterministic() {
    // Fewer cases than a unit-level property — each case is a full
    // engine run — but they sweep every plan toggle and nproc.
    forall(
        8,
        |rng| {
            (
                random_plan(rng),
                [1u32, 2, 4, 8][rng.range(0, 4)],
                [2u64, 4][rng.range(0, 2)],
            )
        },
        |&(plan, gpus, batch)| {
            let (r1, t1) = run_traced_for(plan, "1B", batch, gpus);
            let (r2, t2) = run_traced_for(plan, "1B", batch, gpus);
            if t1 != t2 {
                let i = t1
                    .iter()
                    .zip(t2.iter())
                    .position(|(a, b)| a != b)
                    .unwrap_or(t1.len().min(t2.len()));
                return Err(format!(
                    "trace not deterministic for {plan:?} gpus {gpus}: \
                     first divergence at line {i}"
                ));
            }
            let (d1, d2) = (format!("{r1:?}"), format!("{r2:?}"));
            if d1 != d2 {
                return Err(format!(
                    "report not byte-identical for {plan:?} gpus \
                     {gpus}:\n  {d1}\n  {d2}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn tracing_is_a_pure_observer_across_pipeline_cells() {
    // The traced session must report exactly like the untraced one in
    // every pipeline cell (the golden tests pin only the serial,
    // fully-pipelined and adaptive cells; this sweeps the rest).
    for (label, plan) in [
        ("base", OptimizationPlan::default()),
        ("overlap", OptimizationPlan::overlap_only()),
        ("pipelined", OptimizationPlan::pipelined()),
        ("collectives", OptimizationPlan::collectives_pipelined()),
        ("pinned", OptimizationPlan::pinned_pipeline()),
        ("adaptive", OptimizationPlan::adaptive_pipeline()),
    ] {
        let task =
            TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2);
        let e = Engine::new(ClusterPreset::yard(), task).with_opt(plan);
        let plain = e.run().unwrap();
        let (traced, trace) = e.run_traced().unwrap();
        assert!(!trace.is_empty(), "{label}: empty trace");
        assert_eq!(
            plain.iter_time_s.to_bits(),
            traced.iter_time_s.to_bits(),
            "{label}: iter time drifted under tracing"
        );
        assert_eq!(format!("{plain:?}"), format!("{traced:?}"),
                   "{label}: report drifted under tracing");
    }
}

// ---------------------------------------------------------------------
// 3. Chaos determinism contracts (ISSUE 6)
// ---------------------------------------------------------------------

/// A `ChaosBackend` with every fault lane off must be an *exact*
/// passthrough: same dispatch results, same pricing, same probes, and
/// zero RNG draws — for arbitrary operation sequences.
#[test]
fn property_disabled_chaos_wrapper_is_bit_identical_to_plain_sim() {
    let net = ClusterPreset::yard().net;
    forall(200, gen_ops, |&(overlap, ref ops)| {
        let mut plain = SimBackend::new(overlap, net, 2);
        let mut wrapped = ChaosBackend::new(
            SimBackend::new(overlap, net, 2),
            ChaosPlan::disabled(41),
        );
        for (i, op) in ops.iter().enumerate() {
            {
                let a: &mut dyn ExecutionBackend = &mut plain;
                let b: &mut dyn ExecutionBackend = &mut wrapped;
                for be in [a, b] {
                    match *op {
                        Op::Execute(s) => {
                            be.execute_moment(Phase::FwdBwd, s);
                        }
                        Op::DemandCopy(s, d) => {
                            be.demand_copy(Phase::CpuToGpu, s, d, 0.0);
                        }
                        Op::IssueCopy(s, d, r) => {
                            be.issue_copy(Phase::GpuToCpu, s, d, 0.0, r);
                        }
                        Op::DemandColl(s) => {
                            be.demand_collective(Phase::AllGather, s);
                        }
                        Op::IssueColl(s) => {
                            be.issue_collective(Phase::ReduceScatter, s);
                        }
                        Op::SyncCopies => be.sync_until(1.0),
                        Op::SyncColl => be.sync_collective(1.0),
                    }
                }
            }
            // Dispatch state, pricing and every probe the session or
            // controller reads must agree byte-for-byte.
            if plain.snapshot() != wrapped.snapshot() {
                return Err(format!("snapshot diverged at op {i}"));
            }
            for (bytes, route) in [(64 << 20, CopyRoute::Pinned),
                                   (3 << 20, CopyRoute::Pageable)] {
                if plain.copy_secs(bytes, route).to_bits()
                    != wrapped.copy_secs(bytes, route).to_bits()
                {
                    return Err(format!("copy pricing diverged at {i}"));
                }
            }
            let (ap, aw) =
                (plain.allgather_cost(1 << 20), wrapped.allgather_cost(1 << 20));
            let (rp, rw) = (plain.reduce_scatter_cost(1 << 20),
                            wrapped.reduce_scatter_cost(1 << 20));
            if ap.secs.to_bits() != aw.secs.to_bits()
                || ap.bytes != aw.bytes
                || rp.secs.to_bits() != rw.secs.to_bits()
                || rp.bytes != rw.bytes
            {
                return Err(format!("collective pricing diverged at {i}"));
            }
            for dir in [CopyDir::H2D, CopyDir::D2H] {
                if plain.copy_backlog(dir).to_bits()
                    != wrapped.copy_backlog(dir).to_bits()
                {
                    return Err(format!("copy backlog diverged at {i}"));
                }
            }
            if plain.collective_backlog().to_bits()
                != wrapped.collective_backlog().to_bits()
                || wrapped.poll_abort()
            {
                return Err(format!("collective probe diverged at {i}"));
            }
        }
        if plain.makespan().to_bits() != wrapped.makespan().to_bits() {
            return Err("makespan diverged".into());
        }
        let st = wrapped.chaos_stats().expect("wrapper reports stats");
        if st != Default::default() {
            return Err(format!("disabled plan injected faults: {st:?}"));
        }
        Ok(())
    });
}

/// A whole engine run through a disabled chaos wrapper lands on the
/// plain engine's timeline exactly (the report differs only in carrying
/// zeroed fault counters).
#[test]
fn disabled_chaos_engine_run_matches_plain_engine_run() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 4);
    let plan = OptimizationPlan::pinned_pipeline();
    let e = Engine::new(ClusterPreset::yard(), task).with_opt(plan);
    let (plain, plain_trace) = e.run_traced().unwrap();
    let (off, off_trace) = Engine::new(ClusterPreset::yard(), task)
        .with_opt(plan)
        .with_chaos(ChaosPlan::disabled(99))
        .run_traced()
        .unwrap();
    assert_eq!(plain_trace, off_trace);
    assert_eq!(plain.iter_time_s.to_bits(), off.iter_time_s.to_bits());
    assert_eq!(format!("{:?}", plain.breakdown),
               format!("{:?}", off.breakdown));
    assert_eq!(format!("{:?}", plain.move_stats),
               format!("{:?}", off.move_stats));
    assert_eq!(plain.chaos, None);
    assert_eq!(off.chaos, Some(Default::default()));
}

/// Same seed, same faults: two chaos-on engine runs are byte-identical,
/// report and trace.
#[test]
fn same_seed_chaos_engine_runs_are_byte_identical() {
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 4);
    let plan = OptimizationPlan::pinned_pipeline();
    let go = || {
        Engine::new(ClusterPreset::yard(), task)
            .with_opt(plan)
            .with_chaos(ChaosPlan::all(0xBAD5EED))
            .run_traced()
            .unwrap()
    };
    let (r1, t1) = go();
    let (r2, t2) = go();
    assert_eq!(t1, t2, "chaos trace not replayable");
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"),
               "chaos report not replayable");
    assert!(r1.chaos.is_some());
}

// ---------------------------------------------------------------------
// 4. NVMe third tier (ISSUE 7)
// ---------------------------------------------------------------------

/// Tier-off identity: `nvme_gb: 0` means **no third tier at all** — a
/// plan that merely carries an NVMe bandwidth override must produce
/// byte-identical reports, traces and rendered text across the
/// randomized plan × model × nproc matrix.  This is the contract that
/// lets every pre-NVMe golden trace stay valid.
#[test]
fn property_nvme_tier_off_is_byte_identical() {
    forall(
        6,
        |rng| {
            (
                random_plan(rng),
                ["1B", "2B"][rng.range(0, 2)],
                [1u32, 2, 4, 8][rng.range(0, 4)],
                [2u64, 4][rng.range(0, 2)],
            )
        },
        |&(plan, model, gpus, batch)| {
            let off = OptimizationPlan { nvme_gb: 0, nvme_gbps: 0.0,
                                         ..plan };
            let carry = OptimizationPlan { nvme_gb: 0, nvme_gbps: 7.5,
                                           ..plan };
            let (r1, t1) = run_traced_for(off, model, batch, gpus);
            let (r2, t2) = run_traced_for(carry, model, batch, gpus);
            if t1 != t2 {
                let i = t1
                    .iter()
                    .zip(t2.iter())
                    .position(|(a, b)| a != b)
                    .unwrap_or(t1.len().min(t2.len()));
                return Err(format!(
                    "tier-off trace diverged for {plan:?} {model} gpus \
                     {gpus}: first divergence at line {i}"
                ));
            }
            if format!("{r1:?}") != format!("{r2:?}") {
                return Err(format!(
                    "tier-off report diverged for {plan:?} {model} \
                     gpus {gpus}"
                ));
            }
            if r1.render() != r2.render() {
                return Err("tier-off render diverged".into());
            }
            if r1.nvme_peak != 0 || r1.move_stats.to_nvme_bytes != 0 {
                return Err("two-tier run touched the NVMe tier".into());
            }
            if r1.render().contains("nvme tier:") {
                return Err("tier-off report rendered an nvme row".into());
            }
            Ok(())
        },
    );
}

/// A 3-tier run on the RAM-starved NVME-LAB box is deterministic, holds
/// its pinned staging leases across both hops of every staged copy
/// (leak_check clean), actually moves bytes through the tier in both
/// directions, and bills the NVMe lane as its own breakdown phase.
#[test]
fn nvme_three_tier_run_is_deterministic_and_lease_clean() {
    let plan = OptimizationPlan {
        nvme_gb: 64,
        ..OptimizationPlan::pinned_pipeline()
    };
    let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 1);
    let go = || {
        Engine::new(ClusterPreset::nvme_lab(), task)
            .with_opt(plan)
            .run_traced()
            .expect("1B must train on NVME-LAB with a 64 GB tier")
    };
    let (r1, t1) = go();
    let (r2, t2) = go();
    assert_eq!(t1, t2, "3-tier trace not deterministic");
    assert_eq!(format!("{r1:?}"), format!("{r2:?}"),
               "3-tier report not deterministic");
    assert_eq!(r1.move_stats.lease_leaks, 0,
               "staged two-hop copies leaked pinned leases");
    assert!(r1.nvme_peak > 0, "tier granted but never occupied");
    assert!(r1.move_stats.to_nvme_bytes > 0, "nothing spilled to NVMe");
    assert!(r1.move_stats.from_nvme_bytes > 0,
            "nothing staged back from NVMe");
    assert!(r1.move_stats.to_nvme_moves > 0);
    assert!(r1.move_stats.from_nvme_moves > 0);
    assert!(r1.breakdown.get(Phase::Nvme) > 0.0,
            "NVMe lane time must be billed on its own phase");
    let text = r1.render();
    assert!(text.contains("nvme tier:"),
            "3-tier report must render the nvme row:\n{text}");
}

/// Collective wire volume is a function of the chunk layout alone: the
/// overlapped 3-tier run, the serial 3-tier run and a serial two-tier
/// run on a roomy cluster all move bit-for-bit the same collective
/// bytes (the tier reroutes PCIe/NVMe traffic, never collectives).
#[test]
fn nvme_tier_never_changes_collective_wire_volume() {
    // Fixed chunk size so all three runs share one layout; 2B on two
    // ranks overflows NVME-LAB's 6 GB DRAM + 6 GB GPU, so the 3-tier
    // runs genuinely exercise the NVMe path.
    let task = TrainTask::new(GptSpec::by_name("2B").unwrap(), 2, 2)
        .with_chunk_elems(32 << 20);
    let three = OptimizationPlan {
        nvme_gb: 64,
        ..OptimizationPlan::pinned_pipeline()
    };
    let overlapped = Engine::new(ClusterPreset::nvme_lab(), task)
        .with_opt(three)
        .run()
        .expect("overlapped 3-tier run");
    let serial3 = Engine::new(ClusterPreset::nvme_lab(), task)
        .with_opt(OptimizationPlan { nvme_gb: 64, ..Default::default() })
        .run()
        .expect("serial 3-tier run");
    let serial2 = Engine::new(ClusterPreset::yard(), task)
        .run()
        .expect("serial two-tier run");
    assert!(overlapped.nvme_peak > 0, "3-tier run never used the tier");
    assert!(overlapped.allgather_bytes > 0);
    assert_eq!(overlapped.allgather_bytes, serial3.allgather_bytes);
    assert_eq!(overlapped.reduce_scatter_bytes,
               serial3.reduce_scatter_bytes);
    assert_eq!(serial3.allgather_bytes, serial2.allgather_bytes);
    assert_eq!(serial3.reduce_scatter_bytes, serial2.reduce_scatter_bytes);
}

#[test]
fn nproc_sweep_is_deterministic_under_the_adaptive_cell() {
    // The heaviest policy path (adaptive controller + ledger + pinned
    // pool + collective stream) stays bit-stable at every process
    // count the paper sweeps.
    for gpus in [1u32, 2, 4, 8] {
        let plan = OptimizationPlan::adaptive_pipeline();
        let (r1, t1) = run_traced_for(plan, "1B", 4, gpus);
        let (r2, t2) = run_traced_for(plan, "1B", 4, gpus);
        assert_eq!(t1, t2, "nproc {gpus}: trace not deterministic");
        assert_eq!(format!("{r1:?}"), format!("{r2:?}"),
                   "nproc {gpus}: report not deterministic");
        assert!(r1.iter_time_s > 0.0);
    }
}

// ---------------------------------------------------------------------
// 5. Eviction order invariance (ISSUE 8 satellite)
// ---------------------------------------------------------------------
//
// A policy's victim must be a pure function of the candidate *set*:
// the manager happens to pass id-sorted slices today, but nothing in
// the `EvictionPolicy` contract promises that, and a pick that depends
// on slice order (or on the insertion order of the droppable set)
// would silently diverge the moment a caller builds candidates
// differently.  Every policy is therefore driven over random
// permutations of the same set and must return the same victim.

use patrickstar::chunk::{ChunkId, ChunkRegistry, TensorSpec};
use patrickstar::evict::{BacklogAwareOpt, EvictionPolicy, FifoPolicy,
                         LfuPolicy, LruPolicy, OptPolicy, TierAwareOpt,
                         TierPricing};
use patrickstar::mem::{Device, Interconnect};
use patrickstar::tracer::MemTracer;
use std::collections::BTreeSet;

#[test]
fn property_eviction_pick_is_candidate_order_invariant() {
    forall(
        150,
        |rng| {
            let n = rng.range(2, 24);
            // Random next-use schedule with deliberate collisions
            // (range 0..n/2 forces equal keys) so tie-breaks are
            // actually exercised, plus some never-used-again chunks.
            let uses: Vec<Option<u32>> = (0..n)
                .map(|_| {
                    if rng.chance(0.2) {
                        None
                    } else {
                        Some((rng.range(1, 2 + n / 2) * 3) as u32)
                    }
                })
                .collect();
            // Droppable subset, in random insertion order.
            let mut drop_order: Vec<u32> =
                (0..n as u32).filter(|_| rng.chance(0.4)).collect();
            rng.shuffle(&mut drop_order);
            let margin = rng.range(0, 7) as u32;
            let now = rng.range(0, 4) as u32;
            let seed = rng.next_u64();
            (uses, drop_order, margin, now, seed)
        },
        |(uses, drop_order, margin, now, seed)| {
            let n = uses.len();
            let mut t = MemTracer::new(n);
            for (i, u) in uses.iter().enumerate() {
                if let Some(m) = u {
                    t.record_chunk_use(ChunkId(i as u32), *m);
                }
            }
            t.finish_warmup();
            let droppable: BTreeSet<ChunkId> =
                drop_order.iter().map(|&i| ChunkId(i)).collect();
            // Real chunk metadata for the priced policy (uniform
            // sizes: the price tie-chain falls through to next-use
            // then id, the hardest case for order dependence).
            let specs: Vec<TensorSpec> = (0..n)
                .map(|i| TensorSpec {
                    name: format!("t{i}"),
                    numel: 50,
                    embedding: false,
                })
                .collect();
            let chunks =
                ChunkRegistry::build(&specs, 50).unwrap().chunks;
            let pricing =
                TierPricing::from_net(&Interconnect::v100_node());

            // History-based policies see accesses in random order too.
            let mut rng = patrickstar::util::Rng::new(*seed);
            let mut access: Vec<u32> = (0..n as u32).collect();
            rng.shuffle(&mut access);
            let mut fifo = FifoPolicy::default();
            let mut lru = LruPolicy::default();
            let mut lfu = LfuPolicy::default();
            for (k, &c) in access.iter().enumerate() {
                // Collide LRU stamps/LFU counts across chunks by
                // re-accessing: k % 3 extra touches.
                for _ in 0..=(k % 3) {
                    fifo.on_access(ChunkId(c), k as u32);
                    lru.on_access(ChunkId(c), k as u32);
                    lfu.on_access(ChunkId(c), k as u32);
                }
            }

            let base: Vec<ChunkId> =
                (0..n as u32).map(ChunkId).collect();
            let mut policies: Vec<(&str, Box<dyn FnMut(&[ChunkId])
                -> Option<ChunkId> + '_>)> = vec![
                ("opt", Box::new(|c: &[ChunkId]| {
                    OptPolicy { tracer: &t }.pick(c, &chunks, *now)
                })),
                ("opt+backlog", Box::new(|c: &[ChunkId]| {
                    BacklogAwareOpt {
                        tracer: &t,
                        droppable: droppable.clone(),
                        margin: *margin,
                    }
                    .pick(c, &chunks, *now)
                })),
                ("opt+tier", Box::new(|c: &[ChunkId]| {
                    TierAwareOpt {
                        tracer: &t,
                        droppable: droppable.clone(),
                        margin: *margin,
                        pricing,
                        spill_to: Device::Nvme,
                    }
                    .pick(c, &chunks, *now)
                })),
                ("fifo", Box::new(|c: &[ChunkId]| {
                    fifo.pick(c, &chunks, *now)
                })),
                ("lru", Box::new(|c: &[ChunkId]| {
                    lru.pick(c, &chunks, *now)
                })),
                ("lfu", Box::new(|c: &[ChunkId]| {
                    lfu.pick(c, &chunks, *now)
                })),
            ];

            for (name, pick) in policies.iter_mut() {
                let reference = pick(&base);
                if reference.is_none() {
                    return Err(format!(
                        "{name}: no victim from {n} candidates"
                    ));
                }
                let mut perm = base.clone();
                for _ in 0..6 {
                    rng.shuffle(&mut perm);
                    let got = pick(&perm);
                    if got != reference {
                        return Err(format!(
                            "{name}: pick {got:?} != {reference:?} \
                             for permutation {perm:?} of {base:?} \
                             (droppable {droppable:?}, margin \
                             {margin}, now {now})"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// 6. Elastic re-scaling (ISSUE 9)
// ---------------------------------------------------------------------
//
// A shrink re-shards every chunk group across the smaller world at an
// iteration boundary.  Two conservation contracts pin it down:
//
// * **Payload conservation** — every moved shard ships its full owned
//   state (fp16 + three fp32 lists = 7x the fp16 chunk bytes) exactly
//   once; the re-shard is a permutation route, so the event's wire
//   bytes equal the payload sum with no ring amplification.
// * **Steady-state wire volume** — after the rescale, the measured
//   iteration's collective volume is bit-identical to a run that was
//   *born* at the new world size with the same chunk layout: volume
//   is a function of (layout, world) alone, never of the path taken
//   to reach that world.
//
// (Chunk-coverage conservation of the re-shard map itself — every
// position owned exactly once at both world sizes — is a pure-function
// property and lives next to `CommGroups::reshard_moves` in
// `dp/group.rs`.)

use patrickstar::engine::ElasticPlan;

#[test]
fn property_elastic_shrink_conserves_payload_and_wire_volume() {
    forall(
        6,
        |rng| {
            let model = ["1B", "2B"][rng.range(0, 2)];
            let p = [2u32, 4, 8][rng.range(0, 3)];
            let to = rng.range(1, p as usize) as u32;
            (model, p, to)
        },
        |&(model, p, to)| {
            let chunk = 32u64 << 20;
            let task = TrainTask::new(
                GptSpec::by_name(model).unwrap(), 4, p)
                .with_chunk_elems(chunk);
            let spec = format!("shrink@iter=1:to={to}");
            let go = || {
                Engine::new(ClusterPreset::yard(), task)
                    .with_opt(OptimizationPlan::pinned_pipeline())
                    .with_elastic(ElasticPlan::parse(&spec).unwrap())
                    .run()
                    .map_err(|e| format!("elastic {model} {p}->{to}: {e}"))
            };
            let r1 = go()?;
            let r2 = go()?;
            if format!("{r1:?}") != format!("{r2:?}") {
                return Err(format!(
                    "elastic {model} {p}->{to}: replay diverged"
                ));
            }
            if r1.rescales.len() != 1 {
                return Err(format!(
                    "elastic {model} {p}->{to}: {} rescale events",
                    r1.rescales.len()
                ));
            }
            let ev = &r1.rescales[0];
            if (ev.from, ev.to) != (p as usize, to as usize) {
                return Err(format!(
                    "elastic {model}: event {} -> {}, want {p} -> {to}",
                    ev.from, ev.to
                ));
            }
            if ev.moved_bytes != ev.moved_shards as u64 * 7 * 2 * chunk {
                return Err(format!(
                    "elastic {model} {p}->{to}: {} shards moved {} B, \
                     payload conservation wants {} B",
                    ev.moved_shards,
                    ev.moved_bytes,
                    ev.moved_shards as u64 * 7 * 2 * chunk
                ));
            }
            // The measured iteration ran at world `to`: its collective
            // wire volume must match a run born at `to` ranks.
            let native = Engine::new(
                ClusterPreset::yard(),
                TrainTask::new(GptSpec::by_name(model).unwrap(), 4, to)
                    .with_chunk_elems(chunk),
            )
            .with_opt(OptimizationPlan::pinned_pipeline())
            .run()
            .map_err(|e| format!("native {model} @ {to}: {e}"))?;
            if r1.allgather_bytes != native.allgather_bytes {
                return Err(format!(
                    "elastic {model} {p}->{to}: allgather volume {} != \
                     native {}",
                    r1.allgather_bytes, native.allgather_bytes
                ));
            }
            if r1.reduce_scatter_bytes != native.reduce_scatter_bytes {
                return Err(format!(
                    "elastic {model} {p}->{to}: reduce-scatter volume \
                     {} != native {}",
                    r1.reduce_scatter_bytes, native.reduce_scatter_bytes
                ));
            }
            Ok(())
        },
    );
}
