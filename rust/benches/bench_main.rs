//! Bench harness: regenerates every table and figure of the paper's
//! evaluation (Sec. 9) plus micro-benchmarks of the L3 hot paths.
//!
//! criterion is not in the offline crate cache (DESIGN.md §6.6), so this
//! is a `harness = false` binary: `cargo bench` runs everything;
//! `cargo bench -- fig13 table5` runs a subset.  Output is the text
//! analogue of each paper exhibit, with the paper's reported values
//! quoted for comparison.  Results are summarized in EXPERIMENTS.md.

use std::time::Instant;

use patrickstar::baselines::run_system;
use patrickstar::chunk::{search_chunk_size, ChunkKind, ChunkManager,
                         ChunkRegistry, TensorSpec};
use patrickstar::config::{ClusterPreset, SystemKind, TrainTask};
use patrickstar::engine::{Engine, EvictKind, OptimizationPlan};
use patrickstar::evict::{EvictionPolicy, LruPolicy, OptPolicy};
use patrickstar::mem::{Device, HeterogeneousSpace};
use patrickstar::model::{ActivationPlan, FootprintTimeline, GptSpec};
use patrickstar::scale::{best_over_batches, max_model_scale,
                         max_model_scale_ladder};
use patrickstar::sim::Phase;
use patrickstar::tracer::MemTracer;
use patrickstar::util::{human_bytes, Json, Rng, Table};

fn main() {
    let filters: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let want = |name: &str| {
        filters.is_empty() || filters.iter().any(|f| name.contains(f))
    };
    let benches: &[(&str, fn())] = &[
        ("table2", table2),
        ("fig2", fig2),
        ("table3", table3),
        ("fig12", fig12),
        ("fig13", fig13),
        ("fig14", fig14),
        ("fig15", fig15),
        ("fig16_table4", fig16_table4),
        ("table5", table5),
        ("fig17", fig17),
        ("fig18", fig18),
        ("fig19_pc", fig19_pc),
        ("ablation_eviction", ablation_eviction),
        ("prefetch_overlap", prefetch_overlap),
        ("collective_overlap", collective_overlap),
        ("pinned_pool", pinned_pool),
        ("adaptive_lookahead", adaptive_lookahead),
        ("nvme_offload", nvme_offload),
        ("micro_hotpaths", micro_hotpaths),
    ];
    for (name, f) in benches {
        if want(name) {
            println!("\n################ {name} ################");
            let t0 = Instant::now();
            f();
            println!("[{name} took {:.2}s]", t0.elapsed().as_secs_f64());
        }
    }
}

// =====================================================================
// Table 2 — model configurations
// =====================================================================
fn table2() {
    let mut t = Table::new(&["model", "layers", "hidden", "analytic params"]);
    for m in GptSpec::table2() {
        t.row(vec![
            m.name.into(),
            m.layers.to_string(),
            m.hidden.to_string(),
            format!("{:.2}B", m.n_params() as f64 / 1e9),
        ]);
    }
    print!("{}", t.render());
    println!("paper: head 16, seq 1024, hidden dims as listed.");
}

// =====================================================================
// Fig. 2 — non-model footprint of a 6B model, batch 16, 4 iterations
// =====================================================================
fn fig2() {
    let m = GptSpec::by_name("6B").unwrap();
    let mut t =
        Table::new(&["plan", "peak", "mean", "min", "samples/iter"]);
    for plan in ActivationPlan::ALL {
        let tl = FootprintTimeline::generate(&m, 16, plan, 4);
        let peak = tl.peak();
        let mean =
            tl.samples.iter().sum::<u64>() / tl.samples.len() as u64;
        let min = *tl.samples.iter().min().unwrap();
        t.row(vec![
            plan.name().into(),
            human_bytes(peak),
            human_bytes(mean),
            human_bytes(min),
            (tl.samples.len() / 4).to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "paper Fig. 2: ckpt+offload still peaks close to 5 GB on this \
         task; plans order none > ckpt > ckpt+offload."
    );
}

// =====================================================================
// Table 3 — chunk size search results
// =====================================================================
fn table3() {
    let cases = [
        ("YARD", ClusterPreset::yard(), vec!["10B", "15B", "18B"]),
        ("SuperPod", ClusterPreset::superpod(),
         vec!["20B", "40B", "60B", "68B"]),
    ];
    let mut t = Table::new(&["cluster", "model", "chunk (Mi elems)",
                             "util %"]);
    for (name, cluster, models) in cases {
        let budget =
            cluster.cpu_mem + cluster.n_gpus as u64 * cluster.gpu_mem;
        for model in models {
            let m = GptSpec::by_name(model).unwrap();
            match search_chunk_size(&m.tensor_specs(), budget) {
                Some(res) => {
                    t.row(vec![
                        name.into(),
                        model.into(),
                        (res.best.chunk_elems >> 20).to_string(),
                        format!("{:.2}", 100.0 * res.best.utilization),
                    ]);
                }
                None => {
                    t.row(vec![name.into(), model.into(), "-".into(),
                               "-".into()]);
                }
            }
        }
    }
    print!("{}", t.render());
    println!(
        "paper Table 3: chunk sizes 288-480, util 90.5-97.4%, \
         fragmentation < 10%."
    );
}

// =====================================================================
// Fig. 12 — chunk size vs utilization and throughput
// =====================================================================
fn fig12() {
    let cases = [
        (ClusterPreset::yard(), "15B"),
        (ClusterPreset::superpod(), "50B"),
    ];
    for (cluster, model) in cases {
        let m = GptSpec::by_name(model).unwrap();
        println!("--- {} {model}, 8 GPU, batch 8 ---", cluster.name);
        let mut t = Table::new(&["chunk (Mi elems)", "util %",
                                 "tflops/GPU"]);
        for q in (128..=512u64).step_by(64) {
            let chunk = q << 20;
            let task = TrainTask::new(m, 8, 8).with_chunk_elems(chunk);
            let util = patrickstar::chunk::search::evaluate(
                &m.tensor_specs(), chunk, 0)
                .map(|c| c.utilization)
                .unwrap_or(0.0);
            match Engine::new(cluster, task).run() {
                Ok(r) => t.row(vec![
                    q.to_string(),
                    format!("{:.1}", 100.0 * util),
                    format!("{:.1}", r.tflops_per_gpu),
                ]),
                Err(_) => t.row(vec![q.to_string(),
                                     format!("{:.1}", 100.0 * util),
                                     "infeasible".into()]),
            };
        }
        print!("{}", t.render());
    }
    println!(
        "paper Fig. 12: feasible sizes have util > 80% and similar \
         throughput; some sizes infeasible on 50B (search is necessary)."
    );
}

// =====================================================================
// Fig. 13 — max model scale
// =====================================================================
fn fig13() {
    let mut t = Table::new(&["cluster", "gpus", "system", "max model",
                             "tflops/GPU"]);
    for cluster in [ClusterPreset::yard(), ClusterPreset::superpod()] {
        for gpus in [1u32, 2, 4, 8] {
            for system in [
                SystemKind::PyTorchDdp,
                SystemKind::DeepSpeedDp,
                SystemKind::DeepSpeedMp(gpus),
                SystemKind::PatrickStar,
            ] {
                if matches!(system, SystemKind::DeepSpeedMp(1)) {
                    continue;
                }
                match max_model_scale(system, cluster, gpus) {
                    Some(p) => {
                        let r = p.best.unwrap();
                        t.row(vec![
                            cluster.name.into(),
                            gpus.to_string(),
                            system.name(),
                            p.model.into(),
                            format!("{:.1}", r.tflops_per_gpu),
                        ]);
                    }
                    None => {
                        t.row(vec![
                            cluster.name.into(),
                            gpus.to_string(),
                            system.name(),
                            "-".into(),
                            "-".into(),
                        ]);
                    }
                };
            }
        }
    }
    print!("{}", t.render());
    println!(
        "paper Fig. 13: YARD 8g — PyTorch 1B / DeepSpeed-DP 4B / \
         DeepSpeed-MP 8B / PatrickStar 18B (2.25x MP); SuperPod 8g — \
         DeepSpeed 30B / PatrickStar 68B (2.27x).  Known deviation: our \
         honest per-GPU flops accounting keeps deeps-mp below the \
         throughput bar (see EXPERIMENTS.md)."
    );
}

// =====================================================================
// Fig. 14 — single-GPU throughput vs model and batch size
// =====================================================================
fn fig14() {
    for cluster in [ClusterPreset::yard(), ClusterPreset::superpod()] {
        println!("--- {} (1 GPU) ---", cluster.name);
        let models: &[&str] = if cluster.name == "YARD" {
            &["1B", "2B", "4B", "6B", "8B"]
        } else {
            &["1B", "4B", "6B", "10B", "15B"]
        };
        let mut t = Table::new(&["model", "batch", "pytorch", "deepspeed",
                                 "patrickstar"]);
        for model in models {
            let m = GptSpec::by_name(model).unwrap();
            for batch in [4u64, 16, 32, 64] {
                let cell = |system| {
                    let task = TrainTask::new(m, batch, 1);
                    match run_system(system, cluster, task) {
                        Ok(r) => format!("{:.1}", r.tflops_per_gpu),
                        Err(_) => "x".into(),
                    }
                };
                t.row(vec![
                    model.to_string(),
                    batch.to_string(),
                    cell(SystemKind::PyTorchDdp),
                    cell(SystemKind::DeepSpeedDp),
                    cell(SystemKind::PatrickStar),
                ]);
            }
        }
        print!("{}", t.render());
    }
    println!(
        "paper Fig. 14: PatrickStar >= DeepSpeed everywhere; PyTorch \
         fastest where it fits (1B) but OOMs beyond; PatrickStar \
         supports larger batches at every size."
    );
}

// =====================================================================
// Fig. 15 — multi-GPU throughput on YARD
// =====================================================================
fn fig15() {
    multi_gpu_throughput(ClusterPreset::yard(),
                         &["1B", "2B", "4B", "8B", "12B", "18B"]);
    println!(
        "paper Fig. 15: PatrickStar 1.08-1.47x (avg 1.23x) over \
         DeepSpeed-DP; only PatrickStar trains 8B-18B with DP alone; \
         419 Tflops on 18B/8g = 94% of the 1B 444 Tflops."
    );
}

// =====================================================================
// Fig. 17 — multi-GPU throughput on SuperPod
// =====================================================================
fn fig17() {
    multi_gpu_throughput(ClusterPreset::superpod(),
                         &["6B", "10B", "20B", "30B", "50B", "68B"]);
    println!(
        "paper Fig. 17: speedup over DeepSpeed 1.07-2.43x (avg 1.53x); \
         857 Tflops on 68B/8g = 73% of the 6B 1180 Tflops."
    );
}

fn multi_gpu_throughput(cluster: ClusterPreset, models: &[&str]) {
    println!("--- {} best-batch total Tflops ---", cluster.name);
    let mut t = Table::new(&["model", "gpus", "pytorch", "deeps-dp",
                             "deeps-mp", "patrickstar", "ps/deeps"]);
    for model in models {
        let m = GptSpec::by_name(model).unwrap();
        for gpus in [1u32, 2, 4, 8] {
            let probe = |system| {
                best_over_batches(system, cluster, m, gpus)
                    .best
                    .map(|r| r.total_tflops())
            };
            let fmt = |x: Option<f64>| {
                x.map(|v| format!("{v:.0}")).unwrap_or_else(|| "x".into())
            };
            let ps = probe(SystemKind::PatrickStar);
            let ds = probe(SystemKind::DeepSpeedDp);
            let ratio = match (ps, ds) {
                (Some(a), Some(b)) if b > 0.0 => format!("{:.2}", a / b),
                _ => "-".into(),
            };
            t.row(vec![
                model.to_string(),
                gpus.to_string(),
                fmt(probe(SystemKind::PyTorchDdp)),
                fmt(ds),
                fmt(if gpus > 1 {
                    probe(SystemKind::DeepSpeedMp(gpus))
                } else {
                    None
                }),
                fmt(ps),
                ratio,
            ]);
        }
    }
    print!("{}", t.render());
}

// =====================================================================
// Fig. 16 + Table 4 — optimization ablation breakdown
// =====================================================================
fn fig16_table4() {
    let cases = [
        (ClusterPreset::superpod(), "10B", 1u32),
        (ClusterPreset::superpod(), "10B", 8),
        (ClusterPreset::superpod(), "50B", 1),
        (ClusterPreset::superpod(), "50B", 8),
        (ClusterPreset::yard(), "12B", 1),
        (ClusterPreset::yard(), "12B", 8),
    ];
    let mut t4 = Table::new(&["case", "margin(+)/spill(-)"]);
    for (cluster, model, gpus) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, 8, gpus);
        println!("--- {} {model} {gpus}g ---", cluster.name);
        let mut t = Table::new(&["plan", "total s", "fwd+bwd", "adam",
                                 "collectives", "chunk-moves",
                                 "adam-moves"]);
        let mut base_total = None;
        for (label, opt) in [
            ("Base", OptimizationPlan::default()),
            ("OSC", OptimizationPlan::os_on_cpu()),
            ("SP", OptimizationPlan::static_partition()),
        ] {
            match Engine::new(cluster, task).with_opt(opt).run() {
                Ok(r) => {
                    if label == "Base" {
                        base_total = Some(r.iter_time_s);
                        t4.row(vec![
                            format!("{} {model} {gpus}g", cluster.name),
                            format!("{:+}", r.placement.margin_or_spill()),
                        ]);
                    }
                    let rel = base_total
                        .map(|b| format!(" ({:.1}x)", r.iter_time_s / b))
                        .unwrap_or_default();
                    t.row(vec![
                        format!("{gpus}g{label}"),
                        format!("{:.2}{rel}", r.iter_time_s),
                        format!("{:.2}", r.breakdown.get(Phase::FwdBwd)),
                        format!("{:.2}", r.breakdown.get(Phase::Adam)),
                        format!(
                            "{:.2}",
                            r.breakdown.get(Phase::AllGather)
                                + r.breakdown.get(Phase::ReduceScatter)
                        ),
                        format!(
                            "{:.2}",
                            r.breakdown.get(Phase::CpuToGpu)
                                + r.breakdown.get(Phase::GpuToCpu)
                        ),
                        format!("{:.2}", r.breakdown.get(Phase::AdamMove)),
                    ]);
                }
                Err(e) => {
                    t.row(vec![format!("{gpus}g{label}"),
                               format!("infeasible: {e}"), "-".into(),
                               "-".into(), "-".into(), "-".into(),
                               "-".into()]);
                }
            }
        }
        print!("{}", t.render());
    }
    println!("=== Table 4 ===");
    print!("{}", t4.render());
    println!(
        "paper: 8gBase 6.9x faster than 8gSP (10B SuperPod); 8gBase 1.3x \
         faster than 8gOSC (12B YARD); Table 4 margins +2/+6/-20/+1/-1/+5."
    );
}

// =====================================================================
// Table 5 — achieved collective bandwidth
// =====================================================================
fn table5() {
    let cases = [
        (ClusterPreset::superpod(), "10B"),
        (ClusterPreset::superpod(), "50B"),
        (ClusterPreset::yard(), "12B"),
    ];
    let mut t = Table::new(&["cluster", "model", "allgather GB/s",
                             "reduce-scatter GB/s", "saturated GB/s",
                             "ratio"]);
    for (cluster, model) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, 8, 8);
        match Engine::new(cluster, task).run() {
            Ok(r) => {
                let sat = cluster.net.nvlink.peak_bps / 1e9;
                t.row(vec![
                    cluster.name.into(),
                    model.into(),
                    format!("{:.1}", r.allgather_bw / 1e9),
                    format!("{:.1}", r.reduce_scatter_bw / 1e9),
                    format!("{sat:.1}"),
                    format!("{:.0}%", 100.0 * r.allgather_bw / 1e9 / sat),
                ]);
            }
            Err(e) => {
                t.row(vec![cluster.name.into(), model.into(),
                           format!("err: {e}"), "-".into(), "-".into(),
                           "-".into()]);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "paper Table 5: achieved >= 75% of saturated bandwidth on both \
         clusters (chunked transfers are inherently bucketized)."
    );
}

// =====================================================================
// Fig. 18 — scalability
// =====================================================================
fn fig18() {
    for (cluster, models) in [
        (ClusterPreset::yard(), ["1B", "4B", "12B"]),
        (ClusterPreset::superpod(), ["6B", "20B", "50B"]),
    ] {
        println!("--- {} speedup vs 1 GPU ---", cluster.name);
        let mut t = Table::new(&["model", "1g", "2g", "4g", "8g",
                                 "8g speedup"]);
        for model in models {
            let m = GptSpec::by_name(model).unwrap();
            let tput = |gpus| {
                best_over_batches(SystemKind::PatrickStar, cluster, m, gpus)
                    .best
                    .map(|r| r.total_tflops())
            };
            let t1 = tput(1);
            let ts: Vec<Option<f64>> =
                [1u32, 2, 4, 8].iter().map(|&g| tput(g)).collect();
            let fmt = |x: &Option<f64>| {
                x.map(|v| format!("{v:.0}")).unwrap_or_else(|| "x".into())
            };
            let speedup = match (t1, ts[3]) {
                (Some(a), Some(b)) if a > 0.0 => format!("{:.2}x", b / a),
                _ => "-".into(),
            };
            t.row(vec![
                model.to_string(),
                fmt(&ts[0]),
                fmt(&ts[1]),
                fmt(&ts[2]),
                fmt(&ts[3]),
                speedup,
            ]);
        }
        print!("{}", t.render());
    }
    println!(
        "paper Fig. 18: superlinear scaling for large models (more \
         aggregate GPU memory => fewer CPU round trips)."
    );
}

// =====================================================================
// Fig. 19 + 700$-PC — lower hardware requirements
// =====================================================================
fn fig19_pc() {
    println!("--- Fig 19: 8x V100, CPU memory reduced to 120 GB ---");
    let mut t = Table::new(&["system", "max model", "tflops/GPU"]);
    for system in [SystemKind::DeepSpeedDp, SystemKind::DeepSpeedMp(8),
                   SystemKind::PatrickStar] {
        match max_model_scale(system, ClusterPreset::yard_120gb(), 8) {
            Some(p) => {
                let r = p.best.unwrap();
                t.row(vec![system.name(), p.model.into(),
                           format!("{:.1}", r.tflops_per_gpu)]);
            }
            None => {
                t.row(vec![system.name(), "-".into(), "-".into()]);
            }
        };
    }
    print!("{}", t.render());
    println!("paper: PatrickStar 8B @ 48.78; DeepSpeed-MP 4B @ 32.32.");

    println!("--- Sec 9.2.5: 700$ PC (RTX 2060 8 GB + 16 GB DRAM) ---");
    let ladder = GptSpec::pc_models();
    let mut t = Table::new(&["system", "max model", "tflops"]);
    for system in [SystemKind::PyTorchDdp, SystemKind::DeepSpeedDp,
                   SystemKind::PatrickStar] {
        match max_model_scale_ladder(system, ClusterPreset::pc(), 1,
                                     &ladder) {
            Some(p) => {
                let r = p.best.unwrap();
                t.row(vec![system.name(), p.model.into(),
                           format!("{:.1}", r.tflops_per_gpu)]);
            }
            None => {
                t.row(vec![system.name(), "-".into(), "-".into()]);
            }
        };
    }
    print!("{}", t.render());
    println!(
        "paper: PatrickStar trains 0.7B @ 18.46 Tflops; PyTorch/DeepSpeed \
         cap at 0.11B."
    );
}

// =====================================================================
// Ablation: eviction policies (DESIGN.md §5 ablation benches)
// =====================================================================
fn ablation_eviction() {
    let cluster = ClusterPreset::yard();
    let m = GptSpec::by_name("12B").unwrap();
    let task = TrainTask::new(m, 8, 1);
    let mut t = Table::new(&["policy", "iter s", "c2g moved", "g2c moved",
                             "evictions"]);
    for evict in [EvictKind::Opt, EvictKind::Lru, EvictKind::Fifo,
                  EvictKind::Lfu] {
        let opt = OptimizationPlan { eviction: evict, ..Default::default() };
        match Engine::new(cluster, task).with_opt(opt).run() {
            Ok(r) => {
                t.row(vec![
                    format!("{evict:?}"),
                    format!("{:.2}", r.iter_time_s),
                    human_bytes(r.move_stats.cpu_to_gpu_bytes),
                    human_bytes(r.move_stats.gpu_to_cpu_bytes),
                    r.move_stats.evictions.to_string(),
                ]);
            }
            Err(e) => {
                t.row(vec![format!("{evict:?}"), format!("err {e}"),
                           "-".into(), "-".into(), "-".into()]);
            }
        }
    }
    print!("{}", t.render());
    println!(
        "paper Sec. 8.3: the OPT (Belady) policy using warm-up moment \
         lists should move no more bytes than any history-based policy."
    );
}

// =====================================================================
// Prefetch + overlap pipeline ablation (ISSUE 1 tentpole)
// =====================================================================
//
// Serial vs overlap-only vs prefetch+overlap on transfer-bound configs
// (the fig12/fig13 model scales whose fp16 working set spills on one
// node).  Emits machine-readable BENCH_prefetch.json (name/value/unit
// entries, github-action-benchmark "customSmallerIsBetter" style) so the
// perf trajectory is tracked across PRs.
fn prefetch_overlap() {
    // Single-GPU cells of the fig12/fig13 scales are the transfer-bound
    // ones (every CPU-ADAM grad chunk crosses PCIe twice per iteration,
    // plus spill churn on 15B/50B); the 8-GPU cell tracks the
    // distributed story where collectives dominate instead.
    let cases = [
        (ClusterPreset::yard(), "12B", 1u32, 8u64),
        (ClusterPreset::yard(), "15B", 1, 8),
        (ClusterPreset::superpod(), "50B", 1, 8),
        (ClusterPreset::yard(), "15B", 8, 8),
    ];
    let mut entries: Vec<Json> = Vec::new();
    let mut push = |name: String, value: f64, unit: &str| {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]));
    };
    for (cluster, model, gpus, batch) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, batch, gpus);
        let case = format!("{}_{model}_{gpus}g", cluster.name);
        println!("--- {case} ---");
        let mut t = Table::new(&["plan", "iter s", "exposed", "overlapped",
                                 "c2g+g2c moved", "prefetches"]);
        let mut serial: Option<patrickstar::engine::EngineReport> = None;
        for (label, opt) in [
            ("serial", OptimizationPlan::default()),
            ("overlap", OptimizationPlan::overlap_only()),
            ("pf+ov", OptimizationPlan::pipelined()),
        ] {
            match Engine::new(cluster, task).with_opt(opt).run() {
                Ok(r) => {
                    let vol = r.move_stats.cpu_to_gpu_bytes
                        + r.move_stats.gpu_to_cpu_bytes;
                    t.row(vec![
                        label.into(),
                        format!("{:.2}", r.iter_time_s),
                        format!(
                            "{:.2}", r.breakdown.exposed_transfer_s),
                        format!(
                            "{:.2}", r.breakdown.overlapped_transfer_s),
                        human_bytes(vol),
                        r.move_stats.prefetches.to_string(),
                    ]);
                    push(format!("{case}/{label}_iter_s"),
                         r.iter_time_s, "s");
                    push(format!("{case}/{label}_moved_bytes"),
                         vol as f64, "B");
                    if label == "serial" {
                        serial = Some(r);
                    } else if let Some(base) = &serial {
                        let speedup = base.iter_time_s / r.iter_time_s;
                        println!(
                            "{label}: {:.2}x vs serial, volume {}",
                            speedup,
                            if vol
                                <= base.move_stats.cpu_to_gpu_bytes
                                    + base.move_stats.gpu_to_cpu_bytes
                            {
                                "not increased"
                            } else {
                                "INCREASED (regression!)"
                            },
                        );
                        push(format!("{case}/{label}_speedup"),
                             speedup, "x");
                    }
                }
                Err(e) => {
                    t.row(vec![label.into(), format!("err {e}"),
                               "-".into(), "-".into(), "-".into(),
                               "-".into()]);
                }
            }
        }
        print!("{}", t.render());
    }
    let json = Json::Arr(entries).to_string_pretty();
    match std::fs::write("BENCH_prefetch.json", json) {
        Ok(()) => println!("wrote BENCH_prefetch.json"),
        Err(e) => println!("could not write BENCH_prefetch.json: {e}"),
    }
    println!(
        "acceptance: pf+ov speedup >= 1.10x on at least two configs with \
         moved bytes not increased; serial reproduces the pre-pipeline \
         breakdown."
    );
}

// =====================================================================
// Collective-stream overlap ablation (ISSUE 2 tentpole)
// =====================================================================
//
// Serial vs collective-stream (group-level lookahead gathers + draining
// reduce-scatters) on nproc >= 2 configs where all-gather/reduce-scatter
// sit on the critical path.  The contract measured here:
//
//   * exposed collective time drops with the stream on (and is
//     non-increasing in --group-lookahead);
//   * total all-gather/reduce-scatter byte volume is EXACTLY unchanged —
//     the pipeline moves collectives on the clock, never on the wire.
//
// Emits BENCH_collectives.json (name/value/unit entries) next to
// BENCH_prefetch.json so the distributed perf trajectory is tracked
// across PRs.
fn collective_overlap() {
    let cases = [
        (ClusterPreset::yard(), "4B", 2u32, 8u64),
        (ClusterPreset::yard(), "8B", 4, 8),
        (ClusterPreset::yard(), "15B", 8, 8),
        (ClusterPreset::superpod(), "50B", 8, 8),
    ];
    let mut entries: Vec<Json> = Vec::new();
    let mut push = |name: String, value: f64, unit: &str| {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]));
    };
    let coll_volume = |r: &patrickstar::engine::EngineReport| {
        r.allgather_bytes + r.reduce_scatter_bytes
    };
    for (cluster, model, gpus, batch) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, batch, gpus);
        let case = format!("{}_{model}_{gpus}g", cluster.name);
        println!("--- {case} ---");
        let mut t = Table::new(&["plan", "iter s", "coll exposed",
                                 "coll overlapped", "coll volume",
                                 "gathers ahead"]);
        let serial = match Engine::new(cluster, task).run() {
            Ok(r) => r,
            Err(e) => {
                println!("infeasible: {e}");
                continue;
            }
        };
        let serial_exposed = serial.breakdown.critical_collective_s();
        t.row(vec![
            "serial".into(),
            format!("{:.2}", serial.iter_time_s),
            format!("{serial_exposed:.2}"),
            "0.00".into(),
            human_bytes(coll_volume(&serial)),
            "0".into(),
        ]);
        push(format!("{case}/serial_iter_s"), serial.iter_time_s, "s");
        push(format!("{case}/serial_exposed_coll_s"), serial_exposed, "s");
        for la in [1u32, 2, 4] {
            let opt = OptimizationPlan {
                group_lookahead: la,
                ..OptimizationPlan::collectives_pipelined()
            };
            match Engine::new(cluster, task).with_opt(opt).run() {
                Ok(r) => {
                    let exposed = r.breakdown.exposed_collective_s;
                    t.row(vec![
                        format!("coll la={la}"),
                        format!("{:.2}", r.iter_time_s),
                        format!("{exposed:.2}"),
                        format!(
                            "{:.2}", r.breakdown.overlapped_collective_s),
                        human_bytes(coll_volume(&r)),
                        r.gather_prefetches.to_string(),
                    ]);
                    println!(
                        "la={la}: exposed {:.2}s vs serial \
                         {serial_exposed:.2}s, volume {}",
                        exposed,
                        if coll_volume(&r) == coll_volume(&serial) {
                            "unchanged"
                        } else {
                            "CHANGED (regression!)"
                        },
                    );
                    push(format!("{case}/la{la}_iter_s"),
                         r.iter_time_s, "s");
                    push(format!("{case}/la{la}_exposed_coll_s"),
                         exposed, "s");
                    push(format!("{case}/la{la}_coll_bytes"),
                         coll_volume(&r) as f64, "B");
                    push(
                        format!("{case}/la{la}_speedup"),
                        serial.iter_time_s / r.iter_time_s,
                        "x",
                    );
                }
                Err(e) => {
                    t.row(vec![format!("coll la={la}"), format!("err {e}"),
                               "-".into(), "-".into(), "-".into(),
                               "-".into()]);
                }
            }
        }
        push(format!("{case}/serial_coll_bytes"),
             coll_volume(&serial) as f64, "B");
        print!("{}", t.render());
    }
    let json = Json::Arr(entries).to_string_pretty();
    match std::fs::write("BENCH_collectives.json", json) {
        Ok(()) => println!("wrote BENCH_collectives.json"),
        Err(e) => println!("could not write BENCH_collectives.json: {e}"),
    }
    println!(
        "acceptance: exposed collective time < serial on every nproc>=2 \
         config, non-increasing in lookahead, collective byte volume \
         exactly unchanged."
    );
}

// =====================================================================
// Pinned staging-buffer pool sweep (ISSUE 3 tentpole)
// =====================================================================
//
// The full pipeline run under shrinking pinned-pool sizes on the
// transfer-bound configs.  Pool 0 disables the model entirely (every
// copy on the single pinned curve — the PR 1/PR 2 idealization); finite
// pools throttle the prefetch lookahead to the staging backlog and
// downgrade buffer-less evictions/offload to the pageable (~0.5x) curve.
// The contract measured here:
//
//   * iteration time degrades monotonically as the pool shrinks
//     (16 -> 8 -> 4 -> 2 -> 1 buffers);
//   * PCIe transfer *volume* never increases over the disabled pool
//     (same contract as the prefetch bench: the pool re-times and
//     re-prices copies, it never adds traffic).
//
// A serial (no-pipeline) baseline row is printed for context: demand
// copies preempt the pool by construction, but a starved pool CAN run
// slower than serial — pool-dry evictions pay the 0.5x pageable curve,
// which the serial schedule never does — so serial-vs-pool is reported,
// not asserted.  Emits BENCH_pinned.json next to the other artifacts.
fn pinned_pool() {
    let cases = [
        (ClusterPreset::yard(), "12B", 1u32, 8u64),
        (ClusterPreset::superpod(), "50B", 1, 8),
        (ClusterPreset::yard(), "15B", 8, 8),
    ];
    let pools = [0u32, 16, 8, 4, 2, 1];
    let mut entries: Vec<Json> = Vec::new();
    let mut push = |name: String, value: f64, unit: &str| {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]));
    };
    for (cluster, model, gpus, batch) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, batch, gpus);
        let case = format!("{}_{model}_{gpus}g", cluster.name);
        println!("--- {case} ---");
        let mut t = Table::new(&["pool", "iter s", "exposed", "pageable",
                                 "prefetches", "throttled", "moved"]);
        if let Ok(serial) = Engine::new(cluster, task).run() {
            t.row(vec![
                "serial".into(),
                format!("{:.3}", serial.iter_time_s),
                format!("{:.2}", serial.breakdown.exposed_transfer_s),
                "0.00".into(),
                "0".into(),
                "0".into(),
                human_bytes(serial.move_stats.cpu_to_gpu_bytes
                            + serial.move_stats.gpu_to_cpu_bytes),
            ]);
            push(format!("{case}/serial_iter_s"), serial.iter_time_s,
                 "s");
        }
        let mut prev: Option<(u32, f64)> = None;
        let mut vol0: Option<u64> = None;
        let mut monotone = true;
        for pool in pools {
            let opt = OptimizationPlan {
                pinned_buffers: pool,
                ..OptimizationPlan::fully_pipelined()
            };
            match Engine::new(cluster, task).with_opt(opt).run() {
                Ok(r) => {
                    let vol = r.move_stats.cpu_to_gpu_bytes
                        + r.move_stats.gpu_to_cpu_bytes;
                    t.row(vec![
                        if pool == 0 {
                            "off".into()
                        } else {
                            pool.to_string()
                        },
                        format!("{:.3}", r.iter_time_s),
                        format!("{:.2}", r.breakdown.exposed_transfer_s),
                        format!("{:.2}", r.breakdown.pageable_copy_s),
                        r.move_stats.prefetches.to_string(),
                        r.move_stats.pinned_waits.to_string(),
                        human_bytes(vol),
                    ]);
                    let tag = if pool == 0 {
                        "off".to_string()
                    } else {
                        pool.to_string()
                    };
                    push(format!("{case}/pool_{tag}_iter_s"),
                         r.iter_time_s, "s");
                    push(format!("{case}/pool_{tag}_pageable_s"),
                         r.breakdown.pageable_copy_s, "s");
                    push(format!("{case}/pool_{tag}_throttled"),
                         r.move_stats.pinned_waits as f64, "count");
                    match vol0 {
                        None => vol0 = Some(vol),
                        Some(v) => {
                            if vol > v {
                                println!(
                                    "pool {pool}: volume INCREASED \
                                     (regression!): {vol} > {v}"
                                );
                            }
                        }
                    }
                    // Monotonicity only over the finite pool sizes —
                    // pool 0 is the disabled idealization, not the
                    // largest pool.
                    if let Some((pp, pt)) = prev {
                        if pool > 0
                            && pp > 0
                            && r.iter_time_s < pt * (1.0 - 1e-9)
                        {
                            monotone = false;
                            println!(
                                "pool {pool}: FASTER than pool {pp} \
                                 ({:.4} < {pt:.4}) — not monotone!",
                                r.iter_time_s
                            );
                        }
                    }
                    if pool > 0 {
                        prev = Some((pool, r.iter_time_s));
                    }
                }
                Err(e) => {
                    t.row(vec![pool.to_string(), format!("err {e}"),
                               "-".into(), "-".into(), "-".into(),
                               "-".into(), "-".into()]);
                }
            }
        }
        print!("{}", t.render());
        println!(
            "monotone degradation as the pool shrinks: {}",
            if monotone { "yes" } else { "VIOLATED (regression!)" }
        );
    }
    let json = Json::Arr(entries).to_string_pretty();
    match std::fs::write("BENCH_pinned.json", json) {
        Ok(()) => println!("wrote BENCH_pinned.json"),
        Err(e) => println!("could not write BENCH_pinned.json: {e}"),
    }
    println!(
        "acceptance: iter time non-decreasing as the pool shrinks on \
         every config, transfer volume never increased over the \
         disabled pool, pool off == PR 2 pipeline numbers; serial row \
         is context only (a starved pool may exceed it)."
    );
}

// =====================================================================
// Adaptive lookahead sweep (ISSUE 4 tentpole)
// =====================================================================
//
// Static (lookahead, group_lookahead) pairs vs the feedback controller
// on the pinned pipeline, across model sizes.  The acceptance contract:
//
//   * adaptive matches or beats the BEST static pair on every config
//     (within 1% tolerance — printed as PASS/FAIL here, gated at 5% by
//     the CI diff step over BENCH_adaptive.json);
//   * adaptive beats the DEFAULT static windows (32, 1) outright on at
//     least one config;
//   * volume discipline is covered by the test suites, not re-measured
//     here.
//
// Emits BENCH_adaptive.json next to the other artifacts.
fn adaptive_lookahead() {
    let cases = [
        (ClusterPreset::yard(), "4B", 1u32, 8u64),
        (ClusterPreset::yard(), "12B", 1, 8),
        (ClusterPreset::yard(), "15B", 8, 8),
        (ClusterPreset::superpod(), "50B", 8, 8),
    ];
    let mut entries: Vec<Json> = Vec::new();
    let mut push = |name: String, value: f64, unit: &str| {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]));
    };
    let mut beats_default_somewhere = false;
    let mut within_best_everywhere = true;
    for (cluster, model, gpus, batch) in cases {
        let m = GptSpec::by_name(model).unwrap();
        let task = TrainTask::new(m, batch, gpus);
        let case = format!("{}_{model}_{gpus}g", cluster.name);
        println!("--- {case} ---");
        let mut t = Table::new(&["plan", "iter s", "exposed tx",
                                 "exposed coll", "avg la", "avg gla"]);
        // Static sweep: window depths around the default; the group
        // dimension only exists on multi-GPU configs.
        let las = [8u32, 32, 64];
        let glas: &[u32] = if gpus > 1 { &[1, 2, 4] } else { &[1] };
        let mut best_static: Option<(f64, u32, u32)> = None;
        let mut default_static: Option<f64> = None;
        for &la in &las {
            for &gla in glas {
                let opt = OptimizationPlan {
                    lookahead: la,
                    group_lookahead: gla,
                    ..OptimizationPlan::pinned_pipeline()
                };
                match Engine::new(cluster, task).with_opt(opt).run() {
                    Ok(r) => {
                        t.row(vec![
                            format!("la={la} gla={gla}"),
                            format!("{:.3}", r.iter_time_s),
                            format!(
                                "{:.2}", r.breakdown.exposed_transfer_s),
                            format!(
                                "{:.2}",
                                r.breakdown.exposed_collective_s),
                            la.to_string(),
                            gla.to_string(),
                        ]);
                        push(
                            format!("{case}/static_la{la}_gla{gla}_iter_s"),
                            r.iter_time_s,
                            "s",
                        );
                        if la == 32 && gla == 1 {
                            default_static = Some(r.iter_time_s);
                        }
                        if best_static
                            .map(|(b, _, _)| r.iter_time_s < b)
                            .unwrap_or(true)
                        {
                            best_static = Some((r.iter_time_s, la, gla));
                        }
                    }
                    Err(e) => {
                        t.row(vec![format!("la={la} gla={gla}"),
                                   format!("err {e}"), "-".into(),
                                   "-".into(), "-".into(), "-".into()]);
                    }
                }
            }
        }
        let adaptive = match Engine::new(cluster, task)
            .with_opt(OptimizationPlan::adaptive_pipeline())
            .run()
        {
            Ok(r) => r,
            Err(e) => {
                println!("adaptive infeasible: {e}");
                continue;
            }
        };
        t.row(vec![
            "adaptive".into(),
            format!("{:.3}", adaptive.iter_time_s),
            format!("{:.2}", adaptive.breakdown.exposed_transfer_s),
            format!("{:.2}", adaptive.breakdown.exposed_collective_s),
            format!("{:.1}", adaptive.avg_chunk_lookahead),
            format!("{:.1}", adaptive.avg_group_lookahead),
        ]);
        print!("{}", t.render());
        push(format!("{case}/adaptive_iter_s"), adaptive.iter_time_s,
             "s");
        push(format!("{case}/adaptive_avg_lookahead"),
             adaptive.avg_chunk_lookahead, "moments");
        push(format!("{case}/adaptive_avg_group_lookahead"),
             adaptive.avg_group_lookahead, "groups");
        if let Some((best, bla, bgla)) = best_static {
            push(format!("{case}/best_static_iter_s"), best, "s");
            push(
                format!("{case}/adaptive_vs_best_static"),
                adaptive.iter_time_s / best,
                "x",
            );
            let ok = adaptive.iter_time_s <= best * 1.01;
            if !ok {
                within_best_everywhere = false;
            }
            println!(
                "best static: la={bla} gla={bgla} @ {best:.3}s | \
                 adaptive {:.3}s -> {}",
                adaptive.iter_time_s,
                if ok { "PASS (within 1%)" } else { "FAIL (>1% behind)" },
            );
        }
        if let Some(d) = default_static {
            push(format!("{case}/default_static_iter_s"), d, "s");
            if adaptive.iter_time_s < d * (1.0 - 1e-9) {
                beats_default_somewhere = true;
            }
        }
    }
    let json = Json::Arr(entries).to_string_pretty();
    match std::fs::write("BENCH_adaptive.json", json) {
        Ok(()) => println!("wrote BENCH_adaptive.json"),
        Err(e) => println!("could not write BENCH_adaptive.json: {e}"),
    }
    println!(
        "acceptance: adaptive within 1% of the best static pair on \
         every config ({}), beats the default static windows outright \
         on at least one config ({}).",
        if within_best_everywhere { "PASS" } else { "FAIL" },
        if beats_default_somewhere { "PASS" } else { "FAIL" },
    );
}

// =====================================================================
// NVMe third-tier "infinity" offload (ISSUE 7 tentpole)
// =====================================================================
//
// The headline claim measured here: on the RAM-starved NVME-LAB box
// (6 GB GPU + 6 GB DRAM), the 1B model's ~14 GB of chunked data
// provably cannot fit CPU+GPU — the two-tier engine must REFUSE the
// config — while the same config trains once `--nvme-gb` grants the
// third tier.  Around that, the bench sweeps:
//
//   * serial vs pinned-pipeline 3-tier runs (overlap must still help
//     when the slow tier is in the loop);
//   * the NVMe link peak bandwidth (iter time must degrade as the
//     curve slows, proving the alpha-beta NVMe lane is actually on the
//     critical path and not absorbed into PCIe accounting).
//
// Emits BENCH_nvme.json (name/value/unit entries) next to the other
// artifacts; infeasible_without_nvme is 1.0/0.0 so the CI gate can
// hard-require the refusal.
fn nvme_offload() {
    let cluster = ClusterPreset::nvme_lab();
    let m = GptSpec::by_name("1B").unwrap();
    let task = TrainTask::new(m, 4, 1);
    let case = "NVME-LAB_1B_1g";
    let mut entries: Vec<Json> = Vec::new();
    let mut push = |name: String, value: f64, unit: &str| {
        entries.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("value", Json::num(value)),
            ("unit", Json::str(unit)),
        ]));
    };
    println!("--- {case}: two tiers must refuse, three must train ---");
    let two_tier = Engine::new(cluster, task)
        .with_opt(OptimizationPlan::pinned_pipeline())
        .run();
    match &two_tier {
        Ok(r) => println!(
            "UNEXPECTED: 1B trained on CPU+GPU alone ({:.2}s) — the \
             lab box is no longer starved (regression!)",
            r.iter_time_s
        ),
        Err(e) => println!("two-tier refusal (expected): {e:#}"),
    }
    push(format!("{case}/infeasible_without_nvme"),
         if two_tier.is_err() { 1.0 } else { 0.0 }, "bool");

    let mut t = Table::new(&["plan", "iter s", "nvme lane s",
                             "nvme moved", "nvme peak", "spilled down",
                             "staged up"]);
    let mut serial_iter = None;
    for (label, opt) in [
        ("serial+nvme64",
         OptimizationPlan { nvme_gb: 64, ..Default::default() }),
        ("pipeline+nvme64",
         OptimizationPlan { nvme_gb: 64,
                            ..OptimizationPlan::pinned_pipeline() }),
    ] {
        match Engine::new(cluster, task).with_opt(opt).run() {
            Ok(r) => {
                let moved = r.move_stats.to_nvme_bytes
                    + r.move_stats.from_nvme_bytes;
                t.row(vec![
                    label.into(),
                    format!("{:.3}", r.iter_time_s),
                    format!("{:.2}", r.breakdown.get(Phase::Nvme)),
                    human_bytes(moved),
                    human_bytes(r.nvme_peak),
                    human_bytes(r.move_stats.to_nvme_bytes),
                    human_bytes(r.move_stats.from_nvme_bytes),
                ]);
                push(format!("{case}/{label}_iter_s"), r.iter_time_s,
                     "s");
                push(format!("{case}/{label}_nvme_lane_s"),
                     r.breakdown.get(Phase::Nvme), "s");
                push(format!("{case}/{label}_nvme_moved_bytes"),
                     moved as f64, "B");
                if label == "serial+nvme64" {
                    serial_iter = Some(r.iter_time_s);
                } else if let Some(s) = serial_iter {
                    push(format!("{case}/pipeline_speedup"),
                         s / r.iter_time_s, "x");
                    println!("pipeline: {:.2}x vs serial 3-tier",
                             s / r.iter_time_s);
                }
            }
            Err(e) => {
                t.row(vec![label.into(), format!("err {e}"), "-".into(),
                           "-".into(), "-".into(), "-".into(),
                           "-".into()]);
            }
        }
    }
    print!("{}", t.render());

    // Bandwidth sensitivity: halving/doubling the NVMe peak must move
    // iteration time the right way (slower link -> slower iteration).
    println!("--- NVMe link bandwidth sweep (pinned pipeline) ---");
    let mut t = Table::new(&["nvme GB/s", "iter s", "nvme lane s"]);
    let mut last: Option<f64> = None;
    let mut ordered = true;
    for gbps in [1.6f64, 3.2, 6.4] {
        let opt = OptimizationPlan {
            nvme_gb: 64,
            nvme_gbps: gbps,
            ..OptimizationPlan::pinned_pipeline()
        };
        match Engine::new(cluster, task).with_opt(opt).run() {
            Ok(r) => {
                t.row(vec![
                    format!("{gbps:.1}"),
                    format!("{:.3}", r.iter_time_s),
                    format!("{:.2}", r.breakdown.get(Phase::Nvme)),
                ]);
                push(format!("{case}/gbps{gbps}_iter_s"), r.iter_time_s,
                     "s");
                if let Some(prev) = last {
                    if r.iter_time_s > prev * (1.0 + 1e-9) {
                        ordered = false;
                        println!(
                            "{gbps} GB/s SLOWER than the previous, \
                             slower link — NVMe lane not on the \
                             critical path?"
                        );
                    }
                }
                last = Some(r.iter_time_s);
            }
            Err(e) => t.row(vec![format!("{gbps:.1}"),
                            format!("err {e}"), "-".into()]),
        }
    }
    print!("{}", t.render());
    let json = Json::Arr(entries).to_string_pretty();
    match std::fs::write("BENCH_nvme.json", json) {
        Ok(()) => println!("wrote BENCH_nvme.json"),
        Err(e) => println!("could not write BENCH_nvme.json: {e}"),
    }
    println!(
        "acceptance: two-tier run refuses (infeasible_without_nvme = 1), \
         3-tier runs train with nvme traffic > 0, iter time \
         non-increasing as the NVMe link speeds up ({}).",
        if ordered { "PASS" } else { "FAIL" }
    );
}

// =====================================================================
// Micro-benchmarks of L3 hot paths (perf pass, EXPERIMENTS.md §Perf)
// =====================================================================
fn micro_hotpaths() {
    // chunk manager: ensure_on with eviction pressure.
    let n_tensors = 512usize;
    let specs: Vec<TensorSpec> = (0..n_tensors)
        .map(|i| TensorSpec {
            name: format!("t{i}"),
            numel: 1000,
            embedding: false,
        })
        .collect();
    let reg = ChunkRegistry::build(&specs, 4000).unwrap();
    let n_chunks = reg.chunks.len();
    let fp16: Vec<_> = reg.list(ChunkKind::ParamFp16);
    // GPU fits 1/4 of the fp16 list -> heavy eviction churn.
    let space = HeterogeneousSpace::new(
        (fp16.len() as u64 / 4) * 8000,
        1 << 30,
    );
    let mut mgr = ChunkManager::new(reg, space);
    let mut lru = LruPolicy::default();
    let t0 = Instant::now();
    let rounds = 200;
    for round in 0..rounds {
        for (i, &c) in fp16.iter().enumerate() {
            mgr.ensure_on(c, Device::Gpu(0), &mut lru,
                          (round * fp16.len() + i) as u32)
                .unwrap();
        }
        mgr.drain_events();
    }
    let per_op =
        t0.elapsed().as_secs_f64() / (rounds * fp16.len()) as f64;
    println!(
        "ensure_on (LRU, churn): {:.2} us/op over {} ops, {} evictions",
        per_op * 1e6,
        rounds * fp16.len(),
        mgr.stats.evictions
    );

    // tracer next_use binary search.
    let mut tracer = MemTracer::new(n_chunks);
    let mut rng = Rng::new(1);
    for c in 0..n_chunks {
        let mut ms: Vec<u32> =
            (0..64).map(|_| rng.range(0, 4000) as u32).collect();
        ms.sort_unstable();
        for m in ms {
            tracer.record_chunk_use(
                patrickstar::chunk::ChunkId(c as u32), m);
        }
    }
    tracer.finish_warmup();
    let t0 = Instant::now();
    let mut acc = 0u64;
    let queries = 2_000_000u64;
    for i in 0..queries {
        let c = patrickstar::chunk::ChunkId((i % n_chunks as u64) as u32);
        if let Some(m) = tracer.next_use(c, (i % 4000) as u32) {
            acc = acc.wrapping_add(m as u64);
        }
    }
    println!(
        "tracer.next_use: {:.1} ns/query ({} queries, checksum {acc})",
        t0.elapsed().as_secs_f64() / queries as f64 * 1e9,
        queries
    );

    // OPT policy victim scan.
    let candidates: Vec<_> =
        (0..n_chunks as u32).map(patrickstar::chunk::ChunkId).collect();
    let mut opt = OptPolicy { tracer: &tracer };
    let t0 = Instant::now();
    let picks = 20_000u64;
    let mut sum = 0u32;
    for i in 0..picks {
        if let Some(c) = opt.pick(&candidates, &[], (i % 4000) as u32) {
            sum = sum.wrapping_add(c.0);
        }
    }
    println!(
        "OptPolicy.pick over {} candidates: {:.1} us/pick (checksum {sum})",
        candidates.len(),
        t0.elapsed().as_secs_f64() / picks as f64 * 1e6
    );

    // Engine end-to-end (simulated iteration wall time).
    let t0 = Instant::now();
    let task = TrainTask::new(GptSpec::by_name("12B").unwrap(), 8, 8);
    let r = Engine::new(ClusterPreset::yard(), task).run().unwrap();
    println!(
        "engine.run (12B, 8 GPU sim): {:.2}s wall for {:.2}s simulated",
        t0.elapsed().as_secs_f64(),
        r.iter_time_s
    );
}
