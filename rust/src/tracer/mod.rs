//! Runtime memory tracer (paper Sec. 8.1, Fig. 11).
//!
//! A **moment** is the start or finish of an operator.  During a warm-up
//! iteration the tracer records, per moment, the real GPU memory in use
//! `R` and the chunkable memory `C` it granted; non-model footprint is
//! `R - C`.  It also records the list of moments at which each chunk is
//! used.  After warm-up, the schedule repeats (PTM iterations are
//! structurally identical), so:
//!
//! * `chunkable_gpu(moment)` = GPU capacity − non-model(moment) bounds how
//!   much chunk payload may sit on the GPU at that moment, and
//! * the per-chunk moment lists feed the OPT eviction policy (Sec. 8.3).
//!
//! During warm-up itself only `warmup_gpu_frac` (default 20%) of GPU
//! memory is granted to chunks and eviction falls back to chunk-list
//! order (paper: "it simply evicts chunks in the order of the chunk
//! list").

use crate::chunk::ChunkId;

pub type Moment = u32;

/// Default conservative GPU fraction for chunks during warm-up.
pub const WARMUP_GPU_FRAC: f64 = 0.20;

#[derive(Clone, Debug, Default)]
pub struct MemTracer {
    /// Non-model GPU bytes per moment, recorded in warm-up.
    non_model: Vec<u64>,
    /// Moments at which each chunk is accessed (sorted, by construction).
    chunk_moments: Vec<Vec<Moment>>,
    /// The subset of `chunk_moments` whose access targeted the GPU —
    /// the prefetcher's work list (a CPU-targeted ADAM access must not
    /// trigger a CPU->GPU prefetch).
    gpu_moments: Vec<Vec<Moment>>,
    /// Total moments in one iteration.
    pub n_moments: Moment,
    pub warmed_up: bool,
}

impl MemTracer {
    pub fn new(n_chunks: usize) -> Self {
        MemTracer {
            non_model: Vec::new(),
            chunk_moments: vec![Vec::new(); n_chunks],
            gpu_moments: vec![Vec::new(); n_chunks],
            n_moments: 0,
            warmed_up: false,
        }
    }

    // ------------------------------------------------------ warm-up phase

    /// Record the non-model footprint at the current moment and advance
    /// the moment counter.  Returns the moment just recorded.
    pub fn record_moment(&mut self, non_model_bytes: u64) -> Moment {
        let m = self.n_moments;
        self.non_model.push(non_model_bytes);
        self.n_moments += 1;
        m
    }

    /// Record that `chunk` is needed at moment `m` (access during
    /// warm-up), assumed GPU-targeted.
    pub fn record_chunk_use(&mut self, chunk: ChunkId, m: Moment) {
        self.record_chunk_use_at(chunk, m, true);
    }

    /// Record a warm-up access with its target device: `gpu_target`
    /// accesses also enter the prefetcher's GPU work list.
    pub fn record_chunk_use_at(
        &mut self,
        chunk: ChunkId,
        m: Moment,
        gpu_target: bool,
    ) {
        let v = &mut self.chunk_moments[chunk.0 as usize];
        if v.last() != Some(&m) {
            v.push(m);
        }
        if gpu_target {
            let g = &mut self.gpu_moments[chunk.0 as usize];
            if g.last() != Some(&m) {
                g.push(m);
            }
        }
    }

    pub fn finish_warmup(&mut self) {
        self.warmed_up = true;
    }

    // ------------------------------------------------------ steady state

    /// Non-model footprint at a moment of the steady-state iteration.
    pub fn non_model_at(&self, m: Moment) -> u64 {
        if self.non_model.is_empty() {
            return 0;
        }
        self.non_model[(m as usize).min(self.non_model.len() - 1)]
    }

    /// Peak non-model footprint across the iteration (defines the GPU
    /// margin space for OS chunks, Sec. 8.2).
    pub fn peak_non_model(&self) -> u64 {
        self.non_model.iter().copied().max().unwrap_or(0)
    }

    /// Chunkable GPU bytes at moment `m` given total GPU capacity.
    /// Before warm-up completes this is the conservative 20% grant.
    pub fn chunkable_gpu(&self, gpu_capacity: u64, m: Moment) -> u64 {
        if !self.warmed_up {
            return (gpu_capacity as f64 * WARMUP_GPU_FRAC) as u64;
        }
        gpu_capacity.saturating_sub(self.non_model_at(m))
    }

    /// Tightest chunkable-GPU grant over the moment span `[from, to]`
    /// (inclusive, clamped to the recorded iteration).  The prefetch
    /// headroom budget: chunk payload staged ahead of moment `to` must
    /// stay under every intervening cap, or the staging itself would
    /// trigger the evictions it is trying to avoid.
    pub fn min_chunkable_gpu(
        &self,
        gpu_capacity: u64,
        from: Moment,
        to: Moment,
    ) -> u64 {
        if !self.warmed_up {
            return (gpu_capacity as f64 * WARMUP_GPU_FRAC) as u64;
        }
        if self.non_model.is_empty() {
            return gpu_capacity;
        }
        let last = self.non_model.len() - 1;
        let lo = (from as usize).min(last);
        let hi = (to.max(from) as usize).min(last);
        let worst = self.non_model[lo..=hi].iter().copied().max().unwrap_or(0);
        gpu_capacity.saturating_sub(worst)
    }

    /// Next moment >= `now` at which `chunk` is used; None if never again
    /// this iteration.  O(log T) binary search (paper Sec. 8.3).
    pub fn next_use(&self, chunk: ChunkId, now: Moment) -> Option<Moment> {
        let v = &self.chunk_moments[chunk.0 as usize];
        let i = v.partition_point(|&m| m < now);
        v.get(i).copied()
    }

    pub fn moments_of(&self, chunk: ChunkId) -> &[Moment] {
        &self.chunk_moments[chunk.0 as usize]
    }

    /// GPU-targeted use moments of `chunk` (the prefetcher's view).
    pub fn gpu_moments_of(&self, chunk: ChunkId) -> &[Moment] {
        &self.gpu_moments[chunk.0 as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    #[test]
    fn warmup_grant_is_20_pct() {
        let t = MemTracer::new(4);
        assert_eq!(t.chunkable_gpu(1000, 0), 200);
    }

    #[test]
    fn chunkable_is_capacity_minus_non_model() {
        let mut t = MemTracer::new(1);
        t.record_moment(300);
        t.record_moment(700);
        t.finish_warmup();
        assert_eq!(t.chunkable_gpu(1000, 0), 700);
        assert_eq!(t.chunkable_gpu(1000, 1), 300);
        // Past-the-end moments clamp to the last recorded footprint.
        assert_eq!(t.chunkable_gpu(1000, 99), 300);
        assert_eq!(t.peak_non_model(), 700);
    }

    #[test]
    fn saturating_when_non_model_exceeds_gpu() {
        let mut t = MemTracer::new(1);
        t.record_moment(2000);
        t.finish_warmup();
        assert_eq!(t.chunkable_gpu(1000, 0), 0);
    }

    #[test]
    fn next_use_binary_search() {
        let mut t = MemTracer::new(2);
        for m in [2u32, 5, 9] {
            t.record_chunk_use(ChunkId(0), m);
        }
        t.finish_warmup();
        assert_eq!(t.next_use(ChunkId(0), 0), Some(2));
        assert_eq!(t.next_use(ChunkId(0), 2), Some(2));
        assert_eq!(t.next_use(ChunkId(0), 3), Some(5));
        assert_eq!(t.next_use(ChunkId(0), 10), None);
        assert_eq!(t.next_use(ChunkId(1), 0), None);
    }

    #[test]
    fn min_chunkable_is_worst_cap_over_span() {
        let mut t = MemTracer::new(1);
        for nm in [300u64, 700, 100] {
            t.record_moment(nm);
        }
        t.finish_warmup();
        assert_eq!(t.min_chunkable_gpu(1000, 0, 0), 700);
        assert_eq!(t.min_chunkable_gpu(1000, 0, 2), 300);
        assert_eq!(t.min_chunkable_gpu(1000, 2, 2), 900);
        // Spans past the recorded iteration clamp to the last moment.
        assert_eq!(t.min_chunkable_gpu(1000, 2, 99), 900);
        // Degenerate reversed span behaves like a single moment.
        assert_eq!(t.min_chunkable_gpu(1000, 1, 0), 300);
    }

    #[test]
    fn cpu_targeted_uses_stay_off_gpu_list() {
        let mut t = MemTracer::new(1);
        t.record_chunk_use_at(ChunkId(0), 2, true);
        t.record_chunk_use_at(ChunkId(0), 5, false); // ADAM on CPU
        t.record_chunk_use_at(ChunkId(0), 9, true);
        t.finish_warmup();
        // OPT eviction sees every use...
        assert_eq!(t.moments_of(ChunkId(0)), &[2, 5, 9]);
        // ...the prefetcher only the GPU-targeted ones.
        assert_eq!(t.gpu_moments_of(ChunkId(0)), &[2, 9]);
    }

    #[test]
    fn duplicate_moment_dedup() {
        let mut t = MemTracer::new(1);
        t.record_chunk_use(ChunkId(0), 3);
        t.record_chunk_use(ChunkId(0), 3);
        assert_eq!(t.moments_of(ChunkId(0)), &[3]);
    }

    #[test]
    fn property_next_use_is_minimal_geq_now() {
        forall(
            100,
            |rng| {
                let n = rng.range(1, 30);
                let mut ms: Vec<Moment> =
                    (0..n).map(|_| rng.range(0, 100) as Moment).collect();
                ms.sort_unstable();
                ms.dedup();
                let now = rng.range(0, 110) as Moment;
                (ms, now)
            },
            |(ms, now)| {
                let mut t = MemTracer::new(1);
                for &m in ms {
                    t.record_chunk_use(ChunkId(0), m);
                }
                let got = t.next_use(ChunkId(0), *now);
                let want = ms.iter().copied().filter(|&m| m >= *now).min();
                if got == want {
                    Ok(())
                } else {
                    Err(format!("next_use({now}) = {got:?}, want {want:?}"))
                }
            },
        );
    }
}
