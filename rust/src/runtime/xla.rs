//! Check-only shim for the `xla` bindings (ISSUE 5 satellite).
//!
//! The real `xla` crate (PJRT C-API bindings) is not in the offline
//! crate cache, so the `pjrt` feature could never even *type-check* in
//! CI — `runtime/` and `train/` rotted unbuilt.  This module mirrors
//! the exact slice of the `xla` API the runtime uses, with every
//! entry point returning a "bindings not linked" error at runtime, so
//! `cargo check --features pjrt` keeps the whole real-training path
//! honest while execution still requires the vendored bindings.
//!
//! When the real crate is vendored, delete this file and re-export the
//! crate under the same path (`pub use ::xla;` in `runtime/mod.rs`);
//! every call site already goes through `crate::runtime::xla`.

use std::fmt;

/// Error surfaced by every stubbed entry point.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unlinked<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: the xla PJRT bindings are not linked into this build \
         (the `pjrt` feature is check-only without them); vendor the \
         bindings and replace runtime/xla.rs with a re-export"
    )))
}

/// A PJRT device handle (never materialized by the stub).
#[derive(Clone, Copy, Debug)]
pub struct PjRtDevice;

/// The PJRT client over one platform (CPU here).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        unlinked("PjRtClient::cpu")
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unlinked("PjRtClient::compile")
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _lit: &Literal,
    ) -> Result<PjRtBuffer, XlaError> {
        unlinked("PjRtClient::buffer_from_host_literal")
    }
}

/// A compiled executable resident on the client.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<B>(
        &self,
        _args: &[PjRtBuffer],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unlinked("PjRtLoadedExecutable::execute_b")
    }
}

/// A device buffer owned by rust (freed on Drop in the real crate).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unlinked("PjRtBuffer::to_literal_sync")
    }
}

/// An HLO module in proto form.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        unlinked("HloModuleProto::from_text_file")
    }
}

/// A computation handed to `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// A host literal (tensor value).  The stub carries no data — every
/// consumer path errors before a literal can exist.
#[derive(Clone, Debug)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unlinked("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unlinked("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unlinked("Literal::to_tuple")
    }
}
