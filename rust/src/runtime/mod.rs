//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! The bridge follows /opt/xla-example/load_hlo: HLO **text** (emitted by
//! `python/compile/aot.py`) is parsed by `HloModuleProto::from_text_file`
//! (which reassigns the 64-bit instruction ids jax >= 0.5 emits and
//! xla_extension 0.5.1 rejects in proto form), compiled once on the PJRT
//! CPU client, then executed with `Literal` arguments.  Python never runs
//! at training time.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

// The PJRT bindings facade: a check-only stub mirroring the slice of
// the `xla` crate API this module uses (the real crate is not in the
// offline cache).  All call sites — here and in `train/` — resolve
// `xla::` through this module path, so vendoring the real bindings is
// a one-line swap to `pub use ::xla;`.
pub mod xla;

/// One parameter tensor of the AOT model, from manifest.json.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub numel: usize,
    pub embedding: bool,
}

/// The rust<->python contract emitted next to the artifacts.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub vocab: usize,
    pub seq: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub batch: usize,
    pub n_params: usize,
    pub chunk_elems: usize,
    pub adam_hp_len: usize,
    pub params: Vec<ParamSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json"))
            .context("parsing manifest.json")?;
        let model = j.req("model")?;
        let g = |k: &str| -> Result<usize> {
            model
                .req(k)?
                .as_usize()
                .ok_or_else(|| anyhow!("model.{k} not a number"))
        };
        let params = j
            .req("params")?
            .as_arr()
            .ok_or_else(|| anyhow!("params not an array"))?
            .iter()
            .map(|p| -> Result<ParamSpec> {
                Ok(ParamSpec {
                    name: p
                        .req("name")?
                        .as_str()
                        .ok_or_else(|| anyhow!("param name"))?
                        .to_string(),
                    shape: p
                        .req("shape")?
                        .as_arr()
                        .ok_or_else(|| anyhow!("param shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    numel: p
                        .req("numel")?
                        .as_usize()
                        .ok_or_else(|| anyhow!("param numel"))?,
                    embedding: p
                        .get("embedding")
                        .and_then(|b| b.as_bool())
                        .unwrap_or(false),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let m = Manifest {
            vocab: g("vocab")?,
            seq: g("seq")?,
            hidden: g("hidden")?,
            layers: g("layers")?,
            heads: g("heads")?,
            batch: g("batch")?,
            n_params: g("n_params")?,
            chunk_elems: j
                .req("chunk_elems")?
                .as_usize()
                .ok_or_else(|| anyhow!("chunk_elems"))?,
            adam_hp_len: j
                .req("adam_hp_len")?
                .as_usize()
                .unwrap_or(8),
            params,
        };
        let total: usize = m.params.iter().map(|p| p.numel).sum();
        if total != m.n_params {
            bail!("manifest inconsistent: params sum {total} != n_params {}",
                  m.n_params);
        }
        Ok(m)
    }
}

/// Compiled-executable cache over one PJRT CPU client.
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtRuntime {
    /// Load the artifact directory; compiles nothing until first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu: {e}"))?;
        Ok(PjrtRuntime { client, dir, manifest, executables: HashMap::new() })
    }

    /// Compile (once) and return the named executable, e.g. "train_step".
    pub fn executable(
        &mut self,
        name: &str,
    ) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e}"))?;
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    /// Execute `name` with literal args; returns the flattened tuple
    /// elements (aot.py lowers everything with return_tuple=True).
    ///
    /// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`, whose
    /// C++ shim leaks every input device buffer (`buffer.release()` with
    /// no matching free — ~1 GB/step on the e2e model, OOM after ~30
    /// steps).  Instead the input buffers are materialized as rust-owned
    /// `PjRtBuffer`s (freed on Drop) and passed through `execute_b`.
    pub fn run(
        &mut self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let mut bufs = Vec::with_capacity(args.len());
        for lit in args {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("uploading arg for {name}: {e}"))?,
            );
        }
        let exe = self.executable(name)?;
        let out = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        drop(bufs); // release input device buffers eagerly
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e}"))
    }
}

/// f32 slice -> 1-D literal.
pub fn lit_f32(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// f32 slice -> literal with shape.
pub fn lit_f32_shaped(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// i32 slice -> literal with shape.
pub fn lit_i32_shaped(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e}"))
}

/// Literal -> Vec<f32>.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))
}

/// Scalar f32 from a literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = to_f32(lit)?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Manifest parsing against a synthetic manifest (no PJRT needed).
    #[test]
    fn manifest_roundtrip() {
        let dir = std::env::temp_dir().join("ps_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model": {"vocab": 64, "seq": 8, "hidden": 16,
                 "layers": 1, "heads": 2, "batch": 1, "use_pallas": true,
                 "n_params": 30},
                "params": [
                 {"name": "wte", "shape": [2, 10], "numel": 20,
                  "embedding": true},
                 {"name": "w", "shape": [10], "numel": 10,
                  "embedding": false}],
                "chunk_elems": 64, "adam_hp_len": 8,
                "artifacts": {}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.vocab, 64);
        assert_eq!(m.params.len(), 2);
        assert!(m.params[0].embedding);
        assert_eq!(m.params[1].shape, vec![10]);
        assert_eq!(m.chunk_elems, 64);
    }

    #[test]
    fn manifest_rejects_inconsistent_totals() {
        let dir = std::env::temp_dir().join("ps_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"model": {"vocab": 1, "seq": 1, "hidden": 1, "layers": 1,
                 "heads": 1, "batch": 1, "n_params": 999},
                "params": [{"name": "w", "shape": [10], "numel": 10,
                            "embedding": false}],
                "chunk_elems": 64, "adam_hp_len": 8, "artifacts": {}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
