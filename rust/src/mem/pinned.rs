//! Pinned staging-buffer pool (ISSUE 3 tentpole).
//!
//! Real offload engines do not DMA pageable host memory at the rates the
//! paper's bandwidth argument assumes: `cudaMemcpyAsync` from pageable
//! memory is staged through a driver bounce buffer at roughly half the
//! pinned rate, and true async overlap requires `cudaMallocHost`-style
//! pinned buffers — of which a training process keeps only a small,
//! fixed pool (ZeRO-Infinity and AutoHete both make this pool the
//! central contended resource of their pipelines).  This module models
//! that pool for the simulator: a fixed number of chunk-sized pinned
//! buffers with acquire/release semantics on the simulated clock.
//!
//! A *lease* is one buffer held for the lifetime of one staged copy —
//! from the moment the copy is enqueued (the payload is memcpy'd into
//! the pinned buffer at issue, so a queued copy holds its buffer while
//! it waits for the engine) until the DMA completes.  Lease release
//! times therefore equal copy completion times on the stream timeline;
//! the pool answers "is a buffer free at simulated time t" by counting
//! outstanding leases, pruning expired ones lazily.
//!
//! Contention policy (wired up by the engine):
//!
//! * **demand copies preempt** — they never consult the pool and are
//!   always charged at the pinned rate (the runtime reserves staging
//!   capacity for the critical path);
//! * **prefetches wait** — a chunk prefetch or lookahead group gather
//!   that cannot acquire a buffer is simply not issued this moment and
//!   retries at the next tick, so the effective lookahead window is
//!   throttled by pool availability;
//! * **evictions and activation offload downgrade** — pressure-driven
//!   copies cannot wait, so they fall back to the pageable curve
//!   ([`crate::mem::Interconnect::pcie_pageable`]) when the pool is
//!   exhausted.
//!
//! A pool of capacity 0 is *disabled*: the engine skips all pool logic
//! and every transfer charges the single pinned curve, reproducing the
//! pre-pool numbers bit-for-bit.
//!
//! # Per-direction sub-pools (ISSUE 4 satellite)
//!
//! Real runtimes keep *separate* H2D and D2H staging rings (and NCCL
//! its own registered buffers), so a burst of D2H evictions must not
//! be able to lease every buffer out from under the H2D prefetcher.
//! The pool therefore carries optional per-direction caps on top of
//! the shared total: a lease is granted only while both the total and
//! the requested direction's cap have room.  The default is *unsplit*
//! (each direction may use the whole pool) — bit-identical to the
//! single shared pool this generalizes.

use crate::sim::CopyDir;

/// Default pool size when the pinned pipeline is switched on wholesale
/// (`OptimizationPlan::pinned_pipeline`, the CLI breakdown row): enough
/// buffers to keep both copy engines and one lookahead gather fed while
/// still exercising contention under a deep prefetch backlog.
pub const DEFAULT_PINNED_BUFFERS: u32 = 4;

/// One outstanding buffer lease (opaque handle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PinnedLease(u64);

#[derive(Clone, Copy, Debug)]
struct Lease {
    id: u64,
    /// Release time on the simulated clock.  A fresh lease releases at
    /// +inf until the caller learns the copy's completion time and
    /// calls [`PinnedPool::set_release`].
    release: f64,
    dir: CopyDir,
}

/// Fixed-size pool of chunk-sized pinned staging buffers with optional
/// per-direction sub-pool caps.
#[derive(Clone, Debug, Default)]
pub struct PinnedPool {
    capacity: usize,
    /// Per-direction lease caps (each `<= capacity`; both default to
    /// `capacity`, i.e. unsplit).
    h2d_cap: usize,
    d2h_cap: usize,
    next_id: u64,
    /// Outstanding leases, pruned lazily as they expire.
    leases: Vec<Lease>,
}

impl PinnedPool {
    pub fn new(capacity: usize) -> Self {
        PinnedPool {
            capacity,
            h2d_cap: capacity,
            d2h_cap: capacity,
            next_id: 0,
            leases: Vec::new(),
        }
    }

    /// Cap the per-direction sub-pools (values clamp to the total).
    /// `capacity:capacity` is the explicit spelling of the unsplit
    /// default and behaves identically to it.
    pub fn with_split(mut self, h2d_cap: usize, d2h_cap: usize) -> Self {
        self.h2d_cap = h2d_cap.min(self.capacity);
        self.d2h_cap = d2h_cap.min(self.capacity);
        self
    }

    fn dir_cap(&self, dir: CopyDir) -> usize {
        match dir {
            CopyDir::H2D => self.h2d_cap,
            CopyDir::D2H => self.d2h_cap,
        }
    }

    /// The disabled pool: no buffers, no modeling.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    /// False means the engine must skip pool routing entirely (single
    /// pinned curve, pre-pool behaviour).
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Leases still held at simulated time `now` (both directions).
    pub fn in_use_at(&self, now: f64) -> usize {
        self.leases.iter().filter(|l| l.release > now).count()
    }

    /// Leases held at `now` by copies in one direction.
    pub fn dir_in_use_at(&self, now: f64, dir: CopyDir) -> usize {
        self.leases
            .iter()
            .filter(|l| l.dir == dir && l.release > now)
            .count()
    }

    /// Buffers grantable to a `dir` copy at simulated time `now`: both
    /// the shared total and the direction's sub-pool cap must have room.
    pub fn available_at(&self, now: f64, dir: CopyDir) -> usize {
        let total_free = self.capacity.saturating_sub(self.in_use_at(now));
        let dir_free = self
            .dir_cap(dir)
            .saturating_sub(self.dir_in_use_at(now, dir));
        total_free.min(dir_free)
    }

    /// Acquire a buffer for a `dir` copy at simulated time `now`,
    /// releasing "never" until [`PinnedPool::set_release`] pins down the
    /// copy's completion time.  Returns None when the total pool or the
    /// direction's sub-pool is exhausted at `now` — the caller either
    /// waits (prefetch) or downgrades to the pageable curve
    /// (eviction/offload).
    pub fn try_acquire(&mut self, now: f64, dir: CopyDir)
        -> Option<PinnedLease> {
        // Lazy prune keeps the scan short across a long run.
        self.leases.retain(|l| l.release > now);
        if self.leases.len() >= self.capacity
            || self.leases.iter().filter(|l| l.dir == dir).count()
                >= self.dir_cap(dir)
        {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.leases.push(Lease { id, release: f64::INFINITY, dir });
        Some(PinnedLease(id))
    }

    /// The copy holding `lease` completes (and its buffer frees) at `t`.
    /// Also used to *shift* a release when FIFO queue compression moves
    /// the copy's completion time.
    pub fn set_release(&mut self, lease: PinnedLease, t: f64) {
        if let Some(e) = self.leases.iter_mut().find(|e| e.id == lease.0) {
            e.release = t;
        }
    }

    /// Release `lease` immediately (the copy was cancelled before the
    /// wire).  Unknown or already-expired leases are a no-op.
    pub fn release(&mut self, lease: PinnedLease) {
        self.leases.retain(|l| l.id != lease.0);
    }

    /// Forget every lease (iteration boundary: the timeline restarts at
    /// zero, so stale release times must not leak across).
    pub fn clear(&mut self) {
        self.leases.clear();
    }

    /// Iteration-end leak probe (ISSUE 6 satellite): leases still held
    /// at `now` — the iteration's makespan — are leaks, because every
    /// sim-path lease either expires at its copy's completion time
    /// (which the makespan bounds) or is released by a cancel path.
    /// Debug builds fail fast; release callers count and report.
    pub fn leak_check(&self, now: f64) -> usize {
        let leaked = self.in_use_at(now);
        debug_assert_eq!(
            leaked, 0,
            "pinned-lease leak: {leaked} lease(s) still held at \
             iteration end (t = {now})"
        );
        leaked
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const H2D: CopyDir = CopyDir::H2D;
    const D2H: CopyDir = CopyDir::D2H;

    #[test]
    fn acquire_release_roundtrip() {
        let mut p = PinnedPool::new(2);
        assert!(p.enabled());
        assert_eq!(p.available_at(0.0, H2D), 2);
        let a = p.try_acquire(0.0, H2D).unwrap();
        let b = p.try_acquire(0.0, D2H).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.available_at(0.0, H2D), 0);
        assert!(p.try_acquire(0.0, H2D).is_none(), "pool exhausted");
        p.release(a);
        assert_eq!(p.available_at(0.0, H2D), 1);
        assert!(p.try_acquire(0.0, H2D).is_some());
    }

    #[test]
    fn leases_expire_at_release_time() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0, H2D).unwrap();
        // Unset release: held forever.
        assert_eq!(p.available_at(1e12, H2D), 0);
        p.set_release(a, 2.0);
        assert_eq!(p.available_at(1.9, H2D), 0, "still on the wire");
        assert_eq!(p.available_at(2.0, H2D), 1, "freed exactly at done");
        // A later acquire at t=3 succeeds and prunes the expired lease.
        assert!(p.try_acquire(3.0, H2D).is_some());
        assert_eq!(p.in_use_at(3.0), 1);
    }

    #[test]
    fn queue_compression_shifts_release_earlier() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0, H2D).unwrap();
        p.set_release(a, 5.0);
        // The copy ahead of it was reclaimed: it now lands at 3.5.
        p.set_release(a, 3.5);
        assert_eq!(p.available_at(4.0, H2D), 1);
        assert_eq!(p.available_at(3.0, H2D), 0);
    }

    #[test]
    fn disabled_pool_never_grants() {
        let mut p = PinnedPool::disabled();
        assert!(!p.enabled());
        assert_eq!(p.capacity(), 0);
        assert!(p.try_acquire(0.0, H2D).is_none());
        assert_eq!(p.available_at(0.0, H2D), 0);
    }

    #[test]
    fn clear_forgets_all_leases() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0, H2D).unwrap();
        p.set_release(a, 100.0);
        p.clear();
        assert_eq!(p.in_use_at(0.0), 0);
        assert!(p.try_acquire(0.0, H2D).is_some());
        // Releasing a cleared lease is a harmless no-op.
        p.release(a);
    }

    #[test]
    fn full_split_is_identical_to_unsplit() {
        // `N:N` is the explicit spelling of the default: every grant
        // decision matches the single shared pool.
        let mut unsplit = PinnedPool::new(2);
        let mut full = PinnedPool::new(2).with_split(2, 2);
        for p in [&mut unsplit, &mut full] {
            let a = p.try_acquire(0.0, D2H).unwrap();
            let _b = p.try_acquire(0.0, D2H).unwrap();
            assert!(p.try_acquire(0.0, H2D).is_none());
            p.set_release(a, 1.0);
            assert_eq!(p.available_at(1.0, H2D), 1);
            assert_eq!(p.available_at(1.0, D2H), 1);
            assert!(p.try_acquire(1.0, H2D).is_some());
        }
    }

    #[test]
    fn split_protects_h2d_from_a_d2h_burst() {
        // Pool of 3 split 2:1 — the regression the satellite exists
        // for: an eviction burst (D2H) saturates its sub-pool after one
        // lease and the H2D prefetcher still gets buffers.
        let mut p = PinnedPool::new(3).with_split(2, 1);
        assert!(p.try_acquire(0.0, D2H).is_some());
        assert!(p.try_acquire(0.0, D2H).is_none(), "D2H sub-pool full");
        assert_eq!(p.available_at(0.0, D2H), 0);
        assert_eq!(p.available_at(0.0, H2D), 2, "H2D unaffected");
        assert!(p.try_acquire(0.0, H2D).is_some());
        assert!(p.try_acquire(0.0, H2D).is_some());
        assert!(p.try_acquire(0.0, H2D).is_none(), "H2D sub-pool full");
        // The shared total still binds: a 2:2 split over capacity 3
        // grants at most 3 leases overall.
        let mut p = PinnedPool::new(3).with_split(2, 2);
        assert!(p.try_acquire(0.0, H2D).is_some());
        assert!(p.try_acquire(0.0, H2D).is_some());
        assert!(p.try_acquire(0.0, D2H).is_some());
        assert!(p.try_acquire(0.0, D2H).is_none(), "total exhausted");
    }

    #[test]
    fn leak_check_passes_once_every_lease_has_expired() {
        let mut p = PinnedPool::new(2);
        let a = p.try_acquire(0.0, H2D).unwrap();
        let b = p.try_acquire(0.0, D2H).unwrap();
        p.set_release(a, 2.0);
        p.release(b);
        // At the makespan both leases are gone: expired and released.
        assert_eq!(p.leak_check(2.0), 0);
        assert_eq!(p.leak_check(5.0), 0);
    }

    #[test]
    fn split_caps_clamp_to_capacity() {
        let p = PinnedPool::new(2).with_split(100, 0);
        assert_eq!(p.dir_cap(H2D), 2);
        assert_eq!(p.dir_cap(D2H), 0);
        let mut p = p;
        assert!(p.try_acquire(0.0, D2H).is_none(), "0-cap direction");
        assert!(p.try_acquire(0.0, H2D).is_some());
    }
}
