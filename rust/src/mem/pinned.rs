//! Pinned staging-buffer pool (ISSUE 3 tentpole).
//!
//! Real offload engines do not DMA pageable host memory at the rates the
//! paper's bandwidth argument assumes: `cudaMemcpyAsync` from pageable
//! memory is staged through a driver bounce buffer at roughly half the
//! pinned rate, and true async overlap requires `cudaMallocHost`-style
//! pinned buffers — of which a training process keeps only a small,
//! fixed pool (ZeRO-Infinity and AutoHete both make this pool the
//! central contended resource of their pipelines).  This module models
//! that pool for the simulator: a fixed number of chunk-sized pinned
//! buffers with acquire/release semantics on the simulated clock.
//!
//! A *lease* is one buffer held for the lifetime of one staged copy —
//! from the moment the copy is enqueued (the payload is memcpy'd into
//! the pinned buffer at issue, so a queued copy holds its buffer while
//! it waits for the engine) until the DMA completes.  Lease release
//! times therefore equal copy completion times on the stream timeline;
//! the pool answers "is a buffer free at simulated time t" by counting
//! outstanding leases, pruning expired ones lazily.
//!
//! Contention policy (wired up by the engine):
//!
//! * **demand copies preempt** — they never consult the pool and are
//!   always charged at the pinned rate (the runtime reserves staging
//!   capacity for the critical path);
//! * **prefetches wait** — a chunk prefetch or lookahead group gather
//!   that cannot acquire a buffer is simply not issued this moment and
//!   retries at the next tick, so the effective lookahead window is
//!   throttled by pool availability;
//! * **evictions and activation offload downgrade** — pressure-driven
//!   copies cannot wait, so they fall back to the pageable curve
//!   ([`crate::mem::Interconnect::pcie_pageable`]) when the pool is
//!   exhausted.
//!
//! A pool of capacity 0 is *disabled*: the engine skips all pool logic
//! and every transfer charges the single pinned curve, reproducing the
//! pre-pool numbers bit-for-bit.

/// Default pool size when the pinned pipeline is switched on wholesale
/// (`OptimizationPlan::pinned_pipeline`, the CLI breakdown row): enough
/// buffers to keep both copy engines and one lookahead gather fed while
/// still exercising contention under a deep prefetch backlog.
pub const DEFAULT_PINNED_BUFFERS: u32 = 4;

/// One outstanding buffer lease (opaque handle).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PinnedLease(u64);

/// Fixed-size pool of chunk-sized pinned staging buffers.
#[derive(Clone, Debug, Default)]
pub struct PinnedPool {
    capacity: usize,
    next_id: u64,
    /// Outstanding leases: (id, release time on the simulated clock).
    /// A fresh lease releases at +inf until the caller learns the
    /// copy's completion time and calls [`PinnedPool::set_release`].
    leases: Vec<(u64, f64)>,
}

impl PinnedPool {
    pub fn new(capacity: usize) -> Self {
        PinnedPool { capacity, next_id: 0, leases: Vec::new() }
    }

    /// The disabled pool: no buffers, no modeling.
    pub fn disabled() -> Self {
        Self::new(0)
    }

    /// False means the engine must skip pool routing entirely (single
    /// pinned curve, pre-pool behaviour).
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Leases still held at simulated time `now`.
    pub fn in_use_at(&self, now: f64) -> usize {
        self.leases.iter().filter(|&&(_, rel)| rel > now).count()
    }

    /// Buffers free at simulated time `now`.
    pub fn available_at(&self, now: f64) -> usize {
        self.capacity.saturating_sub(self.in_use_at(now))
    }

    /// Acquire a buffer at simulated time `now`, releasing "never" until
    /// [`PinnedPool::set_release`] pins down the copy's completion time.
    /// Returns None when every buffer is held at `now` — the caller
    /// either waits (prefetch) or downgrades to the pageable curve
    /// (eviction/offload).
    pub fn try_acquire(&mut self, now: f64) -> Option<PinnedLease> {
        // Lazy prune keeps the scan short across a long run.
        self.leases.retain(|&(_, rel)| rel > now);
        if self.leases.len() >= self.capacity {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        self.leases.push((id, f64::INFINITY));
        Some(PinnedLease(id))
    }

    /// The copy holding `lease` completes (and its buffer frees) at `t`.
    /// Also used to *shift* a release when FIFO queue compression moves
    /// the copy's completion time.
    pub fn set_release(&mut self, lease: PinnedLease, t: f64) {
        if let Some(e) = self.leases.iter_mut().find(|e| e.0 == lease.0) {
            e.1 = t;
        }
    }

    /// Release `lease` immediately (the copy was cancelled before the
    /// wire).  Unknown or already-expired leases are a no-op.
    pub fn release(&mut self, lease: PinnedLease) {
        self.leases.retain(|&(id, _)| id != lease.0);
    }

    /// Forget every lease (iteration boundary: the timeline restarts at
    /// zero, so stale release times must not leak across).
    pub fn clear(&mut self) {
        self.leases.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_roundtrip() {
        let mut p = PinnedPool::new(2);
        assert!(p.enabled());
        assert_eq!(p.available_at(0.0), 2);
        let a = p.try_acquire(0.0).unwrap();
        let b = p.try_acquire(0.0).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.available_at(0.0), 0);
        assert!(p.try_acquire(0.0).is_none(), "pool exhausted");
        p.release(a);
        assert_eq!(p.available_at(0.0), 1);
        assert!(p.try_acquire(0.0).is_some());
    }

    #[test]
    fn leases_expire_at_release_time() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0).unwrap();
        // Unset release: held forever.
        assert_eq!(p.available_at(1e12), 0);
        p.set_release(a, 2.0);
        assert_eq!(p.available_at(1.9), 0, "still on the wire");
        assert_eq!(p.available_at(2.0), 1, "freed exactly at done");
        // A later acquire at t=3 succeeds and prunes the expired lease.
        assert!(p.try_acquire(3.0).is_some());
        assert_eq!(p.in_use_at(3.0), 1);
    }

    #[test]
    fn queue_compression_shifts_release_earlier() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0).unwrap();
        p.set_release(a, 5.0);
        // The copy ahead of it was reclaimed: it now lands at 3.5.
        p.set_release(a, 3.5);
        assert_eq!(p.available_at(4.0), 1);
        assert_eq!(p.available_at(3.0), 0);
    }

    #[test]
    fn disabled_pool_never_grants() {
        let mut p = PinnedPool::disabled();
        assert!(!p.enabled());
        assert_eq!(p.capacity(), 0);
        assert!(p.try_acquire(0.0).is_none());
        assert_eq!(p.available_at(0.0), 0);
    }

    #[test]
    fn clear_forgets_all_leases() {
        let mut p = PinnedPool::new(1);
        let a = p.try_acquire(0.0).unwrap();
        p.set_release(a, 100.0);
        p.clear();
        assert_eq!(p.in_use_at(0.0), 0);
        assert!(p.try_acquire(0.0).is_some());
        // Releasing a cleared lease is a harmless no-op.
        p.release(a);
    }
}
