//! Interconnect bandwidth model.
//!
//! The paper's efficiency argument (Sec. 4) rests on the measured fact
//! that PCIe/NVLink only reach peak bandwidth for large messages: "the
//! message size to saturate the bandwidth of PCI-e and NVLink has to be
//! at least 4MB/16MB and 4MB/128MB for P2P/collective communications"
//! (Li et al. [23]).  We model effective bandwidth with the classic
//! latency-bandwidth (alpha-beta) saturation curve
//!
//! ```text
//! eff(s) = peak * s / (s + s_half)
//! ```
//!
//! where `s_half` is the message size achieving 50% of peak.  Calibration
//! (`tests` below) checks ~80% of peak at the paper's saturation sizes.

/// A point-to-point or collective link with a saturation curve.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Peak unidirectional bandwidth, bytes/second.
    pub peak_bps: f64,
    /// Message size (bytes) reaching 50% of peak.
    pub half_sat_bytes: f64,
    /// Fixed per-transfer latency, seconds (kernel launch + driver).
    pub latency_s: f64,
}

impl Link {
    pub fn new(peak_gbps: f64, half_sat_mb: f64, latency_us: f64) -> Self {
        Link {
            peak_bps: peak_gbps * 1e9,
            half_sat_bytes: half_sat_mb * 1e6,
            latency_s: latency_us * 1e-6,
        }
    }

    /// Effective bandwidth (bytes/s) at message size `bytes`.
    pub fn effective_bps(&self, bytes: u64) -> f64 {
        let s = bytes as f64;
        self.peak_bps * s / (s + self.half_sat_bytes)
    }

    /// Wall time to move `bytes` in one message.
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.latency_s + bytes as f64 / self.effective_bps(bytes)
    }

    /// Wall time to move `total` bytes split into `n_msgs` (near-)equal
    /// messages (models per-tensor vs per-chunk transfer granularity).
    /// The remainder of the integer division is distributed one byte per
    /// message — `total % n_msgs` messages carry `per + 1` bytes — so
    /// the bytes charged always sum to exactly `total` (truncating to
    /// `per` undercharged the DeepSpeed baseline's per-tensor grad
    /// transfers by up to `n_msgs - 1` bytes).
    pub fn transfer_time_split(&self, total: u64, n_msgs: u64) -> f64 {
        if total == 0 || n_msgs == 0 {
            return 0.0;
        }
        let per = total / n_msgs;
        let rem = total % n_msgs;
        // `transfer_time(0) == 0`: when total < n_msgs the empty
        // messages are never sent and cost nothing.
        (n_msgs - rem) as f64 * self.transfer_time(per)
            + rem as f64 * self.transfer_time(per + 1)
    }

    /// The pageable-memory variant of this link: same saturation shape
    /// and latency, [`PAGEABLE_FRACTION`] of the peak — a host copy not
    /// staged through a pinned buffer bounces through the driver and
    /// reaches roughly half the pinned DMA rate.
    pub fn pageable(self) -> Link {
        Link { peak_bps: self.peak_bps * PAGEABLE_FRACTION, ..self }
    }
}

/// Fraction of pinned PCIe bandwidth a pageable host copy achieves.
pub const PAGEABLE_FRACTION: f64 = 0.5;

/// The interconnect complement of a cluster node.
#[derive(Clone, Copy, Debug)]
pub struct Interconnect {
    /// CPU<->GPU link (PCIe) at the *pinned*-memory DMA rate.  Copies
    /// staged through a pinned buffer from the staging pool
    /// ([`crate::mem::PinnedPool`]) are charged on this curve.
    pub pcie: Link,
    /// CPU<->GPU link for copies that could not acquire a pinned
    /// staging buffer: the driver bounces them through its own pageable
    /// path at roughly half the pinned rate.
    pub pcie_pageable: Link,
    /// GPU<->GPU link (NVLink) used by collectives.
    pub nvlink: Link,
    /// CPU<->NVMe link (ZeRO-Infinity third tier).  An order of
    /// magnitude slower than PCIe with a much deeper saturation knee
    /// (NVMe block I/O needs multi-MB requests to stream) and a far
    /// higher fixed latency (submission queue + flash access).  Only
    /// consulted when the plan enables the tier; every preset still
    /// carries a calibrated curve so `--nvme-gb` works everywhere.
    pub nvme: Link,
}

impl Interconnect {
    fn node(pcie: Link, nvlink: Link, nvme: Link) -> Self {
        Interconnect { pcie, pcie_pageable: pcie.pageable(), nvlink, nvme }
    }

    /// PCIe 3.0 x16 (~16 GB/s peak) + NVLink2 (~150 GB/s per direction
    /// aggregate as seen by one GPU in a DGX-style mesh).  Saturation
    /// points from Li et al. [23]: P2P half-sat well below 4 MB, NVLink
    /// collectives need tens of MB.  NVMe: datacenter U.2 drive,
    /// ~3.2 GB/s sequential.
    pub fn v100_node() -> Self {
        Self::node(
            Link::new(16.0, 1.0, 10.0),
            Link::new(150.0, 32.0, 20.0),
            Link::new(3.2, 8.0, 100.0),
        )
    }

    /// PCIe 4.0 x16 (~32 GB/s) + NVLink3 (~300 GB/s) + Gen4 NVMe
    /// (~6.4 GB/s sequential).
    pub fn a100_node() -> Self {
        Self::node(
            Link::new(32.0, 1.0, 10.0),
            Link::new(300.0, 32.0, 20.0),
            Link::new(6.4, 8.0, 80.0),
        )
    }

    /// Consumer PC: PCIe 3.0 x16, no NVLink (collectives over PCIe),
    /// consumer NVMe (~2 GB/s sustained).
    pub fn pc() -> Self {
        let pcie = Link::new(12.0, 1.0, 15.0);
        Self::node(pcie, pcie, Link::new(2.0, 8.0, 120.0))
    }

    /// Override the NVMe curve's peak (`--nvme-gbps`), keeping the
    /// preset's saturation knee and latency.  `gbps <= 0` keeps the
    /// preset curve.
    pub fn with_nvme_gbps(mut self, gbps: f64) -> Self {
        if gbps > 0.0 {
            self.nvme.peak_bps = gbps * 1e9;
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_message_size() {
        let l = Link::new(16.0, 1.0, 10.0);
        let mut prev = 0.0;
        for mb in [0.01, 0.1, 1.0, 4.0, 16.0, 64.0] {
            let bw = l.effective_bps((mb * 1e6) as u64);
            assert!(bw > prev, "bandwidth must increase with message size");
            prev = bw;
        }
    }

    #[test]
    fn paper_saturation_calibration() {
        // Li et al. [23]: >=4 MB saturates PCIe P2P.  With half-sat at
        // 1 MB, a 4 MB message reaches 80% of peak; a 64 KB message (a
        // small per-tensor transfer) reaches only ~6%.
        let pcie = Interconnect::v100_node().pcie;
        let at4mb = pcie.effective_bps(4_000_000) / pcie.peak_bps;
        let at64kb = pcie.effective_bps(64_000) / pcie.peak_bps;
        assert!(at4mb > 0.75, "4MB should be near saturation: {at4mb}");
        assert!(at64kb < 0.10, "64KB should be far from peak: {at64kb}");
    }

    #[test]
    fn split_transfers_slower_than_bulk() {
        // Chunked (single 64 MB message) vs per-tensor (512 x 128 KB):
        // the chunk layout must win by a wide margin — this is the core
        // mechanism behind the paper's bandwidth-utilization claim.
        let pcie = Interconnect::v100_node().pcie;
        let bulk = pcie.transfer_time(64 << 20);
        let split = pcie.transfer_time_split(64 << 20, 512);
        assert!(
            split > 5.0 * bulk,
            "per-tensor {split} should be >> chunked {bulk}"
        );
    }

    #[test]
    fn zero_bytes_is_free() {
        let l = Link::new(16.0, 1.0, 10.0);
        assert_eq!(l.transfer_time(0), 0.0);
        assert_eq!(l.transfer_time_split(0, 10), 0.0);
    }

    #[test]
    fn latency_dominates_tiny_messages() {
        let l = Link::new(16.0, 1.0, 10.0);
        let t = l.transfer_time(16);
        assert!(t > 0.9e-5, "latency floor applies: {t}");
    }

    #[test]
    fn property_split_charges_exactly_total_bytes() {
        // ISSUE 3 satellite: the integer division used to drop up to
        // n_msgs - 1 remainder bytes.  For arbitrary (total, n_msgs),
        // build the reference message-size list, check its sizes sum
        // to `total`, and require the function's time to equal the
        // per-message time sum of that list — so any implementation
        // that bills a different byte total (like the old truncating
        // one, checked explicitly below) fails.
        use crate::util::quickcheck::forall;
        let l = Link::new(16.0, 1.0, 10.0);
        forall(
            300,
            |rng| {
                (
                    rng.range(0, 1 << 22) as u64,
                    rng.range(0, 1000) as u64,
                )
            },
            |&(total, n_msgs)| {
                let got = l.transfer_time_split(total, n_msgs);
                if total == 0 || n_msgs == 0 {
                    return if got == 0.0 {
                        Ok(())
                    } else {
                        Err(format!("degenerate case not free: {got}"))
                    };
                }
                // Reference model: rem messages of per+1 bytes, the
                // rest of per bytes — sizes must sum to total (sanity
                // of the reference, not of the implementation).
                let per = total / n_msgs;
                let rem = total % n_msgs;
                let sizes: Vec<u64> = (0..n_msgs)
                    .map(|i| if i < rem { per + 1 } else { per })
                    .collect();
                if sizes.iter().sum::<u64>() != total {
                    return Err("reference sizes don't sum".into());
                }
                // The implementation must bill exactly that list.
                let want: f64 =
                    sizes.iter().map(|&s| l.transfer_time(s)).sum();
                if (got - want).abs() > 1e-9 * want.max(1e-30) {
                    return Err(format!(
                        "time {got} != per-message sum {want}"
                    ));
                }
                // And whenever a remainder exists, the old truncating
                // formula (n_msgs equal messages of `per` bytes,
                // clamped to 1) must disagree — the regression this
                // satellite fixes cannot silently come back.
                if rem > 0 {
                    let truncating =
                        n_msgs as f64 * l.transfer_time(per.max(1));
                    if got == truncating {
                        return Err(format!(
                            "remainder dropped again: {got} matches \
                             the truncating formula"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn split_remainder_not_dropped() {
        // 1001 bytes over 10 messages: one message carries 101 bytes.
        // The truncating version charged 10 x 100 = 1000 bytes, i.e.
        // strictly less time than the fixed version.
        let l = Link::new(16.0, 1.0, 10.0);
        let fixed = l.transfer_time_split(1001, 10);
        let truncated = 10.0 * l.transfer_time(100);
        assert!(fixed > truncated, "{fixed} !> {truncated}");
        // total < n_msgs: the empty messages are free, the occupied
        // ones carry exactly one byte each.
        let tiny = l.transfer_time_split(3, 10);
        assert!((tiny - 3.0 * l.transfer_time(1)).abs() < 1e-15);
    }

    #[test]
    fn nvme_curve_slower_than_pcie_and_overridable() {
        for net in
            [Interconnect::v100_node(), Interconnect::a100_node(), Interconnect::pc()]
        {
            assert!(net.nvme.peak_bps < net.pcie_pageable.peak_bps);
            for bytes in [64_000u64, 4_000_000, 64 << 20] {
                assert!(
                    net.nvme.transfer_time(bytes)
                        > net.pcie.transfer_time(bytes)
                );
            }
        }
        let net = Interconnect::v100_node().with_nvme_gbps(7.0);
        assert!((net.nvme.peak_bps - 7.0e9).abs() < 1e-3);
        // Shape and latency survive the override; 0 keeps the preset.
        assert_eq!(
            net.nvme.half_sat_bytes,
            Interconnect::v100_node().nvme.half_sat_bytes
        );
        let kept = Interconnect::v100_node().with_nvme_gbps(0.0);
        assert_eq!(kept.nvme.peak_bps, Interconnect::v100_node().nvme.peak_bps);
    }

    #[test]
    fn pageable_curve_is_half_peak_same_shape() {
        let net = Interconnect::v100_node();
        assert!(
            (net.pcie_pageable.peak_bps
                - PAGEABLE_FRACTION * net.pcie.peak_bps)
                .abs()
                < 1e-6
        );
        assert_eq!(net.pcie_pageable.half_sat_bytes, net.pcie.half_sat_bytes);
        assert_eq!(net.pcie_pageable.latency_s, net.pcie.latency_s);
        // Any real transfer is strictly slower on the pageable curve.
        for bytes in [64_000u64, 4_000_000, 64 << 20] {
            assert!(
                net.pcie_pageable.transfer_time(bytes)
                    > net.pcie.transfer_time(bytes)
            );
        }
        assert_eq!(net.pcie_pageable.transfer_time(0), 0.0);
    }
}
