//! Heterogeneous memory substrate.
//!
//! The paper's chunks live in a CPU+GPU heterogeneous memory space
//! (Sec. 5).  On this testbed there is no GPU; `DeviceMem` provides
//! byte-accurate capacity accounting per simulated device and
//! `HeterogeneousSpace` the per-process composite view (whole GPU +
//! 1/nproc of CPU, paper Sec. 7).  The *same* accounting drives both the
//! discrete-event simulator and the real PJRT-backed trainer, so eviction
//! and placement decisions are identical to a physical deployment with
//! these capacities (DESIGN.md §1).

pub mod bandwidth;
pub mod device;
pub mod pinned;
pub mod space;

pub use bandwidth::{Interconnect, Link, PAGEABLE_FRACTION};
pub use device::{Device, DeviceMem, MemError};
pub use pinned::{PinnedLease, PinnedPool, DEFAULT_PINNED_BUFFERS};
pub use space::HeterogeneousSpace;
