//! The per-process heterogeneous memory space: one GPU + a 1/nproc share
//! of host CPU memory (paper Sec. 7).

use std::collections::BTreeMap;

use super::device::{Device, DeviceMem, MemError};

/// Composite memory space a single training process sees.
#[derive(Clone, Debug)]
pub struct HeterogeneousSpace {
    devices: BTreeMap<Device, DeviceMem>,
}

impl HeterogeneousSpace {
    /// `gpu_bytes` of device memory + `cpu_bytes` host share.
    pub fn new(gpu_bytes: u64, cpu_bytes: u64) -> Self {
        let mut devices = BTreeMap::new();
        devices.insert(
            Device::Gpu(0),
            DeviceMem::new(Device::Gpu(0), gpu_bytes),
        );
        devices.insert(Device::Cpu, DeviceMem::new(Device::Cpu, cpu_bytes));
        HeterogeneousSpace { devices }
    }

    /// Build the per-process view of a node: the whole of one GPU and
    /// cpu_total/nproc of the host (paper Sec. 7).
    pub fn per_process(gpu_bytes: u64, cpu_total: u64, nproc: u32) -> Self {
        Self::new(gpu_bytes, cpu_total / nproc as u64)
    }

    /// Grant the space an NVMe tier of `bytes` capacity (ZeRO-Infinity
    /// third tier).  `bytes == 0` is a no-op: the device stays absent
    /// and every NVMe code path (gated on `has(Device::Nvme)`) stays
    /// dead, which is what makes `--nvme-gb 0` bit-identical.
    pub fn with_nvme(mut self, bytes: u64) -> Self {
        if bytes > 0 {
            self.devices
                .insert(Device::Nvme, DeviceMem::new(Device::Nvme, bytes));
        }
        self
    }

    /// Whether the space was built with this device tier.
    pub fn has(&self, d: Device) -> bool {
        self.devices.contains_key(&d)
    }

    pub fn dev(&self, d: Device) -> &DeviceMem {
        self.devices.get(&d).expect("unknown device")
    }

    pub fn dev_mut(&mut self, d: Device) -> &mut DeviceMem {
        self.devices.get_mut(&d).expect("unknown device")
    }

    pub fn alloc(&mut self, d: Device, bytes: u64) -> Result<(), MemError> {
        self.dev_mut(d).alloc(bytes)
    }

    pub fn dealloc(&mut self, d: Device, bytes: u64) -> Result<(), MemError> {
        self.dev_mut(d).dealloc(bytes)
    }

    pub fn total_capacity(&self) -> u64 {
        self.devices.values().map(|m| m.capacity).sum()
    }

    pub fn total_used(&self) -> u64 {
        self.devices.values().map(|m| m.used()).sum()
    }

    /// Overall utilization in [0,1] — the paper reports 86–87.5%
    /// heterogeneous-space utilization at max model scale (Sec. 9.2.1).
    pub fn utilization(&self) -> f64 {
        self.total_used() as f64 / self.total_capacity() as f64
    }

    pub fn devices(&self) -> impl Iterator<Item = &DeviceMem> {
        self.devices.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: u64 = 1 << 30;

    #[test]
    fn per_process_splits_cpu() {
        let s = HeterogeneousSpace::per_process(32 * GB, 240 * GB, 8);
        assert_eq!(s.dev(Device::Gpu(0)).capacity, 32 * GB);
        assert_eq!(s.dev(Device::Cpu).capacity, 30 * GB);
        assert_eq!(s.total_capacity(), 62 * GB);
    }

    #[test]
    fn utilization_tracks_allocs() {
        let mut s = HeterogeneousSpace::new(100, 300);
        s.alloc(Device::Gpu(0), 50).unwrap();
        s.alloc(Device::Cpu, 150).unwrap();
        assert!((s.utilization() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn nvme_tier_is_opt_in() {
        let two = HeterogeneousSpace::new(100, 300);
        assert!(!two.has(Device::Nvme));
        assert!(!two.clone().with_nvme(0).has(Device::Nvme));
        let three = two.with_nvme(500);
        assert!(three.has(Device::Nvme));
        assert_eq!(three.dev(Device::Nvme).capacity, 500);
        assert_eq!(three.total_capacity(), 900);
    }

    #[test]
    fn oom_on_one_device_even_if_other_has_room() {
        // This is exactly the failure mode the paper ascribes to static
        // partitioning (Sec. 4): per-device capacity is hard.
        let mut s = HeterogeneousSpace::new(100, 1000);
        assert!(s.alloc(Device::Gpu(0), 101).is_err());
        assert!(s.alloc(Device::Cpu, 101).is_ok());
    }
}
