//! Per-device memory accounting.

use thiserror::Error;

/// A compute/memory device in the heterogeneous space.
///
/// `Gpu(i)` is rank-local GPU *i*; in the single-process engine only
/// `Gpu(0)` and `Cpu` exist (the paper's per-process view: each process
/// owns one GPU and shares the CPU, Sec. 7).  `Nvme` is the optional
/// ZeRO-Infinity-style third tier: present in the space only when the
/// plan grants it capacity (`--nvme-gb`), absent otherwise so the
/// two-tier engine never observes it.  The derived `Ord` keeps the
/// hot-to-cold tier order Gpu < Cpu < Nvme.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Device {
    Gpu(u32),
    Cpu,
    Nvme,
}

impl Device {
    pub fn is_gpu(&self) -> bool {
        matches!(self, Device::Gpu(_))
    }

    pub fn name(&self) -> String {
        match self {
            Device::Gpu(i) => format!("gpu{i}"),
            Device::Cpu => "cpu".to_string(),
            Device::Nvme => "nvme".to_string(),
        }
    }
}

#[derive(Error, Debug, PartialEq)]
pub enum MemError {
    #[error(
        "out of memory on {device}: requested {requested} B, used {used} B \
         of {capacity} B"
    )]
    OutOfMemory {
        device: String,
        requested: u64,
        used: u64,
        capacity: u64,
    },
    #[error("double free of {0} B on {1}")]
    DoubleFree(u64, String),
}

/// Byte-accurate capacity accounting for one device.
#[derive(Clone, Debug)]
pub struct DeviceMem {
    pub device: Device,
    pub capacity: u64,
    used: u64,
    peak: u64,
}

impl DeviceMem {
    pub fn new(device: Device, capacity: u64) -> Self {
        DeviceMem { device, capacity, used: 0, peak: 0 }
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn free(&self) -> u64 {
        self.capacity.saturating_sub(self.used)
    }

    pub fn can_fit(&self, bytes: u64) -> bool {
        self.used + bytes <= self.capacity
    }

    pub fn alloc(&mut self, bytes: u64) -> Result<(), MemError> {
        if !self.can_fit(bytes) {
            return Err(MemError::OutOfMemory {
                device: self.device.name(),
                requested: bytes,
                used: self.used,
                capacity: self.capacity,
            });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    pub fn dealloc(&mut self, bytes: u64) -> Result<(), MemError> {
        if bytes > self.used {
            return Err(MemError::DoubleFree(bytes, self.device.name()));
        }
        self.used -= bytes;
        Ok(())
    }

    /// Reset usage but keep peak statistics (between iterations).
    pub fn reset_used(&mut self) {
        self.used = 0;
    }

    /// Re-cap the device (the tracer shrinks/grows the chunkable GPU
    /// capacity per moment as non-model data ebbs and flows, Sec. 8.1).
    /// `used > capacity` is allowed transiently; the chunk manager's
    /// `evict_to_fit` restores the invariant.
    pub fn set_capacity(&mut self, capacity: u64) {
        self.capacity = capacity;
    }

    /// True when usage exceeds the (possibly just lowered) capacity.
    pub fn over_capacity(&self) -> bool {
        self.used > self.capacity
    }

    pub fn reset_peak(&mut self) {
        self.peak = self.used;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut m = DeviceMem::new(Device::Gpu(0), 1000);
        m.alloc(400).unwrap();
        m.alloc(600).unwrap();
        assert_eq!(m.free(), 0);
        assert_eq!(m.peak(), 1000);
        m.dealloc(600).unwrap();
        assert_eq!(m.used(), 400);
        assert_eq!(m.peak(), 1000);
    }

    #[test]
    fn oom_is_reported_with_context() {
        let mut m = DeviceMem::new(Device::Cpu, 100);
        m.alloc(80).unwrap();
        let err = m.alloc(21).unwrap_err();
        match err {
            MemError::OutOfMemory { requested, used, capacity, .. } => {
                assert_eq!((requested, used, capacity), (21, 80, 100));
            }
            _ => panic!("wrong error"),
        }
        // Failed alloc must not change accounting.
        assert_eq!(m.used(), 80);
    }

    #[test]
    fn double_free_detected() {
        let mut m = DeviceMem::new(Device::Gpu(1), 100);
        m.alloc(10).unwrap();
        assert!(m.dealloc(11).is_err());
    }

    #[test]
    fn device_names() {
        assert_eq!(Device::Gpu(3).name(), "gpu3");
        assert_eq!(Device::Cpu.name(), "cpu");
        assert_eq!(Device::Nvme.name(), "nvme");
        assert!(Device::Gpu(0).is_gpu() && !Device::Cpu.is_gpu());
        assert!(!Device::Nvme.is_gpu());
    }

    #[test]
    fn tier_order_is_hot_to_cold() {
        assert!(Device::Gpu(0) < Device::Cpu && Device::Cpu < Device::Nvme);
    }
}
