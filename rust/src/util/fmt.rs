//! Formatting helpers: human-readable byte sizes and aligned text tables
//! (the bench harness prints paper tables/figures as text rows).

/// "1.5 GB", "240.0 MB", "312 B".
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// "1.23 s", "45.6 ms", "789 us".
pub fn human_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{:.0} us", secs * 1e6)
    }
}

/// Minimal aligned-column table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                out.push_str(&" ".repeat(width[i] - c.len() + 1));
            }
            out.push_str("|\n");
        };
        line(&mut out, &self.header);
        for (i, w) in width.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            out.push_str(&"-".repeat(w + 2));
        }
        out.push_str("|\n");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(human_bytes(42), "42 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(240 * (1 << 30)), "240.00 GB");
    }

    #[test]
    fn time_units() {
        assert_eq!(human_time(1.5), "1.500 s");
        assert_eq!(human_time(0.0123), "12.30 ms");
        assert_eq!(human_time(12e-6), "12 us");
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["model", "tflops"]);
        t.row(vec!["1B".into(), "47.1".into()]);
        t.row(vec!["18B".into(), "419".into()]);
        let s = t.render();
        assert!(s.contains("| model | tflops |"));
        assert!(s.lines().count() == 4);
        // All rows render to equal width.
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        Table::new(&["a"]).row(vec!["x".into(), "y".into()]);
    }
}
