//! proptest-lite: a randomized invariant harness (proptest itself is not in
//! the offline crate cache — DESIGN.md §6.6).
//!
//! Usage:
//! ```ignore
//! forall(200, |rng| gen_case(rng), |case| check_invariant(case));
//! ```
//! Each failing case is reported with its seed so it can be replayed with
//! `replay(seed, gen, prop)`.

use super::rng::Rng;

/// Run `prop` on `n` random cases drawn by `gen`.  Panics with the
/// offending seed on the first failure.  Base seed is fixed for
/// reproducibility; set `PATRICKSTAR_QC_SEED` to explore other universes.
pub fn forall<T, G, P>(n: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let base = std::env::var("PATRICKSTAR_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    for i in 0..n {
        let seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            panic!(
                "property failed (case {i}, seed {seed:#x}):\n  {msg}\n  \
                 case: {case:?}"
            );
        }
    }
}

/// Replay a single failing seed printed by `forall`.
pub fn replay<T, G, P>(seed: u64, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    let case = gen(&mut rng);
    if let Err(msg) = prop(&case) {
        panic!("replay failed (seed {seed:#x}): {msg}\ncase: {case:?}");
    }
}

/// Assert helper returning Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(
            100,
            |rng| rng.range(0, 1000),
            |&x| {
                if x < 1000 {
                    Ok(())
                } else {
                    Err(format!("{x} out of range"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        forall(
            100,
            |rng| rng.range(0, 10),
            |&x| {
                if x < 5 {
                    Ok(())
                } else {
                    Err("too big".into())
                }
            },
        );
    }
}
