//! Deterministic PRNG: SplitMix64 core with helpers for floats, ranges,
//! Gaussians (Box–Muller) and Zipf sampling.
//!
//! Determinism is a design requirement (DESIGN.md §1): every simulated
//! experiment and the synthetic corpus must be bit-for-bit reproducible, so
//! all randomness flows through this seeded generator.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// Cached second Gaussian from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare: None }
    }

    /// Derive an independent stream (for per-rank / per-worker use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi > lo.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// N(0, std) as f32 — GPT-2 style init.
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.gaussian() as f32) * std
    }

    /// Zipf(s) over [0, n) using rejection-free inverse-CDF on a cached
    /// harmonic table owned by the caller (see `ZipfTable`).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed inverse-CDF table for Zipf-distributed token sampling.
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // total_cmp: a degenerate exponent (s = NaN/inf) fills the CDF
        // with NaNs, and partial_cmp().unwrap() here used to panic on
        // the first draw.  Under the total order every NaN sorts above
        // u in [0,1), so the search lands on index 0 deterministically.
        match self
            .cdf
            .binary_search_by(|c| crate::util::total_cmp(*c, u))
        {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn zipf_head_heavy() {
        let t = ZipfTable::new(1000, 1.1);
        let mut r = Rng::new(9);
        let mut head = 0;
        let n = 10_000;
        for _ in 0..n {
            if t.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Top-10 of a 1000-token Zipf(1.1) carries far more than 10/1000.
        assert!(head > n / 4, "head {head}");
    }

    #[test]
    fn zipf_degenerate_weights_never_panic() {
        // A NaN/inf exponent poisons the whole CDF.  The old
        // partial_cmp().unwrap() search panicked on the first draw;
        // under total_cmp every NaN sorts above u, so sampling is a
        // deterministic index-0 pick — same seed, same answer.
        for s in [f64::NAN, f64::INFINITY] {
            let t = ZipfTable::new(16, s);
            let mut a = Rng::new(21);
            let mut b = Rng::new(21);
            for _ in 0..1000 {
                let x = t.sample(&mut a);
                assert!(x < 16);
                assert_eq!(x, t.sample(&mut b));
            }
        }
        // And an all-equal (s = 0) table still covers the full range.
        let t = ZipfTable::new(16, 0.0);
        let mut r = Rng::new(5);
        let mut seen = [false; 16];
        for _ in 0..10_000 {
            seen[t.sample(&mut r)] = true;
        }
        assert!(seen.iter().all(|&s| s), "uniform table skipped an index");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
