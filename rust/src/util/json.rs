//! Minimal JSON: parse + emit, enough for artifacts/manifest.json and the
//! config files.  (serde is unavailable in the offline crate cache —
//! DESIGN.md §6.6.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.  Object keys are ordered (BTreeMap) so emission is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    // -------------------------------------------------------- constructors
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ------------------------------------------------------------- parsing
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Json::parse(&text)
    }

    // ------------------------------------------------------------ emitting
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, 0);
        out
    }

    fn emit(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                out.push_str(if *b { "true" } else { "false" })
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => emit_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    x.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    emit_str(out, k);
                    out.push_str(": ");
                    x.emit(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            bail!("truncated utf-8");
                        }
                        s.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [true, null, "x\n"], "c": {"d": 2.5}}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string_pretty();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn parse_manifest_like() {
        let src = r#"{"chunk_elems": 262144, "params": [
            {"name": "wte", "shape": [64, 16], "numel": 1024,
             "embedding": true}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("chunk_elems").unwrap().as_usize(), Some(262144));
        let p = &v.req("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("wte"));
        assert_eq!(p.get("embedding").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::num(42.0).to_string_pretty(), "42");
        assert_eq!(Json::num(2.5).to_string_pretty(), "2.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{bad}").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ü""#).unwrap();
        assert_eq!(v.as_str(), Some("café ü"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn negative_and_exponent_numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
    }
}
