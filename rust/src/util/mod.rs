//! Self-contained utilities.
//!
//! The offline crate cache lacks serde/clap/criterion/proptest/rand, so this
//! module hand-rolls the small slices of each that the project needs (see
//! DESIGN.md §6.6): a JSON value + parser/writer, a deterministic SplitMix64
//! PRNG, a proptest-style randomized invariant harness, and formatting
//! helpers for the bench tables.

pub mod fmt;
pub mod json;
pub mod quickcheck;
pub mod rng;

pub use fmt::{human_bytes, Table};
pub use json::Json;
pub use rng::Rng;

/// Total order over `f64` for sorts, binary searches and min/max picks
/// in policy code (ISSUE 8).  `partial_cmp().unwrap()` panics on NaN —
/// and a NaN that slips into a cost or CDF table should pick a
/// deterministic branch, not kill the run.  IEEE-754 `totalOrder`
/// semantics (`f64::total_cmp`): every NaN compares greater than every
/// real value (and -NaN less), so degenerate inputs sort last instead
/// of panicking.  The `nan-unwrap` lint rule rejects `partial_cmp` in
/// favour of this helper.
pub fn total_cmp(a: f64, b: f64) -> std::cmp::Ordering {
    a.total_cmp(&b)
}
