//! Self-contained utilities.
//!
//! The offline crate cache lacks serde/clap/criterion/proptest/rand, so this
//! module hand-rolls the small slices of each that the project needs (see
//! DESIGN.md §6.6): a JSON value + parser/writer, a deterministic SplitMix64
//! PRNG, a proptest-style randomized invariant harness, and formatting
//! helpers for the bench tables.

pub mod fmt;
pub mod json;
pub mod quickcheck;
pub mod rng;

pub use fmt::{human_bytes, Table};
pub use json::Json;
pub use rng::Rng;
