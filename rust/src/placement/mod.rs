//! Device-aware operator placement (paper Sec. 8.2, Table 4).
//!
//! After the warm-up iteration, the **GPU margin space** is what remains
//! of GPU memory after the peak non-model footprint and the resident
//! param fp16 working set.  As many OS chunk groups (param fp32 +
//! momentum + variance, 12 bytes/elem) as fit are placed in the margin:
//! their ADAM runs on GPU with no PCIe round trip.  Conversely, if param
//! fp16 chunks themselves do not fit, the overflow *spills* to CPU and is
//! streamed in per iteration.  Embedding operators are pinned to the CPU:
//! moving O(V·H) parameters costs more than moving O(B·S·H) activations.

use crate::model::zoo::GptSpec;

/// Placement decision for one training task (paper Table 4's
/// margin(+)/spilling(-) row is `os_chunks_on_gpu` / `-spilled_fp16_chunks`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlacementPlan {
    /// OS chunk groups resident in GPU margin space.
    pub os_groups_on_gpu: usize,
    /// Param fp16 chunks that do NOT fit on GPU during FWD/BWD.
    pub spilled_fp16_chunks: usize,
    /// Total fp16 chunks / OS groups, for context.
    pub total_fp16_chunks: usize,
    /// Embedding FWD/BWD pinned to CPU.
    pub embedding_on_cpu: bool,
}

impl PlacementPlan {
    /// Paper Table 4 convention: positive = OS groups in margin,
    /// negative = spilled fp16 chunks.
    pub fn margin_or_spill(&self) -> i64 {
        if self.spilled_fp16_chunks > 0 {
            -(self.spilled_fp16_chunks as i64)
        } else {
            self.os_groups_on_gpu as i64
        }
    }
}

/// Compute the placement from warm-up statistics.
///
/// * `gpu_capacity`     — total GPU bytes.
/// * `peak_non_model`   — tracer's peak non-model footprint (Sec. 8.1).
/// * `chunk_elems`      — chunk size in elements.
/// * `n_fp16_chunks`    — length of the param fp16 chunk list.
pub fn plan(
    gpu_capacity: u64,
    peak_non_model: u64,
    chunk_elems: u64,
    n_fp16_chunks: usize,
    device_aware: bool,
) -> PlacementPlan {
    let fp16_chunk_bytes = 2 * chunk_elems;
    let os_group_bytes = 12 * chunk_elems; // p32 + momentum + variance
    let avail = gpu_capacity.saturating_sub(peak_non_model);
    let fp16_total = fp16_chunk_bytes * n_fp16_chunks as u64;
    if avail < fp16_total {
        // Not all param fp16 fits: some chunks stream from CPU each
        // iteration, and no margin exists for OS.
        let deficit = fp16_total - avail;
        let spilled = deficit.div_ceil(fp16_chunk_bytes) as usize;
        return PlacementPlan {
            os_groups_on_gpu: 0,
            spilled_fp16_chunks: spilled.min(n_fp16_chunks),
            total_fp16_chunks: n_fp16_chunks,
            embedding_on_cpu: true,
        };
    }
    let margin = avail - fp16_total;
    let os_groups = if device_aware {
        ((margin / os_group_bytes) as usize).min(n_fp16_chunks)
    } else {
        0 // OSC ablation: OS fixed on CPU
    };
    PlacementPlan {
        os_groups_on_gpu: os_groups,
        spilled_fp16_chunks: 0,
        total_fp16_chunks: n_fp16_chunks,
        embedding_on_cpu: true,
    }
}

/// Embedding placement trade-off (paper Sec. 8.2): moving O(V·H) params
/// vs O(B·S·H) activations.  Returns true when CPU placement moves fewer
/// bytes.
pub fn embedding_prefers_cpu(m: &GptSpec, batch: u64) -> bool {
    let param_bytes = 2 * m.embedding_params();
    // fwd activation out + bwd grad in, fp16.
    let act_bytes = 2 * 2 * batch * m.seq * m.hidden;
    act_bytes < param_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: u64 = 1 << 30;

    #[test]
    fn margin_positive_when_room() {
        // 32 GB GPU, 5 GB non-model, 10 fp16 chunks of 64 MB: margin
        // hosts (32-5-0.625)GB / 384MB ≈ 70 groups, capped at 10.
        let p = plan(32 * GB, 5 * GB, 32 << 20, 10, true);
        assert_eq!(p.spilled_fp16_chunks, 0);
        assert_eq!(p.os_groups_on_gpu, 10);
        assert_eq!(p.margin_or_spill(), 10);
    }

    #[test]
    fn spilling_when_fp16_exceeds_gpu() {
        // 8 GB GPU, 6 GB non-model: only 2 GB for fp16; 100 chunks of
        // 64 MB (6.25 GB) -> 68 spilled.
        let p = plan(8 * GB, 6 * GB, 32 << 20, 100, true);
        assert!(p.spilled_fp16_chunks > 0);
        assert_eq!(p.os_groups_on_gpu, 0);
        assert_eq!(p.margin_or_spill(), -(p.spilled_fp16_chunks as i64));
        // Deficit math: need 6400 MB, have 2048 MB -> 4352/64 = 68 chunks.
        assert_eq!(p.spilled_fp16_chunks, 68);
    }

    #[test]
    fn osc_ablation_disables_margin() {
        let p = plan(32 * GB, 5 * GB, 32 << 20, 10, false);
        assert_eq!(p.os_groups_on_gpu, 0);
        assert_eq!(p.spilled_fp16_chunks, 0);
    }

    #[test]
    fn embedding_cpu_wins_for_big_vocab() {
        let m = GptSpec::new("10B", 78, 4096);
        // V*H = 50257*4096 ≈ 206M params vs B*S*H = 16*1024*4096 ≈ 67M.
        assert!(embedding_prefers_cpu(&m, 16));
        // A huge batch flips the trade.
        assert!(!embedding_prefers_cpu(&m, 16 * 1024));
    }

    #[test]
    fn margin_scales_with_non_model() {
        let at = |nm| plan(32 * GB, nm, 32 << 20, 200, true).os_groups_on_gpu;
        assert!(at(2 * GB) > at(20 * GB));
    }
}
