//! The chunk: a fixed-size block of contiguous memory holding model-data
//! tensors of one kind (paper Sec. 5).

use crate::mem::Device;
use crate::tensor::TensorId;

/// Dense chunk id, global across all four chunk lists.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u32);

/// The four model-data chunk lists (paper Sec. 6.1).  There is *no* grad
/// fp16 list: gradients reuse the param fp16 chunks (Fig. 6), which is why
/// PatrickStar's model-data footprint is 14M bytes vs ZeRO-Offload's 18M.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChunkKind {
    ParamFp16,
    ParamFp32,
    Momentum,
    Variance,
}

impl ChunkKind {
    pub const ALL: [ChunkKind; 4] =
        [ChunkKind::ParamFp16, ChunkKind::ParamFp32, ChunkKind::Momentum,
         ChunkKind::Variance];

    /// Bytes per element in this list (fp16 vs fp32) — used for *memory
    /// accounting*.  The e2e trainer stores all payloads as f32 because
    /// the CPU PJRT backend has no f16 compute; accounting still charges
    /// 2 bytes for the fp16 list so placement decisions match a true-fp16
    /// deployment (DESIGN.md §1).
    pub fn bytes_per_elem(&self) -> u64 {
        match self {
            ChunkKind::ParamFp16 => 2,
            _ => 4,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChunkKind::ParamFp16 => "param_fp16",
            ChunkKind::ParamFp32 => "param_fp32",
            ChunkKind::Momentum => "momentum",
            ChunkKind::Variance => "variance",
        }
    }
}

/// A chunk: metadata only; payload lives in the manager's payload store.
#[derive(Clone, Debug)]
pub struct Chunk {
    pub id: ChunkId,
    pub kind: ChunkKind,
    /// Capacity in elements (equal for all chunks — required both for
    /// memory reuse and for collective communication, Sec. 6.1).
    pub capacity: u64,
    /// Elements actually occupied by tensors.
    pub used: u64,
    /// Tensors mapped into this chunk, in offset order.
    pub tensors: Vec<TensorId>,
    /// Current device (None = no payload anywhere, i.e. all-FREE and
    /// released).
    pub device: Option<Device>,
    /// Pinned chunks may not be evicted (during collectives, Sec. 7, or
    /// embedding chunks, Sec. 8.2).
    pub pinned: bool,
    /// Position of this chunk within its kind's chunk list (communication
    /// groups are formed from equal list positions, Sec. 7).
    pub list_pos: u32,
    /// True for embedding chunks: CPU-resident, not orchestrated
    /// (Sec. 8.2).
    pub embedding: bool,
}

impl Chunk {
    pub fn bytes(&self) -> u64 {
        self.capacity * self.kind.bytes_per_elem()
    }

    /// Unused tail of the chunk, in elements (fragmentation).
    pub fn waste(&self) -> u64 {
        self.capacity - self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_bytes_fp16_vs_fp32() {
        let mk = |kind| Chunk {
            id: ChunkId(0),
            kind,
            capacity: 100,
            used: 80,
            tensors: vec![],
            device: None,
            pinned: false,
            list_pos: 0,
            embedding: false,
        };
        assert_eq!(mk(ChunkKind::ParamFp16).bytes(), 200);
        assert_eq!(mk(ChunkKind::ParamFp32).bytes(), 400);
        assert_eq!(mk(ChunkKind::Momentum).waste(), 20);
    }

    #[test]
    fn model_data_is_14m_bytes_per_param() {
        // Paper Sec. 6.1: 2 (p16) + 4 (p32) + 4 (mom) + 4 (var) = 14 bytes
        // per parameter — no grad list.
        let total: u64 =
            ChunkKind::ALL.iter().map(|k| k.bytes_per_elem()).sum();
        assert_eq!(total, 14);
    }
}
