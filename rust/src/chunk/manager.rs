//! Runtime chunk orchestration (paper Sec. 6.2, 8.3).
//!
//! The manager owns the registry, the heterogeneous space accounting and
//! (in real mode) the chunk payloads.  It implements the single-process
//! parts of the paper's Algorithm 1 (Access) and Algorithm 2 (Release);
//! the distributed parts (FetchRemoteChunks / ReleaseRemoteChunk) live in
//! `dp::` and call back into these primitives.
//!
//! Every payload movement is emitted as a `MoveEvent`; the simulator
//! charges interconnect time for them, the e2e trainer uses them for
//! telemetry.  This keeps one orchestration code path for both backends
//! (DESIGN.md §6.1).

use anyhow::{anyhow, bail, Result};

use super::chunk::{Chunk, ChunkId, ChunkKind};
use super::layout::ChunkRegistry;
use crate::evict::EvictionPolicy;
use crate::mem::{Device, HeterogeneousSpace};
use crate::tensor::TensorState;
use crate::tracer::Moment;

/// What happened to a chunk payload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MoveKind {
    /// Fresh payload materialized on a device (no transfer).
    Alloc,
    /// Payload copied between devices on the requester's critical path.
    Transfer,
    /// Payload pushed off a device to make room (also a transfer, but
    /// attributed to eviction in the breakdown).
    Evict,
    /// Payload dropped entirely.
    Release,
}

#[derive(Clone, Copy, Debug)]
pub struct MoveEvent {
    pub chunk: ChunkId,
    pub from: Option<Device>,
    pub to: Option<Device>,
    pub bytes: u64,
    pub kind: MoveKind,
}

/// Aggregate movement statistics (paper Fig. 16's chunk-moving bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct MoveStats {
    pub cpu_to_gpu_bytes: u64,
    pub gpu_to_cpu_bytes: u64,
    pub cpu_to_gpu_moves: u64,
    pub gpu_to_cpu_moves: u64,
    pub evictions: u64,
    pub allocs: u64,
}

/// The chunk manager.
pub struct ChunkManager {
    pub reg: ChunkRegistry,
    pub space: HeterogeneousSpace,
    pub stats: MoveStats,
    /// Undrained movement events (consumed by the engine per operator).
    events: Vec<MoveEvent>,
    /// Real payloads (e2e mode): one optional f32 buffer per chunk.
    payloads: Vec<Option<Vec<f32>>>,
    real_mode: bool,
}

impl ChunkManager {
    pub fn new(reg: ChunkRegistry, space: HeterogeneousSpace) -> Self {
        let n = reg.chunks.len();
        ChunkManager {
            reg,
            space,
            stats: MoveStats::default(),
            events: Vec::new(),
            payloads: vec![None; n],
            real_mode: false,
        }
    }

    /// Enable real payload storage (e2e trainer).
    pub fn with_real_payloads(mut self) -> Self {
        self.real_mode = true;
        self
    }

    // ------------------------------------------------------------ queries

    pub fn chunk(&self, id: ChunkId) -> &Chunk {
        &self.reg.chunks[id.0 as usize]
    }

    fn chunk_mut(&mut self, id: ChunkId) -> &mut Chunk {
        &mut self.reg.chunks[id.0 as usize]
    }

    /// Derived chunk mobility (paper Sec. 6.2): a chunk is movable iff no
    /// tensor is COMPUTE and it is not pinned.
    pub fn movable(&self, id: ChunkId) -> bool {
        let c = self.chunk(id);
        !c.pinned
            && c.device.is_some()
            && c.tensors.iter().all(|t| {
                self.reg.tensors[t.0 as usize].state != TensorState::Compute
            })
    }

    /// All tensors FREE -> payload reusable/releasable.
    pub fn all_free(&self, id: ChunkId) -> bool {
        let c = self.chunk(id);
        c.tensors
            .iter()
            .all(|t| self.reg.tensors[t.0 as usize].state == TensorState::Free)
    }

    /// Chunks currently resident on `device` that could be evicted.
    pub fn eviction_candidates(&self, device: Device) -> Vec<ChunkId> {
        self.reg
            .chunks
            .iter()
            .filter(|c| c.device == Some(device))
            .map(|c| c.id)
            .filter(|&id| self.movable(id))
            .collect()
    }

    pub fn drain_events(&mut self) -> Vec<MoveEvent> {
        std::mem::take(&mut self.events)
    }

    pub fn payload(&self, id: ChunkId) -> Option<&[f32]> {
        self.payloads[id.0 as usize].as_deref()
    }

    pub fn payload_mut(&mut self, id: ChunkId) -> Option<&mut [f32]> {
        self.payloads[id.0 as usize].as_deref_mut()
    }

    // --------------------------------------------------------- primitives

    fn record(&mut self, ev: MoveEvent) {
        match (ev.kind, ev.from, ev.to) {
            (MoveKind::Alloc, _, _) => self.stats.allocs += 1,
            (_, Some(Device::Cpu), Some(Device::Gpu(_))) => {
                self.stats.cpu_to_gpu_bytes += ev.bytes;
                self.stats.cpu_to_gpu_moves += 1;
            }
            (_, Some(Device::Gpu(_)), Some(Device::Cpu)) => {
                self.stats.gpu_to_cpu_bytes += ev.bytes;
                self.stats.gpu_to_cpu_moves += 1;
            }
            _ => {}
        }
        if ev.kind == MoveKind::Evict {
            self.stats.evictions += 1;
        }
        self.events.push(ev);
    }

    /// Materialize a payload for `id` on `device` (paper: "prepare payload
    /// on comp_dev").  Fails if the device cannot fit it; eviction is the
    /// caller's job (`ensure_on`).
    pub fn alloc_payload(&mut self, id: ChunkId, device: Device) -> Result<()> {
        let bytes = self.chunk(id).bytes();
        if self.chunk(id).device.is_some() {
            bail!("chunk {id:?} already has a payload");
        }
        self.space.alloc(device, bytes)?;
        self.chunk_mut(id).device = Some(device);
        if self.real_mode {
            let cap = self.chunk(id).capacity as usize;
            self.payloads[id.0 as usize] = Some(vec![0.0; cap]);
        }
        self.record(MoveEvent {
            chunk: id,
            from: None,
            to: Some(device),
            bytes,
            kind: MoveKind::Alloc,
        });
        Ok(())
    }

    /// Drop a payload (paper: release remote chunk / FREE reuse).
    pub fn release_payload(&mut self, id: ChunkId) -> Result<()> {
        let c = self.chunk(id);
        let (bytes, dev) = (c.bytes(), c.device);
        let dev = dev.ok_or_else(|| anyhow!("chunk {id:?} has no payload"))?;
        self.space.dealloc(dev, bytes)?;
        self.chunk_mut(id).device = None;
        if self.real_mode {
            self.payloads[id.0 as usize] = None;
        }
        self.record(MoveEvent {
            chunk: id,
            from: Some(dev),
            to: None,
            bytes,
            kind: MoveKind::Release,
        });
        Ok(())
    }

    fn move_payload(
        &mut self,
        id: ChunkId,
        to: Device,
        kind: MoveKind,
    ) -> Result<()> {
        let c = self.chunk(id);
        let (bytes, from) = (c.bytes(), c.device);
        let from =
            from.ok_or_else(|| anyhow!("chunk {id:?} has no payload"))?;
        if from == to {
            return Ok(());
        }
        self.space.alloc(to, bytes)?;
        self.space.dealloc(from, bytes)?;
        self.chunk_mut(id).device = Some(to);
        // Real payloads live in host RAM either way; the accounting move
        // above is the honest analogue of cudaMemcpy on this testbed.
        self.record(MoveEvent { chunk: id, from: Some(from), to: Some(to),
                                bytes, kind });
        Ok(())
    }

    /// Make `id` resident on `device`, evicting other chunks if needed
    /// (paper Sec. 8.3).  `policy` picks victims among HOLD-like resident
    /// chunks; victims go to the *other* device.
    pub fn ensure_on(
        &mut self,
        id: ChunkId,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<()> {
        if self.chunk(id).device == Some(device) {
            policy.on_access(id, now);
            return Ok(());
        }
        let bytes = self.chunk(id).bytes();
        // Evict until the target device can host the chunk.
        while !self.space.dev(device).can_fit(bytes) {
            let mut candidates = self.eviction_candidates(device);
            candidates.retain(|&c| c != id);
            let victim = policy
                .pick(&candidates, &self.reg.chunks, now)
                .ok_or_else(|| {
                    anyhow!(
                        "cannot place chunk {id:?} on {}: no evictable \
                         chunk (need {bytes} B, free {} B)",
                        device.name(),
                        self.space.dev(device).free()
                    )
                })?;
            let other = match device {
                Device::Cpu => Device::Gpu(0),
                Device::Gpu(_) => Device::Cpu,
            };
            if self.all_free(victim) {
                // FREE chunks are dropped, not moved (paper: reuse/release).
                self.release_payload(victim)?;
            } else {
                self.move_payload(victim, other, MoveKind::Evict)?;
            }
        }
        if self.chunk(id).device.is_none() {
            self.alloc_payload(id, device)?;
        } else {
            self.move_payload(id, device, MoveKind::Transfer)?;
        }
        policy.on_access(id, now);
        Ok(())
    }

    /// Evict chunks from `device` until usage fits its (possibly just
    /// shrunk) capacity — invoked after the tracer lowers the chunkable
    /// GPU cap at a moment boundary (Sec. 8.1).
    pub fn evict_to_fit(
        &mut self,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<()> {
        while self.space.dev(device).over_capacity() {
            let candidates = self.eviction_candidates(device);
            let victim = policy
                .pick(&candidates, &self.reg.chunks, now)
                .ok_or_else(|| {
                    anyhow!(
                        "cannot shrink {} to {} B: no evictable chunk \
                         (used {} B)",
                        device.name(),
                        self.space.dev(device).capacity,
                        self.space.dev(device).used()
                    )
                })?;
            let other = match device {
                Device::Cpu => Device::Gpu(0),
                Device::Gpu(_) => Device::Cpu,
            };
            if self.all_free(victim) {
                self.release_payload(victim)?;
            } else {
                self.move_payload(victim, other, MoveKind::Evict)?;
            }
        }
        Ok(())
    }

    pub fn pin(&mut self, id: ChunkId) {
        self.chunk_mut(id).pinned = true;
    }

    pub fn unpin(&mut self, id: ChunkId) {
        self.chunk_mut(id).pinned = false;
    }

    // ----------------------------------------------- Algorithm 1 (Access)

    /// Access one tensor for computing on `device` (Algorithm 1, lines
    /// 21–35, single-process portion).  Returns true if the tensor was
    /// FREE and its payload slot must be zero-filled.
    pub fn access_tensor(
        &mut self,
        kind: ChunkKind,
        idx: usize,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<bool> {
        let ti = self.reg.tensor_index(kind, idx);
        let chunk = ChunkId(self.reg.tensors[ti].chunk as u32);
        self.ensure_on(chunk, device, policy, now)?;
        let was_free = self.reg.tensors[ti].state == TensorState::Free;
        if was_free && self.real_mode {
            // Zero the tensor's slot (Algorithm 1 line 31).
            let (off, n) =
                (self.reg.tensors[ti].offset, self.reg.tensors[ti].numel);
            if let Some(buf) = self.payload_mut(chunk) {
                buf[off as usize..(off + n) as usize].fill(0.0);
            }
        }
        self.reg.tensors[ti]
            .set_state(TensorState::Compute)
            .map_err(|e| anyhow!(e))?;
        self.reg.tensors[ti].ref_count += 1;
        Ok(was_free)
    }

    // ---------------------------------------------- Algorithm 2 (Release)

    /// Release one tensor to `target` (Algorithm 2, lines 31–39,
    /// single-process portion).  With shared parameters the state only
    /// changes when the access refcount drains.
    pub fn release_tensor(
        &mut self,
        kind: ChunkKind,
        idx: usize,
        target: TensorState,
    ) -> Result<()> {
        let ti = self.reg.tensor_index(kind, idx);
        let t = &mut self.reg.tensors[ti];
        if t.ref_count == 0 {
            bail!("release of unaccessed tensor {}", t.name);
        }
        t.ref_count -= 1;
        if t.ref_count == 0 {
            t.set_state(target).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    /// Reset all tensors of a kind from HOLD_AFTER_FWD to HOLD (paper:
    /// end of FWD, required for checkpoint-recompute disambiguation).
    pub fn reset_after_fwd(&mut self, kind: ChunkKind) -> Result<()> {
        for i in 0..self.reg.n_model_tensors {
            let ti = self.reg.tensor_index(kind, i);
            if self.reg.tensors[ti].state == TensorState::HoldAfterFwd {
                self.reg.tensors[ti]
                    .set_state(TensorState::Hold)
                    .map_err(|e| anyhow!(e))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::layout::TensorSpec;
    use crate::evict::FifoPolicy;

    fn mk(n_tensors: usize, numel: u64, chunk_elems: u64,
          gpu: u64, cpu: u64) -> ChunkManager {
        let specs: Vec<TensorSpec> = (0..n_tensors)
            .map(|i| TensorSpec {
                name: format!("t{i}"),
                numel,
                embedding: false,
            })
            .collect();
        let reg = ChunkRegistry::build(&specs, chunk_elems).unwrap();
        ChunkManager::new(reg, HeterogeneousSpace::new(gpu, cpu))
    }

    #[test]
    fn alloc_then_release_roundtrip() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Gpu(0)).unwrap();
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)));
        assert_eq!(m.space.dev(Device::Gpu(0)).used(), 200); // 100 elem fp16
        m.release_payload(id).unwrap();
        assert_eq!(m.chunk(id).device, None);
        assert_eq!(m.space.dev(Device::Gpu(0)).used(), 0);
    }

    #[test]
    fn ensure_on_evicts_hold_chunks() {
        // GPU fits exactly one fp16 chunk (200 B); placing the second must
        // evict the first to CPU.
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let (a, b) = (list[0], list[1]);
        let mut pol = FifoPolicy::default();
        m.ensure_on(a, Device::Gpu(0), &mut pol, 0).unwrap();
        // Mark a's tensors HOLD so it is evictable but not droppable.
        for i in [0usize, 1] {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.ensure_on(b, Device::Gpu(0), &mut pol, 1).unwrap();
        assert_eq!(m.chunk(a).device, Some(Device::Cpu), "a evicted");
        assert_eq!(m.chunk(b).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.evictions, 1);
        assert_eq!(m.stats.gpu_to_cpu_bytes, 200);
    }

    #[test]
    fn free_chunks_are_dropped_not_moved() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        // Tensors stay FREE -> chunk 0's payload is reusable.
        m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).unwrap();
        assert_eq!(m.chunk(list[0]).device, None, "dropped");
        assert_eq!(m.stats.gpu_to_cpu_bytes, 0, "no transfer for FREE");
    }

    #[test]
    fn compute_chunks_never_evicted() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        // Access both tensors of chunk0 -> COMPUTE.
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.access_tensor(ChunkKind::ParamFp16, 1, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        // No evictable chunk -> placing chunk1 on GPU must fail.
        let err =
            m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).unwrap_err();
        assert!(err.to_string().contains("no evictable"), "{err}");
    }

    #[test]
    fn pinned_chunks_never_evicted() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        m.pin(list[0]);
        assert!(m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).is_err());
        m.unpin(list[0]);
        assert!(m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).is_ok());
    }

    #[test]
    fn refcount_gates_release() {
        // A parameter shared by two operators only leaves COMPUTE when
        // both release it (paper Sec. 6.2).
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let mut pol = FifoPolicy::default();
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.release_tensor(ChunkKind::ParamFp16, 0, TensorState::HoldAfterFwd)
            .unwrap();
        let ti = m.reg.tensor_index(ChunkKind::ParamFp16, 0);
        assert_eq!(m.reg.tensors[ti].state, TensorState::Compute);
        m.release_tensor(ChunkKind::ParamFp16, 0, TensorState::HoldAfterFwd)
            .unwrap();
        assert_eq!(m.reg.tensors[ti].state, TensorState::HoldAfterFwd);
    }

    #[test]
    fn access_zeroes_free_tensor_in_real_mode() {
        let mut m = mk(2, 50, 100, 10_000, 10_000).with_real_payloads();
        let mut pol = FifoPolicy::default();
        let was_free = m
            .access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        assert!(was_free);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        assert!(m.payload(id).unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn reset_after_fwd() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let mut pol = FifoPolicy::default();
        for i in 0..2 {
            m.access_tensor(ChunkKind::ParamFp16, i, Device::Gpu(0),
                            &mut pol, 0).unwrap();
            m.release_tensor(ChunkKind::ParamFp16, i,
                             TensorState::HoldAfterFwd).unwrap();
        }
        m.reset_after_fwd(ChunkKind::ParamFp16).unwrap();
        for i in 0..2 {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            assert_eq!(m.reg.tensors[ti].state, TensorState::Hold);
        }
    }

    #[test]
    fn move_events_drained() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        m.ensure_on(id, Device::Gpu(0), &mut pol, 0).unwrap();
        let ev = m.drain_events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].kind, MoveKind::Alloc);
        assert_eq!(ev[1].kind, MoveKind::Transfer);
        assert!(m.drain_events().is_empty());
    }
}
