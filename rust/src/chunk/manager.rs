//! Runtime chunk orchestration (paper Sec. 6.2, 8.3).
//!
//! The manager owns the registry, the heterogeneous space accounting and
//! (in real mode) the chunk payloads.  It implements the single-process
//! parts of the paper's Algorithm 1 (Access) and Algorithm 2 (Release);
//! the distributed parts (FetchRemoteChunks / ReleaseRemoteChunk) live in
//! `dp::` and call back into these primitives.
//!
//! Every payload movement is emitted as a `MoveEvent`; the simulator
//! charges interconnect time for them, the e2e trainer uses them for
//! telemetry.  This keeps one orchestration code path for both backends
//! (DESIGN.md §6.1).

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use super::chunk::{Chunk, ChunkId, ChunkKind};
use super::layout::ChunkRegistry;
use crate::evict::EvictionPolicy;
use crate::mem::{Device, HeterogeneousSpace};
use crate::tensor::TensorState;
use crate::tracer::Moment;

/// What happened to a chunk payload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MoveKind {
    /// Fresh payload materialized on a device (no transfer).
    Alloc,
    /// Payload copied between devices on the requester's critical path.
    Transfer,
    /// Payload pushed off a device to make room (also a transfer, but
    /// attributed to eviction in the breakdown).
    Evict,
    /// Payload dropped entirely.
    Release,
    /// Payload staged ahead of use on an async copy stream; the chunk is
    /// *in flight* until its first access completes the copy.
    Prefetch,
    /// A pending prefetch reclaimed under memory pressure before its
    /// copy reached the wire: the chunk returns to its source device and
    /// the traffic accounted at issue is credited back.
    PrefetchCancel,
    /// A remote chunk's payload, staged for an in-flight lookahead
    /// all-gather, reclaimed under memory pressure: the payload is
    /// dropped (remote chunks have no home to return to) and the engine
    /// credits the group's collective back.
    GatherCancel,
}

#[derive(Clone, Copy, Debug)]
pub struct MoveEvent {
    pub chunk: ChunkId,
    pub from: Option<Device>,
    pub to: Option<Device>,
    pub bytes: u64,
    pub kind: MoveKind,
}

impl MoveEvent {
    /// The PCIe copy engine this event's transfer rides, or None for
    /// events that move no bytes across the link (allocs, releases,
    /// cancels).  The completion-protocol half of the move: every
    /// execution backend translates drained events into copy charges
    /// through this one classifier, so the simulator and the real
    /// trainer agree on what counts as H2D vs D2H traffic.
    pub fn copy_dir(&self) -> Option<crate::sim::CopyDir> {
        use crate::sim::CopyDir;
        match (self.from, self.to) {
            (Some(Device::Cpu), Some(Device::Gpu(_))) => Some(CopyDir::H2D),
            (Some(Device::Gpu(_)), Some(Device::Cpu)) => Some(CopyDir::D2H),
            _ => None,
        }
    }
}

/// Aggregate movement statistics (paper Fig. 16's chunk-moving bars).
#[derive(Clone, Copy, Debug, Default)]
pub struct MoveStats {
    pub cpu_to_gpu_bytes: u64,
    pub gpu_to_cpu_bytes: u64,
    pub cpu_to_gpu_moves: u64,
    pub gpu_to_cpu_moves: u64,
    /// NVMe-tier traffic (ISSUE 7): bytes moved onto / off the NVMe
    /// device, whatever the other endpoint (Cpu spills and staged
    /// Gpu<->Nvme copies alike).  All zero with the tier off.
    pub to_nvme_bytes: u64,
    pub to_nvme_moves: u64,
    pub from_nvme_bytes: u64,
    pub from_nvme_moves: u64,
    pub evictions: u64,
    pub allocs: u64,
    /// Prefetches issued (cancelled ones included; their bytes are not).
    pub prefetches: u64,
    pub prefetch_cancels: u64,
    /// In-flight lookahead gathers reclaimed under memory pressure.
    pub gather_cancels: u64,
    /// Prefetch/lookahead-gather issues deferred because the pinned
    /// staging pool had no free buffer (the engine retries next moment;
    /// the effective lookahead window is throttled by pool capacity).
    pub pinned_waits: u64,
    /// Pinned staging leases still held when an iteration ended (ISSUE
    /// 6 satellite).  Always zero on a healthy schedule: every sim-path
    /// lease expires by the iteration makespan or is released by its
    /// cancel path.  Debug builds assert instead of counting.
    pub lease_leaks: u64,
}

/// The chunk manager.
#[derive(Clone)]
pub struct ChunkManager {
    pub reg: ChunkRegistry,
    pub space: HeterogeneousSpace,
    pub stats: MoveStats,
    /// Undrained movement events (consumed by the engine per operator).
    events: Vec<MoveEvent>,
    /// Chunks with a pending (issued, not yet consumed) prefetch copy,
    /// mapped to the *source* device the copy left (cancellation
    /// restores there — with three tiers the source is no longer
    /// implied by the target).  In-flight chunks already occupy space
    /// on their target device but may not be evicted — only cancelled —
    /// until first access completes the copy.
    inflight: BTreeMap<ChunkId, Device>,
    /// Remote chunks whose payload is being filled by an in-flight
    /// lookahead all-gather on the collective stream.  Same
    /// cancel-never-victimize contract as `inflight`: invisible to
    /// eviction, reclaimed whole (the payload is dropped) as the victim
    /// of last resort.
    gathering: BTreeSet<ChunkId>,
    /// Real payloads (e2e mode): one optional f32 buffer per chunk.
    payloads: Vec<Option<Vec<f32>>>,
    real_mode: bool,
}

impl ChunkManager {
    pub fn new(reg: ChunkRegistry, space: HeterogeneousSpace) -> Self {
        let n = reg.chunks.len();
        ChunkManager {
            reg,
            space,
            stats: MoveStats::default(),
            events: Vec::new(),
            inflight: BTreeMap::new(),
            gathering: BTreeSet::new(),
            payloads: vec![None; n],
            real_mode: false,
        }
    }

    /// Enable real payload storage (e2e trainer).
    pub fn with_real_payloads(mut self) -> Self {
        self.real_mode = true;
        self
    }

    /// Re-derive the shared (host-sharded) tier budgets after an
    /// elastic rescale (ISSUE 9): each rank sees `cpu_total/nproc` of
    /// host memory and `nvme_total/nproc` of the NVMe tier, so a
    /// world-size change re-caps both.  GPU capacity is per-device and
    /// untouched.  A shrink *grows* the per-rank shares (resident
    /// payloads always still fit); a grow may leave a tier transiently
    /// over-capacity, which the same `evict_to_fit` pass that settles
    /// warm-up cap-shrinks restores.
    pub fn resize_shared_tiers(
        &mut self,
        cpu_bytes: u64,
        nvme_bytes: Option<u64>,
    ) {
        self.set_device_capacity(Device::Cpu, cpu_bytes);
        if let Some(nb) = nvme_bytes {
            if self.space.has(Device::Nvme) {
                self.set_device_capacity(Device::Nvme, nb);
            }
        }
    }

    /// Re-cap one tier.  The only sanctioned mutable path to
    /// `MemSpace` capacities from outside the manager: policy code
    /// (the session's warm-up cap schedule, the elastic rescale path)
    /// calls this instead of reaching through `space.dev_mut`, which
    /// the `dev-mut-layering` lint rule enforces.
    pub fn set_device_capacity(&mut self, d: Device, bytes: u64) {
        self.space.dev_mut(d).set_capacity(bytes);
    }

    // ------------------------------------------------------------ queries

    pub fn chunk(&self, id: ChunkId) -> &Chunk {
        &self.reg.chunks[id.0 as usize]
    }

    fn chunk_mut(&mut self, id: ChunkId) -> &mut Chunk {
        &mut self.reg.chunks[id.0 as usize]
    }

    /// Derived chunk mobility (paper Sec. 6.2): a chunk is movable iff no
    /// tensor is COMPUTE, it is not pinned, and no prefetch copy or
    /// lookahead all-gather is in flight for it (an in-flight chunk is
    /// cancelled, never evicted — spilling a half-arrived payload to the
    /// CPU would persist garbage).
    pub fn movable(&self, id: ChunkId) -> bool {
        let c = self.chunk(id);
        !c.pinned
            && c.device.is_some()
            && !self.inflight.contains_key(&id)
            && !self.gathering.contains(&id)
            && c.tensors.iter().all(|t| {
                self.reg.tensors[t.0 as usize].state != TensorState::Compute
            })
    }

    /// All tensors FREE -> payload reusable/releasable.
    pub fn all_free(&self, id: ChunkId) -> bool {
        let c = self.chunk(id);
        c.tensors
            .iter()
            .all(|t| self.reg.tensors[t.0 as usize].state == TensorState::Free)
    }

    /// Chunks currently resident on `device` that could be evicted.
    pub fn eviction_candidates(&self, device: Device) -> Vec<ChunkId> {
        self.reg
            .chunks
            .iter()
            .filter(|c| c.device == Some(device))
            .map(|c| c.id)
            .filter(|&id| self.movable(id))
            .collect()
    }

    pub fn drain_events(&mut self) -> Vec<MoveEvent> {
        std::mem::take(&mut self.events)
    }

    /// True while a prefetch copy for `id` is pending.
    pub fn is_inflight(&self, id: ChunkId) -> bool {
        self.inflight.contains_key(&id)
    }

    /// Lowest-id chunk with a pending prefetch on `device` — the victim
    /// of last resort when eviction finds no movable chunk.
    pub fn pending_prefetch_on(&self, device: Device) -> Option<ChunkId> {
        self.inflight
            .keys()
            .copied()
            .filter(|&c| self.chunk(c).device == Some(device))
            .min()
    }

    /// True while an in-flight lookahead all-gather is filling `id`.
    pub fn is_gathering(&self, id: ChunkId) -> bool {
        self.gathering.contains(&id)
    }

    /// Lowest-id chunk on `device` mid-gather — reclaimed after pending
    /// prefetches when eviction has nothing else left.
    pub fn gathering_on(&self, device: Device) -> Option<ChunkId> {
        self.gathering
            .iter()
            .copied()
            .filter(|&c| self.chunk(c).device == Some(device))
            .min()
    }

    /// All chunks currently mid-gather (iteration-boundary settling).
    pub fn gathering_chunks(&self) -> Vec<ChunkId> {
        let mut v: Vec<ChunkId> = self.gathering.iter().copied().collect();
        v.sort_unstable();
        v
    }

    pub fn payload(&self, id: ChunkId) -> Option<&[f32]> {
        self.payloads[id.0 as usize].as_deref()
    }

    pub fn payload_mut(&mut self, id: ChunkId) -> Option<&mut [f32]> {
        self.payloads[id.0 as usize].as_deref_mut()
    }

    // --------------------------------------------------------- primitives

    fn record(&mut self, ev: MoveEvent) {
        match (ev.kind, ev.from, ev.to) {
            (MoveKind::Alloc, _, _) => self.stats.allocs += 1,
            // Credit back the traffic accounted when the prefetch was
            // issued (the copy never reached the wire).  The `to` device
            // is the recorded *source* the chunk returns to: an NVMe
            // source means the issue charged `from_nvme`, a CPU source
            // charged `cpu_to_gpu`, a GPU source charged `gpu_to_cpu`.
            (
                MoveKind::PrefetchCancel,
                Some(Device::Gpu(_)),
                Some(Device::Nvme),
            ) => {
                self.stats.from_nvme_bytes =
                    self.stats.from_nvme_bytes.saturating_sub(ev.bytes);
                self.stats.from_nvme_moves =
                    self.stats.from_nvme_moves.saturating_sub(1);
                self.stats.prefetch_cancels += 1;
            }
            (MoveKind::PrefetchCancel, Some(Device::Gpu(_)), _) => {
                self.stats.cpu_to_gpu_bytes =
                    self.stats.cpu_to_gpu_bytes.saturating_sub(ev.bytes);
                self.stats.cpu_to_gpu_moves =
                    self.stats.cpu_to_gpu_moves.saturating_sub(1);
                self.stats.prefetch_cancels += 1;
            }
            (MoveKind::PrefetchCancel, _, _) => {
                self.stats.gpu_to_cpu_bytes =
                    self.stats.gpu_to_cpu_bytes.saturating_sub(ev.bytes);
                self.stats.gpu_to_cpu_moves =
                    self.stats.gpu_to_cpu_moves.saturating_sub(1);
                self.stats.prefetch_cancels += 1;
            }
            // Tier traffic: any copy that touches NVMe counts on the
            // NVMe side regardless of the other endpoint (the PCIe hop
            // of a staged copy is billed by phase, not here).
            (_, Some(Device::Nvme), Some(_)) => {
                self.stats.from_nvme_bytes += ev.bytes;
                self.stats.from_nvme_moves += 1;
            }
            (_, Some(_), Some(Device::Nvme)) => {
                self.stats.to_nvme_bytes += ev.bytes;
                self.stats.to_nvme_moves += 1;
            }
            (_, Some(Device::Cpu), Some(Device::Gpu(_))) => {
                self.stats.cpu_to_gpu_bytes += ev.bytes;
                self.stats.cpu_to_gpu_moves += 1;
            }
            (_, Some(Device::Gpu(_)), Some(Device::Cpu)) => {
                self.stats.gpu_to_cpu_bytes += ev.bytes;
                self.stats.gpu_to_cpu_moves += 1;
            }
            _ => {}
        }
        match ev.kind {
            MoveKind::Evict => self.stats.evictions += 1,
            MoveKind::Prefetch => self.stats.prefetches += 1,
            MoveKind::GatherCancel => self.stats.gather_cancels += 1,
            _ => {}
        }
        self.events.push(ev);
    }

    /// Materialize a payload for `id` on `device` (paper: "prepare payload
    /// on comp_dev").  Fails if the device cannot fit it; eviction is the
    /// caller's job (`ensure_on`).
    pub fn alloc_payload(&mut self, id: ChunkId, device: Device) -> Result<()> {
        let bytes = self.chunk(id).bytes();
        if self.chunk(id).device.is_some() {
            bail!("chunk {id:?} already has a payload");
        }
        self.space.alloc(device, bytes)?;
        self.chunk_mut(id).device = Some(device);
        if self.real_mode {
            let cap = self.chunk(id).capacity as usize;
            self.payloads[id.0 as usize] = Some(vec![0.0; cap]);
        }
        self.record(MoveEvent {
            chunk: id,
            from: None,
            to: Some(device),
            bytes,
            kind: MoveKind::Alloc,
        });
        Ok(())
    }

    /// Drop a payload (paper: release remote chunk / FREE reuse).
    pub fn release_payload(&mut self, id: ChunkId) -> Result<()> {
        if let Some(src) = self.inflight.remove(&id) {
            // Releasing an in-flight chunk implicitly cancels its copy;
            // reclaim the accounted traffic before dropping the payload.
            // The recorded source tells `record` which direction was
            // charged at issue.
            let c = self.chunk(id);
            let (bytes, dev) = (c.bytes(), c.device);
            self.record(MoveEvent {
                chunk: id,
                from: dev,
                to: Some(src),
                bytes,
                kind: MoveKind::PrefetchCancel,
            });
        }
        // Releasing a gathered chunk simply drops the (consumed or
        // superfluous) gather state along with the payload.
        self.gathering.remove(&id);
        let c = self.chunk(id);
        let (bytes, dev) = (c.bytes(), c.device);
        let dev = dev.ok_or_else(|| anyhow!("chunk {id:?} has no payload"))?;
        self.space.dealloc(dev, bytes)?;
        self.chunk_mut(id).device = None;
        if self.real_mode {
            self.payloads[id.0 as usize] = None;
        }
        self.record(MoveEvent {
            chunk: id,
            from: Some(dev),
            to: None,
            bytes,
            kind: MoveKind::Release,
        });
        Ok(())
    }

    fn move_payload(
        &mut self,
        id: ChunkId,
        to: Device,
        kind: MoveKind,
    ) -> Result<()> {
        let c = self.chunk(id);
        let (bytes, from) = (c.bytes(), c.device);
        let from =
            from.ok_or_else(|| anyhow!("chunk {id:?} has no payload"))?;
        if from == to {
            return Ok(());
        }
        // Moving an in-flight chunk forces its copy to completion first
        // (callers wait on the timeline before relocating such chunks).
        self.inflight.remove(&id);
        self.gathering.remove(&id);
        self.space.alloc(to, bytes)?;
        self.space.dealloc(from, bytes)?;
        self.chunk_mut(id).device = Some(to);
        // Real payloads live in host RAM either way; the accounting move
        // above is the honest analogue of cudaMemcpy on this testbed.
        self.record(MoveEvent { chunk: id, from: Some(from), to: Some(to),
                                bytes, kind });
        Ok(())
    }

    /// True when the optimization plan granted an NVMe tier (the device
    /// exists in the space).  Everything tier-aware gates on this so a
    /// two-tier run takes bit-identical decisions to the pre-NVMe code.
    pub fn has_nvme(&self) -> bool {
        self.space.has(Device::Nvme)
    }

    /// The device victims spill to: one tier colder.  CPU victims spill
    /// to NVMe when the tier exists, otherwise back to GPU 0 (the
    /// two-tier ping-pong of the original design); NVMe victims climb
    /// back to the CPU (only reachable via explicit relocation).
    fn spill_target(&self, device: Device) -> Device {
        match device {
            Device::Cpu if self.has_nvme() => Device::Nvme,
            Device::Cpu => Device::Gpu(0),
            Device::Gpu(_) => Device::Cpu,
            Device::Nvme => Device::Cpu,
        }
    }

    /// Push `victim` off `device`: FREE chunks are dropped, not moved
    /// (paper: reuse/release); the rest spill one tier colder.  With an
    /// NVMe tier, a GPU victim that finds the CPU full cascades first:
    /// room is made on the CPU (spilling *its* coldest chunks to NVMe)
    /// before the move, so pressure flows GPU -> CPU -> NVMe instead of
    /// failing at the middle tier.
    /// Demote one chunk to a colder tier outside the pressure path
    /// (post-warm-up NVMe placement).  Same safety rules as eviction:
    /// pinned, computing, mid-gather or in-flight chunks stay put, and
    /// the target tier must already have room.  Returns whether the
    /// chunk actually moved.
    pub fn demote(&mut self, id: ChunkId, to: Device) -> Result<bool> {
        if !self.movable(id)
            || !self.space.dev(to).can_fit(self.chunk(id).bytes())
        {
            return Ok(false);
        }
        self.move_payload(id, to, MoveKind::Evict)?;
        Ok(true)
    }

    fn evict_one(
        &mut self,
        victim: ChunkId,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<()> {
        if self.all_free(victim) {
            return self.release_payload(victim);
        }
        let to = self.spill_target(device);
        let bytes = self.chunk(victim).bytes();
        if to == Device::Cpu
            && self.has_nvme()
            && !self.space.dev(to).can_fit(bytes)
        {
            self.evict_until(
                Device::Cpu,
                policy,
                now,
                Some(victim),
                |m| m.space.dev(Device::Cpu).can_fit(bytes),
                |m| {
                    format!(
                        "cannot cascade chunk {victim:?} to cpu: no \
                         evictable chunk (need {bytes} B, free {} B)",
                        m.space.dev(Device::Cpu).free()
                    )
                },
            )?;
        }
        self.move_payload(victim, to, MoveKind::Evict)
    }

    /// One pressure event: evict policy-picked victims from `device`
    /// until `done` holds.  Candidates are collected once and victims
    /// retired in place — nothing inside the loop changes any tensor
    /// state, so the movable set cannot grow and a fresh registry scan
    /// per victim is pure waste.  When no movable chunk remains, a
    /// pending prefetch is reclaimed (cancelled, not fetched twice) as
    /// the victim of last resort; if its source device is itself full,
    /// the copy is completed instead and spilled normally.
    fn evict_until(
        &mut self,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
        exclude: Option<ChunkId>,
        done: impl Fn(&Self) -> bool,
        describe: impl Fn(&Self) -> String,
    ) -> Result<()> {
        if done(self) {
            return Ok(());
        }
        let mut candidates = self.eviction_candidates(device);
        if let Some(x) = exclude {
            candidates.retain(|&c| c != x);
        }
        while !done(self) {
            match policy.pick(&candidates, &self.reg.chunks, now) {
                Some(victim) => {
                    candidates.retain(|&c| c != victim);
                    self.evict_one(victim, device, policy, now)?;
                }
                None => {
                    if let Some(c) = self.pending_prefetch_on(device) {
                        if self.cancel_prefetch(c).is_err() {
                            self.complete_prefetch(c);
                            candidates.push(c);
                        }
                        continue;
                    }
                    // Mid-gather chunks are the victims after that:
                    // reclaimed whole (never spilled half-filled).
                    if let Some(c) = self.gathering_on(device) {
                        self.cancel_gather(c)?;
                        continue;
                    }
                    bail!("{}", describe(self));
                }
            }
        }
        Ok(())
    }

    /// Make `id` resident on `device`, evicting other chunks if needed
    /// (paper Sec. 8.3).  `policy` picks victims among HOLD-like resident
    /// chunks; victims go to the *other* device.
    pub fn ensure_on(
        &mut self,
        id: ChunkId,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<()> {
        if self.chunk(id).device == Some(device) {
            // First access of a prefetched chunk consumes the in-flight
            // copy (the engine waits on the timeline before this call).
            self.inflight.remove(&id);
            policy.on_access(id, now);
            return Ok(());
        }
        let bytes = self.chunk(id).bytes();
        self.evict_until(
            device,
            policy,
            now,
            Some(id),
            |m| m.space.dev(device).can_fit(bytes),
            |m| {
                format!(
                    "cannot place chunk {id:?} on {}: no evictable \
                     chunk (need {bytes} B, free {} B)",
                    device.name(),
                    m.space.dev(device).free()
                )
            },
        )?;
        if self.chunk(id).device.is_none() {
            self.alloc_payload(id, device)?;
        } else {
            self.move_payload(id, device, MoveKind::Transfer)?;
        }
        policy.on_access(id, now);
        Ok(())
    }

    /// Evict chunks from `device` until usage fits its (possibly just
    /// shrunk) capacity — invoked after the tracer lowers the chunkable
    /// GPU cap at a moment boundary (Sec. 8.1).
    pub fn evict_to_fit(
        &mut self,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<()> {
        self.evict_until(
            device,
            policy,
            now,
            None,
            |m| !m.space.dev(device).over_capacity(),
            |m| {
                format!(
                    "cannot shrink {} to {} B: no evictable chunk \
                     (used {} B)",
                    device.name(),
                    m.space.dev(device).capacity,
                    m.space.dev(device).used()
                )
            },
        )
    }

    // ----------------------------------------------------------- prefetch

    /// Stage `id` onto `device` ahead of its next use (warm-up-guided
    /// pipeline).  Works in both directions: CPU->GPU for upcoming
    /// FWD/BWD operator uses, GPU->CPU for the next CPU-ADAM group.
    /// Best-effort: returns Ok(false) without touching anything when the
    /// chunk is not a HOLD-like chunk resident on the opposite device,
    /// or when making room would require evicting a chunk `may_evict`
    /// rejects (the engine passes a Belady guard: only victims whose
    /// next use lies beyond the prefetched chunk's use may spill).
    ///
    /// `limit_bytes` caps the device's post-prefetch usage — the caller
    /// derives it from the tightest `chunkable_gpu` grant between now
    /// and the use moment, so staged payload never triggers the very
    /// evictions it is meant to hide.
    ///
    /// On success the chunk is accounted on `device` and marked
    /// in-flight: it cannot be evicted (only cancelled) until an access
    /// completes the copy.
    pub fn prefetch_to(
        &mut self,
        id: ChunkId,
        device: Device,
        limit_bytes: u64,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
        may_evict: &dyn Fn(ChunkId) -> bool,
    ) -> Result<bool> {
        let src = {
            let c = self.chunk(id);
            // Tier-aware source rule: a GPU prefetch pulls from either
            // colder tier (CPU, or NVMe via the staged two-hop route);
            // the ADAM-staging direction only ever stages GPU-resident
            // chunks down to the CPU.  NVMe is never a prefetch
            // *target* — chunks reach it by eviction or relocation.
            let ok_source = match device {
                Device::Gpu(_) => matches!(
                    c.device,
                    Some(Device::Cpu) | Some(Device::Nvme)
                ),
                Device::Cpu => c.device == Some(Device::Gpu(0)),
                Device::Nvme => false,
            };
            if !ok_source
                || c.embedding
                || self.inflight.contains_key(&id)
                || !self.movable(id)
            {
                return Ok(false);
            }
            c.device.unwrap()
        };
        let bytes = self.chunk(id).bytes();
        let mut projected = self.space.dev(device).used();
        if projected + bytes <= limit_bytes {
            // Common case: headroom exists, no victim planning needed —
            // skip the registry scan entirely (this runs for every
            // window chunk at every moment tick).
            self.move_payload(id, device, MoveKind::Prefetch)?;
            self.inflight.insert(id, src);
            return Ok(true);
        }
        // Plan the full victim set first so an infeasible prefetch
        // abstains without having moved anything — including checking
        // that the spill device can absorb every non-FREE victim (the
        // staged chunk vacates its own slot only after the victims
        // land, so its bytes don't count as room).
        let spill = self.spill_target(device);
        let mut spill_free = self.space.dev(spill).free();
        let mut candidates: Vec<ChunkId> = self
            .eviction_candidates(device)
            .into_iter()
            .filter(|&v| v != id && may_evict(v))
            .collect();
        let mut victims = Vec::new();
        while projected + bytes > limit_bytes {
            match policy.pick(&candidates, &self.reg.chunks, now) {
                Some(v) => {
                    candidates.retain(|&c| c != v);
                    let vb = self.chunk(v).bytes();
                    if !self.all_free(v) {
                        if spill_free < vb {
                            return Ok(false);
                        }
                        spill_free -= vb;
                    }
                    projected = projected.saturating_sub(vb);
                    victims.push(v);
                }
                None => return Ok(false),
            }
        }
        for v in victims {
            self.evict_one(v, device, policy, now)?;
        }
        self.move_payload(id, device, MoveKind::Prefetch)?;
        self.inflight.insert(id, src);
        Ok(true)
    }

    /// Reclaim a pending prefetch: the chunk returns to its source
    /// device and the traffic accounted at issue is credited back (the
    /// copy is assumed still queued behind the copy stream's backlog,
    /// not on the wire).  Atomic: if the source device can no longer
    /// host the chunk, nothing changes and the prefetch stays pending —
    /// callers fall back to completing the copy and evicting normally.
    pub fn cancel_prefetch(&mut self, id: ChunkId) -> Result<()> {
        let Some(&restore) = self.inflight.get(&id) else {
            bail!("chunk {id:?} has no pending prefetch");
        };
        let c = self.chunk(id);
        let (bytes, dev) = (c.bytes(), c.device);
        let dev = dev.ok_or_else(|| anyhow!("in-flight chunk {id:?} \
                                             lost its payload"))?;
        self.space.alloc(restore, bytes)?;
        self.space.dealloc(dev, bytes)?;
        self.inflight.remove(&id);
        self.chunk_mut(id).device = Some(restore);
        self.record(MoveEvent {
            chunk: id,
            from: Some(dev),
            to: Some(restore),
            bytes,
            kind: MoveKind::PrefetchCancel,
        });
        Ok(())
    }

    /// Mark the in-flight copy of `id` consumed (the engine calls this
    /// after blocking on the copy's completion time).
    pub fn complete_prefetch(&mut self, id: ChunkId) {
        self.inflight.remove(&id);
    }

    // ------------------------------------------------- lookahead gathers

    /// Mark `id` as being filled by an in-flight lookahead all-gather.
    /// The payload must already be materialized (the gather writes into
    /// it); until `finish_gather`, the chunk is invisible to eviction
    /// and can only be reclaimed whole via `cancel_gather`.
    pub fn begin_gather(&mut self, id: ChunkId) -> Result<()> {
        if self.chunk(id).device.is_none() {
            bail!("cannot gather into chunk {id:?}: no payload");
        }
        self.gathering.insert(id);
        Ok(())
    }

    /// The gather landed (or its group was consumed): `id` becomes a
    /// normal resident chunk again.
    pub fn finish_gather(&mut self, id: ChunkId) {
        self.gathering.remove(&id);
    }

    /// Reclaim a mid-gather chunk under memory pressure: the payload is
    /// dropped — a remote chunk has no source device to return to; the
    /// demand path will re-gather the whole group.  The engine reacts to
    /// the `GatherCancel` event by cancelling the group's collective and
    /// crediting its time and bytes back.
    pub fn cancel_gather(&mut self, id: ChunkId) -> Result<()> {
        if !self.gathering.remove(&id) {
            bail!("chunk {id:?} has no in-flight gather");
        }
        let c = self.chunk(id);
        let (bytes, dev) = (c.bytes(), c.device);
        let dev = dev.ok_or_else(|| {
            anyhow!("gathering chunk {id:?} lost its payload")
        })?;
        self.space.dealloc(dev, bytes)?;
        self.chunk_mut(id).device = None;
        if self.real_mode {
            self.payloads[id.0 as usize] = None;
        }
        self.record(MoveEvent {
            chunk: id,
            from: Some(dev),
            to: None,
            bytes,
            kind: MoveKind::GatherCancel,
        });
        Ok(())
    }

    /// Retag every `from`-state tensor of `id` to `to` — remote payload
    /// arrival (FREE -> HOLD, Algorithm 1 line 14) and gather
    /// cancellation (HOLD -> FREE) share this.
    pub fn retag_tensors(
        &mut self,
        id: ChunkId,
        from: TensorState,
        to: TensorState,
    ) -> Result<()> {
        let tensors = self.chunk(id).tensors.clone();
        for t in tensors {
            let ti = &mut self.reg.tensors[t.0 as usize];
            if ti.state == from {
                ti.set_state(to).map_err(|e| anyhow!(e))?;
            }
        }
        Ok(())
    }

    pub fn pin(&mut self, id: ChunkId) {
        self.chunk_mut(id).pinned = true;
    }

    pub fn unpin(&mut self, id: ChunkId) {
        self.chunk_mut(id).pinned = false;
    }

    // ----------------------------------------------- Algorithm 1 (Access)

    /// Access one tensor for computing on `device` (Algorithm 1, lines
    /// 21–35, single-process portion).  Returns true if the tensor was
    /// FREE and its payload slot must be zero-filled.
    pub fn access_tensor(
        &mut self,
        kind: ChunkKind,
        idx: usize,
        device: Device,
        policy: &mut dyn EvictionPolicy,
        now: Moment,
    ) -> Result<bool> {
        let ti = self.reg.tensor_index(kind, idx);
        let chunk = ChunkId(self.reg.tensors[ti].chunk as u32);
        self.ensure_on(chunk, device, policy, now)?;
        let was_free = self.reg.tensors[ti].state == TensorState::Free;
        if was_free && self.real_mode {
            // Zero the tensor's slot (Algorithm 1 line 31).
            let (off, n) =
                (self.reg.tensors[ti].offset, self.reg.tensors[ti].numel);
            if let Some(buf) = self.payload_mut(chunk) {
                buf[off as usize..(off + n) as usize].fill(0.0);
            }
        }
        self.reg.tensors[ti]
            .set_state(TensorState::Compute)
            .map_err(|e| anyhow!(e))?;
        self.reg.tensors[ti].ref_count += 1;
        Ok(was_free)
    }

    // ---------------------------------------------- Algorithm 2 (Release)

    /// Release one tensor to `target` (Algorithm 2, lines 31–39,
    /// single-process portion).  With shared parameters the state only
    /// changes when the access refcount drains.
    pub fn release_tensor(
        &mut self,
        kind: ChunkKind,
        idx: usize,
        target: TensorState,
    ) -> Result<()> {
        let ti = self.reg.tensor_index(kind, idx);
        let t = &mut self.reg.tensors[ti];
        if t.ref_count == 0 {
            bail!("release of unaccessed tensor {}", t.name);
        }
        t.ref_count -= 1;
        if t.ref_count == 0 {
            t.set_state(target).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    /// Reset all tensors of a kind from HOLD_AFTER_FWD to HOLD (paper:
    /// end of FWD, required for checkpoint-recompute disambiguation).
    pub fn reset_after_fwd(&mut self, kind: ChunkKind) -> Result<()> {
        for i in 0..self.reg.n_model_tensors {
            let ti = self.reg.tensor_index(kind, i);
            if self.reg.tensors[ti].state == TensorState::HoldAfterFwd {
                self.reg.tensors[ti]
                    .set_state(TensorState::Hold)
                    .map_err(|e| anyhow!(e))?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::layout::TensorSpec;
    use crate::evict::FifoPolicy;

    fn mk3(n_tensors: usize, numel: u64, chunk_elems: u64,
           gpu: u64, cpu: u64, nvme: u64) -> ChunkManager {
        let specs: Vec<TensorSpec> = (0..n_tensors)
            .map(|i| TensorSpec {
                name: format!("t{i}"),
                numel,
                embedding: false,
            })
            .collect();
        let reg = ChunkRegistry::build(&specs, chunk_elems).unwrap();
        ChunkManager::new(
            reg,
            HeterogeneousSpace::new(gpu, cpu).with_nvme(nvme),
        )
    }

    fn mk(n_tensors: usize, numel: u64, chunk_elems: u64,
          gpu: u64, cpu: u64) -> ChunkManager {
        mk3(n_tensors, numel, chunk_elems, gpu, cpu, 0)
    }

    #[test]
    fn resize_shared_tiers_recaps_cpu_and_nvme_only() {
        let mut m = mk3(2, 50, 100, 1_000, 10_000, 4_000);
        m.resize_shared_tiers(20_000, Some(8_000));
        assert_eq!(m.space.dev(Device::Cpu).capacity, 20_000);
        assert_eq!(m.space.dev(Device::Nvme).capacity, 8_000);
        assert_eq!(m.space.dev(Device::Gpu(0)).capacity, 1_000);
        // A two-tier manager ignores the NVMe share (the device is
        // absent, not zero-capacity — the --nvme-gb 0 contract).
        let mut two = mk(2, 50, 100, 1_000, 10_000);
        two.resize_shared_tiers(5_000, Some(8_000));
        assert_eq!(two.space.dev(Device::Cpu).capacity, 5_000);
        assert!(!two.space.has(Device::Nvme));
    }

    #[test]
    fn set_device_capacity_recaps_one_tier() {
        let mut m = mk(2, 50, 100, 1_000, 10_000);
        m.set_device_capacity(Device::Gpu(0), 2_500);
        assert_eq!(m.space.dev(Device::Gpu(0)).capacity, 2_500);
        assert_eq!(m.space.dev(Device::Cpu).capacity, 10_000);
        // Capacity is a cap, not an allocation: used bytes untouched.
        assert_eq!(m.space.dev(Device::Gpu(0)).used(), 0);
    }

    #[test]
    fn alloc_then_release_roundtrip() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Gpu(0)).unwrap();
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)));
        assert_eq!(m.space.dev(Device::Gpu(0)).used(), 200); // 100 elem fp16
        m.release_payload(id).unwrap();
        assert_eq!(m.chunk(id).device, None);
        assert_eq!(m.space.dev(Device::Gpu(0)).used(), 0);
    }

    #[test]
    fn ensure_on_evicts_hold_chunks() {
        // GPU fits exactly one fp16 chunk (200 B); placing the second must
        // evict the first to CPU.
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let (a, b) = (list[0], list[1]);
        let mut pol = FifoPolicy::default();
        m.ensure_on(a, Device::Gpu(0), &mut pol, 0).unwrap();
        // Mark a's tensors HOLD so it is evictable but not droppable.
        for i in [0usize, 1] {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.ensure_on(b, Device::Gpu(0), &mut pol, 1).unwrap();
        assert_eq!(m.chunk(a).device, Some(Device::Cpu), "a evicted");
        assert_eq!(m.chunk(b).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.evictions, 1);
        assert_eq!(m.stats.gpu_to_cpu_bytes, 200);
    }

    #[test]
    fn free_chunks_are_dropped_not_moved() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        // Tensors stay FREE -> chunk 0's payload is reusable.
        m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).unwrap();
        assert_eq!(m.chunk(list[0]).device, None, "dropped");
        assert_eq!(m.stats.gpu_to_cpu_bytes, 0, "no transfer for FREE");
    }

    #[test]
    fn compute_chunks_never_evicted() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        // Access both tensors of chunk0 -> COMPUTE.
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.access_tensor(ChunkKind::ParamFp16, 1, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        // No evictable chunk -> placing chunk1 on GPU must fail.
        let err =
            m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).unwrap_err();
        assert!(err.to_string().contains("no evictable"), "{err}");
    }

    #[test]
    fn pinned_chunks_never_evicted() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        m.pin(list[0]);
        assert!(m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).is_err());
        m.unpin(list[0]);
        assert!(m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).is_ok());
    }

    #[test]
    fn refcount_gates_release() {
        // A parameter shared by two operators only leaves COMPUTE when
        // both release it (paper Sec. 6.2).
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let mut pol = FifoPolicy::default();
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        m.release_tensor(ChunkKind::ParamFp16, 0, TensorState::HoldAfterFwd)
            .unwrap();
        let ti = m.reg.tensor_index(ChunkKind::ParamFp16, 0);
        assert_eq!(m.reg.tensors[ti].state, TensorState::Compute);
        m.release_tensor(ChunkKind::ParamFp16, 0, TensorState::HoldAfterFwd)
            .unwrap();
        assert_eq!(m.reg.tensors[ti].state, TensorState::HoldAfterFwd);
    }

    #[test]
    fn access_zeroes_free_tensor_in_real_mode() {
        let mut m = mk(2, 50, 100, 10_000, 10_000).with_real_payloads();
        let mut pol = FifoPolicy::default();
        let was_free = m
            .access_tensor(ChunkKind::ParamFp16, 0, Device::Gpu(0), &mut pol, 0)
            .unwrap();
        assert!(was_free);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        assert!(m.payload(id).unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn reset_after_fwd() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let mut pol = FifoPolicy::default();
        for i in 0..2 {
            m.access_tensor(ChunkKind::ParamFp16, i, Device::Gpu(0),
                            &mut pol, 0).unwrap();
            m.release_tensor(ChunkKind::ParamFp16, i,
                             TensorState::HoldAfterFwd).unwrap();
        }
        m.reset_after_fwd(ChunkKind::ParamFp16).unwrap();
        for i in 0..2 {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            assert_eq!(m.reg.tensors[ti].state, TensorState::Hold);
        }
    }

    #[test]
    fn prefetch_roundtrip_completes_on_access() {
        let mut m = mk(4, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Gpu(0), 10_000, &mut pol, 0, &|_| true)
            .unwrap();
        assert!(issued);
        assert!(m.is_inflight(id));
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.prefetches, 1);
        assert_eq!(m.stats.cpu_to_gpu_bytes, 200);
        // In-flight chunks are invisible to eviction.
        assert!(!m.eviction_candidates(Device::Gpu(0)).contains(&id));
        // Re-issue is a no-op.
        assert!(!m
            .prefetch_to(id, Device::Gpu(0), 10_000, &mut pol, 0, &|_| true)
            .unwrap());
        // First access consumes the copy.
        m.ensure_on(id, Device::Gpu(0), &mut pol, 1).unwrap();
        assert!(!m.is_inflight(id));
    }

    #[test]
    fn pressure_cancels_pending_prefetch_instead_of_failing() {
        // GPU fits exactly one chunk; a pending prefetch occupies it.
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let (a, b) = (list[0], list[1]);
        m.alloc_payload(a, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        assert!(m
            .prefetch_to(a, Device::Gpu(0), 200, &mut pol, 0, &|_| true)
            .unwrap());
        assert_eq!(m.stats.cpu_to_gpu_bytes, 200);
        // Demand access for b finds no evictable chunk (a is in flight)
        // and reclaims the prefetch rather than erroring.
        m.access_tensor(ChunkKind::ParamFp16, 2, Device::Gpu(0), &mut pol, 1)
            .unwrap();
        assert!(!m.is_inflight(a));
        assert_eq!(m.chunk(a).device, Some(Device::Cpu), "a back home");
        assert_eq!(m.chunk(b).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.prefetch_cancels, 1);
        // The cancelled copy's traffic was credited back.
        assert_eq!(m.stats.cpu_to_gpu_bytes, 0);
    }

    #[test]
    fn prefetch_abstains_when_guard_rejects_victims() {
        let mut m = mk(4, 50, 100, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let (a, b) = (list[0], list[1]);
        let mut pol = FifoPolicy::default();
        // b occupies the whole GPU in HOLD.
        m.ensure_on(b, Device::Gpu(0), &mut pol, 0).unwrap();
        for i in [2usize, 3] {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.alloc_payload(a, Device::Cpu).unwrap();
        let before = m.stats;
        // Belady guard refuses to spill b -> the prefetch abstains with
        // nothing moved.
        let issued = m
            .prefetch_to(a, Device::Gpu(0), 200, &mut pol, 1, &|_| false)
            .unwrap();
        assert!(!issued);
        assert_eq!(m.chunk(a).device, Some(Device::Cpu));
        assert_eq!(m.chunk(b).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.gpu_to_cpu_bytes, before.gpu_to_cpu_bytes);
        // With the guard's blessing the same prefetch evicts b.
        let issued = m
            .prefetch_to(a, Device::Gpu(0), 200, &mut pol, 1, &|_| true)
            .unwrap();
        assert!(issued);
        assert_eq!(m.chunk(b).device, Some(Device::Cpu), "b spilled");
    }

    #[test]
    fn d2h_staging_and_cancel_credit_gpu_to_cpu() {
        // The ADAM-bound direction: stage a GPU-resident grad chunk
        // toward the CPU, then cancel and verify the g2c traffic (not
        // c2g) is credited back.
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Gpu(0)).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Cpu, 10_000, &mut pol, 0, &|_| false)
            .unwrap();
        assert!(issued);
        assert_eq!(m.chunk(id).device, Some(Device::Cpu));
        assert!(m.is_inflight(id));
        assert_eq!(m.stats.gpu_to_cpu_bytes, 200);
        m.cancel_prefetch(id).unwrap();
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)), "restored");
        assert_eq!(m.stats.gpu_to_cpu_bytes, 0, "g2c credited back");
        assert_eq!(m.stats.cpu_to_gpu_bytes, 0, "c2g untouched");
    }

    #[test]
    fn prefetch_respects_limit_below_capacity() {
        // Capacity would fit the chunk, but the caller's forward-looking
        // cap (limit) does not: abstain.
        let mut m = mk(4, 50, 100, 400, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Gpu(0), 100, &mut pol, 0, &|_| true)
            .unwrap();
        assert!(!issued);
        assert_eq!(m.chunk(id).device, Some(Device::Cpu));
    }

    #[test]
    fn evict_to_fit_shrink_retires_candidates_in_place() {
        // Three chunks resident on GPU in HOLD; shrinking the cap to one
        // chunk must evict two, and FREE chunks must still be dropped.
        let mut m = mk(6, 50, 100, 600, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        for (i, &c) in list.iter().take(3).enumerate() {
            m.ensure_on(c, Device::Gpu(0), &mut pol, i as u32).unwrap();
        }
        // chunk0 stays all-FREE; chunk1, chunk2 HOLD.  FIFO retires in
        // arrival order: chunk0 (dropped), then chunk1 (spilled).
        for i in 2..6usize {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.space.dev_mut(Device::Gpu(0)).set_capacity(200);
        m.evict_to_fit(Device::Gpu(0), &mut pol, 9).unwrap();
        assert!(!m.space.dev(Device::Gpu(0)).over_capacity());
        // The FREE chunk was dropped, not transferred.
        assert_eq!(m.chunk(list[0]).device, None);
        assert_eq!(m.chunk(list[1]).device, Some(Device::Cpu));
        assert_eq!(m.chunk(list[2]).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.gpu_to_cpu_bytes, 200);
    }

    #[test]
    fn evict_to_fit_never_victimizes_gathering_chunks() {
        // ISSUE 2 satellite regression: before the `movable` guard, a
        // remote chunk mid-all-gather was a legal eviction victim — the
        // pressure loop would spill its half-filled payload to the CPU
        // as if it were ordinary HOLD data.  This test was written
        // first (failing) and the guard added after.
        let mut m = mk(6, 50, 100, 600, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        for (i, &c) in list.iter().take(3).enumerate() {
            m.ensure_on(c, Device::Gpu(0), &mut pol, i as u32).unwrap();
        }
        // All tensors HOLD; chunk0 is mid-gather.
        for i in 0..6usize {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.begin_gather(list[0]).unwrap();
        assert!(!m.movable(list[0]), "gathering chunk must be immovable");
        assert!(!m.eviction_candidates(Device::Gpu(0)).contains(&list[0]));
        // Shrink to two chunks: FIFO would pick chunk0 first, but it is
        // mid-gather — chunk1 must go instead.
        m.space.dev_mut(Device::Gpu(0)).set_capacity(400);
        m.evict_to_fit(Device::Gpu(0), &mut pol, 9).unwrap();
        assert_eq!(m.chunk(list[0]).device, Some(Device::Gpu(0)),
                   "mid-gather chunk spilled by pressure");
        assert_eq!(m.chunk(list[1]).device, Some(Device::Cpu));
        assert_eq!(m.stats.gather_cancels, 0);
        // Shrink below the gathering chunk with nothing else left: the
        // gather is reclaimed whole (payload dropped), never spilled.
        m.space.dev_mut(Device::Gpu(0)).set_capacity(100);
        m.evict_to_fit(Device::Gpu(0), &mut pol, 10).unwrap();
        assert_eq!(m.chunk(list[0]).device, None, "reclaimed, not moved");
        assert!(!m.is_gathering(list[0]));
        assert_eq!(m.stats.gather_cancels, 1);
        let cancels: Vec<_> = m
            .drain_events()
            .into_iter()
            .filter(|e| e.kind == MoveKind::GatherCancel)
            .collect();
        assert_eq!(cancels.len(), 1);
        assert_eq!(cancels[0].chunk, list[0]);
        assert_eq!(cancels[0].to, None);
    }

    #[test]
    fn gather_roundtrip_and_release_clear_state() {
        let mut m = mk(4, 50, 100, 10_000, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let (a, b) = (list[0], list[1]);
        // begin_gather requires a payload.
        assert!(m.begin_gather(a).is_err());
        m.alloc_payload(a, Device::Gpu(0)).unwrap();
        m.begin_gather(a).unwrap();
        assert!(m.is_gathering(a));
        assert_eq!(m.gathering_on(Device::Gpu(0)), Some(a));
        assert_eq!(m.gathering_chunks(), vec![a]);
        // A prefetch of a gathering chunk abstains (immovable).
        let mut pol = FifoPolicy::default();
        assert!(!m
            .prefetch_to(a, Device::Cpu, 10_000, &mut pol, 0, &|_| true)
            .unwrap());
        m.finish_gather(a);
        assert!(!m.is_gathering(a));
        // Releasing a still-gathering payload drops the state silently.
        m.alloc_payload(b, Device::Gpu(0)).unwrap();
        m.begin_gather(b).unwrap();
        m.release_payload(b).unwrap();
        assert!(!m.is_gathering(b));
        assert_eq!(m.stats.gather_cancels, 0);
        // cancel_gather on a non-gathering chunk is an error.
        assert!(m.cancel_gather(a).is_err());
    }

    #[test]
    fn move_events_drained() {
        let mut m = mk(2, 50, 100, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        m.ensure_on(id, Device::Gpu(0), &mut pol, 0).unwrap();
        let ev = m.drain_events();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].kind, MoveKind::Alloc);
        assert_eq!(ev[1].kind, MoveKind::Transfer);
        assert!(m.drain_events().is_empty());
    }

    // ------------------------------------------------- NVMe tier (ISSUE 7)

    #[test]
    fn gpu_pressure_cascades_through_full_cpu_to_nvme() {
        // GPU and CPU each fit exactly one chunk (200 B).  Placing a
        // third chunk on the GPU spills one victim to the CPU — which is
        // full, so *its* resident first cascades down to NVMe.
        let mut m = mk3(6, 50, 100, 200, 200, 10_000);
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        for i in 0..6usize {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        m.ensure_on(list[1], Device::Gpu(0), &mut pol, 1).unwrap();
        assert_eq!(m.chunk(list[0]).device, Some(Device::Cpu));
        m.ensure_on(list[2], Device::Gpu(0), &mut pol, 2).unwrap();
        assert_eq!(m.chunk(list[0]).device, Some(Device::Nvme),
                   "cpu resident cascaded to nvme");
        assert_eq!(m.chunk(list[1]).device, Some(Device::Cpu));
        assert_eq!(m.chunk(list[2]).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.to_nvme_bytes, 200);
        assert_eq!(m.stats.to_nvme_moves, 1);
        // The cascade hop is a real eviction, counted as one.
        assert_eq!(m.stats.evictions, 3);
    }

    #[test]
    fn tier_off_cpu_pressure_never_reaches_for_nvme() {
        // Without the tier, the two-tier ping-pong still holds: a CPU
        // victim spills back to GPU 0, and a full CPU with a full GPU is
        // a hard error rather than a cascade.
        let mut m = mk(4, 50, 100, 200, 200);
        assert!(!m.has_nvme());
        let list = m.reg.list(ChunkKind::ParamFp16);
        let mut pol = FifoPolicy::default();
        for i in 0..4usize {
            let ti = m.reg.tensor_index(ChunkKind::ParamFp16, i);
            m.reg.tensors[ti].set_state(TensorState::Hold).unwrap();
        }
        m.ensure_on(list[0], Device::Gpu(0), &mut pol, 0).unwrap();
        assert!(m.ensure_on(list[1], Device::Cpu, &mut pol, 1).is_ok());
        assert_eq!(m.stats.to_nvme_bytes, 0);
        assert_eq!(m.stats.from_nvme_bytes, 0);
    }

    #[test]
    fn nvme_source_prefetch_cancel_restores_to_nvme() {
        let mut m = mk3(2, 50, 100, 10_000, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Nvme).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Gpu(0), 10_000, &mut pol, 0, &|_| true)
            .unwrap();
        assert!(issued);
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)));
        assert!(m.is_inflight(id));
        assert_eq!(m.stats.from_nvme_bytes, 200);
        m.cancel_prefetch(id).unwrap();
        assert_eq!(m.chunk(id).device, Some(Device::Nvme),
                   "restored to its recorded source tier");
        assert_eq!(m.stats.from_nvme_bytes, 0, "nvme traffic credited");
        assert_eq!(m.stats.cpu_to_gpu_bytes, 0);
        assert_eq!(m.stats.prefetch_cancels, 1);
    }

    #[test]
    fn adam_staging_cancel_restores_to_gpu_despite_nvme() {
        // Regression guard for the source-recording fix: with the NVMe
        // tier present, spill_target(Cpu) is Nvme — but a cancelled
        // GPU->CPU ADAM-staging prefetch must return to the GPU it left,
        // not to NVMe.
        let mut m = mk3(2, 50, 100, 10_000, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Gpu(0)).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Cpu, 10_000, &mut pol, 0, &|_| true)
            .unwrap();
        assert!(issued);
        assert_eq!(m.stats.gpu_to_cpu_bytes, 200);
        m.cancel_prefetch(id).unwrap();
        assert_eq!(m.chunk(id).device, Some(Device::Gpu(0)));
        assert_eq!(m.stats.gpu_to_cpu_bytes, 0, "g2c credited back");
        assert_eq!(m.stats.to_nvme_bytes, 0);
    }

    #[test]
    fn nvme_is_never_a_prefetch_target() {
        let mut m = mk3(2, 50, 100, 10_000, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Cpu).unwrap();
        let mut pol = FifoPolicy::default();
        let issued = m
            .prefetch_to(id, Device::Nvme, 10_000, &mut pol, 0, &|_| true)
            .unwrap();
        assert!(!issued);
        assert_eq!(m.chunk(id).device, Some(Device::Cpu));
    }

    #[test]
    fn releasing_inflight_nvme_prefetch_credits_nvme_traffic() {
        let mut m = mk3(2, 50, 100, 10_000, 10_000, 10_000);
        let id = m.reg.list(ChunkKind::ParamFp16)[0];
        m.alloc_payload(id, Device::Nvme).unwrap();
        let mut pol = FifoPolicy::default();
        assert!(m
            .prefetch_to(id, Device::Gpu(0), 10_000, &mut pol, 0, &|_| true)
            .unwrap());
        // Implicit cancel via release: the charged from-NVMe traffic is
        // credited back before the payload drops.
        m.release_payload(id).unwrap();
        assert_eq!(m.chunk(id).device, None);
        assert_eq!(m.stats.from_nvme_bytes, 0);
        assert_eq!(m.stats.prefetch_cancels, 1);
    }
}
