//! Preprocessing stage: the tensor→chunk mapping schema (paper Sec. 6.1).
//!
//! Chunks are built per kind by appending tensors in model-definition
//! order (N-ary storage model locality); a tensor that does not fit the
//! remaining space of the current chunk opens a new chunk.  The four
//! lists (param fp16 / param fp32 / momentum / variance) share offsets, so
//! the chunks used by ADAM for one parameter sit at the same list position
//! — the property that makes ZeRO-style partitioning communication-free in
//! the ADAM stage (Sec. 7).

use anyhow::{bail, Result};

use super::chunk::{Chunk, ChunkId, ChunkKind};
use crate::tensor::{TensorId, TensorInfo, TensorState};

/// Input to the layout: one model-data tensor.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub numel: u64,
    /// Embedding tensors get dedicated CPU-pinned chunks (Sec. 8.2).
    pub embedding: bool,
}

/// Fragmentation statistics of a layout (paper reports < 10%, Table 3).
#[derive(Clone, Copy, Debug, Default)]
pub struct LayoutStats {
    pub n_chunks: usize,
    pub capacity_elems: u64,
    pub used_elems: u64,
}

impl LayoutStats {
    /// Fraction of chunk space wasted by fragmentation.
    pub fn fragmentation(&self) -> f64 {
        if self.capacity_elems == 0 {
            return 0.0;
        }
        1.0 - self.used_elems as f64 / self.capacity_elems as f64
    }

    /// Paper Table 3's UTIL column.
    pub fn utilization(&self) -> f64 {
        1.0 - self.fragmentation()
    }
}

/// The complete preprocessing output: chunks + per-tensor placements for
/// all four kinds.
#[derive(Clone, Debug)]
pub struct ChunkRegistry {
    pub chunk_elems: u64,
    pub chunks: Vec<Chunk>,
    /// One `TensorInfo` per (kind, tensor) pair; indexed by
    /// `tensor_index(kind, i)`.
    pub tensors: Vec<TensorInfo>,
    /// Number of model tensors (per kind).
    pub n_model_tensors: usize,
    /// Chunks per kind list (embedding chunks excluded).
    pub list_len: usize,
}

impl ChunkRegistry {
    /// Build the mapping schema.  `chunk_elems` must fit every
    /// non-embedding tensor.
    pub fn build(specs: &[TensorSpec], chunk_elems: u64) -> Result<Self> {
        for s in specs {
            if !s.embedding && s.numel > chunk_elems {
                bail!(
                    "tensor {} ({} elems) exceeds chunk size {}",
                    s.name,
                    s.numel,
                    chunk_elems
                );
            }
        }
        let mut chunks: Vec<Chunk> = Vec::new();
        let mut tensors: Vec<TensorInfo> = Vec::new();

        // First pass: param fp16 list layout (non-embedding tensors).
        // (chunk index within list, offset) per spec; embeddings get
        // (usize::MAX, 0) placeholders replaced by dedicated chunks below.
        let mut placement: Vec<(usize, u64)> = Vec::with_capacity(specs.len());
        let mut list_len = 0usize;
        let mut cursor = 0u64; // offset within current chunk
        for s in specs {
            if s.embedding {
                placement.push((usize::MAX, 0));
                continue;
            }
            if list_len == 0 || cursor + s.numel > chunk_elems {
                list_len += 1;
                cursor = 0;
            }
            placement.push((list_len - 1, cursor));
            cursor += s.numel;
        }

        // Second pass: materialize the four aligned lists.
        for kind in ChunkKind::ALL {
            let kind_base = chunks.len();
            for pos in 0..list_len {
                chunks.push(Chunk {
                    id: ChunkId(chunks.len() as u32),
                    kind,
                    capacity: chunk_elems,
                    used: 0,
                    tensors: Vec::new(),
                    device: None,
                    pinned: false,
                    list_pos: pos as u32,
                    embedding: false,
                });
            }
            for (i, s) in specs.iter().enumerate() {
                if s.embedding {
                    continue;
                }
                let (list_idx, offset) = placement[i];
                let chunk_idx = kind_base + list_idx;
                let tid = TensorId(tensors.len() as u32);
                chunks[chunk_idx].tensors.push(tid);
                chunks[chunk_idx].used += s.numel;
                tensors.push(TensorInfo {
                    id: tid,
                    name: format!("{}/{}", kind.name(), s.name),
                    numel: s.numel,
                    chunk: chunk_idx,
                    offset,
                    state: TensorState::Free,
                    ref_count: 0,
                });
            }
        }

        // Third pass: embedding tensors — dedicated CPU-pinned chunks,
        // fp16+fp32+momentum+variance folded into one accounting unit per
        // embedding (they never move, so list alignment is irrelevant).
        for (i, s) in specs.iter().enumerate() {
            if !s.embedding {
                continue;
            }
            debug_assert_eq!(placement[i].0, usize::MAX);
            let n_chunks = s.numel.div_ceil(chunk_elems);
            for c in 0..n_chunks {
                let this = (s.numel - c * chunk_elems).min(chunk_elems);
                let tid = TensorId(tensors.len() as u32);
                let cid = ChunkId(chunks.len() as u32);
                chunks.push(Chunk {
                    id: cid,
                    kind: ChunkKind::ParamFp32,
                    capacity: chunk_elems,
                    used: this,
                    tensors: vec![tid],
                    device: None,
                    pinned: true,
                    list_pos: 0,
                    embedding: true,
                });
                tensors.push(TensorInfo {
                    id: tid,
                    name: format!("emb/{}#{}", s.name, c),
                    numel: this,
                    chunk: chunks.len() - 1,
                    offset: 0,
                    state: TensorState::Free,
                    ref_count: 0,
                });
            }
        }

        Ok(ChunkRegistry {
            chunk_elems,
            chunks,
            tensors,
            n_model_tensors: specs.iter().filter(|s| !s.embedding).count(),
            list_len,
        })
    }

    /// Index of tensor `i` (model-definition order among non-embedding
    /// tensors) in list `kind`.
    pub fn tensor_index(&self, kind: ChunkKind, i: usize) -> usize {
        let k = ChunkKind::ALL.iter().position(|x| *x == kind).unwrap();
        k * self.n_model_tensors + i
    }

    pub fn tensor(&self, kind: ChunkKind, i: usize) -> &TensorInfo {
        &self.tensors[self.tensor_index(kind, i)]
    }

    /// Layout statistics over the orchestrated (non-embedding) chunks.
    pub fn stats(&self) -> LayoutStats {
        let mut s = LayoutStats::default();
        for c in self.chunks.iter().filter(|c| !c.embedding) {
            s.n_chunks += 1;
            s.capacity_elems += c.capacity;
            s.used_elems += c.used;
        }
        s
    }

    /// Total model-data bytes under management (paper: 14M for M params).
    pub fn model_data_bytes(&self) -> u64 {
        self.chunks
            .iter()
            .filter(|c| !c.embedding)
            .map(|c| c.bytes())
            .sum()
    }

    /// All non-embedding chunks of a kind, in list order.
    pub fn list(&self, kind: ChunkKind) -> Vec<ChunkId> {
        let mut v: Vec<&Chunk> = self
            .chunks
            .iter()
            .filter(|c| c.kind == kind && !c.embedding)
            .collect();
        v.sort_by_key(|c| c.list_pos);
        v.iter().map(|c| c.id).collect()
    }

    /// The aligned (fp32, momentum, variance) chunk ids for a param fp16
    /// chunk — the ADAM working set of that chunk (Sec. 6.2).
    pub fn os_chunks_for(&self, param_fp16: ChunkId) -> [ChunkId; 3] {
        let pos = self.chunks[param_fp16.0 as usize].list_pos;
        debug_assert_eq!(
            self.chunks[param_fp16.0 as usize].kind,
            ChunkKind::ParamFp16
        );
        let find = |kind: ChunkKind| {
            self.chunks
                .iter()
                .find(|c| c.kind == kind && c.list_pos == pos && !c.embedding)
                .map(|c| c.id)
                .expect("aligned chunk missing")
        };
        [
            find(ChunkKind::ParamFp32),
            find(ChunkKind::Momentum),
            find(ChunkKind::Variance),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, numel: u64) -> TensorSpec {
        TensorSpec { name: name.into(), numel, embedding: false }
    }

    #[test]
    fn append_first_fit() {
        let specs =
            vec![spec("a", 60), spec("b", 50), spec("c", 40), spec("d", 10)];
        let reg = ChunkRegistry::build(&specs, 100).unwrap();
        // a opens chunk0 (60); b doesn't fit -> chunk1 (50); c fits after b
        // (90); d doesn't fit (90+10=100 fits exactly!) -> stays in chunk1.
        let p16 = reg.list(ChunkKind::ParamFp16);
        assert_eq!(p16.len(), 2);
        let t = |i: usize| reg.tensor(ChunkKind::ParamFp16, i);
        assert_eq!((t(0).chunk, t(0).offset), (0, 0));
        assert_eq!((t(1).chunk, t(1).offset), (1, 0));
        assert_eq!((t(2).chunk, t(2).offset), (1, 50));
        assert_eq!((t(3).chunk, t(3).offset), (1, 90));
    }

    #[test]
    fn four_lists_share_offsets() {
        let specs = vec![spec("a", 30), spec("b", 80), spec("c", 20)];
        let reg = ChunkRegistry::build(&specs, 100).unwrap();
        for i in 0..3 {
            let base = reg.tensor(ChunkKind::ParamFp16, i);
            for kind in
                [ChunkKind::ParamFp32, ChunkKind::Momentum, ChunkKind::Variance]
            {
                let t = reg.tensor(kind, i);
                assert_eq!(t.offset, base.offset, "offset alignment");
                assert_eq!(
                    reg.chunks[t.chunk].list_pos,
                    reg.chunks[base.chunk].list_pos,
                    "list position alignment"
                );
            }
        }
    }

    #[test]
    fn model_data_is_14_bytes_per_param() {
        let specs = vec![spec("a", 100), spec("b", 100)];
        let reg = ChunkRegistry::build(&specs, 200).unwrap();
        // Exactly one chunk per list, all full: 200 elems * (2+4+4+4).
        assert_eq!(reg.model_data_bytes(), 200 * 14);
    }

    #[test]
    fn oversized_tensor_rejected() {
        let specs = vec![spec("big", 1000)];
        assert!(ChunkRegistry::build(&specs, 100).is_err());
    }

    #[test]
    fn embedding_gets_pinned_chunks() {
        let specs = vec![
            TensorSpec { name: "wte".into(), numel: 250, embedding: true },
            spec("w", 80),
        ];
        let reg = ChunkRegistry::build(&specs, 100).unwrap();
        let emb: Vec<&Chunk> =
            reg.chunks.iter().filter(|c| c.embedding).collect();
        assert_eq!(emb.len(), 3); // ceil(250/100)
        assert!(emb.iter().all(|c| c.pinned));
        // Embedding chunks are excluded from orchestration stats.
        assert_eq!(reg.stats().n_chunks, 4); // 1 chunk x 4 lists
    }

    #[test]
    fn fragmentation_math() {
        let specs = vec![spec("a", 60), spec("b", 60)];
        let reg = ChunkRegistry::build(&specs, 100).unwrap();
        // Two chunks/list, 120/200 used -> 40% waste.
        let s = reg.stats();
        assert!((s.fragmentation() - 0.4).abs() < 1e-9);
        assert!((s.utilization() - 0.6).abs() < 1e-9);
    }

    #[test]
    fn os_chunks_aligned() {
        let specs = vec![spec("a", 60), spec("b", 60), spec("c", 30)];
        let reg = ChunkRegistry::build(&specs, 100).unwrap();
        let p16 = reg.list(ChunkKind::ParamFp16);
        for &cid in &p16 {
            let pos = reg.chunks[cid.0 as usize].list_pos;
            for os in reg.os_chunks_for(cid) {
                assert_eq!(reg.chunks[os.0 as usize].list_pos, pos);
            }
        }
    }
}
