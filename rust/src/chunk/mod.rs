//! Chunk-based memory management — the paper's core contribution (Sec. 5–6).
//!
//! * [`chunk`]   — `Chunk` and the derived chunk location rules.
//! * [`layout`]  — the preprocessing-stage tensor→chunk mapping schema
//!                 (Sec. 6.1): four aligned chunk lists, append-first-fit.
//! * [`search`]  — offline chunk-size search minimizing fragmentation
//!                 (Sec. 9.1, Table 3).
//! * [`manager`] — runtime chunk orchestration: prepare/move/pin/evict
//!                 (Sec. 6.2, 8.3).

pub mod chunk;
pub mod layout;
pub mod manager;
pub mod search;

pub use chunk::{Chunk, ChunkId, ChunkKind};
pub use layout::{ChunkRegistry, LayoutStats, TensorSpec};
pub use manager::{ChunkManager, MoveEvent, MoveKind, MoveStats};
pub use search::{search_chunk_size, search_chunk_size_tiered, SearchResult};
