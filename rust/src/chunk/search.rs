//! Offline chunk-size search (paper Sec. 9.1 "Chunk Size Searching",
//! Table 3, Fig. 12).
//!
//! "This searching method builds the tensor chunk mapping schema by
//! looking for the optimal chunk size that can host the overall model data
//! in CPU+GPU from a size range of 128 to 512 with a step of 32" — the
//! units there are 2^16 elements (the published PatrickStar's
//! `chunk_size_search` uses 64K-element quanta); we search the same grid
//! and additionally expose an arbitrary-grid search for the e2e model.

use super::layout::{ChunkRegistry, TensorSpec};

/// One candidate evaluated by the search.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub chunk_elems: u64,
    pub utilization: f64,
    pub n_chunks: usize,
    /// Whether overall model data fits the byte budget (CPU+GPU, plus
    /// the NVMe tier when one is granted).
    pub feasible: bool,
    /// Bytes overflowing CPU+GPU that must live on the NVMe tier
    /// (0 when the model fits two tiers or the budget is unconstrained).
    pub nvme_spill: u64,
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    pub best: Candidate,
    pub all: Vec<Candidate>,
}

/// Evaluate one chunk size against the specs and a heterogeneous-space
/// byte budget (0 = unconstrained).
pub fn evaluate(
    specs: &[TensorSpec],
    chunk_elems: u64,
    budget_bytes: u64,
) -> Option<Candidate> {
    evaluate_tiered(specs, chunk_elems, budget_bytes, 0)
}

/// 3-tier evaluation (ISSUE 7): `budget_bytes` is the CPU+GPU budget
/// and `nvme_bytes` the third-tier grant.  A candidate is feasible if
/// model data fits the *combined* budget; `nvme_spill` reports how many
/// bytes overflow the two hot tiers onto NVMe.
pub fn evaluate_tiered(
    specs: &[TensorSpec],
    chunk_elems: u64,
    budget_bytes: u64,
    nvme_bytes: u64,
) -> Option<Candidate> {
    let reg = ChunkRegistry::build(specs, chunk_elems).ok()?;
    let stats = reg.stats();
    let model = reg.model_data_bytes();
    let feasible =
        budget_bytes == 0 || model <= budget_bytes + nvme_bytes;
    let nvme_spill = if budget_bytes == 0 {
        0
    } else {
        model.saturating_sub(budget_bytes)
    };
    Some(Candidate {
        chunk_elems,
        utilization: stats.utilization(),
        n_chunks: stats.n_chunks,
        feasible,
        nvme_spill,
    })
}

/// Paper-grid search: sizes 128..=512 step 32, in units of 2^20 elements
/// (Table 3's "chunk size 288" = 288 Mi-elements; at fp16 that is a
/// 576 MB chunk, comfortably above the PCIe/NVLink saturation points of
/// Sec. 4 and large enough to hold any transformer tensor of Table 2).
pub fn search_chunk_size(
    specs: &[TensorSpec],
    budget_bytes: u64,
) -> Option<SearchResult> {
    search_chunk_size_tiered(specs, budget_bytes, 0)
}

/// Paper-grid search with a third-tier grant: feasibility is judged
/// against CPU+GPU *plus* `nvme_bytes`, and each candidate reports its
/// `nvme_spill`.  `nvme_bytes == 0` is exactly [`search_chunk_size`].
pub fn search_chunk_size_tiered(
    specs: &[TensorSpec],
    budget_bytes: u64,
    nvme_bytes: u64,
) -> Option<SearchResult> {
    let grid: Vec<u64> =
        (128..=512).step_by(32).map(|q| q << 20).collect();
    search_grid_tiered(specs, &grid, budget_bytes, nvme_bytes)
}

/// Search an explicit grid of chunk sizes; best = feasible candidate with
/// maximal utilization (ties -> smaller chunk, which lowers peak memory).
pub fn search_grid(
    specs: &[TensorSpec],
    grid: &[u64],
    budget_bytes: u64,
) -> Option<SearchResult> {
    search_grid_tiered(specs, grid, budget_bytes, 0)
}

/// Grid search under a 3-tier budget (see [`evaluate_tiered`]).
pub fn search_grid_tiered(
    specs: &[TensorSpec],
    grid: &[u64],
    budget_bytes: u64,
    nvme_bytes: u64,
) -> Option<SearchResult> {
    let mut all = Vec::new();
    for &c in grid {
        if let Some(cand) = evaluate_tiered(specs, c, budget_bytes, nvme_bytes)
        {
            all.push(cand);
        }
    }
    let best = all
        .iter()
        .filter(|c| c.feasible)
        .max_by(|a, b| {
            crate::util::total_cmp(a.utilization, b.utilization)
                .then(b.chunk_elems.cmp(&a.chunk_elems))
        })
        .copied()?;
    Some(SearchResult { best, all })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::forall;

    fn specs(sizes: &[u64]) -> Vec<TensorSpec> {
        sizes
            .iter()
            .enumerate()
            .map(|(i, &numel)| TensorSpec {
                name: format!("t{i}"),
                numel,
                embedding: false,
            })
            .collect()
    }

    #[test]
    fn picks_exact_fit() {
        // Tensors of 100 elems: a chunk of 300 wastes nothing; 400 wastes
        // 25% on the last chunk boundary pattern.
        let s = specs(&[100; 12]);
        let r = search_grid(&s, &[300, 400, 500], 0).unwrap();
        assert_eq!(r.best.chunk_elems, 300);
        assert!((r.best.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn budget_filters_infeasible() {
        let s = specs(&[100; 12]);
        // 1200 elems * 14 B = 16.8 KB minimum; a 1 KB budget is infeasible
        // for every candidate.
        assert!(search_grid(&s, &[300, 400], 1000).is_none());
    }

    #[test]
    fn nvme_grant_rescues_budget() {
        // The same 1 KB two-tier budget becomes feasible once a 32 KB
        // NVMe grant joins it, and the candidate reports the overflow.
        let s = specs(&[100; 12]);
        let r = search_grid_tiered(&s, &[300, 400], 1000, 32 << 10)
            .expect("tiered budget must be feasible");
        assert!(r.best.feasible);
        assert!(r.best.nvme_spill > 0, "overflow bytes must be reported");
        // Zero grant is exactly the two-tier search.
        assert!(search_grid_tiered(&s, &[300, 400], 1000, 0).is_none());
    }

    #[test]
    fn unconstrained_budget_reports_no_spill() {
        let s = specs(&[100; 12]);
        let r = search_grid(&s, &[300], 0).unwrap();
        assert!(r.best.feasible);
        assert_eq!(r.best.nvme_spill, 0);
    }

    #[test]
    fn paper_grid_utilization_above_80pct() {
        // GPT-like tensor sizes (hidden 4096): util must be high on the
        // paper grid, matching Table 3's 90%+ results.
        let h: u64 = 4096;
        let mut sizes = Vec::new();
        for _ in 0..20 {
            sizes.extend_from_slice(&[
                h,
                h,
                3 * h * h,
                3 * h,
                h * h,
                h,
                h,
                h,
                4 * h * h,
                4 * h,
                4 * h * h,
                h,
            ]);
        }
        let r = search_chunk_size(&specs(&sizes), 0).unwrap();
        assert!(
            r.best.utilization > 0.8,
            "utilization {}",
            r.best.utilization
        );
    }

    #[test]
    fn property_best_is_feasible_max() {
        forall(
            60,
            |rng| {
                let n = rng.range(1, 40);
                (0..n).map(|_| rng.range(1, 5000) as u64).collect::<Vec<_>>()
            },
            |sizes| {
                let s = specs(sizes);
                let max = *sizes.iter().max().unwrap();
                let grid: Vec<u64> =
                    (1..=4).map(|k| max * k).collect();
                let r = search_grid(&s, &grid, 0)
                    .ok_or("search returned none")?;
                for c in &r.all {
                    if c.feasible && c.utilization > r.best.utilization + 1e-12
                    {
                        return Err(format!(
                            "candidate {c:?} beats best {:?}",
                            r.best
                        ));
                    }
                }
                // Utilization is always in (0, 1].
                if !(r.best.utilization > 0.0 && r.best.utilization <= 1.0) {
                    return Err("utilization out of range".into());
                }
                Ok(())
            },
        );
    }
}
