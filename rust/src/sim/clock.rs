//! Simulated wall clock with per-phase attribution (paper Fig. 16's time
//! breakdown categories).

use std::collections::BTreeMap;

/// Where simulated time is spent.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Phase {
    /// FWD+BWD operator compute.
    FwdBwd,
    /// ADAM parameter update compute.
    Adam,
    /// Inter-GPU all-gather of param fp16 chunks.
    AllGather,
    /// Inter-GPU reduce-scatter of grad fp16 chunks.
    ReduceScatter,
    /// CPU->GPU chunk movement during FWD+BWD.
    CpuToGpu,
    /// GPU->CPU chunk movement during FWD+BWD (evictions).
    GpuToCpu,
    /// CPU<->GPU movement + fp precision conversion around ADAM
    /// (paper's "gpufp16->cpufp32" / "cpufp32->gpufp16" bars).
    AdamMove,
    /// Activation offload traffic (ckpt+offload plan).
    ActOffload,
    /// CPU<->NVMe tier traffic: the NVMe-link hop of a staged
    /// NVMe<->GPU copy plus direct CPU<->NVMe spills/fetches.  The
    /// PCIe hop of a staged copy keeps its CpuToGpu/GpuToCpu phase.
    Nvme,
}

impl Phase {
    pub const ALL: [Phase; 9] = [
        Phase::FwdBwd,
        Phase::Adam,
        Phase::AllGather,
        Phase::ReduceScatter,
        Phase::CpuToGpu,
        Phase::GpuToCpu,
        Phase::AdamMove,
        Phase::ActOffload,
        Phase::Nvme,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Phase::FwdBwd => "fwd+bwd",
            Phase::Adam => "adam",
            Phase::AllGather => "allgather",
            Phase::ReduceScatter => "reduce-scatter",
            Phase::CpuToGpu => "cpu->gpu",
            Phase::GpuToCpu => "gpu->cpu",
            Phase::AdamMove => "adam-move",
            Phase::ActOffload => "act-offload",
            Phase::Nvme => "nvme",
        }
    }
}

/// Accumulating per-phase clock.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    acc: BTreeMap<Phase, f64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, phase: Phase, secs: f64) {
        debug_assert!(secs >= 0.0 && secs.is_finite(), "bad time {secs}");
        *self.acc.entry(phase).or_insert(0.0) += secs;
    }

    /// Remove previously-charged time (a queued copy that was reclaimed
    /// before reaching the wire); clamps at zero.
    pub fn sub(&mut self, phase: Phase, secs: f64) {
        debug_assert!(secs >= 0.0 && secs.is_finite(), "bad time {secs}");
        if let Some(t) = self.acc.get_mut(&phase) {
            *t = (*t - secs).max(0.0);
        }
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.acc.get(&phase).copied().unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.acc.values().sum()
    }

    pub fn reset(&mut self) {
        self.acc.clear();
    }

    /// (phase, seconds) rows with non-zero time, largest first.
    pub fn breakdown(&self) -> Vec<(Phase, f64)> {
        let mut v: Vec<(Phase, f64)> =
            self.acc.iter().map(|(&p, &t)| (p, t)).collect();
        v.retain(|&(_, t)| t > 0.0);
        v.sort_by(|a, b| crate::util::total_cmp(b.1, a.1));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let mut c = SimClock::new();
        c.add(Phase::FwdBwd, 1.0);
        c.add(Phase::FwdBwd, 0.5);
        c.add(Phase::Adam, 0.25);
        assert_eq!(c.get(Phase::FwdBwd), 1.5);
        assert_eq!(c.total(), 1.75);
    }

    #[test]
    fn breakdown_sorted_desc() {
        let mut c = SimClock::new();
        c.add(Phase::Adam, 2.0);
        c.add(Phase::AllGather, 5.0);
        c.add(Phase::CpuToGpu, 1.0);
        let b = c.breakdown();
        assert_eq!(b[0].0, Phase::AllGather);
        assert_eq!(b.len(), 3);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn negative_time_rejected() {
        SimClock::new().add(Phase::Adam, -1.0);
    }
}
