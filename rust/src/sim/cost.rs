//! Per-device roofline profiles.

use crate::model::OpKind;

/// Achievable compute/bandwidth figures for one device class.
#[derive(Clone, Copy, Debug)]
pub struct DeviceProfile {
    pub name: &'static str,
    /// Achievable mixed-precision GEMM throughput, flop/s (not peak:
    /// includes realistic MXU/tensor-core utilization on transformer
    /// shapes).
    pub gemm_flops: f64,
    /// Achievable memory bandwidth for elementwise ops, bytes/s.
    pub mem_bw: f64,
    /// Fixed per-operator launch overhead, seconds.
    pub launch_s: f64,
}

impl DeviceProfile {
    /// V100-32GB (YARD node GPUs).  Paper reaches ~47–56 Tflops/GPU
    /// end-to-end; achievable GEMM on transformer shapes ≈ 70 Tflop/s.
    pub fn v100() -> Self {
        DeviceProfile {
            name: "V100",
            gemm_flops: 70e12,
            mem_bw: 800e9,
            launch_s: 8e-6,
        }
    }

    /// A100-40GB (SuperPod GPUs); paper reaches ~147 Tflops/GPU.
    pub fn a100() -> Self {
        DeviceProfile {
            name: "A100",
            gemm_flops: 180e12,
            mem_bw: 1500e9,
            launch_s: 8e-6,
        }
    }

    /// RTX 2060 (700$-PC experiment).
    pub fn rtx2060() -> Self {
        DeviceProfile {
            name: "RTX2060",
            gemm_flops: 24e12,
            mem_bw: 300e9,
            launch_s: 10e-6,
        }
    }

    /// 12-core Xeon-class host (YARD: 240 GB, 12 cores).
    pub fn cpu_yard() -> Self {
        DeviceProfile {
            name: "cpu12",
            gemm_flops: 1.0e12,
            mem_bw: 25e9,
            launch_s: 2e-6,
        }
    }

    /// 192-core EPYC-class host (SuperPod: 1 TB, 192 cores).
    pub fn cpu_superpod() -> Self {
        DeviceProfile {
            name: "cpu192",
            gemm_flops: 8.0e12,
            mem_bw: 120e9,
            launch_s: 2e-6,
        }
    }

    /// Ryzen 3700X desktop.
    pub fn cpu_pc() -> Self {
        DeviceProfile {
            name: "cpu8",
            gemm_flops: 0.8e12,
            mem_bw: 20e9,
            launch_s: 2e-6,
        }
    }

    /// Time for one operator of `flops` total work.
    pub fn op_time(&self, kind: OpKind, flops: f64) -> f64 {
        match kind {
            OpKind::ComputeIntensive => self.launch_s + flops / self.gemm_flops,
            // Memory-intensive ops move ~2 bytes per flop (read+write
            // fp16): bandwidth-bound.
            OpKind::MemoryIntensive | OpKind::Embedding => {
                self.launch_s + 2.0 * flops / self.mem_bw
            }
        }
    }

    /// ADAM over `bytes` of optimizer state + grads: pure streaming —
    /// read p32/m/v/g (+write back p32/m/v/p16), ~2x bytes of traffic.
    pub fn adam_time(&self, bytes: u64) -> f64 {
        self.launch_s + 2.0 * bytes as f64 / self.mem_bw
    }

    /// fp16<->fp32 conversion of `bytes` (read+write, bandwidth-bound).
    pub fn cast_time(&self, bytes: u64) -> f64 {
        self.launch_s + 1.5 * bytes as f64 / self.mem_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::OpKind;

    #[test]
    fn gemm_faster_on_gpu_than_cpu() {
        let flops = 1e12;
        let gpu = DeviceProfile::v100().op_time(OpKind::ComputeIntensive, flops);
        let cpu =
            DeviceProfile::cpu_yard().op_time(OpKind::ComputeIntensive, flops);
        assert!(cpu > 20.0 * gpu);
    }

    #[test]
    fn adam_is_bandwidth_bound_and_cheap_relative_to_gemm() {
        // Paper Sec. 8.2: memory-intensive operators take a small share of
        // iteration time.  1B params of OS (16 GB) on the SuperPod CPU
        // should cost ~0.27 s, far less than the ~10 s of fwd+bwd GEMMs
        // for that model at batch 8.
        let cpu = DeviceProfile::cpu_superpod();
        let adam = cpu.adam_time(16 * (1 << 30) as u64);
        assert!(adam < 0.5, "adam {adam}");
    }

    #[test]
    fn launch_overhead_floors_tiny_ops() {
        let gpu = DeviceProfile::v100();
        assert!(gpu.op_time(OpKind::ComputeIntensive, 1.0) >= 8e-6);
    }

    #[test]
    fn calibration_1b_v100_tflops_band() {
        // Whole-iteration GEMM-only bound for the 1B model at batch 32 on
        // one V100 must sit in the paper's throughput band (they report
        // 40–62 Tflops/GPU for PatrickStar/PyTorch on 1B).
        use crate::model::GptSpec;
        let m = GptSpec::by_name("1B").unwrap();
        let flops = m.iter_flops(32);
        let t = flops / DeviceProfile::v100().gemm_flops; // compute-only
        let tflops = flops / t / 1e12;
        assert!((60.0..80.0).contains(&tflops), "GEMM-only bound {tflops}");
    }
}
