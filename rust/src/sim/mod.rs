//! Discrete-event cost model for the cluster simulator.
//!
//! Calibrated roofline per device (DESIGN.md §1): GEMM ops are
//! flops-bound at an *achievable* (not peak) rate; memory-intensive ops
//! (LayerNorm, ADAM) are bandwidth-bound.  Absolute numbers are testbed
//! translations of the paper's V100/A100 results; the comparisons between
//! systems depend only on the compute/transfer *ratios*.
//!
//! Time lives on two levels:
//!
//! * [`clock`] — the flat per-phase accumulator (paper Fig. 16's bars):
//!   how much *work* each phase performed.
//! * [`stream`] — the three-stream timeline (compute + H2D + D2H copy
//!   engines) that decides how much of that work ran *concurrently*.
//!   The engine's overlap/prefetch pipeline enqueues chunk copies on the
//!   copy streams and only blocks compute when a consumer catches up
//!   with an in-flight transfer; with overlap disabled the timeline
//!   collapses to the serial accumulator, so the pre-pipeline numbers
//!   stay reproducible.

pub mod clock;
pub mod cost;
pub mod stream;

pub use clock::{Phase, SimClock};
pub use cost::DeviceProfile;
pub use stream::{CopyDir, CopyRoute, StreamTimeline};
