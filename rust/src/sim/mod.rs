//! Discrete-event cost model for the cluster simulator.
//!
//! Calibrated roofline per device (DESIGN.md §1): GEMM ops are
//! flops-bound at an *achievable* (not peak) rate; memory-intensive ops
//! (LayerNorm, ADAM) are bandwidth-bound.  Absolute numbers are testbed
//! translations of the paper's V100/A100 results; the comparisons between
//! systems depend only on the compute/transfer *ratios*.

pub mod clock;
pub mod cost;

pub use clock::{Phase, SimClock};
pub use cost::DeviceProfile;
