//! Multi-stream timeline: compute / H2D-copy / D2H-copy / collective
//! overlap.
//!
//! The GPU model behind the prefetch pipeline: one compute stream, two
//! copy engines (CPU->GPU and GPU->CPU), as on every discrete GPU since
//! Fermi, and one **collective stream** (the dedicated NCCL stream real
//! frameworks use for all-gather/reduce-scatter).  Each stream tracks its
//! own time frontier.  Work charged to the compute stream advances only
//! the compute frontier; a copy enqueued on a copy stream starts no
//! earlier than (a) the moment it was issued (the compute frontier at
//! enqueue time), (b) the copy stream's own frontier (copies on one
//! engine are FIFO), and (c) an optional `ready` dependency — used to
//! model an H2D fetch that must wait for the D2H eviction that frees its
//! space.  Collectives queue FIFO on the collective stream the same way.
//!
//! Two kinds of copies (and, symmetrically, collectives):
//!
//! * **demand** copies sit on the requester's critical path: the compute
//!   stream blocks until the copy completes.  The stall (queueing delay +
//!   wire time) is accounted as *exposed* transfer time.
//! * **async** copies (prefetches, evictions, activation offload,
//!   lookahead group gathers, draining reduce-scatters) do not block;
//!   they return their completion time so the engine can `wait until` it
//!   if a later operator actually needs the payload.  Whatever part of an
//!   async copy the compute stream never waits for is *overlapped*
//!   (hidden) time.
//!
//! Copy time and collective time are attributed separately (`exposed_
//! transfer`/`overlapped_transfer` vs `exposed_collective`/`overlapped_
//! collective`) because the paper's multi-GPU story hinges on hiding the
//! latter behind compute specifically.
//!
//! With `overlap = false` the timeline degenerates to the flat per-phase
//! accumulator semantics the serial engine always had: every copy charges
//! the compute frontier and `makespan() == clock.total()`, bit-for-bit —
//! the overlap-off ablation reproduces the pre-pipeline numbers exactly.

use super::clock::{Phase, SimClock};

/// Direction of a PCIe copy, selecting the copy engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyDir {
    /// CPU -> GPU (host-to-device engine).
    H2D,
    /// GPU -> CPU (device-to-host engine).
    D2H,
}

/// Which host-memory path a PCIe copy was charged on (ISSUE 3
/// tentpole).  The engine decides per copy: pinned while holding a
/// staging buffer from the [`crate::mem::PinnedPool`], pageable
/// otherwise.  The timeline only *attributes* the split
/// ([`StreamTimeline::pageable_transfer`]) — the duration difference is
/// already baked into `secs` by the caller's curve choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyRoute {
    /// DMA out of a pinned staging buffer (full PCIe rate).
    Pinned,
    /// Driver-bounced pageable copy (~half the pinned rate).
    Pageable,
    /// Two-hop NVMe<->GPU copy staged through a pinned host buffer
    /// (ZeRO-Infinity style).  As a pricing route (`copy_secs`) it
    /// selects the NVMe curve for the NVMe-link hop; the PCIe hop is
    /// priced separately on Pinned/Pageable.  On the timeline the two
    /// hops are sequenced by [`StreamTimeline::async_copy_staged`].
    NvmeStaged,
}

/// Four-stream simulated timeline with per-phase attribution.
#[derive(Clone, Debug)]
pub struct StreamTimeline {
    clock: SimClock,
    overlap: bool,
    /// Stream frontiers (seconds since iteration start).
    compute: f64,
    h2d: f64,
    d2h: f64,
    /// Collective (NCCL) stream frontier.
    coll: f64,
    /// NVMe I/O lane frontier (CPU<->NVMe block transfers).  Stays 0.0
    /// forever when the NVMe tier is disabled — no method touches it
    /// except the `*_nvme`/`*_staged` family.
    nvme: f64,
    /// Sum of all copy durations (both engines, both kinds).
    copy_total: f64,
    /// Sum of compute-stream *work* charged via [`StreamTimeline::
    /// charge`] — unlike the `compute` frontier it excludes stall time,
    /// so the adaptive lookahead controller can difference it per
    /// moment to estimate pure compute throughput.
    compute_work: f64,
    /// Per-engine copy-duration sums (subset of `copy_total`): the
    /// controller's transfer-rate feedback signals.
    h2d_work: f64,
    d2h_work: f64,
    /// NVMe-lane duration sum (subset of `copy_total`): the tier-aware
    /// window controller's feedback signal.
    nvme_work: f64,
    /// Compute-stream stall time attributable to copies.
    exposed: f64,
    /// Sum of all collective durations enqueued on the collective stream.
    coll_total: f64,
    /// Compute-stream stall time attributable to collectives.
    coll_exposed: f64,
    /// Copy time (within `copy_total`) charged on the pageable curve —
    /// transfers that could not acquire a pinned staging buffer.
    pageable_total: f64,
}

impl StreamTimeline {
    pub fn new(overlap: bool) -> Self {
        StreamTimeline {
            clock: SimClock::new(),
            overlap,
            compute: 0.0,
            h2d: 0.0,
            d2h: 0.0,
            coll: 0.0,
            nvme: 0.0,
            copy_total: 0.0,
            compute_work: 0.0,
            h2d_work: 0.0,
            d2h_work: 0.0,
            nvme_work: 0.0,
            exposed: 0.0,
            coll_total: 0.0,
            coll_exposed: 0.0,
            pageable_total: 0.0,
        }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Per-phase attribution (serial-sum semantics: phases always add up
    /// to the *work* performed, regardless of how much was hidden).
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.clock.get(phase)
    }

    /// Charge work to the compute stream (operators, ADAM, collectives).
    pub fn charge(&mut self, phase: Phase, secs: f64) {
        self.clock.add(phase, secs);
        self.compute_work += secs;
        self.compute += secs;
    }

    fn stream_mut(&mut self, dir: CopyDir) -> &mut f64 {
        match dir {
            CopyDir::H2D => &mut self.h2d,
            CopyDir::D2H => &mut self.d2h,
        }
    }

    fn work_mut(&mut self, dir: CopyDir) -> &mut f64 {
        match dir {
            CopyDir::H2D => &mut self.h2d_work,
            CopyDir::D2H => &mut self.d2h_work,
        }
    }

    /// Blocking copy on the compute critical path.  `ready` is an extra
    /// start dependency (0.0 for none).  Charged as pinned — demand
    /// copies preempt the staging pool (see [`CopyRoute`]).
    pub fn demand_copy(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) {
        self.demand_copy_on(phase, secs, dir, ready, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::demand_copy`] with an explicit host-memory
    /// route for the pinned/pageable attribution.  The engine never
    /// routes a demand copy Pageable (demand preempts the pool — see
    /// [`CopyRoute`]), so production callers go through `demand_copy`;
    /// this variant keeps the demand/async/reclaim API symmetric for
    /// tests and for future policies where demand copies, too, queue
    /// on the staging pool (e.g. a strict-FIFO pool model).
    pub fn demand_copy_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
        route: CopyRoute,
    ) {
        self.clock.add(phase, secs);
        self.copy_total += secs;
        *self.work_mut(dir) += secs;
        if route == CopyRoute::Pageable {
            self.pageable_total += secs;
        }
        if !self.overlap {
            self.compute += secs;
            return;
        }
        let issue = self.compute;
        let start = issue.max(*self.stream_mut(dir)).max(ready);
        let done = start + secs;
        *self.stream_mut(dir) = done;
        self.exposed += done - issue;
        self.compute = done;
    }

    /// Non-blocking copy; returns its completion time.  With overlap off
    /// the copy is charged serially and "completes" immediately.
    pub fn async_copy(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) -> f64 {
        self.async_copy_on(phase, secs, dir, ready, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::async_copy`] with an explicit host-memory
    /// route for the pinned/pageable attribution.
    pub fn async_copy_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
        route: CopyRoute,
    ) -> f64 {
        self.clock.add(phase, secs);
        self.copy_total += secs;
        *self.work_mut(dir) += secs;
        if route == CopyRoute::Pageable {
            self.pageable_total += secs;
        }
        if !self.overlap {
            self.compute += secs;
            return self.compute;
        }
        let start = self.compute.max(*self.stream_mut(dir)).max(ready);
        let done = start + secs;
        *self.stream_mut(dir) = done;
        done
    }

    /// Un-charge a previously enqueued async copy that was cancelled
    /// before reaching the wire: the queue behind it compresses, so its
    /// duration comes back off the stream frontier, the phase clock and
    /// the copy total.  Keeps time accounting consistent with the byte
    /// accounting (`MoveStats` credits cancelled prefetches back too).
    pub fn reclaim(&mut self, phase: Phase, secs: f64, dir: CopyDir) {
        self.reclaim_on(phase, secs, dir, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::reclaim`] for a copy charged on an explicit
    /// route — a cancelled pageable copy credits the pageable
    /// attribution back too.
    pub fn reclaim_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        route: CopyRoute,
    ) {
        self.clock.sub(phase, secs);
        self.copy_total = (self.copy_total - secs).max(0.0);
        let w = self.work_mut(dir);
        *w = (*w - secs).max(0.0);
        if route == CopyRoute::Pageable {
            self.pageable_total = (self.pageable_total - secs).max(0.0);
        }
        if self.overlap {
            let s = self.stream_mut(dir);
            *s = (*s - secs).max(0.0);
        } else {
            self.compute = (self.compute - secs).max(0.0);
        }
    }

    // -------------------------------------------- NVMe lane (ISSUE 7)
    //
    // CPU<->NVMe block I/O runs on its own lane (the drive's submission
    // queue), independent of both PCIe copy engines.  NVMe<->GPU is
    // physically a *two-hop* copy: the payload stages through a pinned
    // host buffer, so it occupies the NVMe lane for the block-I/O hop
    // and one PCIe engine for the DMA hop, strictly sequenced.  The
    // caller prices each hop on its own curve and holds one pinned
    // lease across both hops.

    /// Non-blocking two-hop NVMe<->GPU copy staged through a pinned
    /// host buffer; returns the second hop's completion time.  `dir`
    /// is the PCIe hop's engine (`H2D`: NVMe->host->GPU, the NVMe hop
    /// runs first; `D2H`: GPU->host->NVMe, the PCIe hop runs first).
    /// `pcie_route` attributes the PCIe hop (pinned vs pageable); the
    /// NVMe hop has no pageable variant.  With overlap off both hops
    /// charge the compute frontier serially.
    #[allow(clippy::too_many_arguments)]
    pub fn async_copy_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        ready: f64,
        pcie_route: CopyRoute,
    ) -> f64 {
        self.clock.add(nvme_phase, nvme_secs);
        self.clock.add(pcie_phase, pcie_secs);
        self.copy_total += nvme_secs + pcie_secs;
        self.nvme_work += nvme_secs;
        *self.work_mut(dir) += pcie_secs;
        if pcie_route == CopyRoute::Pageable {
            self.pageable_total += pcie_secs;
        }
        if !self.overlap {
            self.compute += nvme_secs + pcie_secs;
            return self.compute;
        }
        match dir {
            CopyDir::H2D => {
                // Hop 1: NVMe -> pinned host buffer on the NVMe lane.
                let start = self.compute.max(self.nvme).max(ready);
                let hop1 = start + nvme_secs;
                self.nvme = hop1;
                // Hop 2: pinned host -> GPU on the H2D engine, gated
                // on hop 1 landing in the staging buffer.
                let start = self.compute.max(self.h2d).max(hop1);
                let done = start + pcie_secs;
                self.h2d = done;
                done
            }
            CopyDir::D2H => {
                // Hop 1: GPU -> pinned host buffer on the D2H engine.
                let start = self.compute.max(self.d2h).max(ready);
                let hop1 = start + pcie_secs;
                self.d2h = hop1;
                // Hop 2: pinned host -> NVMe on the NVMe lane.
                let start = self.compute.max(self.nvme).max(hop1);
                let done = start + nvme_secs;
                self.nvme = done;
                done
            }
        }
    }

    /// Blocking two-hop staged copy: the compute stream stalls until
    /// the second hop completes (demand fault on an NVMe-resident
    /// chunk).  The stall is exposed transfer time.
    #[allow(clippy::too_many_arguments)]
    pub fn demand_copy_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        ready: f64,
        pcie_route: CopyRoute,
    ) {
        let done = self.async_copy_staged(
            nvme_phase, nvme_secs, pcie_phase, pcie_secs, dir, ready,
            pcie_route,
        );
        self.wait_until(done);
    }

    /// Un-charge a queued staged copy cancelled before reaching the
    /// wire: both hops come back off their lanes, the phase clock and
    /// the totals — the two-hop analogue of [`StreamTimeline::
    /// reclaim_on`].
    pub fn reclaim_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        pcie_route: CopyRoute,
    ) {
        self.clock.sub(nvme_phase, nvme_secs);
        self.clock.sub(pcie_phase, pcie_secs);
        self.copy_total =
            (self.copy_total - nvme_secs - pcie_secs).max(0.0);
        self.nvme_work = (self.nvme_work - nvme_secs).max(0.0);
        let w = self.work_mut(dir);
        *w = (*w - pcie_secs).max(0.0);
        if pcie_route == CopyRoute::Pageable {
            self.pageable_total =
                (self.pageable_total - pcie_secs).max(0.0);
        }
        if self.overlap {
            self.nvme = (self.nvme - nvme_secs).max(0.0);
            let s = self.stream_mut(dir);
            *s = (*s - pcie_secs).max(0.0);
        } else {
            self.compute =
                (self.compute - nvme_secs - pcie_secs).max(0.0);
        }
    }

    /// Non-blocking single-hop CPU<->NVMe transfer (tier spill/fetch
    /// that never touches a GPU); rides only the NVMe lane.  Returns
    /// its completion time.
    pub fn async_copy_nvme(
        &mut self,
        phase: Phase,
        secs: f64,
        ready: f64,
    ) -> f64 {
        self.clock.add(phase, secs);
        self.copy_total += secs;
        self.nvme_work += secs;
        if !self.overlap {
            self.compute += secs;
            return self.compute;
        }
        let start = self.compute.max(self.nvme).max(ready);
        let done = start + secs;
        self.nvme = done;
        done
    }

    /// Blocking single-hop CPU<->NVMe transfer.
    pub fn demand_copy_nvme(&mut self, phase: Phase, secs: f64, ready: f64) {
        let done = self.async_copy_nvme(phase, secs, ready);
        self.wait_until(done);
    }

    /// Un-charge a queued CPU<->NVMe transfer cancelled before reaching
    /// the drive.
    pub fn reclaim_nvme(&mut self, phase: Phase, secs: f64) {
        self.clock.sub(phase, secs);
        self.copy_total = (self.copy_total - secs).max(0.0);
        self.nvme_work = (self.nvme_work - secs).max(0.0);
        if self.overlap {
            self.nvme = (self.nvme - secs).max(0.0);
        } else {
            self.compute = (self.compute - secs).max(0.0);
        }
    }

    /// Cumulative NVMe-lane durations (staged NVMe hops + direct
    /// CPU<->NVMe transfers; reclaims subtracted).  The controller's
    /// tier-aware window feedback signal.  Always 0.0 with the tier
    /// off.
    pub fn nvme_busy(&self) -> f64 {
        self.nvme_work
    }

    /// Block the compute stream until `t` (completion of an async copy a
    /// consumer now needs).  The stall counts as exposed transfer time.
    pub fn wait_until(&mut self, t: f64) {
        if self.overlap && t > self.compute {
            self.exposed += t - self.compute;
            self.compute = t;
        }
    }

    // ------------------------------------------------- collective stream

    /// Blocking collective on the collective stream: the compute stream
    /// stalls until it completes (queueing delay behind earlier
    /// collectives included).  The stall is exposed collective time.
    pub fn demand_collective(&mut self, phase: Phase, secs: f64) {
        self.clock.add(phase, secs);
        self.coll_total += secs;
        if !self.overlap {
            self.compute += secs;
            return;
        }
        let issue = self.compute;
        let start = issue.max(self.coll);
        let done = start + secs;
        self.coll = done;
        self.coll_exposed += done - issue;
        self.compute = done;
    }

    /// Non-blocking collective (a lookahead group gather or a draining
    /// reduce-scatter); returns its completion time.  With overlap off
    /// the collective is charged serially and "completes" immediately.
    pub fn async_collective(&mut self, phase: Phase, secs: f64) -> f64 {
        self.clock.add(phase, secs);
        self.coll_total += secs;
        if !self.overlap {
            self.compute += secs;
            return self.compute;
        }
        let start = self.compute.max(self.coll);
        let done = start + secs;
        self.coll = done;
        done
    }

    /// Block the compute stream until `t` (completion of an async
    /// collective a consumer now needs).  The stall counts as exposed
    /// collective time.
    pub fn wait_collective(&mut self, t: f64) {
        if self.overlap && t > self.compute {
            self.coll_exposed += t - self.compute;
            self.compute = t;
        }
    }

    /// Un-charge a queued async collective cancelled before reaching the
    /// wire (a lookahead gather reclaimed under memory pressure) — the
    /// collective analogue of [`StreamTimeline::reclaim`].
    pub fn reclaim_collective(&mut self, phase: Phase, secs: f64) {
        self.clock.sub(phase, secs);
        self.coll_total = (self.coll_total - secs).max(0.0);
        if self.overlap {
            self.coll = (self.coll - secs).max(0.0);
        } else {
            self.compute = (self.compute - secs).max(0.0);
        }
    }

    /// Collective time the compute stream actually waited for.
    pub fn exposed_collective(&self) -> f64 {
        if self.overlap {
            self.coll_exposed
        } else {
            self.coll_total
        }
    }

    /// Collective time hidden under compute by the collective stream.
    pub fn overlapped_collective(&self) -> f64 {
        if self.overlap {
            (self.coll_total - self.coll_exposed).max(0.0)
        } else {
            0.0
        }
    }

    /// Current compute-stream time (used to decide whether an async
    /// copy being cancelled had already landed).
    pub fn now(&self) -> f64 {
        self.compute
    }

    // ------------------------------------- feedback accessors (ISSUE 4)
    //
    // Per-stream busy/backlog probes for the adaptive lookahead
    // controller.  None of these enter `snapshot()` — they are derived
    // observers, and the golden traces must stay byte-comparable across
    // the PR that introduced them.

    /// Cumulative compute *work* charged so far (stall time excluded).
    pub fn compute_work(&self) -> f64 {
        self.compute_work
    }

    /// Cumulative copy durations enqueued on one copy engine (demand +
    /// async, both routes; reclaims subtracted).
    pub fn copy_busy(&self, dir: CopyDir) -> f64 {
        match dir {
            CopyDir::H2D => self.h2d_work,
            CopyDir::D2H => self.d2h_work,
        }
    }

    /// How far one copy engine's frontier runs ahead of the compute
    /// stream: the queued copy work a new enqueue would wait behind.
    /// Zero in serial mode (copies charge the compute stream directly).
    pub fn copy_backlog(&self, dir: CopyDir) -> f64 {
        if !self.overlap {
            return 0.0;
        }
        let f = match dir {
            CopyDir::H2D => self.h2d,
            CopyDir::D2H => self.d2h,
        };
        (f - self.compute).max(0.0)
    }

    /// Cumulative collective durations enqueued on the collective
    /// stream (demand + async; reclaims subtracted).
    pub fn collective_work(&self) -> f64 {
        self.coll_total
    }

    /// How far the collective stream's frontier runs ahead of compute.
    pub fn collective_backlog(&self) -> f64 {
        if !self.overlap {
            return 0.0;
        }
        (self.coll - self.compute).max(0.0)
    }

    /// Iteration wall time: the latest stream frontier (overlap mode) or
    /// the flat per-phase sum (serial mode).
    pub fn makespan(&self) -> f64 {
        if self.overlap {
            self.compute
                .max(self.h2d)
                .max(self.d2h)
                .max(self.coll)
                .max(self.nvme)
        } else {
            self.clock.total()
        }
    }

    /// Copy time the compute stream actually waited for.
    pub fn exposed_transfer(&self) -> f64 {
        if self.overlap {
            self.exposed
        } else {
            self.copy_total
        }
    }

    /// Copy time hidden under compute.
    pub fn overlapped_transfer(&self) -> f64 {
        if self.overlap {
            (self.copy_total - self.exposed).max(0.0)
        } else {
            0.0
        }
    }

    /// Copy time charged on the pageable curve (no staging buffer held).
    /// Zero whenever the pinned pool is disabled.
    pub fn pageable_transfer(&self) -> f64 {
        self.pageable_total
    }

    pub fn reset(&mut self) {
        self.clock.reset();
        self.compute = 0.0;
        self.h2d = 0.0;
        self.d2h = 0.0;
        self.coll = 0.0;
        self.nvme = 0.0;
        self.copy_total = 0.0;
        self.compute_work = 0.0;
        self.h2d_work = 0.0;
        self.d2h_work = 0.0;
        self.nvme_work = 0.0;
        self.exposed = 0.0;
        self.coll_total = 0.0;
        self.coll_exposed = 0.0;
        self.pageable_total = 0.0;
    }

    /// Bit-exact snapshot of the full timeline state: every stream
    /// frontier, the exposure accumulators and the per-phase clock, as
    /// hex-encoded f64 bits.  The golden-trace regression tests
    /// serialize one snapshot per moment; any change to stream or
    /// eviction scheduling shows up as a textual diff.
    ///
    /// The feedback accumulators (`compute_work`, per-engine copy work)
    /// are deliberately *not* serialized: they are derived observers for
    /// the adaptive controller, and including them would invalidate
    /// every golden trace recorded before they existed.
    pub fn snapshot(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for v in [
            self.compute,
            self.h2d,
            self.d2h,
            self.coll,
            self.copy_total,
            self.exposed,
            self.coll_total,
            self.coll_exposed,
            self.pageable_total,
            // NVMe lane frontier last so pre-tier snapshots are a
            // strict prefix (goldens regenerate; within-build
            // comparisons are what the identity properties use).
            // `nvme_work` stays out, like the other feedback
            // accumulators.
            self.nvme,
        ] {
            let _ = write!(s, "{:016x} ", v.to_bits());
        }
        for p in Phase::ALL {
            let _ = write!(s, "{:016x} ", self.clock.get(p).to_bits());
        }
        s.pop();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_mode_matches_flat_clock() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.demand_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 0.25, CopyDir::D2H, 0.0);
        assert_eq!(tl.makespan(), tl.clock().total());
        assert!((tl.makespan() - 1.75).abs() < 1e-12);
        // Serial mode: every copy is exposed by definition.
        assert!((tl.exposed_transfer() - 0.75).abs() < 1e-12);
        assert_eq!(tl.overlapped_transfer(), 0.0);
    }

    #[test]
    fn async_copy_hides_under_compute() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.wait_until(done); // copy finished long ago: no stall
        assert_eq!(tl.makespan(), 1.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert!((tl.overlapped_transfer() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn late_wait_exposes_remainder() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.charge(Phase::FwdBwd, 0.4);
        tl.wait_until(done); // 0.6 s of the copy still outstanding
        assert!((tl.exposed_transfer() - 0.6).abs() < 1e-12);
        assert!((tl.overlapped_transfer() - 0.4).abs() < 1e-12);
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn demand_copy_blocks_and_queues_fifo() {
        let mut tl = StreamTimeline::new(true);
        // A prefetch occupies the H2D engine for 1 s...
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        // ...so a demand fetch issued at t=0 waits behind it.
        tl.demand_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        assert!((tl.makespan() - 1.5).abs() < 1e-12);
        assert!((tl.exposed_transfer() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ready_dependency_delays_start() {
        let mut tl = StreamTimeline::new(true);
        // Eviction on D2H completes at 0.3; the fetch into the freed
        // space cannot start before that.
        let evict_done =
            tl.async_copy(Phase::GpuToCpu, 0.3, CopyDir::D2H, 0.0);
        tl.demand_copy(Phase::CpuToGpu, 0.2, CopyDir::H2D, evict_done);
        assert!((tl.makespan() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn copy_streams_independent_of_each_other() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 1.0, CopyDir::D2H, 0.0);
        // Both engines run concurrently: makespan 1, not 2.
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reclaim_undoes_a_cancelled_queued_copy() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.reclaim(Phase::CpuToGpu, 1.0, CopyDir::H2D);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.get(Phase::CpuToGpu), 0.0);
        assert_eq!(tl.overlapped_transfer(), 0.0);
        // Serial mode nets out the same way.
        let mut tl = StreamTimeline::new(false);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.reclaim(Phase::CpuToGpu, 1.0, CopyDir::H2D);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
    }

    #[test]
    fn reset_clears_frontiers() {
        let mut tl = StreamTimeline::new(true);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_collective(Phase::AllGather, 2.0);
        tl.reset();
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.clock().total(), 0.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert_eq!(tl.exposed_collective(), 0.0);
    }

    #[test]
    fn async_collective_hides_under_compute() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_collective(Phase::AllGather, 0.5);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.wait_collective(done); // landed long ago: no stall
        assert_eq!(tl.makespan(), 1.0);
        assert_eq!(tl.exposed_collective(), 0.0);
        assert!((tl.overlapped_collective() - 0.5).abs() < 1e-12);
        // Collective accounting is separate from copy accounting.
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert_eq!(tl.overlapped_transfer(), 0.0);
    }

    #[test]
    fn late_collective_wait_exposes_remainder() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_collective(Phase::AllGather, 1.0);
        tl.charge(Phase::FwdBwd, 0.4);
        tl.wait_collective(done); // 0.6 s still on the wire
        assert!((tl.exposed_collective() - 0.6).abs() < 1e-12);
        assert!((tl.overlapped_collective() - 0.4).abs() < 1e-12);
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn demand_collective_queues_fifo_behind_async() {
        let mut tl = StreamTimeline::new(true);
        // A lookahead gather occupies the collective stream for 1 s...
        tl.async_collective(Phase::AllGather, 1.0);
        // ...so a demand gather issued at t=0 waits behind it.
        tl.demand_collective(Phase::AllGather, 0.5);
        assert!((tl.makespan() - 1.5).abs() < 1e-12);
        assert!((tl.exposed_collective() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn collective_stream_independent_of_copy_engines() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 1.0, CopyDir::D2H, 0.0);
        tl.async_collective(Phase::ReduceScatter, 1.0);
        // All three engines run concurrently: makespan 1, not 3.
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn serial_mode_collective_charges_compute() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        let done = tl.async_collective(Phase::AllGather, 0.5);
        tl.demand_collective(Phase::ReduceScatter, 0.25);
        tl.wait_collective(done); // no-op serially
        assert_eq!(tl.makespan(), tl.clock().total());
        assert!((tl.makespan() - 1.75).abs() < 1e-12);
        // Serial mode: every collective is exposed by definition.
        assert!((tl.exposed_collective() - 0.75).abs() < 1e-12);
        assert_eq!(tl.overlapped_collective(), 0.0);
    }

    #[test]
    fn reclaim_collective_undoes_a_cancelled_queued_gather() {
        let mut tl = StreamTimeline::new(true);
        tl.async_collective(Phase::AllGather, 1.0);
        tl.reclaim_collective(Phase::AllGather, 1.0);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.get(Phase::AllGather), 0.0);
        assert_eq!(tl.overlapped_collective(), 0.0);
    }

    #[test]
    fn pinned_route_is_bit_identical_to_legacy_methods() {
        // ISSUE 3 acceptance: with the pool disabled every copy routes
        // Pinned, and that path must reproduce the pre-pool timeline
        // bit-for-bit — the routed methods with Pinned ARE the legacy
        // methods.
        for overlap in [false, true] {
            let mut legacy = StreamTimeline::new(overlap);
            let mut routed = StreamTimeline::new(overlap);
            legacy.charge(Phase::FwdBwd, 0.1 + 0.2);
            routed.charge(Phase::FwdBwd, 0.1 + 0.2);
            legacy.async_copy(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0);
            routed.async_copy_on(
                Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0,
                CopyRoute::Pinned,
            );
            legacy.demand_copy(Phase::GpuToCpu, 0.7, CopyDir::D2H, 0.1);
            routed.demand_copy_on(
                Phase::GpuToCpu, 0.7, CopyDir::D2H, 0.1, CopyRoute::Pinned,
            );
            legacy.reclaim(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D);
            routed.reclaim_on(
                Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, CopyRoute::Pinned,
            );
            assert_eq!(legacy.snapshot(), routed.snapshot());
            assert_eq!(routed.pageable_transfer(), 0.0);
        }
    }

    #[test]
    fn pageable_route_is_attributed_and_reclaimable() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy_on(
            Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0, CopyRoute::Pageable,
        );
        tl.demand_copy_on(
            Phase::GpuToCpu, 0.25, CopyDir::D2H, 0.0, CopyRoute::Pinned,
        );
        assert!((tl.pageable_transfer() - 0.5).abs() < 1e-12);
        // Stream scheduling is route-independent: both engines advanced.
        assert!((tl.makespan() - 0.5).abs() < 1e-12);
        tl.reclaim_on(Phase::CpuToGpu, 0.5, CopyDir::H2D,
                      CopyRoute::Pageable);
        assert_eq!(tl.pageable_transfer(), 0.0);
        tl.reset();
        assert_eq!(tl.pageable_transfer(), 0.0);
    }

    #[test]
    fn feedback_accessors_track_busy_and_backlog() {
        let mut tl = StreamTimeline::new(true);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 0.5, CopyDir::D2H, 0.0);
        tl.async_collective(Phase::AllGather, 3.0);
        assert!((tl.compute_work() - 1.0).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::D2H) - 0.5).abs() < 1e-12);
        // Copies start at the compute frontier (1.0): the H2D engine
        // runs ahead to 3.0, so its backlog past compute is 2.0.
        assert!((tl.copy_backlog(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert!((tl.copy_backlog(CopyDir::D2H) - 0.5).abs() < 1e-12);
        assert!((tl.collective_work() - 3.0).abs() < 1e-12);
        assert!((tl.collective_backlog() - 3.0).abs() < 1e-12);
        // A wait advances the compute frontier but not compute work,
        // and drains the backlog.
        tl.wait_until(3.0);
        assert!((tl.compute_work() - 1.0).abs() < 1e-12);
        assert_eq!(tl.copy_backlog(CopyDir::H2D), 0.0);
        // Reclaim subtracts from the per-engine busy accumulator.
        tl.reclaim(Phase::GpuToCpu, 0.5, CopyDir::D2H);
        assert_eq!(tl.copy_busy(CopyDir::D2H), 0.0);
        tl.reset();
        assert_eq!(tl.compute_work(), 0.0);
        assert_eq!(tl.copy_busy(CopyDir::H2D), 0.0);
    }

    #[test]
    fn feedback_accessors_zero_backlog_in_serial_mode() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_collective(Phase::AllGather, 3.0);
        // Work is still attributed per engine, but nothing queues: the
        // serial timeline has no stream to run ahead of compute.
        assert!((tl.copy_busy(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert_eq!(tl.copy_backlog(CopyDir::H2D), 0.0);
        assert_eq!(tl.collective_backlog(), 0.0);
    }

    #[test]
    fn staged_copy_sequences_two_hops_h2d() {
        // NVMe->GPU: the NVMe hop (0.6) lands in the staging buffer
        // first, then the PCIe hop (0.2) DMAs it up — the H2D engine's
        // frontier ends at 0.8 even though it was idle until 0.6.
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy_staged(
            Phase::Nvme, 0.6, Phase::CpuToGpu, 0.2, CopyDir::H2D, 0.0,
            CopyRoute::Pinned,
        );
        assert!((done - 0.8).abs() < 1e-12);
        assert!((tl.makespan() - 0.8).abs() < 1e-12);
        assert!((tl.nvme_busy() - 0.6).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::H2D) - 0.2).abs() < 1e-12);
        assert!((tl.get(Phase::Nvme) - 0.6).abs() < 1e-12);
        assert!((tl.get(Phase::CpuToGpu) - 0.2).abs() < 1e-12);
        // Both lanes busy: a second staged copy queues behind both.
        let done2 = tl.async_copy_staged(
            Phase::Nvme, 0.6, Phase::CpuToGpu, 0.2, CopyDir::H2D, 0.0,
            CopyRoute::Pinned,
        );
        assert!((done2 - 1.4).abs() < 1e-12, "{done2}");
    }

    #[test]
    fn staged_copy_sequences_two_hops_d2h() {
        // GPU->NVMe: PCIe hop first (0.2), then the NVMe hop (0.6).
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy_staged(
            Phase::GpuToCpu, 0.6, Phase::GpuToCpu, 0.2, CopyDir::D2H, 0.0,
            CopyRoute::Pageable,
        );
        // nvme_phase is the first arg: here both hops attribute to
        // GpuToCpu for simplicity of the assertion below.
        assert!((done - 0.8).abs() < 1e-12);
        assert!((tl.nvme_busy() - 0.6).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::D2H) - 0.2).abs() < 1e-12);
        // Only the PCIe hop is pageable-attributed.
        assert!((tl.pageable_transfer() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn staged_demand_blocks_and_serial_mode_charges_compute() {
        let mut tl = StreamTimeline::new(true);
        tl.demand_copy_staged(
            Phase::Nvme, 0.6, Phase::CpuToGpu, 0.2, CopyDir::H2D, 0.0,
            CopyRoute::Pinned,
        );
        assert!((tl.now() - 0.8).abs() < 1e-12);
        assert!((tl.exposed_transfer() - 0.8).abs() < 1e-12);
        // Serial: both hops charge the compute frontier, makespan is
        // the flat clock sum.
        let mut tl = StreamTimeline::new(false);
        tl.demand_copy_staged(
            Phase::Nvme, 0.6, Phase::CpuToGpu, 0.2, CopyDir::H2D, 0.0,
            CopyRoute::Pinned,
        );
        assert_eq!(tl.makespan(), tl.clock().total());
        assert!((tl.makespan() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn reclaim_staged_undoes_both_hops() {
        for overlap in [true, false] {
            let mut tl = StreamTimeline::new(overlap);
            tl.async_copy_staged(
                Phase::Nvme, 0.6, Phase::GpuToCpu, 0.2, CopyDir::D2H, 0.0,
                CopyRoute::Pageable,
            );
            tl.reclaim_staged(
                Phase::Nvme, 0.6, Phase::GpuToCpu, 0.2, CopyDir::D2H,
                CopyRoute::Pageable,
            );
            assert_eq!(tl.makespan(), 0.0);
            assert_eq!(tl.nvme_busy(), 0.0);
            assert_eq!(tl.copy_busy(CopyDir::D2H), 0.0);
            assert_eq!(tl.get(Phase::Nvme), 0.0);
            assert_eq!(tl.pageable_transfer(), 0.0);
        }
    }

    #[test]
    fn nvme_lane_independent_of_copy_engines() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 1.0, CopyDir::D2H, 0.0);
        tl.async_copy_nvme(Phase::Nvme, 1.0, 0.0);
        // Three independent lanes: makespan 1, not 3.
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
        // Direct CPU<->NVMe transfers queue FIFO on the lane.
        let done = tl.async_copy_nvme(Phase::Nvme, 0.5, 0.0);
        assert!((done - 1.5).abs() < 1e-12);
        tl.reclaim_nvme(Phase::Nvme, 0.5);
        assert!((tl.nvme_busy() - 1.0).abs() < 1e-12);
        tl.reset();
        assert_eq!(tl.nvme_busy(), 0.0);
        assert_eq!(tl.makespan(), 0.0);
    }

    #[test]
    fn nvme_demand_copy_blocks() {
        let mut tl = StreamTimeline::new(true);
        tl.charge(Phase::FwdBwd, 0.1);
        tl.demand_copy_nvme(Phase::Nvme, 0.4, 0.0);
        assert!((tl.now() - 0.5).abs() < 1e-12);
        assert!((tl.exposed_transfer() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn property_staged_hops_conserve_time_and_bytes() {
        // ISSUE 7 satellite (two-hop conservation): price each hop of
        // an NVMe<->GPU staged transfer on its own curve with the
        // remainder-exact split, issue the staged copy, and require
        // every accumulator to carry exactly the per-hop totals — no
        // time (hence no bytes) lost or double-billed between hops.
        use crate::mem::Interconnect;
        use crate::util::quickcheck::forall;
        let net = Interconnect::v100_node();
        forall(
            200,
            |rng| {
                (
                    rng.range(1, 1 << 26) as u64,
                    rng.range(1, 64) as u64,
                    rng.range(0, 2) == 0,
                )
            },
            |&(total, n_msgs, h2d)| {
                let nvme_secs = net.nvme.transfer_time_split(total, n_msgs);
                let pcie_secs = net.pcie.transfer_time_split(total, n_msgs);
                let mut tl = StreamTimeline::new(true);
                let dir = if h2d { CopyDir::H2D } else { CopyDir::D2H };
                let pcie_phase =
                    if h2d { Phase::CpuToGpu } else { Phase::GpuToCpu };
                let done = tl.async_copy_staged(
                    Phase::Nvme, nvme_secs, pcie_phase, pcie_secs, dir,
                    0.0, CopyRoute::Pinned,
                );
                let checks = [
                    (tl.nvme_busy(), nvme_secs, "nvme lane"),
                    (tl.copy_busy(dir), pcie_secs, "pcie lane"),
                    (tl.get(Phase::Nvme), nvme_secs, "nvme phase"),
                    (tl.get(pcie_phase), pcie_secs, "pcie phase"),
                    (done, nvme_secs + pcie_secs, "sequenced end"),
                    (tl.makespan(), nvme_secs + pcie_secs, "makespan"),
                ];
                for (got, want, what) in checks {
                    if (got - want).abs() > 1e-12 * want.max(1.0) {
                        return Err(format!("{what}: {got} != {want}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn snapshot_includes_nvme_frontier() {
        let mut a = StreamTimeline::new(true);
        let b = StreamTimeline::new(true);
        a.async_copy_nvme(Phase::Nvme, 0.5, 0.0);
        assert_ne!(a.snapshot(), b.snapshot());
    }

    #[test]
    fn snapshot_is_bit_exact_and_deterministic() {
        let mut a = StreamTimeline::new(true);
        let mut b = StreamTimeline::new(true);
        for tl in [&mut a, &mut b] {
            tl.charge(Phase::FwdBwd, 0.1 + 0.2); // not a round float
            tl.async_copy(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0);
            tl.async_collective(Phase::AllGather, 0.7);
        }
        assert_eq!(a.snapshot(), b.snapshot());
        b.charge(Phase::FwdBwd, f64::EPSILON);
        assert_ne!(a.snapshot(), b.snapshot(), "1-ulp drift must show");
    }
}
