//! Multi-stream timeline: compute / H2D-copy / D2H-copy / collective
//! overlap.
//!
//! The GPU model behind the prefetch pipeline: one compute stream, two
//! copy engines (CPU->GPU and GPU->CPU), as on every discrete GPU since
//! Fermi, and one **collective stream** (the dedicated NCCL stream real
//! frameworks use for all-gather/reduce-scatter).  Each stream tracks its
//! own time frontier.  Work charged to the compute stream advances only
//! the compute frontier; a copy enqueued on a copy stream starts no
//! earlier than (a) the moment it was issued (the compute frontier at
//! enqueue time), (b) the copy stream's own frontier (copies on one
//! engine are FIFO), and (c) an optional `ready` dependency — used to
//! model an H2D fetch that must wait for the D2H eviction that frees its
//! space.  Collectives queue FIFO on the collective stream the same way.
//!
//! Two kinds of copies (and, symmetrically, collectives):
//!
//! * **demand** copies sit on the requester's critical path: the compute
//!   stream blocks until the copy completes.  The stall (queueing delay +
//!   wire time) is accounted as *exposed* transfer time.
//! * **async** copies (prefetches, evictions, activation offload,
//!   lookahead group gathers, draining reduce-scatters) do not block;
//!   they return their completion time so the engine can `wait until` it
//!   if a later operator actually needs the payload.  Whatever part of an
//!   async copy the compute stream never waits for is *overlapped*
//!   (hidden) time.
//!
//! Copy time and collective time are attributed separately (`exposed_
//! transfer`/`overlapped_transfer` vs `exposed_collective`/`overlapped_
//! collective`) because the paper's multi-GPU story hinges on hiding the
//! latter behind compute specifically.
//!
//! With `overlap = false` the timeline degenerates to the flat per-phase
//! accumulator semantics the serial engine always had: every copy charges
//! the compute frontier and `makespan() == clock.total()`, bit-for-bit —
//! the overlap-off ablation reproduces the pre-pipeline numbers exactly.

use super::clock::{Phase, SimClock};

/// Direction of a PCIe copy, selecting the copy engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyDir {
    /// CPU -> GPU (host-to-device engine).
    H2D,
    /// GPU -> CPU (device-to-host engine).
    D2H,
}

/// Which host-memory path a PCIe copy was charged on (ISSUE 3
/// tentpole).  The engine decides per copy: pinned while holding a
/// staging buffer from the [`crate::mem::PinnedPool`], pageable
/// otherwise.  The timeline only *attributes* the split
/// ([`StreamTimeline::pageable_transfer`]) — the duration difference is
/// already baked into `secs` by the caller's curve choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CopyRoute {
    /// DMA out of a pinned staging buffer (full PCIe rate).
    Pinned,
    /// Driver-bounced pageable copy (~half the pinned rate).
    Pageable,
}

/// Four-stream simulated timeline with per-phase attribution.
#[derive(Clone, Debug)]
pub struct StreamTimeline {
    clock: SimClock,
    overlap: bool,
    /// Stream frontiers (seconds since iteration start).
    compute: f64,
    h2d: f64,
    d2h: f64,
    /// Collective (NCCL) stream frontier.
    coll: f64,
    /// Sum of all copy durations (both engines, both kinds).
    copy_total: f64,
    /// Sum of compute-stream *work* charged via [`StreamTimeline::
    /// charge`] — unlike the `compute` frontier it excludes stall time,
    /// so the adaptive lookahead controller can difference it per
    /// moment to estimate pure compute throughput.
    compute_work: f64,
    /// Per-engine copy-duration sums (subset of `copy_total`): the
    /// controller's transfer-rate feedback signals.
    h2d_work: f64,
    d2h_work: f64,
    /// Compute-stream stall time attributable to copies.
    exposed: f64,
    /// Sum of all collective durations enqueued on the collective stream.
    coll_total: f64,
    /// Compute-stream stall time attributable to collectives.
    coll_exposed: f64,
    /// Copy time (within `copy_total`) charged on the pageable curve —
    /// transfers that could not acquire a pinned staging buffer.
    pageable_total: f64,
}

impl StreamTimeline {
    pub fn new(overlap: bool) -> Self {
        StreamTimeline {
            clock: SimClock::new(),
            overlap,
            compute: 0.0,
            h2d: 0.0,
            d2h: 0.0,
            coll: 0.0,
            copy_total: 0.0,
            compute_work: 0.0,
            h2d_work: 0.0,
            d2h_work: 0.0,
            exposed: 0.0,
            coll_total: 0.0,
            coll_exposed: 0.0,
            pageable_total: 0.0,
        }
    }

    pub fn overlap(&self) -> bool {
        self.overlap
    }

    /// Per-phase attribution (serial-sum semantics: phases always add up
    /// to the *work* performed, regardless of how much was hidden).
    pub fn clock(&self) -> &SimClock {
        &self.clock
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.clock.get(phase)
    }

    /// Charge work to the compute stream (operators, ADAM, collectives).
    pub fn charge(&mut self, phase: Phase, secs: f64) {
        self.clock.add(phase, secs);
        self.compute_work += secs;
        self.compute += secs;
    }

    fn stream_mut(&mut self, dir: CopyDir) -> &mut f64 {
        match dir {
            CopyDir::H2D => &mut self.h2d,
            CopyDir::D2H => &mut self.d2h,
        }
    }

    fn work_mut(&mut self, dir: CopyDir) -> &mut f64 {
        match dir {
            CopyDir::H2D => &mut self.h2d_work,
            CopyDir::D2H => &mut self.d2h_work,
        }
    }

    /// Blocking copy on the compute critical path.  `ready` is an extra
    /// start dependency (0.0 for none).  Charged as pinned — demand
    /// copies preempt the staging pool (see [`CopyRoute`]).
    pub fn demand_copy(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) {
        self.demand_copy_on(phase, secs, dir, ready, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::demand_copy`] with an explicit host-memory
    /// route for the pinned/pageable attribution.  The engine never
    /// routes a demand copy Pageable (demand preempts the pool — see
    /// [`CopyRoute`]), so production callers go through `demand_copy`;
    /// this variant keeps the demand/async/reclaim API symmetric for
    /// tests and for future policies where demand copies, too, queue
    /// on the staging pool (e.g. a strict-FIFO pool model).
    pub fn demand_copy_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
        route: CopyRoute,
    ) {
        self.clock.add(phase, secs);
        self.copy_total += secs;
        *self.work_mut(dir) += secs;
        if route == CopyRoute::Pageable {
            self.pageable_total += secs;
        }
        if !self.overlap {
            self.compute += secs;
            return;
        }
        let issue = self.compute;
        let start = issue.max(*self.stream_mut(dir)).max(ready);
        let done = start + secs;
        *self.stream_mut(dir) = done;
        self.exposed += done - issue;
        self.compute = done;
    }

    /// Non-blocking copy; returns its completion time.  With overlap off
    /// the copy is charged serially and "completes" immediately.
    pub fn async_copy(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) -> f64 {
        self.async_copy_on(phase, secs, dir, ready, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::async_copy`] with an explicit host-memory
    /// route for the pinned/pageable attribution.
    pub fn async_copy_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
        route: CopyRoute,
    ) -> f64 {
        self.clock.add(phase, secs);
        self.copy_total += secs;
        *self.work_mut(dir) += secs;
        if route == CopyRoute::Pageable {
            self.pageable_total += secs;
        }
        if !self.overlap {
            self.compute += secs;
            return self.compute;
        }
        let start = self.compute.max(*self.stream_mut(dir)).max(ready);
        let done = start + secs;
        *self.stream_mut(dir) = done;
        done
    }

    /// Un-charge a previously enqueued async copy that was cancelled
    /// before reaching the wire: the queue behind it compresses, so its
    /// duration comes back off the stream frontier, the phase clock and
    /// the copy total.  Keeps time accounting consistent with the byte
    /// accounting (`MoveStats` credits cancelled prefetches back too).
    pub fn reclaim(&mut self, phase: Phase, secs: f64, dir: CopyDir) {
        self.reclaim_on(phase, secs, dir, CopyRoute::Pinned)
    }

    /// [`StreamTimeline::reclaim`] for a copy charged on an explicit
    /// route — a cancelled pageable copy credits the pageable
    /// attribution back too.
    pub fn reclaim_on(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        route: CopyRoute,
    ) {
        self.clock.sub(phase, secs);
        self.copy_total = (self.copy_total - secs).max(0.0);
        let w = self.work_mut(dir);
        *w = (*w - secs).max(0.0);
        if route == CopyRoute::Pageable {
            self.pageable_total = (self.pageable_total - secs).max(0.0);
        }
        if self.overlap {
            let s = self.stream_mut(dir);
            *s = (*s - secs).max(0.0);
        } else {
            self.compute = (self.compute - secs).max(0.0);
        }
    }

    /// Block the compute stream until `t` (completion of an async copy a
    /// consumer now needs).  The stall counts as exposed transfer time.
    pub fn wait_until(&mut self, t: f64) {
        if self.overlap && t > self.compute {
            self.exposed += t - self.compute;
            self.compute = t;
        }
    }

    // ------------------------------------------------- collective stream

    /// Blocking collective on the collective stream: the compute stream
    /// stalls until it completes (queueing delay behind earlier
    /// collectives included).  The stall is exposed collective time.
    pub fn demand_collective(&mut self, phase: Phase, secs: f64) {
        self.clock.add(phase, secs);
        self.coll_total += secs;
        if !self.overlap {
            self.compute += secs;
            return;
        }
        let issue = self.compute;
        let start = issue.max(self.coll);
        let done = start + secs;
        self.coll = done;
        self.coll_exposed += done - issue;
        self.compute = done;
    }

    /// Non-blocking collective (a lookahead group gather or a draining
    /// reduce-scatter); returns its completion time.  With overlap off
    /// the collective is charged serially and "completes" immediately.
    pub fn async_collective(&mut self, phase: Phase, secs: f64) -> f64 {
        self.clock.add(phase, secs);
        self.coll_total += secs;
        if !self.overlap {
            self.compute += secs;
            return self.compute;
        }
        let start = self.compute.max(self.coll);
        let done = start + secs;
        self.coll = done;
        done
    }

    /// Block the compute stream until `t` (completion of an async
    /// collective a consumer now needs).  The stall counts as exposed
    /// collective time.
    pub fn wait_collective(&mut self, t: f64) {
        if self.overlap && t > self.compute {
            self.coll_exposed += t - self.compute;
            self.compute = t;
        }
    }

    /// Un-charge a queued async collective cancelled before reaching the
    /// wire (a lookahead gather reclaimed under memory pressure) — the
    /// collective analogue of [`StreamTimeline::reclaim`].
    pub fn reclaim_collective(&mut self, phase: Phase, secs: f64) {
        self.clock.sub(phase, secs);
        self.coll_total = (self.coll_total - secs).max(0.0);
        if self.overlap {
            self.coll = (self.coll - secs).max(0.0);
        } else {
            self.compute = (self.compute - secs).max(0.0);
        }
    }

    /// Collective time the compute stream actually waited for.
    pub fn exposed_collective(&self) -> f64 {
        if self.overlap {
            self.coll_exposed
        } else {
            self.coll_total
        }
    }

    /// Collective time hidden under compute by the collective stream.
    pub fn overlapped_collective(&self) -> f64 {
        if self.overlap {
            (self.coll_total - self.coll_exposed).max(0.0)
        } else {
            0.0
        }
    }

    /// Current compute-stream time (used to decide whether an async
    /// copy being cancelled had already landed).
    pub fn now(&self) -> f64 {
        self.compute
    }

    // ------------------------------------- feedback accessors (ISSUE 4)
    //
    // Per-stream busy/backlog probes for the adaptive lookahead
    // controller.  None of these enter `snapshot()` — they are derived
    // observers, and the golden traces must stay byte-comparable across
    // the PR that introduced them.

    /// Cumulative compute *work* charged so far (stall time excluded).
    pub fn compute_work(&self) -> f64 {
        self.compute_work
    }

    /// Cumulative copy durations enqueued on one copy engine (demand +
    /// async, both routes; reclaims subtracted).
    pub fn copy_busy(&self, dir: CopyDir) -> f64 {
        match dir {
            CopyDir::H2D => self.h2d_work,
            CopyDir::D2H => self.d2h_work,
        }
    }

    /// How far one copy engine's frontier runs ahead of the compute
    /// stream: the queued copy work a new enqueue would wait behind.
    /// Zero in serial mode (copies charge the compute stream directly).
    pub fn copy_backlog(&self, dir: CopyDir) -> f64 {
        if !self.overlap {
            return 0.0;
        }
        let f = match dir {
            CopyDir::H2D => self.h2d,
            CopyDir::D2H => self.d2h,
        };
        (f - self.compute).max(0.0)
    }

    /// Cumulative collective durations enqueued on the collective
    /// stream (demand + async; reclaims subtracted).
    pub fn collective_work(&self) -> f64 {
        self.coll_total
    }

    /// How far the collective stream's frontier runs ahead of compute.
    pub fn collective_backlog(&self) -> f64 {
        if !self.overlap {
            return 0.0;
        }
        (self.coll - self.compute).max(0.0)
    }

    /// Iteration wall time: the latest stream frontier (overlap mode) or
    /// the flat per-phase sum (serial mode).
    pub fn makespan(&self) -> f64 {
        if self.overlap {
            self.compute.max(self.h2d).max(self.d2h).max(self.coll)
        } else {
            self.clock.total()
        }
    }

    /// Copy time the compute stream actually waited for.
    pub fn exposed_transfer(&self) -> f64 {
        if self.overlap {
            self.exposed
        } else {
            self.copy_total
        }
    }

    /// Copy time hidden under compute.
    pub fn overlapped_transfer(&self) -> f64 {
        if self.overlap {
            (self.copy_total - self.exposed).max(0.0)
        } else {
            0.0
        }
    }

    /// Copy time charged on the pageable curve (no staging buffer held).
    /// Zero whenever the pinned pool is disabled.
    pub fn pageable_transfer(&self) -> f64 {
        self.pageable_total
    }

    pub fn reset(&mut self) {
        self.clock.reset();
        self.compute = 0.0;
        self.h2d = 0.0;
        self.d2h = 0.0;
        self.coll = 0.0;
        self.copy_total = 0.0;
        self.compute_work = 0.0;
        self.h2d_work = 0.0;
        self.d2h_work = 0.0;
        self.exposed = 0.0;
        self.coll_total = 0.0;
        self.coll_exposed = 0.0;
        self.pageable_total = 0.0;
    }

    /// Bit-exact snapshot of the full timeline state: every stream
    /// frontier, the exposure accumulators and the per-phase clock, as
    /// hex-encoded f64 bits.  The golden-trace regression tests
    /// serialize one snapshot per moment; any change to stream or
    /// eviction scheduling shows up as a textual diff.
    ///
    /// The feedback accumulators (`compute_work`, per-engine copy work)
    /// are deliberately *not* serialized: they are derived observers for
    /// the adaptive controller, and including them would invalidate
    /// every golden trace recorded before they existed.
    pub fn snapshot(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for v in [
            self.compute,
            self.h2d,
            self.d2h,
            self.coll,
            self.copy_total,
            self.exposed,
            self.coll_total,
            self.coll_exposed,
            self.pageable_total,
        ] {
            let _ = write!(s, "{:016x} ", v.to_bits());
        }
        for p in Phase::ALL {
            let _ = write!(s, "{:016x} ", self.clock.get(p).to_bits());
        }
        s.pop();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_mode_matches_flat_clock() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.demand_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 0.25, CopyDir::D2H, 0.0);
        assert_eq!(tl.makespan(), tl.clock().total());
        assert!((tl.makespan() - 1.75).abs() < 1e-12);
        // Serial mode: every copy is exposed by definition.
        assert!((tl.exposed_transfer() - 0.75).abs() < 1e-12);
        assert_eq!(tl.overlapped_transfer(), 0.0);
    }

    #[test]
    fn async_copy_hides_under_compute() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.wait_until(done); // copy finished long ago: no stall
        assert_eq!(tl.makespan(), 1.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert!((tl.overlapped_transfer() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn late_wait_exposes_remainder() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.charge(Phase::FwdBwd, 0.4);
        tl.wait_until(done); // 0.6 s of the copy still outstanding
        assert!((tl.exposed_transfer() - 0.6).abs() < 1e-12);
        assert!((tl.overlapped_transfer() - 0.4).abs() < 1e-12);
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn demand_copy_blocks_and_queues_fifo() {
        let mut tl = StreamTimeline::new(true);
        // A prefetch occupies the H2D engine for 1 s...
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        // ...so a demand fetch issued at t=0 waits behind it.
        tl.demand_copy(Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0);
        assert!((tl.makespan() - 1.5).abs() < 1e-12);
        assert!((tl.exposed_transfer() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn ready_dependency_delays_start() {
        let mut tl = StreamTimeline::new(true);
        // Eviction on D2H completes at 0.3; the fetch into the freed
        // space cannot start before that.
        let evict_done =
            tl.async_copy(Phase::GpuToCpu, 0.3, CopyDir::D2H, 0.0);
        tl.demand_copy(Phase::CpuToGpu, 0.2, CopyDir::H2D, evict_done);
        assert!((tl.makespan() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn copy_streams_independent_of_each_other() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 1.0, CopyDir::D2H, 0.0);
        // Both engines run concurrently: makespan 1, not 2.
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reclaim_undoes_a_cancelled_queued_copy() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.reclaim(Phase::CpuToGpu, 1.0, CopyDir::H2D);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.get(Phase::CpuToGpu), 0.0);
        assert_eq!(tl.overlapped_transfer(), 0.0);
        // Serial mode nets out the same way.
        let mut tl = StreamTimeline::new(false);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.reclaim(Phase::CpuToGpu, 1.0, CopyDir::H2D);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
    }

    #[test]
    fn reset_clears_frontiers() {
        let mut tl = StreamTimeline::new(true);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_collective(Phase::AllGather, 2.0);
        tl.reset();
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.clock().total(), 0.0);
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert_eq!(tl.exposed_collective(), 0.0);
    }

    #[test]
    fn async_collective_hides_under_compute() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_collective(Phase::AllGather, 0.5);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.wait_collective(done); // landed long ago: no stall
        assert_eq!(tl.makespan(), 1.0);
        assert_eq!(tl.exposed_collective(), 0.0);
        assert!((tl.overlapped_collective() - 0.5).abs() < 1e-12);
        // Collective accounting is separate from copy accounting.
        assert_eq!(tl.exposed_transfer(), 0.0);
        assert_eq!(tl.overlapped_transfer(), 0.0);
    }

    #[test]
    fn late_collective_wait_exposes_remainder() {
        let mut tl = StreamTimeline::new(true);
        let done = tl.async_collective(Phase::AllGather, 1.0);
        tl.charge(Phase::FwdBwd, 0.4);
        tl.wait_collective(done); // 0.6 s still on the wire
        assert!((tl.exposed_collective() - 0.6).abs() < 1e-12);
        assert!((tl.overlapped_collective() - 0.4).abs() < 1e-12);
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn demand_collective_queues_fifo_behind_async() {
        let mut tl = StreamTimeline::new(true);
        // A lookahead gather occupies the collective stream for 1 s...
        tl.async_collective(Phase::AllGather, 1.0);
        // ...so a demand gather issued at t=0 waits behind it.
        tl.demand_collective(Phase::AllGather, 0.5);
        assert!((tl.makespan() - 1.5).abs() < 1e-12);
        assert!((tl.exposed_collective() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn collective_stream_independent_of_copy_engines() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy(Phase::CpuToGpu, 1.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 1.0, CopyDir::D2H, 0.0);
        tl.async_collective(Phase::ReduceScatter, 1.0);
        // All three engines run concurrently: makespan 1, not 3.
        assert!((tl.makespan() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn serial_mode_collective_charges_compute() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        let done = tl.async_collective(Phase::AllGather, 0.5);
        tl.demand_collective(Phase::ReduceScatter, 0.25);
        tl.wait_collective(done); // no-op serially
        assert_eq!(tl.makespan(), tl.clock().total());
        assert!((tl.makespan() - 1.75).abs() < 1e-12);
        // Serial mode: every collective is exposed by definition.
        assert!((tl.exposed_collective() - 0.75).abs() < 1e-12);
        assert_eq!(tl.overlapped_collective(), 0.0);
    }

    #[test]
    fn reclaim_collective_undoes_a_cancelled_queued_gather() {
        let mut tl = StreamTimeline::new(true);
        tl.async_collective(Phase::AllGather, 1.0);
        tl.reclaim_collective(Phase::AllGather, 1.0);
        assert_eq!(tl.makespan(), 0.0);
        assert_eq!(tl.get(Phase::AllGather), 0.0);
        assert_eq!(tl.overlapped_collective(), 0.0);
    }

    #[test]
    fn pinned_route_is_bit_identical_to_legacy_methods() {
        // ISSUE 3 acceptance: with the pool disabled every copy routes
        // Pinned, and that path must reproduce the pre-pool timeline
        // bit-for-bit — the routed methods with Pinned ARE the legacy
        // methods.
        for overlap in [false, true] {
            let mut legacy = StreamTimeline::new(overlap);
            let mut routed = StreamTimeline::new(overlap);
            legacy.charge(Phase::FwdBwd, 0.1 + 0.2);
            routed.charge(Phase::FwdBwd, 0.1 + 0.2);
            legacy.async_copy(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0);
            routed.async_copy_on(
                Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0,
                CopyRoute::Pinned,
            );
            legacy.demand_copy(Phase::GpuToCpu, 0.7, CopyDir::D2H, 0.1);
            routed.demand_copy_on(
                Phase::GpuToCpu, 0.7, CopyDir::D2H, 0.1, CopyRoute::Pinned,
            );
            legacy.reclaim(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D);
            routed.reclaim_on(
                Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, CopyRoute::Pinned,
            );
            assert_eq!(legacy.snapshot(), routed.snapshot());
            assert_eq!(routed.pageable_transfer(), 0.0);
        }
    }

    #[test]
    fn pageable_route_is_attributed_and_reclaimable() {
        let mut tl = StreamTimeline::new(true);
        tl.async_copy_on(
            Phase::CpuToGpu, 0.5, CopyDir::H2D, 0.0, CopyRoute::Pageable,
        );
        tl.demand_copy_on(
            Phase::GpuToCpu, 0.25, CopyDir::D2H, 0.0, CopyRoute::Pinned,
        );
        assert!((tl.pageable_transfer() - 0.5).abs() < 1e-12);
        // Stream scheduling is route-independent: both engines advanced.
        assert!((tl.makespan() - 0.5).abs() < 1e-12);
        tl.reclaim_on(Phase::CpuToGpu, 0.5, CopyDir::H2D,
                      CopyRoute::Pageable);
        assert_eq!(tl.pageable_transfer(), 0.0);
        tl.reset();
        assert_eq!(tl.pageable_transfer(), 0.0);
    }

    #[test]
    fn feedback_accessors_track_busy_and_backlog() {
        let mut tl = StreamTimeline::new(true);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_copy(Phase::GpuToCpu, 0.5, CopyDir::D2H, 0.0);
        tl.async_collective(Phase::AllGather, 3.0);
        assert!((tl.compute_work() - 1.0).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert!((tl.copy_busy(CopyDir::D2H) - 0.5).abs() < 1e-12);
        // Copies start at the compute frontier (1.0): the H2D engine
        // runs ahead to 3.0, so its backlog past compute is 2.0.
        assert!((tl.copy_backlog(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert!((tl.copy_backlog(CopyDir::D2H) - 0.5).abs() < 1e-12);
        assert!((tl.collective_work() - 3.0).abs() < 1e-12);
        assert!((tl.collective_backlog() - 3.0).abs() < 1e-12);
        // A wait advances the compute frontier but not compute work,
        // and drains the backlog.
        tl.wait_until(3.0);
        assert!((tl.compute_work() - 1.0).abs() < 1e-12);
        assert_eq!(tl.copy_backlog(CopyDir::H2D), 0.0);
        // Reclaim subtracts from the per-engine busy accumulator.
        tl.reclaim(Phase::GpuToCpu, 0.5, CopyDir::D2H);
        assert_eq!(tl.copy_busy(CopyDir::D2H), 0.0);
        tl.reset();
        assert_eq!(tl.compute_work(), 0.0);
        assert_eq!(tl.copy_busy(CopyDir::H2D), 0.0);
    }

    #[test]
    fn feedback_accessors_zero_backlog_in_serial_mode() {
        let mut tl = StreamTimeline::new(false);
        tl.charge(Phase::FwdBwd, 1.0);
        tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
        tl.async_collective(Phase::AllGather, 3.0);
        // Work is still attributed per engine, but nothing queues: the
        // serial timeline has no stream to run ahead of compute.
        assert!((tl.copy_busy(CopyDir::H2D) - 2.0).abs() < 1e-12);
        assert_eq!(tl.copy_backlog(CopyDir::H2D), 0.0);
        assert_eq!(tl.collective_backlog(), 0.0);
    }

    #[test]
    fn snapshot_is_bit_exact_and_deterministic() {
        let mut a = StreamTimeline::new(true);
        let mut b = StreamTimeline::new(true);
        for tl in [&mut a, &mut b] {
            tl.charge(Phase::FwdBwd, 0.1 + 0.2); // not a round float
            tl.async_copy(Phase::CpuToGpu, 1.0 / 3.0, CopyDir::H2D, 0.0);
            tl.async_collective(Phase::AllGather, 0.7);
        }
        assert_eq!(a.snapshot(), b.snapshot());
        b.charge(Phase::FwdBwd, f64::EPSILON);
        assert_ne!(a.snapshot(), b.snapshot(), "1-ulp drift must show");
    }
}
