//! `pstar-lint` v2: the determinism & layering static-analysis pass
//! (ISSUE 8/9/10).
//!
//! The repo's determinism contract — bit-exact golden traces, chaos
//! replay, checkpoint/restore, volume invariance — rests on coding
//! rules that `rustc` cannot check.  This module enforces them over
//! `src/`, run four ways: `cargo run --bin pstar-lint` (CI `lint`
//! job, `--json` for the findings artifact), the `tests/lint_clean.rs`
//! gate under plain `cargo test`, the embedded fixture self-tests
//! below, and the line-faithful Python port `scripts/pstar_lint.py`
//! for toolchain-less containers (CI diffs the two `--json` outputs).
//!
//! ## Rules
//!
//! * **`unordered-collection`** — no `HashMap`/`HashSet` in the
//!   deterministic-state modules (`sim/`, `engine/`, `chunk/`,
//!   `evict/`, `dp/`, `mem/`).  Unordered-map iteration varies per
//!   process (`RandomState`), so any policy decision derived from it
//!   diverges across ranks and replays.  Use `BTreeMap`/`BTreeSet`.
//! * **`nan-unwrap`** — no `partial_cmp` anywhere in `src/`: the
//!   `.unwrap()` idiom panics on NaN and `sort_by` falls back to
//!   unspecified order.  Use [`crate::util::total_cmp`].
//! * **`wallclock`** — `Instant::now`/`SystemTime` only in `train/`
//!   and the pjrt half of `engine/backend.rs`: wall-clock reads inside
//!   the planner would leak real time into simulated schedules.
//! * **`timeline-layering`** — the `StreamTimeline` identifier only in
//!   `sim/` and `engine/backend.rs`: all timeline mutation goes
//!   through the `ExecutionBackend` boundary.
//! * **`cfg-test-placement`** — `#[cfg(test)]` must introduce the
//!   single trailing test module; code after it escapes every other
//!   rule, so a mid-file test item or second block is a finding.
//! * **`unseeded-entropy`** — no `thread_rng`/`rand::random`/
//!   `RandomState`/`from_entropy` anywhere: ambient entropy breaks
//!   seeded replay; fork a `SplitMix64` stream instead.
//! * **`thread-spawn`** — no `std::thread` in the policy modules
//!   (the `ordered_state_scope` set): planner state must stay
//!   single-threaded per rank.
//! * **`dev-mut-layering`** — `space.dev_mut` only in
//!   `chunk/manager.rs` (and `mem/space.rs` itself): direct capacity
//!   mutation bypasses the manager's accounting; use a `ChunkManager`
//!   API such as `set_device_capacity`.
//! * **`unused-waiver`** — a `lint:allow(...)` annotation that
//!   suppresses no finding is itself a finding: stale waivers hide
//!   future violations.
//! * **`lease-flow`** — the flow-sensitive pass in [`flow`]: every
//!   `pool.try_acquire` result must reach a release sink on every
//!   path.
//! * **`state-spec`** — the state-machine diff in [`spec`]:
//!   `TensorState` transitions must agree with the declared table in
//!   `docs/INVARIANTS.md`.
//!
//! ## Mechanics
//!
//! There is no `syn` in the offline crate cache, so [`lex`] is a
//! hand-rolled token lexer: comments are dropped, string/char literal
//! contents can never be mistaken for code, lifetimes are
//! distinguished from char literals.  (The retired masked-line
//! scanner survives verbatim in `legacy` (test-only) as the
//! differential oracle
//! for the port — see `differential_fixture_parity`.)
//!
//! * everything from the first first-on-line `#[cfg(test)]` to
//!   end-of-file is out of scope (by repo convention the unit-test
//!   module trails the file; `cfg-test-placement` enforces this);
//! * in `engine/backend.rs`, lines from the first
//!   `#[cfg(feature = "pjrt")]` on are the measuring backend and are
//!   exempt from `unordered-collection` and `wallclock`;
//! * a finding on line *L* is suppressed by
//!   `// lint:allow(<rule>): <reason>` on *L* or on a comment line
//!   directly above — per-line and per-rule so waivers stay
//!   auditable, and unused waivers are themselves findings;
//! * the `lint/` subtree itself is skipped (its fixtures are positive
//!   examples by construction).
//!
//! See `rust/docs/INVARIANTS.md` for the contract this enforces.
//! Keep every function in sync with its named twin in
//! `scripts/pstar_lint.py`.

pub mod flow;
pub mod lex;
pub mod spec;

#[cfg(test)]
mod legacy;

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use crate::util::json::Json;
use self::lex::{cfg_pjrt_at, cfg_test_at, lex, path_sep, skip_attr, Kind, Tok};

/// One enforced rule.  `ALL` (== variant order == derived `Ord`) is
/// the report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnorderedCollection,
    NanUnwrap,
    Wallclock,
    TimelineLayering,
    CfgTestPlacement,
    UnseededEntropy,
    ThreadSpawn,
    DevMutLayering,
    UnusedWaiver,
    LeaseFlow,
    StateSpec,
}

impl Rule {
    pub const ALL: [Rule; 11] = [
        Rule::UnorderedCollection,
        Rule::NanUnwrap,
        Rule::Wallclock,
        Rule::TimelineLayering,
        Rule::CfgTestPlacement,
        Rule::UnseededEntropy,
        Rule::ThreadSpawn,
        Rule::DevMutLayering,
        Rule::UnusedWaiver,
        Rule::LeaseFlow,
        Rule::StateSpec,
    ];

    /// The name used in diagnostics and `lint:allow(...)` annotations.
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnorderedCollection => "unordered-collection",
            Rule::NanUnwrap => "nan-unwrap",
            Rule::Wallclock => "wallclock",
            Rule::TimelineLayering => "timeline-layering",
            Rule::CfgTestPlacement => "cfg-test-placement",
            Rule::UnseededEntropy => "unseeded-entropy",
            Rule::ThreadSpawn => "thread-spawn",
            Rule::DevMutLayering => "dev-mut-layering",
            Rule::UnusedWaiver => "unused-waiver",
            Rule::LeaseFlow => "lease-flow",
            Rule::StateSpec => "state-spec",
        }
    }

    /// Why the rule exists, one line (shown with every finding).
    pub fn message(self) -> &'static str {
        match self {
            Rule::UnorderedCollection => {
                "HashMap/HashSet iteration order varies per process; \
                 use BTreeMap/BTreeSet in deterministic-state modules"
            }
            Rule::NanUnwrap => {
                "partial_cmp panics (unwrap) or mis-sorts on NaN; \
                 use util::total_cmp"
            }
            Rule::Wallclock => {
                "wall-clock reads outside train/ and the pjrt backend \
                 leak real time into simulated schedules"
            }
            Rule::TimelineLayering => {
                "StreamTimeline is backend substrate; go through \
                 ExecutionBackend instead"
            }
            Rule::CfgTestPlacement => {
                "#[cfg(test)] must introduce the single trailing test \
                 module; code after it escapes every other rule"
            }
            Rule::UnseededEntropy => {
                "ambient entropy (thread_rng/rand::random/RandomState) \
                 breaks seeded replay; fork a SplitMix64 stream instead"
            }
            Rule::ThreadSpawn => {
                "std::thread in policy modules makes scheduling racy; \
                 planner state must stay single-threaded per rank"
            }
            Rule::DevMutLayering => {
                "space.dev_mut bypasses the chunk manager's accounting; \
                 use a ChunkManager API (e.g. set_device_capacity)"
            }
            Rule::UnusedWaiver => {
                "lint:allow annotation suppresses no finding; stale \
                 waivers hide future violations — delete it"
            }
            Rule::LeaseFlow => {
                "a pool.try_acquire lease must reach a release sink \
                 (release/set_release/lease field/return) on every path"
            }
            Rule::StateSpec => {
                "tensor state transition disagrees with the declared \
                 table in docs/INVARIANTS.md (transition-spec)"
            }
        }
    }
}

/// One diagnostic: `file:line: [rule] message: excerpt`.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the linted root, '/'-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// The offending source line, trimmed and truncated.
    pub excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}: `{}`",
            self.file,
            self.line,
            self.rule.name(),
            self.rule.message(),
            self.excerpt,
        )
    }
}

/// Result of linting a whole tree.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// The `--json` shape CI archives and diffs against the Python
    /// port (`scripts/pstar_lint.py --json`); keys alphabetical,
    /// `util::json` pretty format.
    pub fn to_json(&self) -> String {
        let items: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("excerpt", Json::str(f.excerpt.clone())),
                    ("file", Json::str(f.file.clone())),
                    ("line", Json::num(f.line as f64)),
                    ("message", Json::str(f.rule.message())),
                    ("rule", Json::str(f.rule.name())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("files", Json::num(self.files as f64)),
            ("findings", Json::Arr(items)),
        ])
        .to_string_pretty()
    }
}

/// Trim a source line down to the diagnostic excerpt.
pub(crate) fn excerpt_of(raw: &str) -> String {
    let t = raw.trim();
    let mut e: String = t.chars().take(80).collect();
    if t.chars().count() > 80 {
        e.push('…');
    }
    e
}

// ------------------------------------------------------------- rule logic

/// Modules whose state feeds deterministic decisions (the
/// `unordered-collection` and `thread-spawn` scope).
pub(crate) fn ordered_state_scope(rel: &str) -> bool {
    ["sim/", "engine/", "chunk/", "evict/", "dp/", "mem/"]
        .iter()
        .any(|p| rel.starts_with(p))
}

/// Parse `lint:allow(<rule>)` out of a raw line, if present.
fn allow_annotation(raw: &str) -> Option<Rule> {
    let i = raw.find("lint:allow(")?;
    let rest = &raw[i + "lint:allow(".len()..];
    let j = rest.find(')')?;
    let name = rest[..j].trim();
    Rule::ALL.iter().copied().find(|r| r.name() == name)
}

/// Is `rule` waived on 0-based line `idx`?  An annotation suppresses
/// the line it sits on and, when it is a whole-line comment, the line
/// directly below it.  The annotation line that fired is recorded in
/// `fired` so stale waivers can be reported (`unused-waiver`).
fn waived(
    raw_lines: &[&str],
    idx: usize,
    rule: Rule,
    fired: &mut BTreeSet<usize>,
) -> bool {
    if allow_annotation(raw_lines[idx]) == Some(rule) {
        fired.insert(idx);
        return true;
    }
    if idx > 0 {
        let above = raw_lines[idx - 1].trim_start();
        if above.starts_with("//") && allow_annotation(above) == Some(rule) {
            fired.insert(idx - 1);
            return true;
        }
    }
    false
}

/// The first-on-line `#[cfg(test)]` cutoff line (1-based) plus
/// `cfg-test-placement` candidates as 0-based `(line, rule)` pairs.
/// The first occurrence must introduce a `(pub) mod` (stacked
/// attributes allowed); any later occurrence is a finding.
pub(crate) fn cfg_cutoff(toks: &[Tok]) -> (Option<usize>, Vec<(usize, Rule)>) {
    let mut cands = Vec::new();
    let mut first = None;
    let mut i = 0;
    while i < toks.len() {
        if cfg_test_at(toks, i) {
            if first.is_none() {
                first = Some(toks[i].line);
                // Skip stacked attributes; the next item must be a
                // (pub) module.
                let mut j = i + 7;
                while lex::tok_is(toks, j, Kind::Punct, "#")
                    && lex::tok_is(toks, j + 1, Kind::Punct, "[")
                {
                    j = skip_attr(toks, j);
                }
                let introduces = lex::tok_is(toks, j, Kind::Ident, "mod")
                    || (lex::tok_is(toks, j, Kind::Ident, "pub")
                        && lex::tok_is(toks, j + 1, Kind::Ident, "mod"));
                if !introduces {
                    cands.push((toks[i].line - 1, Rule::CfgTestPlacement));
                }
            } else {
                cands.push((toks[i].line - 1, Rule::CfgTestPlacement));
            }
            i += 7;
            continue;
        }
        i += 1;
    }
    (first, cands)
}

/// Per-line `(line0, rule)` candidates from the token stream.
fn token_rules(
    rel: &str,
    toks: &[Tok],
    cutoff_line: Option<usize>,
    pjrt_line: Option<usize>,
) -> BTreeSet<(usize, Rule)> {
    let mut cands = BTreeSet::new();
    let in_scope = ordered_state_scope(rel);
    let is_backend = rel == "engine/backend.rs";
    let exec_exempt =
        |line: usize| pjrt_line.is_some_and(|p| line >= p);

    for (i, t) in toks.iter().enumerate() {
        let line = t.line;
        if cutoff_line.is_some_and(|c| line >= c) {
            continue;
        }
        if t.kind != Kind::Ident {
            continue;
        }
        let x = t.text.as_str();
        if in_scope
            && (x == "HashMap" || x == "HashSet")
            && !exec_exempt(line)
        {
            cands.insert((line - 1, Rule::UnorderedCollection));
        }
        if x == "partial_cmp" {
            cands.insert((line - 1, Rule::NanUnwrap));
        }
        if !rel.starts_with("train/") && !exec_exempt(line) {
            if x == "SystemTime" {
                cands.insert((line - 1, Rule::Wallclock));
            }
            if x == "Instant"
                && path_sep(toks, i + 1)
                && lex::tok_is(toks, i + 3, Kind::Ident, "now")
            {
                cands.insert((line - 1, Rule::Wallclock));
            }
        }
        if x == "StreamTimeline" && !rel.starts_with("sim/") && !is_backend
        {
            cands.insert((line - 1, Rule::TimelineLayering));
        }
        if x == "thread_rng" || x == "RandomState" || x == "from_entropy" {
            cands.insert((line - 1, Rule::UnseededEntropy));
        }
        if x == "rand"
            && path_sep(toks, i + 1)
            && lex::tok_is(toks, i + 3, Kind::Ident, "random")
        {
            cands.insert((line - 1, Rule::UnseededEntropy));
        }
        if in_scope {
            if x == "std"
                && path_sep(toks, i + 1)
                && lex::tok_is(toks, i + 3, Kind::Ident, "thread")
            {
                cands.insert((line - 1, Rule::ThreadSpawn));
            }
            if x == "thread"
                && path_sep(toks, i + 1)
                && lex::tok_is(toks, i + 3, Kind::Ident, "spawn")
            {
                cands.insert((line - 1, Rule::ThreadSpawn));
            }
        }
        if x == "dev_mut" && rel != "chunk/manager.rs" && rel != "mem/space.rs"
        {
            cands.insert((line - 1, Rule::DevMutLayering));
        }
    }
    cands
}

/// Lint one file's source: token rules + cfg placement + waivers +
/// unused-waiver detection.  `rel` is the path relative to `src/`,
/// '/'-separated (it selects which rules apply where).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    // The linter's own subtree holds positive fixtures by design.
    if rel.starts_with("lint/") || rel == "lint.rs" {
        return Vec::new();
    }
    let toks = lex(src);
    let mut raw_lines: Vec<&str> = src.split('\n').collect();
    if raw_lines.last() == Some(&"") {
        raw_lines.pop();
    }

    let (cutoff_line, cfg_cands) = cfg_cutoff(&toks);
    let mut pjrt_line = None;
    if rel == "engine/backend.rs" {
        for i in 0..toks.len() {
            if cfg_pjrt_at(&toks, i) {
                pjrt_line = Some(toks[i].line);
                break;
            }
        }
    }
    let mut cands: BTreeSet<(usize, Rule)> =
        cfg_cands.into_iter().collect();
    cands.extend(token_rules(&rel, &toks, cutoff_line, pjrt_line));

    let mut fired = BTreeSet::new();
    let mut findings = Vec::new();
    for &(idx, rule) in &cands {
        if idx >= raw_lines.len() {
            continue;
        }
        if waived(&raw_lines, idx, rule, &mut fired) {
            continue;
        }
        findings.push(Finding {
            file: rel.clone(),
            line: idx + 1,
            rule,
            excerpt: excerpt_of(raw_lines[idx]),
        });
    }

    // Unused-waiver: an annotation (before the test tail) that
    // suppressed nothing is itself a finding.
    let limit = match cutoff_line {
        Some(c) => c - 1,
        None => raw_lines.len(),
    };
    for (idx, raw) in raw_lines.iter().enumerate().take(limit) {
        if allow_annotation(raw).is_some() && !fired.contains(&idx) {
            findings.push(Finding {
                file: rel.clone(),
                line: idx + 1,
                rule: Rule::UnusedWaiver,
                excerpt: excerpt_of(raw),
            });
        }
    }
    sort_findings(&mut findings);
    findings
}

fn sort_findings(findings: &mut [Finding]) {
    findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
}

/// The whole pass over an in-memory tree: per-file rules, the
/// lease-flow pass, then the cross-file spec check.  `files` must be
/// sorted by path; `doc` is `docs/INVARIANTS.md` if present.
pub fn lint_files(
    files: &[(String, String)],
    doc: Option<&str>,
) -> Vec<Finding> {
    let mut findings = Vec::new();
    for (rel, src) in files {
        findings.extend(lint_source(rel, src));
        findings.extend(flow::flow_pass(rel, src));
    }
    findings.extend(spec::spec_pass(files, doc));
    sort_findings(&mut findings);
    findings
}

// --------------------------------------------------------------- the walk

fn walk(
    root: &Path,
    dir: &Path,
    out: &mut Vec<(String, String)>,
) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    // Sorted walk: the report is byte-identical across filesystems.
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        let name = e.file_name();
        if path.is_dir() {
            if name == "lint" {
                continue;
            }
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push((rel, fs::read_to_string(&path)?));
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (normally `rust/src`), skipping
/// the `lint/` subtree.  The transition-spec doc is read from
/// `root/../docs/INVARIANTS.md`.  Findings come back sorted.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    files.sort_by(|a, b| a.0.cmp(&b.0));
    let doc_path = match root.parent() {
        Some(p) => p.join("docs").join("INVARIANTS.md"),
        None => Path::new("docs").join("INVARIANTS.md"),
    };
    let doc = fs::read_to_string(&doc_path).ok();
    Ok(LintReport {
        files: files.len(),
        findings: lint_files(&files, doc.as_deref()),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(found: &[Finding]) -> Vec<Rule> {
        found.iter().map(|f| f.rule).collect()
    }

    fn sites(found: &[Finding]) -> Vec<(usize, Rule)> {
        found.iter().map(|f| (f.line, f.rule)).collect()
    }

    // ------------------------------------------- unordered-collection

    #[test]
    fn unordered_collection_flagged_in_state_modules() {
        let src = "use std::collections::HashMap;\n";
        for rel in
            ["sim/a.rs", "engine/b.rs", "chunk/c.rs", "evict/mod.rs",
             "dp/group.rs", "mem/device.rs"]
        {
            let f = lint_source(rel, src);
            assert_eq!(
                rules(&f),
                vec![Rule::UnorderedCollection],
                "{rel}"
            );
            assert_eq!(f[0].line, 1);
        }
        // HashSet too.
        let f = lint_source("evict/mod.rs", "let s = HashSet::new();\n");
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
    }

    #[test]
    fn unordered_collection_ignored_outside_scope() {
        let src = "use std::collections::HashMap;\n";
        for rel in ["util/mod.rs", "runtime/mod.rs", "main.rs",
                    "train/trainer.rs"]
        {
            assert!(lint_source(rel, src).is_empty(), "{rel}");
        }
    }

    #[test]
    fn backend_pjrt_half_is_exempt_from_state_and_clock_rules() {
        let src = "\
use std::collections::BTreeMap;
#[cfg(feature = \"pjrt\")]
use std::collections::HashMap;
fn measure() { let t0 = std::time::Instant::now(); }
";
        assert!(lint_source("engine/backend.rs", src).is_empty());
        // ... but only in backend.rs; other engine files get no pass.
        let f = lint_source("engine/session.rs", src);
        assert_eq!(
            rules(&f),
            vec![Rule::UnorderedCollection, Rule::Wallclock]
        );
        // And before the marker backend.rs is scoped like the rest.
        let early = "use std::collections::HashMap;\n\
                     #[cfg(feature = \"pjrt\")]\n";
        let f = lint_source("engine/backend.rs", early);
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
    }

    // ----------------------------------------------------- nan-unwrap

    #[test]
    fn nan_unwrap_flagged_everywhere() {
        let src =
            "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        for rel in ["util/mod.rs", "chunk/search.rs", "main.rs"] {
            assert_eq!(
                rules(&lint_source(rel, src)),
                vec![Rule::NanUnwrap],
                "{rel}"
            );
        }
    }

    #[test]
    fn nan_unwrap_ignores_comments_and_strings() {
        let src = "\
// the old partial_cmp().unwrap() panicked here
let msg = \"partial_cmp is banned\";
/* partial_cmp in a block comment */
";
        assert!(lint_source("evict/mod.rs", src).is_empty());
    }

    // ------------------------------------------------------ wallclock

    #[test]
    fn wallclock_flagged_outside_train() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert_eq!(
            rules(&lint_source("engine/session.rs", src)),
            vec![Rule::Wallclock]
        );
        assert_eq!(
            rules(&lint_source("util/mod.rs",
                               "let t = SystemTime::now();\n")),
            vec![Rule::Wallclock]
        );
        assert!(lint_source("train/trainer.rs", src).is_empty());
    }

    // ----------------------------------------------- timeline-layering

    #[test]
    fn timeline_layering_scopes_to_sim_and_backend() {
        let src = "use crate::sim::StreamTimeline;\n";
        assert_eq!(
            rules(&lint_source("engine/report.rs", src)),
            vec![Rule::TimelineLayering]
        );
        assert_eq!(
            rules(&lint_source("chunk/manager.rs", src)),
            vec![Rule::TimelineLayering]
        );
        assert!(lint_source("sim/stream.rs", src).is_empty());
        assert!(lint_source("engine/backend.rs", src).is_empty());
    }

    // ------------------------------------------------ allow annotations

    #[test]
    fn allow_suppresses_same_line_and_line_above() {
        let same = "use std::collections::HashMap; \
                    // lint:allow(unordered-collection): fixture\n";
        assert!(lint_source("evict/mod.rs", same).is_empty());

        let above = "\
// lint:allow(wallclock): measuring the linter itself
let t0 = std::time::Instant::now();
";
        assert!(lint_source("engine/session.rs", above).is_empty());
    }

    #[test]
    fn allow_is_per_rule_and_per_line() {
        // Wrong rule name: no waiver — and the stale waiver itself is
        // now a second finding (ISSUE 10).
        let wrong = "use std::collections::HashMap; \
                     // lint:allow(wallclock): wrong rule\n";
        assert_eq!(
            rules(&lint_source("evict/mod.rs", wrong)),
            vec![Rule::UnorderedCollection, Rule::UnusedWaiver]
        );
        // A waiver two lines up does not reach.
        let far = "\
// lint:allow(unordered-collection): too far away
let x = 1;
use std::collections::HashMap;
";
        assert_eq!(
            rules(&lint_source("evict/mod.rs", far)),
            vec![Rule::UnusedWaiver, Rule::UnorderedCollection]
        );
    }

    // ------------------------------------------- cfg-test-placement

    #[test]
    fn cfg_test_must_introduce_the_trailing_test_module() {
        let good = "let a = 1;\n#[cfg(test)]\nmod tests {}\n";
        assert!(lint_source("evict/mod.rs", good).is_empty());
        // Stacked attributes between the cfg and the module are fine,
        // and a pub test-support module counts too.
        let stacked = "\
let a = 1;
#[cfg(test)]
#[allow(dead_code)]
pub mod testutil {}
";
        assert!(lint_source("evict/mod.rs", stacked).is_empty());
        // A mid-file #[cfg(test)] item hides everything below it from
        // the other rules — exactly what the rule exists to catch.
        let item = "\
#[cfg(test)]
fn helper() {}
use std::collections::HashMap;
";
        let f = lint_source("evict/mod.rs", item);
        assert_eq!(rules(&f), vec![Rule::CfgTestPlacement]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn second_cfg_test_block_is_flagged() {
        let src = "\
#[cfg(test)]
mod tests {}
fn hidden_from_every_other_rule() {}
#[cfg(test)]
mod more_tests {}
";
        let f = lint_source("chunk/c.rs", src);
        assert_eq!(rules(&f), vec![Rule::CfgTestPlacement]);
        assert_eq!(f[0].line, 4);
        // In a string it is prose, not a block.
        let masked = "\
#[cfg(test)]
mod tests {
    const S: &str = \"
#[cfg(test)]
\";
}
";
        assert!(lint_source("chunk/c.rs", masked).is_empty());
    }

    #[test]
    fn trailing_test_module_is_skipped() {
        let src = "\
let a = 1;
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use crate::sim::StreamTimeline;
}
";
        assert!(lint_source("evict/mod.rs", src).is_empty());
    }

    // -------------------------------------------------- lexer torture

    #[test]
    fn lexer_handles_multiline_and_raw_strings() {
        let src = "\
let s = \"multi
line HashMap string\";
let r = r#\"raw HashMap \"quoted\" string\"#;
let c = '\"';
let still_code = HashMap::new();
";
        let f = lint_source("evict/mod.rs", src);
        assert_eq!(sites(&f), vec![(5, Rule::UnorderedCollection)]);
    }

    #[test]
    fn lexer_handles_nested_block_comments_and_lifetimes() {
        let src = "\
/* outer /* nested HashMap */ still comment */
fn f<'a>(x: &'a str) -> &'a str { x }
let esc = '\\'';
let m = HashMap::new();
";
        let f = lint_source("chunk/c.rs", src);
        assert_eq!(sites(&f), vec![(4, Rule::UnorderedCollection)]);
    }

    #[test]
    fn lexer_torture_raw_hash_strings() {
        let src = "\
let a = r##\"one \"# inside HashMap\"##;
let b = HashMap::new();
";
        let f = lint_source("evict/mod.rs", src);
        assert_eq!(sites(&f), vec![(2, Rule::UnorderedCollection)]);
    }

    #[test]
    fn lexer_torture_macro_body_string() {
        // A multi-line string inside a macro invocation must not hide
        // later real code.
        let src = "\
log!(
    \"header
partial_cmp in prose
tail\",
);
let x = a.partial_cmp(b);
";
        let f = lint_source("evict/mod.rs", src);
        assert_eq!(sites(&f), vec![(6, Rule::NanUnwrap)]);
    }

    #[test]
    fn lexer_torture_lifetimes_vs_chars() {
        let src = "\
fn g<'life>(v: &'life [char]) -> char { v[0] }
let c: char = 'h';
let d = '\\u{1F600}';
let e = HashMap::<char, u8>::new();
";
        let f = lint_source("mem/x.rs", src);
        assert_eq!(sites(&f), vec![(4, Rule::UnorderedCollection)]);
    }

    // ------------------------------------------------ three new rules

    #[test]
    fn unseeded_entropy_flagged_everywhere() {
        for (src, rel) in [
            ("let r = rand::thread_rng();\n", "util/rng.rs"),
            ("let x: f64 = rand::random();\n", "main.rs"),
            ("let h = RandomState::new();\n", "engine/policy.rs"),
            ("let g = SmallRng::from_entropy();\n", "sim/cost.rs"),
        ] {
            let f = lint_source(rel, src);
            assert_eq!(rules(&f), vec![Rule::UnseededEntropy], "{src}");
        }
        let clean = "let s = SplitMix64::new(seed);\n";
        assert!(lint_source("util/rng.rs", clean).is_empty());
    }

    #[test]
    fn thread_spawn_scopes_to_policy_modules() {
        let src = "std::thread::spawn(move || work());\n";
        let f = lint_source("engine/session.rs", src);
        assert_eq!(rules(&f), vec![Rule::ThreadSpawn]);
        // Outside the policy modules the rule does not apply.
        assert!(lint_source("train/trainer.rs", src).is_empty());
        let use_then_spawn = "\
use std::thread;
thread::spawn(|| {});
";
        let f = lint_source("dp/group.rs", use_then_spawn);
        assert_eq!(
            sites(&f),
            vec![(1, Rule::ThreadSpawn), (2, Rule::ThreadSpawn)]
        );
    }

    #[test]
    fn dev_mut_layering_sanctions_manager_and_space() {
        let src =
            "self.mgr.space.dev_mut(Device::Gpu(0)).set_capacity(c);\n";
        let f = lint_source("engine/session.rs", src);
        assert_eq!(rules(&f), vec![Rule::DevMutLayering]);
        // The manager and the space definition itself are the two
        // sanctioned homes.
        assert!(lint_source("chunk/manager.rs", src).is_empty());
        assert!(lint_source(
            "mem/space.rs",
            "pub fn dev_mut(&mut self, d: Device) -> &mut DeviceMem {\n",
        )
        .is_empty());
    }

    // --------------------------------------------------- unused waiver

    #[test]
    fn unused_waiver_fixture_pair() {
        let used = "\
// lint:allow(unordered-collection): fixture pair, used
use std::collections::HashMap;
";
        assert!(lint_source("evict/mod.rs", used).is_empty());
        let unused = "\
// lint:allow(unordered-collection): fixture pair, stale
use std::collections::BTreeMap;
";
        let f = lint_source("evict/mod.rs", unused);
        assert_eq!(sites(&f), vec![(1, Rule::UnusedWaiver)]);
    }

    #[test]
    fn unused_waiver_ignores_test_tail() {
        let src = "\
let a = 1;
#[cfg(test)]
mod tests {
    // lint:allow(wallclock): prose in a test module
}
";
        assert!(lint_source("evict/mod.rs", src).is_empty());
    }

    // ------------------------------------------------------ lease flow

    #[test]
    fn flow_clean_shapes() {
        // Shape 1: let + if-let release.
        let src = "\
impl S {
    fn a(&mut self) {
        let lease = self.pool.try_acquire(now, dir);
        if let Some(l) = lease {
            self.pool.set_release(l, done);
        }
    }
}
";
        assert!(flow::flow_pass("engine/session.rs", src).is_empty());
        // Shape 3: match scrutinee, Some arm returns.
        let src = "\
fn b(&mut self) -> Option<PinnedLease> {
    match self.pool.try_acquire(now, dir) {
        Some(lease) => Some(lease),
        None => None,
    }
}
";
        assert!(flow::flow_pass("engine/session.rs", src).is_empty());
        // Struct-field sink (shorthand).
        let src = "\
fn c(&mut self) {
    let lease = self.pool.try_acquire(now, dir);
    self.q.push(PendingCopy { done, secs, lease });
}
";
        assert!(flow::flow_pass("engine/session.rs", src).is_empty());
        // Out-of-scope file: the pass does not run.
        let leaky = "\
fn d(&mut self) {
    let lease = self.pool.try_acquire(now, dir);
}
";
        assert!(flow::flow_pass("mem/pinned.rs", leaky).is_empty());
    }

    #[test]
    fn flow_leak_shapes() {
        // No sink at all.
        let src = "\
fn a(&mut self) {
    let lease = self.pool.try_acquire(now, dir);
    let _ = lease.is_some();
}
";
        let f = flow::flow_pass("engine/session.rs", src);
        assert_eq!(sites(&f), vec![(2, Rule::LeaseFlow)]);
        // Sink removed from one match arm.
        let src = "\
fn b(&mut self) {
    match self.pool.try_acquire(now, dir) {
        Some(l) => { self.note(); }
        None => {}
    }
}
";
        let f = flow::flow_pass("engine/session.rs", src);
        assert_eq!(rules(&f), vec![Rule::LeaseFlow]);
        // Sink on only one side of an if/else.
        let src = "\
fn c(&mut self, cond: bool) {
    let lease = self.pool.try_acquire(now, dir);
    if cond {
        if let Some(l) = lease { self.pool.release(l); }
    } else {
        self.note();
    }
}
";
        let f = flow::flow_pass("engine/session.rs", src);
        assert_eq!(rules(&f), vec![Rule::LeaseFlow]);
        // Result dropped outright.
        let src = "\
fn d(&mut self) {
    self.pool.try_acquire(now, dir);
}
";
        let f = flow::flow_pass("engine/session.rs", src);
        assert_eq!(rules(&f), vec![Rule::LeaseFlow]);
    }

    #[test]
    fn flow_passthrough_arm_needs_downstream_sink() {
        // `Some(l) => Some(l)` hands the obligation to the let
        // binding; with no downstream sink the site leaks.
        let src = "\
fn a(&mut self) {
    let lease = match self.pool.try_acquire(now, dir) {
        Some(l) => Some(l),
        None => None,
    };
    self.note();
}
";
        let f = flow::flow_pass("engine/session.rs", src);
        assert_eq!(sites(&f), vec![(2, Rule::LeaseFlow)]);
        // Same shape with the sink present is clean.
        let ok = src.replace(
            "    self.note();\n",
            "    if let Some(l) = lease {\n\
             \x20       self.pool.release(l);\n\
             \x20   }\n",
        );
        assert!(flow::flow_pass("engine/session.rs", &ok).is_empty());
    }

    #[test]
    fn flow_divergent_arm_is_ok() {
        let src = "\
fn a(&mut self) {
    loop {
        let lease = match self.pool.try_acquire(now, dir) {
            Some(l) => Some(l),
            None => { self.waits += 1; break; }
        };
        if let Some(l) = lease {
            self.pool.set_release(l, done);
        }
    }
}
";
        assert!(flow::flow_pass("engine/session.rs", src).is_empty());
    }

    #[test]
    fn flow_real_tree_shapes() {
        // Condensed replicas of the three live session.rs sites.
        let src = "\
impl<B: ExecutionBackend> TrainingSession<B> {
    fn issue_group_gathers(&mut self) -> Result<()> {
        loop {
            let lease = if self.pool.enabled() {
                match self.pool.try_acquire(self.backend.now(),
                                            CopyDir::H2D) {
                    Some(l) => Some(l),
                    None => {
                        self.mgr.stats.pinned_waits += 1;
                        break;
                    }
                }
            } else {
                None
            };
            let done = self.backend.issue(op.secs);
            if let Some(l) = lease {
                self.pool.set_release(l, done);
            }
            self.coll.issue_gather(g, InFlightGather {
                done,
                secs: op.secs,
                lease,
            });
        }
        Ok(())
    }
    fn route_async_copy(&mut self, dir: CopyDir, bytes: u64)
        -> (f64, CopyRoute, Option<PinnedLease>) {
        if !self.pool.enabled() {
            return (t, CopyRoute::Pinned, None);
        }
        match self.pool.try_acquire(self.backend.now(), dir) {
            Some(lease) => (
                self.backend.copy_secs(bytes, CopyRoute::Pinned),
                CopyRoute::Pinned,
                Some(lease),
            ),
            None => (t2, CopyRoute::Pageable, None),
        }
    }
    fn stage_real(&mut self) -> Result<StageOutcome> {
        if issued {
            let lease = if self.pool.enabled() {
                self.pool.try_acquire(self.backend.now(), CopyDir::H2D)
            } else {
                None
            };
            let old = self.inflight_done.insert(
                chunk,
                PendingCopy {
                    done: f64::INFINITY,
                    secs: 0.0,
                    lease,
                },
            );
        }
        Ok(StageOutcome::Staged)
    }
}
";
        assert!(flow::flow_pass("engine/session.rs", src).is_empty());
    }

    // ------------------------------------------------------ spec check

    fn spec_ok() -> String {
        format!(
            "x\n{}\n\
             | From | To | Driver |\n\
             | --- | --- | --- |\n\
             | Free | Hold | init |\n\
             | Free | Compute | zero-init access |\n\
             | Hold | Compute | access |\n\
             | Compute | Hold | release |\n\
             | Hold | Free | chunk reuse |\n\
             {}\n",
            spec::SPEC_BEGIN,
            spec::SPEC_END,
        )
    }

    const TENSOR_OK: &str = "\
pub fn transition_allowed(from: TensorState, to: TensorState) -> bool {
    use TensorState::*;
    matches!(
        (from, to),
        (Free, Hold) | (Free, Compute)
            | (Hold, Compute)
            | (Compute, Hold)
            | (Hold, Free)
    )
}
";

    fn tree(entries: &[(&str, &str)]) -> Vec<(String, String)> {
        entries
            .iter()
            .map(|(r, s)| (r.to_string(), s.to_string()))
            .collect()
    }

    #[test]
    fn spec_clean() {
        let files = tree(&[("tensor/mod.rs", TENSOR_OK)]);
        assert!(spec::spec_pass(&files, Some(&spec_ok())).is_empty());
    }

    #[test]
    fn spec_undeclared_transition_fires_at_the_guard() {
        let doc = spec_ok().replace("| Hold | Free | chunk reuse |\n", "");
        let files = tree(&[("tensor/mod.rs", TENSOR_OK)]);
        let f = spec::spec_pass(&files, Some(&doc));
        assert_eq!(rules(&f), vec![Rule::StateSpec]);
        assert_eq!(f[0].file, "tensor/mod.rs");
    }

    #[test]
    fn spec_declared_but_absent_fires_at_the_doc() {
        let tensor = TENSOR_OK.replace("            | (Hold, Free)\n", "");
        let files = tree(&[("tensor/mod.rs", &tensor)]);
        let f = spec::spec_pass(&files, Some(&spec_ok()));
        assert_eq!(rules(&f), vec![Rule::StateSpec]);
        assert_eq!(f[0].file, spec::SPEC_DOC);
    }

    #[test]
    fn spec_retag_sites_are_checked() {
        let declared_edge = "\
fn f(&mut self) {
    self.mgr.retag_tensors(
        c, TensorState::Free, TensorState::Hold)?;
}
";
        let files = tree(&[
            ("engine/session.rs", declared_edge),
            ("tensor/mod.rs", TENSOR_OK),
        ]);
        assert!(spec::spec_pass(&files, Some(&spec_ok())).is_empty());
        let undeclared_edge = "\
fn f(&mut self) {
    self.mgr.retag_tensors(
        c, TensorState::Compute, TensorState::Free)?;
}
";
        let files = tree(&[
            ("engine/session.rs", undeclared_edge),
            ("tensor/mod.rs", TENSOR_OK),
        ]);
        let f = spec::spec_pass(&files, Some(&spec_ok()));
        assert_eq!(rules(&f), vec![Rule::StateSpec]);
        assert_eq!(f[0].file, "engine/session.rs");
    }

    #[test]
    fn spec_missing_markers_is_a_finding() {
        let files = tree(&[("tensor/mod.rs", TENSOR_OK)]);
        let f = spec::spec_pass(&files, Some("no table here\n"));
        assert_eq!(rules(&f), vec![Rule::StateSpec]);
    }

    #[test]
    fn spec_unknown_state_name_is_a_finding() {
        let doc = spec_ok()
            .replace("| Free | Hold | init |", "| Free | HOLD | init |");
        let files = tree(&[("tensor/mod.rs", TENSOR_OK)]);
        let f = spec::spec_pass(&files, Some(&doc));
        // Malformed row + (Free, Hold) now implemented-but-undeclared.
        assert!(!f.is_empty());
        assert!(f.iter().all(|x| x.rule == Rule::StateSpec));
        assert!(f.iter().any(|x| x.file == spec::SPEC_DOC));
    }

    // --------------------------------------------------- report format

    #[test]
    fn finding_display_has_file_line_rule() {
        let f = &lint_source(
            "evict/mod.rs",
            "use std::collections::HashMap;\n",
        )[0];
        let s = f.to_string();
        assert!(s.starts_with("evict/mod.rs:1: [unordered-collection]"),
                "{s}");
        assert!(s.contains("BTreeMap"), "{s}");
    }

    #[test]
    fn lint_subtree_is_skipped() {
        assert!(lint_source(
            "lint/mod.rs",
            "use std::collections::HashMap;\n"
        )
        .is_empty());
    }

    #[test]
    fn json_report_matches_the_python_port_format() {
        let empty = LintReport::default();
        assert_eq!(empty.to_json(), "{\n \"files\": 0,\n \"findings\": []\n}");
        let report = LintReport {
            files: 1,
            findings: lint_source(
                "evict/mod.rs",
                "use std::collections::HashMap;\n",
            ),
        };
        let js = report.to_json();
        assert!(js.starts_with("{\n \"files\": 1,\n \"findings\": [\n  {\n"),
                "{js}");
        assert!(js.contains("   \"rule\": \"unordered-collection\""), "{js}");
        assert!(js.contains("   \"line\": 1"), "{js}");
    }

    // ---------------------------------------------- differential suite

    /// Fixtures the retired masked-line scanner handled correctly: on
    /// these the token-stream port must emit byte-identical
    /// diagnostics for the five original rules (new-rule findings are
    /// filtered out before comparing — the parity contract covers the
    /// legacy rule set).
    const PARITY_FIXTURES: &[(&str, &str)] = &[
        ("evict/mod.rs", "use std::collections::HashMap;\n"),
        ("util/mod.rs", "use std::collections::HashMap;\n"),
        ("evict/mod.rs", "let s = HashSet::new();\n"),
        ("main.rs", "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"),
        (
            "evict/mod.rs",
            "// the old partial_cmp().unwrap() panicked here\n\
             let msg = \"partial_cmp is banned\";\n\
             /* partial_cmp in a block comment */\n",
        ),
        ("engine/session.rs", "let t0 = std::time::Instant::now();\n"),
        ("train/trainer.rs", "let t0 = std::time::Instant::now();\n"),
        ("util/mod.rs", "let t = SystemTime::now();\n"),
        ("engine/report.rs", "use crate::sim::StreamTimeline;\n"),
        ("sim/stream.rs", "use crate::sim::StreamTimeline;\n"),
        ("engine/backend.rs", "use crate::sim::StreamTimeline;\n"),
        (
            "engine/backend.rs",
            "use std::collections::BTreeMap;\n\
             #[cfg(feature = \"pjrt\")]\n\
             use std::collections::HashMap;\n\
             fn measure() { let t0 = std::time::Instant::now(); }\n",
        ),
        (
            "engine/session.rs",
            "use std::collections::BTreeMap;\n\
             #[cfg(feature = \"pjrt\")]\n\
             use std::collections::HashMap;\n\
             fn measure() { let t0 = std::time::Instant::now(); }\n",
        ),
        (
            "engine/backend.rs",
            "use std::collections::HashMap;\n\
             #[cfg(feature = \"pjrt\")]\n",
        ),
        (
            "evict/mod.rs",
            "use std::collections::HashMap; \
             // lint:allow(unordered-collection): fixture\n",
        ),
        (
            "engine/session.rs",
            "// lint:allow(wallclock): measuring the linter itself\n\
             let t0 = std::time::Instant::now();\n",
        ),
        (
            "evict/mod.rs",
            "use std::collections::HashMap; \
             // lint:allow(wallclock): wrong rule\n",
        ),
        ("evict/mod.rs", "let a = 1;\n#[cfg(test)]\nmod tests {}\n"),
        (
            "evict/mod.rs",
            "let a = 1;\n\
             #[cfg(test)]\n\
             #[allow(dead_code)]\n\
             pub mod testutil {}\n",
        ),
        (
            "evict/mod.rs",
            "#[cfg(test)]\n\
             fn helper() {}\n\
             use std::collections::HashMap;\n",
        ),
        (
            "chunk/c.rs",
            "#[cfg(test)]\n\
             mod tests {}\n\
             fn hidden_from_every_other_rule() {}\n\
             #[cfg(test)]\n\
             mod more_tests {}\n",
        ),
        (
            "evict/mod.rs",
            "let a = 1;\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 use std::collections::HashMap;\n\
                 use crate::sim::StreamTimeline;\n\
             }\n",
        ),
        (
            "evict/mod.rs",
            "let s = \"multi\n\
             line HashMap string\";\n\
             let r = r#\"raw HashMap \"quoted\" string\"#;\n\
             let c = '\"';\n\
             let still_code = HashMap::new();\n",
        ),
        (
            "chunk/c.rs",
            "/* outer /* nested HashMap */ still comment */\n\
             fn f<'a>(x: &'a str) -> &'a str { x }\n\
             let esc = '\\'';\n\
             let m = HashMap::new();\n",
        ),
        ("lint/mod.rs", "use std::collections::HashMap;\n"),
    ];

    const LEGACY_RULES: [Rule; 5] = [
        Rule::UnorderedCollection,
        Rule::NanUnwrap,
        Rule::Wallclock,
        Rule::TimelineLayering,
        Rule::CfgTestPlacement,
    ];

    fn rendered(findings: &[Finding]) -> Vec<String> {
        let mut v: Vec<String> = findings
            .iter()
            .filter(|f| LEGACY_RULES.contains(&f.rule))
            .map(|f| f.to_string())
            .collect();
        v.sort();
        v
    }

    #[test]
    fn differential_fixture_parity() {
        for (rel, src) in PARITY_FIXTURES {
            let old = rendered(&legacy::lint_source(rel, src));
            let new = rendered(&lint_source(rel, src));
            assert_eq!(old, new, "divergence on {rel}:\n{src}");
        }
    }

    #[test]
    fn differential_lexer_improvements() {
        // Substring matching flagged `HashMap` buried inside a longer
        // identifier; the token engine requires an exact identifier.
        let (rel, src) = ("evict/mod.rs", "type A = SplitHashMapIndex;\n");
        assert_eq!(
            rules(&legacy::lint_source(rel, src)),
            vec![Rule::UnorderedCollection],
            "legacy false positive is the point of this fixture"
        );
        assert!(lint_source(rel, src).is_empty());
        // Substring matching missed a spaced-out path; token-stream
        // matching sees `Instant :: now` regardless of spacing.
        let (rel, src) = ("engine/session.rs", "let t = Instant :: now ();\n");
        assert!(legacy::lint_source(rel, src).is_empty(),
                "legacy false negative is the point of this fixture");
        assert_eq!(rules(&lint_source(rel, src)), vec![Rule::Wallclock]);
    }

    #[test]
    fn lint_files_merges_all_passes() {
        let files = tree(&[
            (
                "engine/session.rs",
                "fn d(&mut self) {\n    self.pool.try_acquire(now, dir);\n}\n",
            ),
            ("tensor/mod.rs", TENSOR_OK),
        ]);
        let f = lint_files(&files, Some(&spec_ok()));
        assert_eq!(sites(&f), vec![(2, Rule::LeaseFlow)]);
        // Findings from every pass sort into one (file, line, rule)
        // stream.
        let files = tree(&[
            (
                "engine/session.rs",
                "use std::collections::HashMap;\n\
                 fn d(&mut self) {\n    self.pool.try_acquire(now, dir);\n}\n",
            ),
            ("tensor/mod.rs", TENSOR_OK),
        ]);
        let f = lint_files(&files, Some(&spec_ok()));
        assert_eq!(
            sites(&f),
            vec![(1, Rule::UnorderedCollection), (3, Rule::LeaseFlow)]
        );
    }
}
