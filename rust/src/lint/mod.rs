//! `pstar-lint`: the determinism & layering lint pass (ISSUE 8).
//!
//! The repo's determinism contract — bit-exact golden traces, chaos
//! replay, checkpoint/restore, volume invariance — rests on a handful
//! of coding rules that `rustc` cannot check.  This module is a
//! zero-dependency, line-based enforcement pass over `src/`, run three
//! ways: `cargo run --bin pstar-lint` (CI `lint` job), the
//! `tests/lint_clean.rs` gate under plain `cargo test`, and the
//! embedded fixture self-tests below.
//!
//! ## Rules
//!
//! * **`unordered-collection`** — no `HashMap`/`HashSet` in the
//!   deterministic-state modules (`sim/`, `engine/`, `chunk/`,
//!   `evict/`, `dp/`, `mem/`).  Unordered-map iteration varies per
//!   process (`RandomState`), so any policy decision derived from it
//!   diverges across ranks and replays.  Use `BTreeMap`/`BTreeSet`.
//! * **`nan-unwrap`** — no `partial_cmp` anywhere in `src/`: the
//!   `.unwrap()` idiom panics on NaN and `sort_by` falls back to
//!   unspecified order.  Use [`crate::util::total_cmp`] (IEEE-754
//!   totalOrder: NaN sorts above every real, deterministically).
//! * **`wallclock`** — `Instant::now`/`SystemTime` only in `train/`
//!   and the pjrt half of `engine/backend.rs`: wall-clock reads inside
//!   the planner would leak real time into simulated schedules.
//! * **`timeline-layering`** — the `StreamTimeline` identifier only in
//!   `sim/` and `engine/backend.rs`: all timeline mutation goes
//!   through the `ExecutionBackend` boundary, so no policy module may
//!   name the substrate type.
//! * **`cfg-test-placement`** — `#[cfg(test)]` must introduce the
//!   single trailing test module.  The scanner skips everything from
//!   the first `#[cfg(test)]` to end-of-file (see Mechanics), so a
//!   mid-file test item or a second test block would silently exempt
//!   all code below it from every other rule; this rule turns that
//!   blind spot into a finding.
//!
//! ## Mechanics
//!
//! There is no `syn` in the offline crate cache, so this is a
//! hand-rolled scanner, deliberately conservative:
//!
//! * string literals (plain, raw, multi-line), char literals and
//!   comments (line, nested block) are masked out before matching, so
//!   prose mentioning `HashMap` never trips a rule;
//! * everything from the first `#[cfg(test)]` line to end-of-file is
//!   skipped — by repo convention the unit-test module trails the file
//!   (enforced loosely: each `src/` file has at most one);
//! * in `engine/backend.rs`, lines after the first
//!   `#[cfg(feature = "pjrt")]` are the measuring backend and are
//!   exempt from `unordered-collection` and `wallclock`;
//! * a finding on line *L* is suppressed by
//!   `// lint:allow(<rule>): <reason>` on *L* or on a comment line
//!   directly above — the escape hatch is deliberately per-line and
//!   per-rule so waivers stay auditable;
//! * the `lint/` subtree itself is skipped (its fixtures are positive
//!   examples by construction).
//!
//! See `rust/docs/INVARIANTS.md` for the contract this enforces.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

/// One enforced rule.  `ALL` is the report order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    UnorderedCollection,
    NanUnwrap,
    Wallclock,
    TimelineLayering,
    CfgTestPlacement,
}

impl Rule {
    pub const ALL: [Rule; 5] = [
        Rule::UnorderedCollection,
        Rule::NanUnwrap,
        Rule::Wallclock,
        Rule::TimelineLayering,
        Rule::CfgTestPlacement,
    ];

    /// The name used in diagnostics and `lint:allow(...)` annotations.
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnorderedCollection => "unordered-collection",
            Rule::NanUnwrap => "nan-unwrap",
            Rule::Wallclock => "wallclock",
            Rule::TimelineLayering => "timeline-layering",
            Rule::CfgTestPlacement => "cfg-test-placement",
        }
    }

    /// Why the rule exists, one line (shown with every finding).
    pub fn message(self) -> &'static str {
        match self {
            Rule::UnorderedCollection => {
                "HashMap/HashSet iteration order varies per process; \
                 use BTreeMap/BTreeSet in deterministic-state modules"
            }
            Rule::NanUnwrap => {
                "partial_cmp panics (unwrap) or mis-sorts on NaN; \
                 use util::total_cmp"
            }
            Rule::Wallclock => {
                "wall-clock reads outside train/ and the pjrt backend \
                 leak real time into simulated schedules"
            }
            Rule::TimelineLayering => {
                "StreamTimeline is backend substrate; go through \
                 ExecutionBackend instead"
            }
            Rule::CfgTestPlacement => {
                "#[cfg(test)] must introduce the single trailing test \
                 module; code after it escapes every other rule"
            }
        }
    }
}

/// One diagnostic: `file:line: [rule] message: excerpt`.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Path relative to the linted root, '/'-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    /// The offending source line, trimmed and truncated.
    pub excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}: `{}`",
            self.file,
            self.line,
            self.rule.name(),
            self.rule.message(),
            self.excerpt,
        )
    }
}

/// Result of linting a whole tree.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// All findings, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
}

// ---------------------------------------------------------------- masking

/// Blank out comments, string literals and char literals, preserving
/// newlines (and therefore line numbers) exactly.  Handles nested block
/// comments, escapes, multi-line strings and `r#"..."#` raw strings.
fn mask_code(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    // Push a masked char: newlines survive, everything else blanks.
    fn blank(out: &mut String, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                blank(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (Rust nests them).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            blank(&mut out, b[i]);
            blank(&mut out, b[i + 1]);
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (prev char must not be part of
        // an identifier, so `writer"` never false-positives).
        if c == 'r'
            && (i == 0
                || !(b[i - 1].is_alphanumeric() || b[i - 1] == '_'))
        {
            let mut j = i + 1;
            while j < n && b[j] == '#' {
                j += 1;
            }
            if j < n && b[j] == '"' {
                let hashes = j - (i + 1);
                for k in i..=j {
                    blank(&mut out, b[k]);
                }
                i = j + 1;
                // Scan for `"` followed by `hashes` '#'s.
                while i < n {
                    if b[i] == '"'
                        && i + hashes < n
                        && (1..=hashes).all(|h| b[i + h] == '#')
                    {
                        for k in i..=i + hashes {
                            blank(&mut out, b[k]);
                        }
                        i += hashes + 1;
                        break;
                    }
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
        }
        // Plain string literal (may span lines, may contain escapes).
        if c == '"' {
            blank(&mut out, c);
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                    continue;
                }
                let done = b[i] == '"';
                blank(&mut out, b[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: '\n', '\'', '\\', '\x41',
                // '\u{1F600}'.
                let mut j = i + 2;
                if j < n && b[j] == 'u' && j + 1 < n && b[j + 1] == '{'
                {
                    j += 2;
                    while j < n && b[j] != '}' {
                        j += 1;
                    }
                    j += 1;
                } else if j < n && b[j] == 'x' {
                    j += 3;
                } else {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    for k in i..=j {
                        blank(&mut out, b[k]);
                    }
                    i = j + 1;
                    continue;
                }
            } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\''
            {
                // Simple char literal like '"' or 'x'.
                for k in i..=i + 2 {
                    blank(&mut out, b[k]);
                }
                i += 3;
                continue;
            }
            // Lifetime: keep as code.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

// ------------------------------------------------------------- rule logic

/// Modules whose state feeds deterministic decisions (the
/// `unordered-collection` scope).
fn ordered_state_scope(rel: &str) -> bool {
    ["sim/", "engine/", "chunk/", "evict/", "dp/", "mem/"]
        .iter()
        .any(|p| rel.starts_with(p))
}

/// Parse `lint:allow(<rule>)` out of a raw line, if present.
fn allow_annotation(raw: &str) -> Option<Rule> {
    let i = raw.find("lint:allow(")?;
    let rest = &raw[i + "lint:allow(".len()..];
    let j = rest.find(')')?;
    let name = rest[..j].trim();
    Rule::ALL.iter().copied().find(|r| r.name() == name)
}

/// Is `rule` waived on 0-based line `idx`?  An annotation suppresses
/// the line it sits on and, when it is a whole-line comment, the line
/// directly below it.
fn waived(raw_lines: &[&str], idx: usize, rule: Rule) -> bool {
    if allow_annotation(raw_lines[idx]) == Some(rule) {
        return true;
    }
    if idx > 0 {
        let above = raw_lines[idx - 1].trim_start();
        if above.starts_with("//")
            && allow_annotation(above) == Some(rule)
        {
            return true;
        }
    }
    false
}

/// Lint one file's source.  `rel` is the path relative to `src/`,
/// '/'-separated (it selects which rules apply where).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    // The linter's own subtree holds positive fixtures by design.
    if rel.starts_with("lint/") || rel == "lint.rs" {
        return Vec::new();
    }
    let masked = mask_code(src);
    let raw_lines: Vec<&str> = src.lines().collect();
    let masked_lines: Vec<&str> = masked.lines().collect();
    debug_assert_eq!(raw_lines.len(), masked_lines.len());

    let is_backend = rel == "engine/backend.rs";
    let mut pjrt_half = false;
    let mut findings = Vec::new();
    let mut push = |idx: usize, rule: Rule, raw: &str| {
        if waived(&raw_lines, idx, rule) {
            return;
        }
        let mut excerpt: String =
            raw.trim().chars().take(80).collect();
        if raw.trim().chars().count() > 80 {
            excerpt.push('…');
        }
        findings.push(Finding {
            file: rel.clone(),
            line: idx + 1,
            rule,
            excerpt,
        });
    };

    for (idx, (&raw, &m)) in
        raw_lines.iter().zip(masked_lines.iter()).enumerate()
    {
        let trimmed = raw.trim_start();
        // Repo convention: the unit-test module trails the file, so
        // everything from the first #[cfg(test)] on is out of scope.
        // `cfg-test-placement` (ISSUE 9) makes that convention a rule
        // rather than a blind spot: the attribute must introduce the
        // single trailing test module — a mid-file #[cfg(test)] item
        // or a second test block would silently exempt everything
        // below it from every other rule.
        if trimmed.starts_with("#[cfg(test)]") {
            let mut j = idx + 1;
            while j < masked_lines.len() {
                let mt = masked_lines[j].trim();
                if mt.is_empty() || mt.starts_with("#[") {
                    j += 1;
                    continue;
                }
                break;
            }
            let introduces_module = masked_lines
                .get(j)
                .map(|l| l.trim_start())
                .is_some_and(|l| {
                    l.starts_with("mod ") || l.starts_with("pub mod ")
                });
            if !introduces_module {
                push(idx, Rule::CfgTestPlacement, raw);
            }
            // Scan the masked tail (strings blanked) for a second
            // test block.
            for (k, &later) in
                masked_lines.iter().enumerate().skip(idx + 1)
            {
                if later.trim_start().starts_with("#[cfg(test)]") {
                    push(k, Rule::CfgTestPlacement, raw_lines[k]);
                }
            }
            break;
        }
        if is_backend
            && trimmed.starts_with("#[cfg(feature = \"pjrt\")]")
        {
            pjrt_half = true;
        }
        let exec_exempt = is_backend && pjrt_half;

        if ordered_state_scope(&rel)
            && !exec_exempt
            && (m.contains("HashMap") || m.contains("HashSet"))
        {
            push(idx, Rule::UnorderedCollection, raw);
        }
        if m.contains("partial_cmp") {
            push(idx, Rule::NanUnwrap, raw);
        }
        if !rel.starts_with("train/")
            && !exec_exempt
            && (m.contains("Instant::now") || m.contains("SystemTime"))
        {
            push(idx, Rule::Wallclock, raw);
        }
        if !rel.starts_with("sim/")
            && !is_backend
            && m.contains("StreamTimeline")
        {
            push(idx, Rule::TimelineLayering, raw);
        }
    }
    findings
}

// --------------------------------------------------------------- the walk

fn walk(
    root: &Path,
    dir: &Path,
    report: &mut LintReport,
) -> io::Result<()> {
    let mut entries: Vec<_> =
        fs::read_dir(dir)?.collect::<Result<_, _>>()?;
    // Sorted walk: the report is byte-identical across filesystems.
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        let name = e.file_name();
        if path.is_dir() {
            if name == "lint" {
                continue;
            }
            walk(root, &path, report)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let src = fs::read_to_string(&path)?;
            report.files += 1;
            report.findings.extend(lint_source(&rel, &src));
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (normally `rust/src`), skipping
/// the `lint/` subtree.  Findings come back sorted.
pub fn lint_tree(root: &Path) -> io::Result<LintReport> {
    let mut report = LintReport::default();
    walk(root, root, &mut report)?;
    report
        .findings
        .sort_by(|a, b| {
            (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
        });
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(found: &[Finding]) -> Vec<Rule> {
        found.iter().map(|f| f.rule).collect()
    }

    // ------------------------------------------- unordered-collection

    #[test]
    fn unordered_collection_flagged_in_state_modules() {
        let src = "use std::collections::HashMap;\n";
        for rel in
            ["sim/a.rs", "engine/b.rs", "chunk/c.rs", "evict/mod.rs",
             "dp/group.rs", "mem/device.rs"]
        {
            let f = lint_source(rel, src);
            assert_eq!(
                rules(&f),
                vec![Rule::UnorderedCollection],
                "{rel}"
            );
            assert_eq!(f[0].line, 1);
        }
        // HashSet too.
        let f = lint_source("evict/mod.rs", "let s = HashSet::new();\n");
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
    }

    #[test]
    fn unordered_collection_ignored_outside_scope() {
        let src = "use std::collections::HashMap;\n";
        for rel in ["util/mod.rs", "runtime/mod.rs", "main.rs",
                    "train/trainer.rs"]
        {
            assert!(lint_source(rel, src).is_empty(), "{rel}");
        }
    }

    #[test]
    fn backend_pjrt_half_is_exempt_from_state_and_clock_rules() {
        let src = "\
use std::collections::BTreeMap;
#[cfg(feature = \"pjrt\")]
use std::collections::HashMap;
fn measure() { let t0 = std::time::Instant::now(); }
";
        assert!(lint_source("engine/backend.rs", src).is_empty());
        // ... but only in backend.rs; other engine files get no pass.
        let f = lint_source("engine/session.rs", src);
        assert_eq!(
            rules(&f),
            vec![Rule::UnorderedCollection, Rule::Wallclock]
        );
        // And before the marker backend.rs is scoped like the rest.
        let early = "use std::collections::HashMap;\n\
                     #[cfg(feature = \"pjrt\")]\n";
        let f = lint_source("engine/backend.rs", early);
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
    }

    // ----------------------------------------------------- nan-unwrap

    #[test]
    fn nan_unwrap_flagged_everywhere() {
        let src =
            "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        for rel in ["util/mod.rs", "chunk/search.rs", "main.rs"] {
            assert_eq!(
                rules(&lint_source(rel, src)),
                vec![Rule::NanUnwrap],
                "{rel}"
            );
        }
    }

    #[test]
    fn nan_unwrap_ignores_comments_and_strings() {
        let src = "\
// the old partial_cmp().unwrap() panicked here
let msg = \"partial_cmp is banned\";
/* partial_cmp in a block comment */
";
        assert!(lint_source("evict/mod.rs", src).is_empty());
    }

    // ------------------------------------------------------ wallclock

    #[test]
    fn wallclock_flagged_outside_train() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert_eq!(
            rules(&lint_source("engine/session.rs", src)),
            vec![Rule::Wallclock]
        );
        assert_eq!(
            rules(&lint_source("util/mod.rs",
                               "let t = SystemTime::now();\n")),
            vec![Rule::Wallclock]
        );
        assert!(lint_source("train/trainer.rs", src).is_empty());
    }

    // ----------------------------------------------- timeline-layering

    #[test]
    fn timeline_layering_scopes_to_sim_and_backend() {
        let src = "use crate::sim::StreamTimeline;\n";
        assert_eq!(
            rules(&lint_source("engine/report.rs", src)),
            vec![Rule::TimelineLayering]
        );
        assert_eq!(
            rules(&lint_source("chunk/manager.rs", src)),
            vec![Rule::TimelineLayering]
        );
        assert!(lint_source("sim/stream.rs", src).is_empty());
        assert!(lint_source("engine/backend.rs", src).is_empty());
    }

    // ------------------------------------------------ allow annotations

    #[test]
    fn allow_suppresses_same_line_and_line_above() {
        let same = "use std::collections::HashMap; \
                    // lint:allow(unordered-collection): fixture\n";
        assert!(lint_source("evict/mod.rs", same).is_empty());

        let above = "\
// lint:allow(wallclock): measuring the linter itself
let t0 = std::time::Instant::now();
";
        assert!(lint_source("engine/session.rs", above).is_empty());
    }

    #[test]
    fn allow_is_per_rule_and_per_line() {
        // Wrong rule name: no waiver.
        let wrong = "use std::collections::HashMap; \
                     // lint:allow(wallclock): wrong rule\n";
        assert_eq!(
            rules(&lint_source("evict/mod.rs", wrong)),
            vec![Rule::UnorderedCollection]
        );
        // A waiver two lines up does not reach.
        let far = "\
// lint:allow(unordered-collection): too far away
let x = 1;
use std::collections::HashMap;
";
        assert_eq!(
            rules(&lint_source("evict/mod.rs", far)),
            vec![Rule::UnorderedCollection]
        );
    }

    // ------------------------------------------------- masking & scope

    // ------------------------------------------- cfg-test-placement

    #[test]
    fn cfg_test_must_introduce_the_trailing_test_module() {
        let good = "let a = 1;\n#[cfg(test)]\nmod tests {}\n";
        assert!(lint_source("evict/mod.rs", good).is_empty());
        // Stacked attributes between the cfg and the module are fine,
        // and a pub test-support module counts too.
        let stacked = "\
let a = 1;
#[cfg(test)]
#[allow(dead_code)]
pub mod testutil {}
";
        assert!(lint_source("evict/mod.rs", stacked).is_empty());
        // A mid-file #[cfg(test)] item hides everything below it from
        // the other rules — exactly what the rule exists to catch.
        let item = "\
#[cfg(test)]
fn helper() {}
use std::collections::HashMap;
";
        let f = lint_source("evict/mod.rs", item);
        assert_eq!(rules(&f), vec![Rule::CfgTestPlacement]);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn second_cfg_test_block_is_flagged() {
        let src = "\
#[cfg(test)]
mod tests {}
fn hidden_from_every_other_rule() {}
#[cfg(test)]
mod more_tests {}
";
        let f = lint_source("chunk/c.rs", src);
        assert_eq!(rules(&f), vec![Rule::CfgTestPlacement]);
        assert_eq!(f[0].line, 4);
        // In a string it is prose, not a block.
        let masked = "\
#[cfg(test)]
mod tests {
    const S: &str = \"
#[cfg(test)]
\";
}
";
        assert!(lint_source("chunk/c.rs", masked).is_empty());
    }

    #[test]
    fn trailing_test_module_is_skipped() {
        let src = "\
let a = 1;
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    use crate::sim::StreamTimeline;
}
";
        assert!(lint_source("evict/mod.rs", src).is_empty());
    }

    #[test]
    fn masking_handles_multiline_and_raw_strings() {
        let src = "\
let s = \"multi
line HashMap string\";
let r = r#\"raw HashMap \"quoted\" string\"#;
let c = '\"';
let still_code = HashMap::new();
";
        let f = lint_source("evict/mod.rs", src);
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
        assert_eq!(f[0].line, 5, "only the real code line flags");
    }

    #[test]
    fn masking_handles_nested_block_comments_and_lifetimes() {
        let src = "\
/* outer /* nested HashMap */ still comment */
fn f<'a>(x: &'a str) -> &'a str { x }
let esc = '\\'';
let m = HashMap::new();
";
        let f = lint_source("chunk/c.rs", src);
        assert_eq!(rules(&f), vec![Rule::UnorderedCollection]);
        assert_eq!(f[0].line, 4);
    }

    #[test]
    fn finding_display_has_file_line_rule() {
        let f = &lint_source(
            "evict/mod.rs",
            "use std::collections::HashMap;\n",
        )[0];
        let s = f.to_string();
        assert!(s.starts_with("evict/mod.rs:1: [unordered-collection]"),
                "{s}");
        assert!(s.contains("BTreeMap"), "{s}");
    }

    #[test]
    fn lint_subtree_is_skipped() {
        assert!(lint_source(
            "lint/mod.rs",
            "use std::collections::HashMap;\n"
        )
        .is_empty());
    }
}
