//! The retired masked-line scanner (ISSUE 8/9), kept verbatim as the
//! **differential oracle** for the token-stream engine (ISSUE 10).
//!
//! The port contract: on every fixture the old scanner handled
//! correctly, the new engine in [`super`] must emit byte-identical
//! diagnostics for the five original rules.  The differential suite in
//! `super::tests::differential_fixture_parity` locks that in — this
//! module has no other callers and no CLI entry point.
//!
//! (The known divergence classes the rewrite fixed — substring
//! matching flags `HashMap` buried inside a longer identifier, and
//! misses a spaced-out `Instant :: now` path — are asserted
//! separately as intentional divergences, see
//! `differential_lexer_improvements`.)

use super::{Finding, Rule};

/// Blank out comments, string literals and char literals, preserving
/// newlines (and therefore line numbers) exactly.  Handles nested
/// block comments, escapes, multi-line strings and `r#"..."#` raw
/// strings.
pub fn mask_code(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    // Push a masked char: newlines survive, everything else blanks.
    fn blank(out: &mut String, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }
    while i < n {
        let c = b[i];
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                blank(&mut out, b[i]);
                i += 1;
            }
            continue;
        }
        // Block comment (Rust nests them).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            blank(&mut out, b[i]);
            blank(&mut out, b[i + 1]);
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                } else {
                    blank(&mut out, b[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (prev char must not be part of
        // an identifier, so `writer"` never false-positives).
        if c == 'r'
            && (i == 0 || !(b[i - 1].is_alphanumeric() || b[i - 1] == '_'))
        {
            let mut j = i + 1;
            while j < n && b[j] == '#' {
                j += 1;
            }
            if j < n && b[j] == '"' {
                let hashes = j - (i + 1);
                for k in i..=j {
                    blank(&mut out, b[k]);
                }
                i = j + 1;
                // Scan for `"` followed by `hashes` '#'s.
                while i < n {
                    if b[i] == '"'
                        && i + hashes < n
                        && (1..=hashes).all(|h| b[i + h] == '#')
                    {
                        for k in i..=i + hashes {
                            blank(&mut out, b[k]);
                        }
                        i += hashes + 1;
                        break;
                    }
                    blank(&mut out, b[i]);
                    i += 1;
                }
                continue;
            }
        }
        // Plain string literal (may span lines, may contain escapes).
        if c == '"' {
            blank(&mut out, c);
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    blank(&mut out, b[i]);
                    blank(&mut out, b[i + 1]);
                    i += 2;
                    continue;
                }
                let done = b[i] == '"';
                blank(&mut out, b[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char literal: '\n', '\'', '\\', '\x41',
                // '\u{1F600}'.
                let mut j = i + 2;
                if j < n && b[j] == 'u' && j + 1 < n && b[j + 1] == '{' {
                    j += 2;
                    while j < n && b[j] != '}' {
                        j += 1;
                    }
                    j += 1;
                } else if j < n && b[j] == 'x' {
                    j += 3;
                } else {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    for k in i..=j {
                        blank(&mut out, b[k]);
                    }
                    i = j + 1;
                    continue;
                }
            } else if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // Simple char literal like '"' or 'x'.
                for k in i..=i + 2 {
                    blank(&mut out, b[k]);
                }
                i += 3;
                continue;
            }
            // Lifetime: keep as code.
            out.push(c);
            i += 1;
            continue;
        }
        out.push(c);
        i += 1;
    }
    out
}

/// The five original rules, in the legacy report order.
const LEGACY_RULES: [Rule; 5] = [
    Rule::UnorderedCollection,
    Rule::NanUnwrap,
    Rule::Wallclock,
    Rule::TimelineLayering,
    Rule::CfgTestPlacement,
];

fn allow_annotation(raw: &str) -> Option<Rule> {
    let i = raw.find("lint:allow(")?;
    let rest = &raw[i + "lint:allow(".len()..];
    let j = rest.find(')')?;
    let name = rest[..j].trim();
    LEGACY_RULES.iter().copied().find(|r| r.name() == name)
}

fn waived(raw_lines: &[&str], idx: usize, rule: Rule) -> bool {
    if allow_annotation(raw_lines[idx]) == Some(rule) {
        return true;
    }
    if idx > 0 {
        let above = raw_lines[idx - 1].trim_start();
        if above.starts_with("//") && allow_annotation(above) == Some(rule) {
            return true;
        }
    }
    false
}

/// The ISSUE 8/9 masked-line pass, verbatim: five rules, per-line
/// substring matching on masked source.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let rel = rel.replace('\\', "/");
    if rel.starts_with("lint/") || rel == "lint.rs" {
        return Vec::new();
    }
    let masked = mask_code(src);
    let raw_lines: Vec<&str> = src.lines().collect();
    let masked_lines: Vec<&str> = masked.lines().collect();
    debug_assert_eq!(raw_lines.len(), masked_lines.len());

    let is_backend = rel == "engine/backend.rs";
    let mut pjrt_half = false;
    let mut findings = Vec::new();
    let mut push = |idx: usize, rule: Rule, raw: &str| {
        if waived(&raw_lines, idx, rule) {
            return;
        }
        let mut excerpt: String = raw.trim().chars().take(80).collect();
        if raw.trim().chars().count() > 80 {
            excerpt.push('…');
        }
        findings.push(Finding {
            file: rel.clone(),
            line: idx + 1,
            rule,
            excerpt,
        });
    };

    for (idx, (&raw, &m)) in
        raw_lines.iter().zip(masked_lines.iter()).enumerate()
    {
        let trimmed = raw.trim_start();
        if trimmed.starts_with("#[cfg(test)]") {
            let mut j = idx + 1;
            while j < masked_lines.len() {
                let mt = masked_lines[j].trim();
                if mt.is_empty() || mt.starts_with("#[") {
                    j += 1;
                    continue;
                }
                break;
            }
            let introduces_module = masked_lines
                .get(j)
                .map(|l| l.trim_start())
                .is_some_and(|l| {
                    l.starts_with("mod ") || l.starts_with("pub mod ")
                });
            if !introduces_module {
                push(idx, Rule::CfgTestPlacement, raw);
            }
            for (k, &later) in
                masked_lines.iter().enumerate().skip(idx + 1)
            {
                if later.trim_start().starts_with("#[cfg(test)]") {
                    push(k, Rule::CfgTestPlacement, raw_lines[k]);
                }
            }
            break;
        }
        if is_backend && trimmed.starts_with("#[cfg(feature = \"pjrt\")]") {
            pjrt_half = true;
        }
        let exec_exempt = is_backend && pjrt_half;

        if super::ordered_state_scope(&rel)
            && !exec_exempt
            && (m.contains("HashMap") || m.contains("HashSet"))
        {
            push(idx, Rule::UnorderedCollection, raw);
        }
        if m.contains("partial_cmp") {
            push(idx, Rule::NanUnwrap, raw);
        }
        if !rel.starts_with("train/")
            && !exec_exempt
            && (m.contains("Instant::now") || m.contains("SystemTime"))
        {
            push(idx, Rule::Wallclock, raw);
        }
        if !rel.starts_with("sim/")
            && !is_backend
            && m.contains("StreamTimeline")
        {
            push(idx, Rule::TimelineLayering, raw);
        }
    }
    findings
}
