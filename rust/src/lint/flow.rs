//! Flow-sensitive lease-balance pass (ISSUE 10): the static twin of
//! `PinnedPool::leak_check`.
//!
//! Within every function of the files in [`FLOW_SCOPE`] (the only
//! modules that acquire pinned-buffer leases), a brace-scoped walk
//! over the token stream proves each `pool.try_acquire(..)` result
//! reaches a release sink on **every** match/if arm:
//!
//! * `pool.release(l)` / `pool.set_release(l, t)`;
//! * storage in a lease-carrying struct field or call argument
//!   (`StreamLease`, `PendingCopy`, `InFlightGather` — a move to an
//!   owner whose drain path releases);
//! * an explicit `return` (the caller inherits the obligation);
//! * a diverging arm (`break`/`continue`/`return`/`panic!` — the
//!   lease never existed on that path).
//!
//! The pass is deliberately *move-generous*: a lease moved into any
//! call or literal counts as consumed, so it proves the **no-leak**
//! direction only.  A finding is always a real dropped-on-some-path
//! hazard; a clean pass does not prove the eventual owner releases —
//! that stays `leak_check`'s job at runtime.
//!
//! Mirrored by `scripts/pstar_lint.py` (`flow_pass` and friends).

use super::lex::{
    at, ident_at, lex, match_brace, match_paren, tok_is, Kind, Tok,
};
use super::{excerpt_of, Finding, Rule};

/// Files audited: the only modules that call `try_acquire` outside
/// the pool's own unit tests.
pub const FLOW_SCOPE: [&str; 2] = ["engine/session.rs", "dp/group.rs"];

/// `(name, body_start, body_end)` for each `fn` with a body; the span
/// excludes the outer braces.
pub fn functions(toks: &[Tok]) -> Vec<(String, usize, usize)> {
    let mut fns = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if tok_is(toks, i, Kind::Ident, "fn") {
            if let Some(name) = ident_at(toks, i + 1) {
                let name = name.to_string();
                // Find the body `{`, bailing at `;` (bodyless decl)
                // at paren/bracket depth 0.
                let mut j = i + 2;
                let mut depth = 0i64;
                let mut body = None;
                while j < toks.len() {
                    let t = &toks[j];
                    if t.kind == Kind::Punct {
                        match t.text.as_str() {
                            "(" | "[" => depth += 1,
                            ")" | "]" => depth -= 1,
                            ";" if depth == 0 => break,
                            "{" if depth == 0 => {
                                body = Some(j);
                                break;
                            }
                            _ => {}
                        }
                    }
                    j += 1;
                }
                if let Some(j) = body {
                    let close = match_brace(toks, j);
                    fns.push((name, j + 1, close));
                    i = j + 1;
                    continue;
                }
                i += 2;
                continue;
            }
        }
        i += 1;
    }
    fns
}

/// `j` indexes a closing `)]}`: return the index before its opener.
fn skip_group_back(toks: &[Tok], lo: usize, j: usize) -> Option<usize> {
    let close = toks[j].text.as_str();
    let opener = match close {
        ")" => "(",
        "]" => "[",
        "}" => "{",
        _ => return Some(j),
    };
    let mut depth = 0i64;
    let mut j = j as i64;
    while j >= lo as i64 {
        let t = &toks[j as usize];
        if t.kind == Kind::Punct {
            if t.text == close {
                depth += 1;
            } else if t.text == opener {
                depth -= 1;
                if depth == 0 {
                    return (j - 1).try_into().ok();
                }
            }
        }
        j -= 1;
    }
    None
}

/// How a `try_acquire` call site binds its result.
enum Shape {
    /// Scrutinee of a value-escaping match (match token index).
    Match(usize),
    /// `let VAR = ... match try_acquire ...` (var, match index).
    LetMatch(String, usize),
    /// Initializer of `let VAR = ...` (or a reassignment).
    Let(String),
    /// `if let Some(VAR) = ... try_acquire(..)` / while-let.
    IfLet(String),
    /// Moved straight into a call / return: obligation discharged.
    Consumed,
    /// Statement-level: the `Option` result is discarded.
    Dropped,
}

/// Walk backwards from the `.try_acquire` at `i` to the construct
/// that owns its result.  The walk skips balanced groups and
/// ordinary expression tokens, and crosses unmatched `{` upward (a
/// value-position block).  On finding `match` it keeps walking: if
/// the match is itself the initializer of a `let`, the obligation
/// continues on the binding ([`Shape::LetMatch`]).
fn classify_site(toks: &[Tok], lo: usize, i: usize) -> Shape {
    let mut j = i as i64 - 1;
    let lo = lo as i64;
    let mut match_idx: Option<usize> = None;
    while j >= lo {
        let t = &toks[j as usize];
        if t.kind == Kind::Punct && matches!(t.text.as_str(), ")" | "]" | "}")
        {
            match skip_group_back(toks, lo as usize, j as usize) {
                Some(k) => {
                    j = k as i64;
                    continue;
                }
                None => break,
            }
        }
        if t.kind == Kind::Punct && t.text == ";" {
            break;
        }
        if t.kind == Kind::Punct
            && t.text == ">"
            && j >= 1
            && tok_is(toks, j as usize - 1, Kind::Punct, "=")
        {
            // `=>`: arm-valued expression; the value escapes upward.
            return Shape::Consumed;
        }
        if t.kind == Kind::Punct && t.text == "=" {
            let ju = j as usize;
            let nxt_gt = tok_is(toks, ju + 1, Kind::Punct, ">");
            let prv_op = ju >= 1
                && at(toks, ju - 1).is_some_and(|p| {
                    p.kind == Kind::Punct
                        && "=!<>+-*/&|^%".contains(&p.text)
                });
            if nxt_gt || prv_op {
                j -= 1; // `=>` tail / comparison / compound op
                continue;
            }
            // `let VAR =` / `[if|while] let Some ( VAR ) =` / `VAR =`.
            let k = ju.wrapping_sub(1);
            if ju >= 5
                && tok_is(toks, k, Kind::Punct, ")")
                && tok_is(toks, k - 2, Kind::Punct, "(")
                && tok_is(toks, k - 3, Kind::Ident, "Some")
                && tok_is(toks, k - 4, Kind::Ident, "let")
                && ident_at(toks, k - 1).is_some()
            {
                return Shape::IfLet(
                    ident_at(toks, k - 1).unwrap().to_string(),
                );
            }
            if ju >= 1 {
                if let Some(var) = ident_at(toks, k) {
                    let var = var.to_string();
                    return match match_idx {
                        Some(m) => Shape::LetMatch(var, m),
                        None => Shape::Let(var),
                    };
                }
            }
            break;
        }
        if t.kind == Kind::Ident {
            if t.text == "match" {
                if match_idx.is_none() {
                    match_idx = Some(j as usize);
                }
                j -= 1;
                continue;
            }
            if t.text == "return" {
                return Shape::Consumed;
            }
            j -= 1;
            continue;
        }
        if t.kind == Kind::Punct && t.text == "{" {
            j -= 1; // value-position block: continue into its header
            continue;
        }
        if t.kind == Kind::Punct && (t.text == "," || t.text == "(") {
            // Argument / field value: moved into the enclosing call.
            return Shape::Consumed;
        }
        // `.` `::` `&` `?` `!` operators: expression glue.
        j -= 1;
    }
    match match_idx {
        Some(m) => Shape::Match(m),
        None => Shape::Dropped,
    }
}

/// Split the `{...}` of a match starting at `lbrace` into arms:
/// `(pat_lo, pat_hi, body_lo, body_hi)` token index ranges.
fn match_arms(toks: &[Tok], lbrace: usize) -> Vec<(usize, usize, usize, usize)> {
    let close = match_brace(toks, lbrace);
    let mut arms = Vec::new();
    let mut i = lbrace + 1;
    while i < close {
        // Pattern: up to `=>` at depth 0.
        let pat_lo = i;
        let mut depth = 0i64;
        while i < close {
            let t = &toks[i];
            if t.kind == Kind::Punct {
                match t.text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    "=" if depth == 0
                        && tok_is(toks, i + 1, Kind::Punct, ">") =>
                    {
                        break;
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        if i >= close {
            break;
        }
        let pat_hi = i;
        i += 2; // past =>
        let body_lo = i;
        let body_hi;
        if tok_is(toks, i, Kind::Punct, "{") {
            body_hi = match_brace(toks, i) + 1;
            i = body_hi;
            if tok_is(toks, i, Kind::Punct, ",") {
                i += 1;
            }
        } else {
            let mut depth = 0i64;
            while i < close {
                let t = &toks[i];
                if t.kind == Kind::Punct {
                    match t.text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        "," if depth == 0 => break,
                        _ => {}
                    }
                }
                i += 1;
            }
            body_hi = i;
            if i < close {
                i += 1; // past ,
            }
        }
        arms.push((pat_lo, pat_hi, body_lo, body_hi));
    }
    arms
}

/// `Some ( ident )` over exactly `[lo, hi)` -> the ident.
fn some_binding(toks: &[Tok], lo: usize, hi: usize) -> Option<&str> {
    if hi - lo == 4
        && tok_is(toks, lo, Kind::Ident, "Some")
        && tok_is(toks, lo + 1, Kind::Punct, "(")
        && tok_is(toks, lo + 3, Kind::Punct, ")")
    {
        return ident_at(toks, lo + 2);
    }
    None
}

/// The region `[lo, hi)` escapes the enclosing scope on every path
/// end (break/continue/return/panic-family).
fn diverges(toks: &[Tok], lo: usize, hi: usize) -> bool {
    let mut i = lo;
    while i < hi {
        let t = &toks[i];
        if t.kind == Kind::Ident {
            if matches!(t.text.as_str(), "break" | "continue" | "return") {
                return true;
            }
            if matches!(
                t.text.as_str(),
                "bail" | "panic" | "unreachable" | "todo"
            ) && tok_is(toks, i + 1, Kind::Punct, "!")
            {
                return true;
            }
        }
        i += 1;
    }
    false
}

/// Token `i` (the tracked ident) sits in a consuming position:
/// * first argument of `.release(` / `.set_release(` / `Some(`;
/// * moved into a literal/call: preceded by one of `{ , : (` and
///   followed by one of `, } )` (field value, shorthand, argument);
/// * `return`ed within the same statement prefix.
fn consuming_position(toks: &[Tok], i: usize) -> bool {
    if i >= 2
        && tok_is(toks, i - 1, Kind::Punct, "(")
        && matches!(
            ident_at(toks, i - 2),
            Some("release") | Some("set_release") | Some("Some")
        )
    {
        return true;
    }
    let prev_in = i >= 1
        && at(toks, i - 1).is_some_and(|t| {
            t.kind == Kind::Punct && matches!(t.text.as_str(), "{" | "," | ":" | "(")
        });
    let next_in = at(toks, i + 1).is_some_and(|t| {
        t.kind == Kind::Punct && matches!(t.text.as_str(), "," | "}" | ")")
    });
    if prev_in && next_in {
        return true;
    }
    // `return ... X`: scan back a short window to the statement edge.
    let floor = i.saturating_sub(12);
    let mut j = i as i64 - 1;
    while j >= floor as i64 {
        let t = &toks[j as usize];
        if t.kind == Kind::Punct
            && matches!(t.text.as_str(), ";" | "{" | "}")
        {
            break;
        }
        if t.kind == Kind::Ident && t.text == "return" {
            return true;
        }
        j -= 1;
    }
    false
}

/// Must-consume analysis of `var` over the straight-line region
/// `[lo, hi)` with branch awareness.  Returns
/// `(consumed_on_all_paths, consumed_on_some_path)`.
fn consumed(toks: &[Tok], lo: usize, hi: usize, var: &str) -> (bool, bool) {
    let mut partial = false;
    let mut i = lo;
    while i < hi {
        let t = &toks[i];
        // `if let Some ( Y ) = var {` — the Some-arm discharges the
        // whole obligation (the None side carries nothing).
        if tok_is(toks, i, Kind::Ident, "if")
            && tok_is(toks, i + 1, Kind::Ident, "let")
            && tok_is(toks, i + 2, Kind::Ident, "Some")
            && tok_is(toks, i + 3, Kind::Punct, "(")
            && ident_at(toks, i + 4).is_some()
            && tok_is(toks, i + 5, Kind::Punct, ")")
            && tok_is(toks, i + 6, Kind::Punct, "=")
            && tok_is(toks, i + 7, Kind::Ident, var)
            && tok_is(toks, i + 8, Kind::Punct, "{")
        {
            let inner = ident_at(toks, i + 4).unwrap().to_string();
            let close = match_brace(toks, i + 8);
            let (ok, _) = consumed(toks, i + 9, close, &inner);
            if ok {
                return (true, partial);
            }
            i = close + 1;
            continue;
        }
        // `match var {` with Some-arms.
        if tok_is(toks, i, Kind::Ident, "match")
            && tok_is(toks, i + 1, Kind::Ident, var)
            && tok_is(toks, i + 2, Kind::Punct, "{")
        {
            for (pl, ph, bl, bh) in match_arms(toks, i + 2) {
                if let Some(y) = some_binding(toks, pl, ph) {
                    let y = y.to_string();
                    let (ok, _) = consumed(toks, bl, bh, &y);
                    if ok {
                        return (true, partial);
                    }
                }
            }
            i = match_brace(toks, i + 2) + 1;
            continue;
        }
        // Plain `if cond { A } [else { B }]`.
        if tok_is(toks, i, Kind::Ident, "if")
            && !tok_is(toks, i + 1, Kind::Ident, "let")
        {
            let mut j = i + 1;
            let mut depth = 0i64;
            while j < hi {
                let tt = &toks[j];
                if tt.kind == Kind::Punct {
                    match tt.text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" if depth == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            if j >= hi {
                break;
            }
            let a_close = match_brace(toks, j);
            let (mut ca, pa) = consumed(toks, j + 1, a_close, var);
            ca = ca || diverges(toks, j + 1, a_close);
            partial = partial || pa;
            let k = a_close + 1;
            if tok_is(toks, k, Kind::Ident, "else")
                && tok_is(toks, k + 1, Kind::Punct, "{")
            {
                let b_close = match_brace(toks, k + 1);
                let (mut cb, pb) = consumed(toks, k + 2, b_close, var);
                cb = cb || diverges(toks, k + 2, b_close);
                partial = partial || pb;
                if ca && cb {
                    return (true, partial);
                }
                if ca || cb {
                    partial = true;
                }
                i = b_close + 1;
                continue;
            }
            if ca {
                partial = true;
            }
            i = k;
            continue;
        }
        // `match other { ... }`: all arms must consume or diverge.
        if tok_is(toks, i, Kind::Ident, "match")
            && !tok_is(toks, i + 1, Kind::Ident, var)
        {
            let mut j = i + 1;
            let mut depth = 0i64;
            while j < hi {
                let tt = &toks[j];
                if tt.kind == Kind::Punct {
                    match tt.text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" if depth == 0 => break,
                        _ => {}
                    }
                }
                j += 1;
            }
            if j >= hi {
                break;
            }
            let arms = match_arms(toks, j);
            let mut results = Vec::new();
            for (_pl, _ph, bl, bh) in &arms {
                let (ok, pb) = consumed(toks, *bl, *bh, var);
                partial = partial || pb;
                results.push(ok || diverges(toks, *bl, *bh));
            }
            if !arms.is_empty() && results.iter().all(|&r| r) {
                return (true, partial);
            }
            if results.iter().any(|&r| r) {
                partial = true;
            }
            i = match_brace(toks, j) + 1;
            continue;
        }
        if t.kind == Kind::Ident
            && t.text == var
            && consuming_position(toks, i)
        {
            return (true, partial);
        }
        i += 1;
    }
    (false, partial)
}

/// Innermost `{...}` span (exclusive of braces) within the function
/// body containing token index `i`; the body itself if none.
fn enclosing_block(
    toks: &[Tok],
    body_lo: usize,
    body_hi: usize,
    i: usize,
) -> (usize, usize) {
    let mut best = (body_lo, body_hi);
    let mut j = body_lo;
    while j < body_hi {
        if tok_is(toks, j, Kind::Punct, "{") {
            let close = match_brace(toks, j);
            if j < i && i < close {
                best = (j + 1, close);
                j += 1;
                continue;
            }
            j = close + 1;
            continue;
        }
        j += 1;
    }
    best
}

/// End of the statement containing a call whose `)` closed at
/// `call_close`: the next `;` at non-positive relative depth (value
/// -position blocks may close before it).
fn stmt_end(toks: &[Tok], body_hi: usize, from: usize) -> usize {
    let mut depth = 0i64;
    let mut k = from;
    while k < body_hi {
        let tt = &toks[k];
        if tt.kind == Kind::Punct {
            match tt.text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                ";" if depth <= 0 => break,
                _ => {}
            }
        }
        k += 1;
    }
    k
}

/// Lease-balance audit over one file.
pub fn flow_pass(rel: &str, src: &str) -> Vec<Finding> {
    if !FLOW_SCOPE.contains(&rel) {
        return Vec::new();
    }
    let mut toks = lex(src);
    if let (Some(cut), _) = super::cfg_cutoff(&toks) {
        toks.retain(|t| t.line < cut);
    }
    let raw_lines: Vec<&str> = src.lines().collect();
    let mut findings = Vec::new();
    let mut leak = |line: usize| {
        let raw = raw_lines.get(line - 1).copied().unwrap_or("");
        findings.push(Finding {
            file: rel.to_string(),
            line,
            rule: Rule::LeaseFlow,
            excerpt: excerpt_of(raw),
        });
    };

    for (_name, body_lo, body_hi) in functions(&toks) {
        let mut i = body_lo;
        while i < body_hi {
            if !(tok_is(&toks, i, Kind::Punct, ".")
                && tok_is(&toks, i + 1, Kind::Ident, "try_acquire")
                && tok_is(&toks, i + 2, Kind::Punct, "("))
            {
                i += 1;
                continue;
            }
            let call_line = toks[i + 1].line;
            let call_close = match_paren(&toks, i + 2);
            match classify_site(&toks, body_lo, i) {
                Shape::Let(var) => {
                    // Obligation on the binding over the rest of the
                    // enclosing block, after the statement's `;`.
                    let k = stmt_end(&toks, body_hi, call_close + 1);
                    let (_, blk_hi) =
                        enclosing_block(&toks, body_lo, body_hi, k);
                    let (ok, _) = consumed(&toks, k + 1, blk_hi, &var);
                    if !ok {
                        leak(call_line);
                    }
                    i = call_close + 1;
                }
                Shape::IfLet(var) => {
                    // Obligation inside the then-block.
                    let mut j = call_close + 1;
                    while j < body_hi && !tok_is(&toks, j, Kind::Punct, "{")
                    {
                        j += 1;
                    }
                    let close = match_brace(&toks, j);
                    let (ok, _) = consumed(&toks, j + 1, close, &var);
                    if !ok {
                        leak(call_line);
                    }
                    i = call_close + 1;
                }
                shape @ (Shape::Match(_) | Shape::LetMatch(..)) => {
                    // Scrutinee: every Some-arm must consume, diverge
                    // or (letmatch) pass through as the match value
                    // `Some(y)` — then the obligation moves to the
                    // let binding over the rest of its block.
                    let pass_var = match shape {
                        Shape::LetMatch(v, _) => Some(v),
                        _ => None,
                    };
                    let mut j = call_close + 1;
                    while j < body_hi && !tok_is(&toks, j, Kind::Punct, "{")
                    {
                        j += 1;
                    }
                    let arms = match_arms(&toks, j);
                    let mut bad = false;
                    let mut saw_some = false;
                    let mut passed_through = false;
                    for (pl, ph, bl, bh) in &arms {
                        let Some(y) = some_binding(&toks, *pl, *ph) else {
                            continue;
                        };
                        let y = y.to_string();
                        saw_some = true;
                        if pass_var.is_some()
                            && some_binding(&toks, *bl, *bh)
                                == Some(y.as_str())
                        {
                            // Arm body is exactly `Some(y)`.
                            passed_through = true;
                            continue;
                        }
                        let (ok, _) = consumed(&toks, *bl, *bh, &y);
                        if !(ok || diverges(&toks, *bl, *bh)) {
                            bad = true;
                        }
                    }
                    if bad || !saw_some {
                        leak(call_line);
                    } else if passed_through {
                        let var = pass_var.unwrap();
                        let k = stmt_end(
                            &toks,
                            body_hi,
                            match_brace(&toks, j) + 1,
                        );
                        let (_, blk_hi) =
                            enclosing_block(&toks, body_lo, body_hi, k);
                        let (ok, _) =
                            consumed(&toks, k + 1, blk_hi, &var);
                        if !ok {
                            leak(call_line);
                        }
                    }
                    i = match_brace(&toks, j) + 1;
                }
                Shape::Consumed => {
                    i = call_close + 1;
                }
                Shape::Dropped => {
                    // Statement-level call: the result is discarded.
                    leak(call_line);
                    i = call_close + 1;
                }
            }
        }
    }
    findings
}
