//! Tensor-state-machine spec check (ISSUE 10).
//!
//! `TensorState` transitions live in three places that can drift:
//! the declared table in `docs/INVARIANTS.md` (the single source of
//! truth, delimited by `transition-spec` markers), the runtime guard
//! `transition_allowed` in `tensor/mod.rs`, and the literal
//! `retag_tensors(..)` call sites that actually drive chunks through
//! the machine.  This pass diffs all three:
//!
//! * implemented-but-undeclared — an edge `transition_allowed`
//!   accepts that the doc table does not list (fires at the
//!   `tensor/mod.rs` line);
//! * declared-but-absent — a table row the implementation rejects
//!   (fires at the doc line);
//! * undeclared retag — a literal `retag_tensors(From, To)` call
//!   whose edge is missing from the table (fires at the call site).
//!
//! Mirrored by `scripts/pstar_lint.py` (`spec_pass` and friends).

use std::collections::BTreeMap;

use super::flow::functions;
use super::lex::{at, ident_at, lex, match_paren, path_sep, tok_is, Kind, Tok};
use super::{excerpt_of, Finding, Rule};

pub const SPEC_BEGIN: &str = "<!-- transition-spec:begin -->";
pub const SPEC_END: &str = "<!-- transition-spec:end -->";
/// Path the doc findings are reported under (relative to `rust/`).
pub const SPEC_DOC: &str = "docs/INVARIANTS.md";

pub const STATES: [&str; 5] =
    ["Free", "Compute", "Hold", "HoldAfterFwd", "HoldAfterBwd"];

fn is_state(s: &str) -> bool {
    STATES.contains(&s)
}

/// Declared `(from, to) -> 0-based doc line` from the marker-delimited
/// markdown table, plus `(line0, raw)` pairs for malformed rows.
/// `None` if the markers are missing.
#[allow(clippy::type_complexity)]
pub fn parse_table(
    doc: &str,
) -> Option<(BTreeMap<(String, String), usize>, Vec<(usize, String)>)> {
    let lines: Vec<&str> = doc.split('\n').collect();
    let mut lo = None;
    let mut hi = None;
    for (i, l) in lines.iter().enumerate() {
        if l.contains(SPEC_BEGIN) && lo.is_none() {
            lo = Some(i);
        } else if l.contains(SPEC_END) && lo.is_some() {
            hi = Some(i);
            break;
        }
    }
    let (lo, hi) = (lo?, hi?);
    let mut edges = BTreeMap::new();
    let mut errors = Vec::new();
    for (i, raw) in lines.iter().enumerate().take(hi).skip(lo + 1) {
        let l = raw.trim();
        if !l.starts_with('|') {
            continue;
        }
        let cells: Vec<&str> = l
            .trim_matches('|')
            .split('|')
            .map(str::trim)
            .collect();
        if cells.len() < 2 {
            continue;
        }
        let (frm, to) = (cells[0], cells[1]);
        if frm == "From"
            || frm.is_empty()
            || frm.chars().all(|c| "-: ".contains(c))
        {
            continue; // header / separator row
        }
        if !is_state(frm) || !is_state(to) {
            errors.push((i, raw.to_string()));
            continue;
        }
        edges
            .entry((frm.to_string(), to.to_string()))
            .or_insert(i);
    }
    Some((edges, errors))
}

/// `(from, to) -> 1-based line` pairs inside `fn transition_allowed`.
pub fn allowed_edges(toks: &[Tok]) -> BTreeMap<(String, String), usize> {
    let mut edges = BTreeMap::new();
    for (name, lo, hi) in functions(toks) {
        if name != "transition_allowed" {
            continue;
        }
        let mut i = lo;
        while i < hi {
            let frm = ident_at(toks, i + 1).filter(|x| is_state(x));
            let to = ident_at(toks, i + 3).filter(|x| is_state(x));
            if let (Some(frm), Some(to)) = (frm, to) {
                if tok_is(toks, i, Kind::Punct, "(")
                    && tok_is(toks, i + 2, Kind::Punct, ",")
                    && tok_is(toks, i + 4, Kind::Punct, ")")
                {
                    edges
                        .entry((frm.to_string(), to.to_string()))
                        .or_insert(toks[i + 1].line);
                    i += 5;
                    continue;
                }
            }
            i += 1;
        }
    }
    edges
}

/// `(from, to, line)` triples from `retag_tensors(..)` call sites:
/// the first two `TensorState :: X` literals inside the parens.
pub fn retag_pairs(toks: &[Tok]) -> Vec<(String, String, usize)> {
    let mut pairs = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if tok_is(toks, i, Kind::Ident, "retag_tensors")
            && tok_is(toks, i + 1, Kind::Punct, "(")
        {
            let close = match_paren(toks, i + 1);
            let mut states: Vec<(String, usize)> = Vec::new();
            let mut j = i + 2;
            while j < close {
                if tok_is(toks, j, Kind::Ident, "TensorState")
                    && path_sep(toks, j + 1)
                    && at(toks, j + 3).is_some_and(|t| {
                        t.kind == Kind::Ident && is_state(&t.text)
                    })
                {
                    states.push((toks[j + 3].text.clone(), toks[j].line));
                    j += 4;
                    continue;
                }
                j += 1;
            }
            if states.len() >= 2 {
                pairs.push((
                    states[0].0.clone(),
                    states[1].0.clone(),
                    states[0].1,
                ));
            }
            i = close + 1;
            continue;
        }
        i += 1;
    }
    pairs
}

fn mk(file: &str, line: usize, excerpt: String) -> Finding {
    Finding {
        file: file.to_string(),
        line,
        rule: Rule::StateSpec,
        excerpt,
    }
}

/// Diff the declared table against the implementation and the retag
/// call sites.  `files` is the sorted in-memory tree; `doc` is the
/// INVARIANTS.md text if present.
pub fn spec_pass(files: &[(String, String)], doc: Option<&str>) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(doc) = doc else {
        findings.push(mk(SPEC_DOC, 1, "missing docs/INVARIANTS.md".into()));
        return findings;
    };
    let Some((declared, errors)) = parse_table(doc) else {
        findings.push(mk(SPEC_DOC, 1, "missing transition-spec markers".into()));
        return findings;
    };
    let doc_lines: Vec<&str> = doc.split('\n').collect();
    for (idx, raw) in &errors {
        findings.push(mk(SPEC_DOC, idx + 1, excerpt_of(raw)));
    }
    let Some(tensor_src) = files
        .iter()
        .find(|(rel, _)| rel == "tensor/mod.rs")
        .map(|(_, src)| src.as_str())
    else {
        findings.push(mk("tensor/mod.rs", 1, "missing tensor/mod.rs".into()));
        return findings;
    };

    let mut ttoks = lex(tensor_src);
    if let (Some(cut), _) = super::cfg_cutoff(&ttoks) {
        ttoks.retain(|t| t.line < cut);
    }
    let allowed = allowed_edges(&ttoks);
    let tensor_lines: Vec<&str> = tensor_src.split('\n').collect();

    // Implemented-but-undeclared (delete a row from the doc table and
    // this fires at the guard line).
    let mut by_line: Vec<_> = allowed.iter().collect();
    by_line.sort_by_key(|(_, line)| **line);
    for (edge, line) in by_line {
        if !declared.contains_key(edge) {
            let raw = tensor_lines.get(line - 1).copied().unwrap_or("");
            findings.push(mk("tensor/mod.rs", *line, excerpt_of(raw)));
        }
    }
    // Declared-but-absent.
    let mut by_doc: Vec<_> = declared.iter().collect();
    by_doc.sort_by_key(|(_, idx)| **idx);
    for (edge, idx) in by_doc {
        if !allowed.contains_key(edge) {
            let raw = doc_lines.get(*idx).copied().unwrap_or("");
            findings.push(mk(SPEC_DOC, idx + 1, excerpt_of(raw)));
        }
    }
    // Every literal retag site must use a declared edge.
    for (rel, src) in files {
        let mut toks = lex(src);
        if let (Some(cut), _) = super::cfg_cutoff(&toks) {
            toks.retain(|t| t.line < cut);
        }
        let src_lines: Vec<&str> = src.split('\n').collect();
        for (frm, to, line) in retag_pairs(&toks) {
            if !declared.contains_key(&(frm, to)) {
                let raw = src_lines.get(line - 1).copied().unwrap_or("");
                findings.push(Finding {
                    file: rel.clone(),
                    line,
                    rule: Rule::StateSpec,
                    excerpt: excerpt_of(raw),
                });
            }
        }
    }
    findings
}
