//! A zero-dependency Rust token lexer for the lint pass (ISSUE 10).
//!
//! `syn` is not in the offline crate cache, so this is hand-rolled —
//! but unlike the retired masked-line scanner (`super::legacy`,
//! test-only) it
//! produces a real token stream: comments are dropped, string/char
//! literal *contents* can never be mistaken for code, lifetimes are
//! distinguished from char literals, and a multi-line string inside a
//! macro body cannot hide the code on the lines after it.
//!
//! The grammar subset is deliberately small: identifiers (keywords are
//! just identifiers here), lifetimes, numbers, string/char literals
//! (plain, raw `r#"…"#`, byte), and single-char punctuation.  That is
//! enough for every rule and pass in `lint/` — multi-char operators
//! like `::` or `=>` are matched as adjacent punct tokens.
//!
//! Mirrored line-for-line by `scripts/pstar_lint.py` (`lex`) for
//! toolchain-less validation; keep the two in sync.

/// Token kind.  `Str` keeps its content (the spec pass matches the
/// `"pjrt"` feature string); the others keep their text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Lifetime,
    Num,
    Str,
    Char,
    Punct,
}

/// One token with its source position.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
    /// Whether this is the first token on its line (comments and
    /// whitespace do not count) — the token-stream analogue of the
    /// old `trim_start().starts_with(..)` line checks.
    pub first: bool,
}

fn is_id_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_id_cont(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into a token stream.  Never fails: unrecognized bytes
/// become single punct tokens, unterminated literals run to EOF.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let mut line_had_tok = false;

    macro_rules! push {
        ($kind:expr, $text:expr, $line:expr) => {{
            toks.push(Tok {
                kind: $kind,
                text: $text,
                line: $line,
                first: !line_had_tok,
            });
            line_had_tok = true;
        }};
    }

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            line_had_tok = false;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                i += 1;
            }
            continue;
        }
        // Block comment (Rust nests them).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == '\n' {
                        line += 1;
                        line_had_tok = false;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (optionally b-prefixed).
        if c == 'r' || c == 'b' {
            let mut j = i;
            if b[j] == 'b' && j + 1 < n && b[j + 1] == 'r' {
                j += 1;
            }
            if b[j] == 'r' {
                let mut k = j + 1;
                while k < n && b[k] == '#' {
                    k += 1;
                }
                if k < n && b[k] == '"' {
                    let hashes = k - (j + 1);
                    let start_line = line;
                    k += 1;
                    let mut content = String::new();
                    while k < n {
                        if b[k] == '"'
                            && k + hashes < n + 1
                            && (1..=hashes).all(|h| {
                                k + h < n && b[k + h] == '#'
                            })
                        {
                            k += 1 + hashes;
                            break;
                        }
                        if b[k] == '\n' {
                            line += 1;
                            line_had_tok = false;
                        }
                        content.push(b[k]);
                        k += 1;
                    }
                    push!(Kind::Str, content, start_line);
                    i = k;
                    continue;
                }
            }
        }
        // Byte string b"..." — fold into the plain-string case.
        let c = if c == 'b' && i + 1 < n && b[i + 1] == '"' {
            i += 1;
            b[i]
        } else {
            c
        };
        // Plain string literal (escapes, may span lines).
        if c == '"' {
            let start_line = line;
            i += 1;
            let mut content = String::new();
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    content.push(b[i]);
                    content.push(b[i + 1]);
                    if b[i + 1] == '\n' {
                        line += 1;
                        line_had_tok = false;
                    }
                    i += 2;
                    continue;
                }
                if b[i] == '"' {
                    i += 1;
                    break;
                }
                if b[i] == '\n' {
                    line += 1;
                    line_had_tok = false;
                }
                content.push(b[i]);
                i += 1;
            }
            push!(Kind::Str, content, start_line);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // Escaped char: '\n', '\'', '\x41', '\u{1F600}'.
                let mut j = i + 2;
                if j < n && b[j] == 'u' && j + 1 < n && b[j + 1] == '{' {
                    j += 2;
                    while j < n && b[j] != '}' {
                        j += 1;
                    }
                    j += 1;
                } else if j < n && b[j] == 'x' {
                    j += 3;
                } else {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    push!(Kind::Char, b[i..=j].iter().collect(), line);
                    i = j + 1;
                    continue;
                }
            }
            if i + 1 < n && is_id_start(b[i + 1]) {
                // `'a'` is a char, `'a` (no closing quote) a lifetime.
                let mut j = i + 1;
                while j < n && is_id_cont(b[j]) {
                    j += 1;
                }
                if j < n && b[j] == '\'' && j == i + 2 {
                    push!(Kind::Char, b[i..=j].iter().collect(), line);
                    i = j + 1;
                    continue;
                }
                push!(Kind::Lifetime, b[i + 1..j].iter().collect(), line);
                i = j;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' && b[i + 1] != '\'' {
                // Simple non-alphanumeric char literal like '"'.
                push!(Kind::Char, b[i..=i + 2].iter().collect(), line);
                i += 3;
                continue;
            }
            push!(Kind::Punct, "'".to_string(), line);
            i += 1;
            continue;
        }
        // Identifier / keyword.
        if is_id_start(c) {
            let mut j = i;
            while j < n && is_id_cont(b[j]) {
                j += 1;
            }
            push!(Kind::Ident, b[i..j].iter().collect(), line);
            i = j;
            continue;
        }
        // Number (digits plus trailing alphanumerics/underscore/dot —
        // good enough for 0x41, 1_000, 1.5e3, 2f64; `0..n` ranges stop
        // before the second consecutive dot).
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (is_id_cont(b[j]) || b[j] == '.') {
                if b[j] == '.' && j + 1 < n && b[j + 1] == '.' {
                    break;
                }
                j += 1;
            }
            push!(Kind::Num, b[i..j].iter().collect(), line);
            i = j;
            continue;
        }
        push!(Kind::Punct, c.to_string(), line);
        i += 1;
    }
    toks
}

// ------------------------------------------------------- stream helpers

/// `toks[i]`, if in range.
pub fn at(toks: &[Tok], i: usize) -> Option<&Tok> {
    toks.get(i)
}

/// Token at `i` matches `(kind, text)`.
pub fn tok_is(toks: &[Tok], i: usize, kind: Kind, text: &str) -> bool {
    toks.get(i).is_some_and(|t| t.kind == kind && t.text == text)
}

/// Token at `i` is an identifier (any text).
pub fn ident_at(toks: &[Tok], i: usize) -> Option<&str> {
    toks.get(i)
        .filter(|t| t.kind == Kind::Ident)
        .map(|t| t.text.as_str())
}

/// `::` (two adjacent `:` puncts) at `i`.
pub fn path_sep(toks: &[Tok], i: usize) -> bool {
    tok_is(toks, i, Kind::Punct, ":") && tok_is(toks, i + 1, Kind::Punct, ":")
}

/// Index of the `}` matching the `{` at `i` (or `toks.len()`).
pub fn match_brace(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
        }
        j += 1;
    }
    toks.len()
}

/// Index of the `)` matching the `(` at `i` (or `toks.len()`).
pub fn match_paren(toks: &[Tok], i: usize) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct {
            if t.text == "(" {
                depth += 1;
            } else if t.text == ")" {
                depth -= 1;
                if depth == 0 {
                    return j;
                }
            }
        }
        j += 1;
    }
    toks.len()
}

/// Attribute group `# [ ... ]` starting at `i`: index after the `]`.
pub fn skip_attr(toks: &[Tok], i: usize) -> usize {
    if !(tok_is(toks, i, Kind::Punct, "#")
        && tok_is(toks, i + 1, Kind::Punct, "["))
    {
        return i;
    }
    let mut depth = 0i64;
    let mut j = i + 1;
    while j < toks.len() {
        let t = &toks[j];
        if t.kind == Kind::Punct {
            if t.text == "[" {
                depth += 1;
            } else if t.text == "]" {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
        }
        j += 1;
    }
    toks.len()
}

/// `# [ cfg ( test ) ]` at `i`, with the `#` first on its line.
pub fn cfg_test_at(toks: &[Tok], i: usize) -> bool {
    tok_is(toks, i, Kind::Punct, "#")
        && toks[i].first
        && tok_is(toks, i + 1, Kind::Punct, "[")
        && tok_is(toks, i + 2, Kind::Ident, "cfg")
        && tok_is(toks, i + 3, Kind::Punct, "(")
        && tok_is(toks, i + 4, Kind::Ident, "test")
        && tok_is(toks, i + 5, Kind::Punct, ")")
        && tok_is(toks, i + 6, Kind::Punct, "]")
}

/// `# [ cfg ( feature = "pjrt" ) ]` at `i`, `#` first on its line.
pub fn cfg_pjrt_at(toks: &[Tok], i: usize) -> bool {
    tok_is(toks, i, Kind::Punct, "#")
        && toks[i].first
        && tok_is(toks, i + 1, Kind::Punct, "[")
        && tok_is(toks, i + 2, Kind::Ident, "cfg")
        && tok_is(toks, i + 3, Kind::Punct, "(")
        && tok_is(toks, i + 4, Kind::Ident, "feature")
        && tok_is(toks, i + 5, Kind::Punct, "=")
        && at(toks, i + 6)
            .is_some_and(|t| t.kind == Kind::Str && t.text == "pjrt")
        && tok_is(toks, i + 7, Kind::Punct, ")")
        && tok_is(toks, i + 8, Kind::Punct, "]")
}
