//! Configuration: cluster presets, training tasks, system selection.
//!
//! Presets mirror the paper's testbeds (Sec. 9.1): **YARD** (8x V100-32GB,
//! 240 GB DRAM, 12 cores), **SuperPod** (8x A100-40GB, 1 TB DRAM, 192
//! cores), the reduced **YARD-120GB** (Sec. 9.2.5) and the **700$ PC**
//! (RTX 2060 8GB + 16 GB DRAM).  Tasks and overrides can also be loaded
//! from a JSON config file (`examples/configs/*.json`).

use anyhow::{anyhow, bail, Result};

use crate::mem::Interconnect;
use crate::model::{ActivationPlan, GptSpec};
use crate::sim::DeviceProfile;
use crate::util::Json;

pub const GB: u64 = 1 << 30;

/// A physical node configuration.
#[derive(Clone, Copy, Debug)]
pub struct ClusterPreset {
    pub name: &'static str,
    pub n_gpus: u32,
    pub gpu_mem: u64,
    pub cpu_mem: u64,
    pub gpu: DeviceProfile,
    pub cpu: DeviceProfile,
    pub net: Interconnect,
    /// Throughput bar for "max model scale" (paper Sec. 9.2.1: 30 Tflops
    /// on YARD, 50 on SuperPod).
    pub scale_bar_tflops: f64,
}

impl ClusterPreset {
    pub fn yard() -> Self {
        ClusterPreset {
            name: "YARD",
            n_gpus: 8,
            gpu_mem: 32 * GB,
            cpu_mem: 240 * GB,
            gpu: DeviceProfile::v100(),
            cpu: DeviceProfile::cpu_yard(),
            net: Interconnect::v100_node(),
            scale_bar_tflops: 30.0,
        }
    }

    pub fn superpod() -> Self {
        ClusterPreset {
            name: "SuperPod",
            n_gpus: 8,
            gpu_mem: 40 * GB,
            cpu_mem: 1024 * GB,
            gpu: DeviceProfile::a100(),
            cpu: DeviceProfile::cpu_superpod(),
            net: Interconnect::a100_node(),
            scale_bar_tflops: 50.0,
        }
    }

    /// Sec. 9.2.5: YARD with host memory halved to 120 GB.
    pub fn yard_120gb() -> Self {
        ClusterPreset { name: "YARD-120GB", cpu_mem: 120 * GB, ..Self::yard() }
    }

    /// Sec. 9.2.5: the 700$ personal computer.
    pub fn pc() -> Self {
        ClusterPreset {
            name: "PC-700USD",
            n_gpus: 1,
            gpu_mem: 8 * GB,
            cpu_mem: 16 * GB,
            gpu: DeviceProfile::rtx2060(),
            cpu: DeviceProfile::cpu_pc(),
            net: Interconnect::pc(),
            scale_bar_tflops: 5.0,
        }
    }

    /// A deliberately RAM-starved single-V100 box for the NVMe tier
    /// (ISSUE 7): 6 GB of GPU memory plus 6 GB of host DRAM cannot hold
    /// a 1B model's ~14 GB of chunked data, so training only becomes
    /// feasible once `--nvme-gb` grants the third tier — the "infinity"
    /// offload demonstrator used by the `nvme_offload` bench and the
    /// CI `nvme-smoke` cell.
    pub fn nvme_lab() -> Self {
        ClusterPreset {
            name: "NVME-LAB",
            n_gpus: 1,
            gpu_mem: 6 * GB,
            cpu_mem: 6 * GB,
            gpu: DeviceProfile::v100(),
            cpu: DeviceProfile::cpu_yard(),
            net: Interconnect::v100_node(),
            scale_bar_tflops: 30.0,
        }
    }

    pub fn by_name(name: &str) -> Result<ClusterPreset> {
        match name.to_ascii_lowercase().as_str() {
            "yard" => Ok(Self::yard()),
            "superpod" | "spod" => Ok(Self::superpod()),
            "yard120" | "yard-120gb" => Ok(Self::yard_120gb()),
            "pc" => Ok(Self::pc()),
            "nvme-lab" | "nvmelab" => Ok(Self::nvme_lab()),
            other => bail!(
                "unknown cluster '{other}' \
                 (yard|superpod|yard120|pc|nvme-lab)"
            ),
        }
    }
}

/// Which training system to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    PatrickStar,
    /// DeepSpeed ZeRO-Offload/Infinity with ZeRO-DP (static partition).
    DeepSpeedDp,
    /// DeepSpeed + Megatron model parallelism of the given degree.
    DeepSpeedMp(u32),
    /// PyTorch DistributedDataParallel (all model data on GPU).
    PyTorchDdp,
}

impl SystemKind {
    pub fn name(&self) -> String {
        match self {
            SystemKind::PatrickStar => "patrickstar".into(),
            SystemKind::DeepSpeedDp => "deepspeed-dp".into(),
            SystemKind::DeepSpeedMp(d) => format!("deepspeed-mp{d}"),
            SystemKind::PyTorchDdp => "pytorch-ddp".into(),
        }
    }

    pub fn parse(s: &str) -> Result<SystemKind> {
        let s = s.to_ascii_lowercase();
        if s == "patrickstar" || s == "ps" {
            return Ok(SystemKind::PatrickStar);
        }
        if s == "deepspeed" || s == "deepspeed-dp" || s == "deeps" {
            return Ok(SystemKind::DeepSpeedDp);
        }
        if s == "pytorch" || s == "ddp" || s == "pytorch-ddp" {
            return Ok(SystemKind::PyTorchDdp);
        }
        if let Some(d) = s.strip_prefix("deepspeed-mp") {
            return Ok(SystemKind::DeepSpeedMp(d.parse()?));
        }
        bail!("unknown system '{s}'")
    }
}

/// One training task (model x batch x activation plan x parallelism).
#[derive(Clone, Copy, Debug)]
pub struct TrainTask {
    pub model: GptSpec,
    pub batch_per_gpu: u64,
    pub n_gpus: u32,
    pub plan: ActivationPlan,
    /// Chunk size in elements (0 = run the chunk-size search).
    pub chunk_elems: u64,
}

impl TrainTask {
    pub fn new(model: GptSpec, batch: u64, n_gpus: u32) -> Self {
        TrainTask {
            model,
            batch_per_gpu: batch,
            n_gpus,
            plan: ActivationPlan::Checkpointing,
            chunk_elems: 0,
        }
    }

    pub fn with_plan(mut self, plan: ActivationPlan) -> Self {
        self.plan = plan;
        self
    }

    pub fn with_chunk_elems(mut self, c: u64) -> Self {
        self.chunk_elems = c;
        self
    }

    /// Parse from a JSON object:
    /// `{"model": "10B", "batch": 16, "gpus": 8, "plan": "ckpt"}`.
    pub fn from_json(j: &Json) -> Result<TrainTask> {
        let model_name = j
            .req("model")?
            .as_str()
            .ok_or_else(|| anyhow!("model must be a string"))?;
        let model = GptSpec::by_name(model_name)
            .ok_or_else(|| anyhow!("unknown model '{model_name}'"))?;
        let batch = j.req("batch")?.as_usize().unwrap_or(8) as u64;
        let gpus = j.get("gpus").and_then(|g| g.as_usize()).unwrap_or(1) as u32;
        let plan = match j.get("plan").and_then(|p| p.as_str()) {
            None | Some("ckpt") => ActivationPlan::Checkpointing,
            Some("none") => ActivationPlan::None,
            Some("ckpt+offload") | Some("offload") => {
                ActivationPlan::CheckpointingOffload
            }
            Some(other) => bail!("unknown activation plan '{other}'"),
        };
        let chunk = j
            .get("chunk_elems")
            .and_then(|c| c.as_usize())
            .unwrap_or(0) as u64;
        Ok(TrainTask::new(model, batch, gpus)
            .with_plan(plan)
            .with_chunk_elems(chunk))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let y = ClusterPreset::yard();
        assert_eq!(y.n_gpus, 8);
        assert_eq!(y.gpu_mem, 32 * GB);
        assert_eq!(y.cpu_mem, 240 * GB);
        let s = ClusterPreset::superpod();
        assert_eq!(s.gpu_mem, 40 * GB);
        assert_eq!(s.cpu_mem, 1024 * GB);
        assert_eq!(ClusterPreset::yard_120gb().cpu_mem, 120 * GB);
        assert_eq!(ClusterPreset::pc().n_gpus, 1);
    }

    #[test]
    fn system_parse_roundtrip() {
        for s in [
            SystemKind::PatrickStar,
            SystemKind::DeepSpeedDp,
            SystemKind::DeepSpeedMp(4),
            SystemKind::PyTorchDdp,
        ] {
            assert_eq!(SystemKind::parse(&s.name()).unwrap(), s);
        }
        assert!(SystemKind::parse("nonsense").is_err());
    }

    #[test]
    fn task_from_json() {
        let j = Json::parse(
            r#"{"model": "10B", "batch": 16, "gpus": 8,
                "plan": "ckpt+offload"}"#,
        )
        .unwrap();
        let t = TrainTask::from_json(&j).unwrap();
        assert_eq!(t.model.name, "10B");
        assert_eq!(t.batch_per_gpu, 16);
        assert_eq!(t.n_gpus, 8);
        assert_eq!(t.plan, ActivationPlan::CheckpointingOffload);
    }

    #[test]
    fn task_json_missing_model_fails() {
        let j = Json::parse(r#"{"batch": 4}"#).unwrap();
        assert!(TrainTask::from_json(&j).is_err());
    }
}
