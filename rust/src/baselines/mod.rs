//! Baseline system simulators (paper Sec. 9.1): DeepSpeed
//! ZeRO-Offload/Infinity (+ Megatron MP) and PyTorch DDP, on the same
//! calibrated cost model as the PatrickStar engine so comparisons are
//! apples-to-apples.

pub mod deepspeed;
pub mod pytorch;

pub use deepspeed::DeepSpeedSim;
pub use pytorch::PyTorchDdpSim;

use crate::config::{ClusterPreset, SystemKind, TrainTask};
use crate::engine::{Engine, EngineReport, OptimizationPlan};
use anyhow::Result;

/// Run any system on a (cluster, task) pair.
pub fn run_system(
    system: SystemKind,
    cluster: ClusterPreset,
    task: TrainTask,
) -> Result<EngineReport> {
    run_system_with_plan(system, cluster, task, OptimizationPlan::default())
}

/// Like [`run_system`] but threading an [`OptimizationPlan`] into the
/// PatrickStar engine (the third-tier `--nvme-gb` budget in particular).
/// The baselines model fixed published systems, so the plan only applies
/// to `SystemKind::PatrickStar`; other systems run exactly as before.
pub fn run_system_with_plan(
    system: SystemKind,
    cluster: ClusterPreset,
    task: TrainTask,
    plan: OptimizationPlan,
) -> Result<EngineReport> {
    match system {
        SystemKind::PatrickStar => {
            Engine::new(cluster, task).with_opt(plan).run()
        }
        SystemKind::DeepSpeedDp => {
            DeepSpeedSim { cluster, task, mp_degree: 1 }.run()
        }
        SystemKind::DeepSpeedMp(d) => {
            DeepSpeedSim { cluster, task, mp_degree: d }.run()
        }
        SystemKind::PyTorchDdp => PyTorchDdpSim { cluster, task }.run(),
    }
}
