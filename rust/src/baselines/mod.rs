//! Baseline system simulators (paper Sec. 9.1): DeepSpeed
//! ZeRO-Offload/Infinity (+ Megatron MP) and PyTorch DDP, on the same
//! calibrated cost model as the PatrickStar engine so comparisons are
//! apples-to-apples.

pub mod deepspeed;
pub mod pytorch;

pub use deepspeed::DeepSpeedSim;
pub use pytorch::PyTorchDdpSim;

use crate::config::{ClusterPreset, SystemKind, TrainTask};
use crate::engine::{Engine, EngineReport};
use anyhow::Result;

/// Run any system on a (cluster, task) pair.
pub fn run_system(
    system: SystemKind,
    cluster: ClusterPreset,
    task: TrainTask,
) -> Result<EngineReport> {
    match system {
        SystemKind::PatrickStar => Engine::new(cluster, task).run(),
        SystemKind::DeepSpeedDp => {
            DeepSpeedSim { cluster, task, mp_degree: 1 }.run()
        }
        SystemKind::DeepSpeedMp(d) => {
            DeepSpeedSim { cluster, task, mp_degree: d }.run()
        }
        SystemKind::PyTorchDdp => PyTorchDdpSim { cluster, task }.run(),
    }
}
