//! PyTorch DistributedDataParallel baseline (paper Sec. 9.1).
//!
//! All model data stays on the GPU: 18M bytes per parameter (param fp16 +
//! grad fp16 + 12M optimizer states + the fp32 master copy is inside the
//! 12M per Sec. 2) plus non-model data.  Gradients all-reduce with the
//! bucketized ring (2(p-1)/p · 2M wire bytes).

use anyhow::{bail, Result};

use crate::config::{ClusterPreset, TrainTask};
use crate::dp::CollectiveCost;
use crate::engine::{EngineReport, IterBreakdown};
use crate::model::activation::non_model_bytes;
use crate::model::{OpGraph, OpKind};
use crate::placement::PlacementPlan;
use crate::sim::{Phase, SimClock};

pub struct PyTorchDdpSim {
    pub cluster: ClusterPreset,
    pub task: TrainTask,
}

impl PyTorchDdpSim {
    pub fn run(&self) -> Result<EngineReport> {
        let m = &self.task.model;
        let batch = self.task.batch_per_gpu;
        let params = m.n_params();

        let peak_nm = (0..=m.layers)
            .map(|l| non_model_bytes(m, batch, self.task.plan, l))
            .max()
            .unwrap_or(0);
        let gpu_need = 18 * params + peak_nm;
        if gpu_need > self.cluster.gpu_mem {
            bail!(
                "PyTorch OOM: 18M model data + non-model = {} B of {} B GPU",
                gpu_need,
                self.cluster.gpu_mem
            );
        }

        let mut clock = SimClock::new();
        let graph = OpGraph::build(*m, batch);
        let gpu = self.cluster.gpu;
        let bwd_mult = 2.0 + self.task.plan.recompute_factor();
        for op in &graph.ops {
            let kind = if op.kind == OpKind::Embedding {
                OpKind::ComputeIntensive
            } else {
                op.kind
            };
            clock.add(
                Phase::FwdBwd,
                gpu.op_time(kind, (1.0 + bwd_mult) * op.fwd_flops),
            );
        }
        // ADAM on GPU (fast, bandwidth-bound over 18M bytes).
        clock.add(Phase::Adam, gpu.adam_time(18 * params));
        // Grad all-reduce (ring = allgather + reduce-scatter volume),
        // bucketized at 25 MB (DDP default).
        let p = self.task.n_gpus as usize;
        if p > 1 {
            let cc = CollectiveCost::new(self.cluster.net.nvlink, p);
            let bucket = 25u64 << 20;
            let n_buckets = (2 * params).div_ceil(bucket).max(1);
            let per = 2 * params / n_buckets;
            clock.add(
                Phase::ReduceScatter,
                2.0 * cc.allgather_time(per) * n_buckets as f64,
            );
        }

        let breakdown = IterBreakdown::from_clock(&clock);
        let total = breakdown.total();
        Ok(EngineReport {
            system: "pytorch-ddp".into(),
            model: m.name.into(),
            n_gpus: self.task.n_gpus,
            batch_per_gpu: batch,
            chunk_elems: 0,
            breakdown,
            iter_time_s: total,
            tflops_per_gpu: m.iter_flops(batch) / total / 1e12,
            placement: PlacementPlan {
                os_groups_on_gpu: 0,
                spilled_fp16_chunks: 0,
                total_fp16_chunks: 0,
                embedding_on_cpu: false,
            },
            move_stats: Default::default(),
            allgather_bytes: 0,
            reduce_scatter_bytes: 0,
            allgather_bw: 0.0,
            reduce_scatter_bw: 0.0,
            gather_prefetches: 0,
            gather_cancels: 0,
            adaptive_lookahead: false,
            avg_chunk_lookahead: 0.0,
            avg_group_lookahead: 0.0,
            gpu_peak: gpu_need,
            cpu_peak: 0,
            nvme_peak: 0,
            non_model_peak: peak_nm,
            chaos: None,
            rescales: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GptSpec;

    fn sim(model: &str, batch: u64, gpus: u32) -> PyTorchDdpSim {
        PyTorchDdpSim {
            cluster: ClusterPreset::yard(),
            task: TrainTask::new(GptSpec::by_name(model).unwrap(), batch,
                                 gpus),
        }
    }

    #[test]
    fn one_b_fits_and_is_fast() {
        let r = sim("1B", 4, 1).run().unwrap();
        // PyTorch is compute-only: highest tflops of the three systems
        // when it fits (paper Fig. 14: ~60 Tflops on V100 1B).
        assert!(r.tflops_per_gpu > 40.0, "tflops {}", r.tflops_per_gpu);
    }

    #[test]
    fn two_b_ooms_on_v100() {
        // Paper Sec. 2: 2B x 18 bytes = 36 GB > 32 GB.
        assert!(sim("2B", 4, 1).run().is_err());
    }

    #[test]
    fn ddp_adds_allreduce_cost() {
        let r1 = sim("1B", 4, 1).run().unwrap();
        let r8 = sim("1B", 4, 8).run().unwrap();
        assert!(r8.iter_time_s > r1.iter_time_s);
    }
}
