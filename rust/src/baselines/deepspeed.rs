//! DeepSpeed ZeRO-Offload/Infinity baseline (paper Sec. 4, Fig. 3), with
//! optional Megatron-LM model parallelism (deeps-mpX in Figs. 13/15).
//!
//! **DP path (mp = 1)** — the static partition of Fig. 3: param fp16
//! shards (ZeRO-3) + a pinned grad staging buffer on GPU; grad fp16 and
//! all optimizer states on CPU; ADAM on CPU; per iteration 2M bytes of
//! grads stream down and 2M bytes of updated params stream up in
//! *per-tensor* messages (the bandwidth-utilization penalty PatrickStar
//! removes).  ZeRO-DP uses the broadcast-based pattern: 10(p-1)/p·M wire
//! bytes vs PatrickStar's 6(p-1)/p·M.  Host footprint is calibrated to
//! the paper's measurement (Sec. 4: a 4B model whose theoretical state
//! is 72 GB exhausted a 240 GB + 32 GB node): **2.1x theoretical + 80
//! GB** of pinned-buffer/fragmentation overhead.  This reproduces both
//! max-scale cliffs (4B on YARD, 30B on SuperPod).
//!
//! **MP path (mp > 1)** — Megatron shards each layer mp ways.  If the
//! shard's full 18M/mp bytes (x1.25 fragmentation) fit the GPU next to
//! the activations, model data stays resident and ADAM runs on GPU;
//! otherwise the shard's OS offloads to CPU like the DP path.
//! Activation all-reduces (4 per layer) and narrow-GEMM efficiency loss
//! are charged.
//!
//! Failure modes reproduced (paper Fig. 10): (a) param fp16 + peak
//! non-model data exceeding GPU memory crashes, even if CPU is idle;
//! (b) OS exceeding CPU memory crashes, even if GPU margin exists.

use anyhow::{bail, Result};

use crate::config::{ClusterPreset, TrainTask};
use crate::dp::CollectiveCost;
use crate::engine::{EngineReport, IterBreakdown};
use crate::model::activation::non_model_bytes;
use crate::model::{OpGraph, OpKind};
use crate::placement::PlacementPlan;
use crate::sim::{Phase, SimClock};

/// Measured host-footprint calibration (Sec. 4): usage = A*theoretical + B.
const CPU_OVERHEAD_FACTOR: f64 = 2.1;
const CPU_OVERHEAD_FIXED: u64 = 80 * (1 << 30);
/// GPU-resident model-data fragmentation factor for the MP path.
const GPU_FRAG_FACTOR: f64 = 1.25;

pub struct DeepSpeedSim {
    pub cluster: ClusterPreset,
    pub task: TrainTask,
    /// Megatron tensor-parallel degree (1 = pure ZeRO-DP).
    pub mp_degree: u32,
}

impl DeepSpeedSim {
    fn nproc(&self) -> usize {
        self.task.n_gpus as usize
    }

    /// Data-parallel degree: GPUs are split into MP groups.
    fn dp_degree(&self) -> usize {
        (self.task.n_gpus / self.mp_degree.max(1)).max(1) as usize
    }

    pub fn run(&self) -> Result<EngineReport> {
        let m = &self.task.model;
        let mp = self.mp_degree.max(1) as u64;
        if self.task.n_gpus as u64 % mp != 0 {
            bail!("mp degree {mp} does not divide {} GPUs", self.task.n_gpus);
        }
        let params = m.n_params();
        let params_per_gpu = params / mp;
        let dp = self.dp_degree() as u64;
        let batch = self.task.batch_per_gpu;

        let peak_nm = (0..=m.layers)
            .map(|l| non_model_bytes(m, batch, self.task.plan, l))
            .max()
            .unwrap_or(0);

        // ---- feasibility ------------------------------------------------
        // Can the MP shard's whole model data live on GPU?
        let resident_need =
            (18 * params_per_gpu) as f64 * GPU_FRAG_FACTOR + peak_nm as f64;
        let gpu_resident =
            mp > 1 && resident_need <= self.cluster.gpu_mem as f64;

        let (gpu_need, cpu_need) = if gpu_resident {
            (resident_need as u64, 0u64)
        } else {
            // Offload path: fp16 shard (ZeRO-3 slices it dp ways) +
            // pinned grad staging on GPU; grads + OS on CPU.
            let fp16_gpu = 2 * params_per_gpu / dp;
            let gpu_need = fp16_gpu + fp16_gpu / 8 + peak_nm;
            if gpu_need > self.cluster.gpu_mem {
                bail!(
                    "DeepSpeed OOM on GPU: fp16 shard + staging + {} B \
                     non-model = {} B of {} B",
                    peak_nm,
                    gpu_need,
                    self.cluster.gpu_mem
                );
            }
            let theoretical = 14 * params;
            let cpu_need = if mp == 1 {
                (theoretical as f64 * CPU_OVERHEAD_FACTOR) as u64
                    + CPU_OVERHEAD_FIXED
            } else {
                // MP+offload runs a leaner path (no ZeRO-3 prefetch
                // pools); charge theoretical + half the fixed pool.
                theoretical + CPU_OVERHEAD_FIXED / 2
            };
            if cpu_need > self.cluster.cpu_mem {
                bail!(
                    "DeepSpeed OOM on CPU: OS+grads need {} B measured \
                     ({} B theoretical) of {} B",
                    cpu_need,
                    theoretical,
                    self.cluster.cpu_mem
                );
            }
            (gpu_need, cpu_need)
        };

        // ---- time model -------------------------------------------------
        let mut clock = SimClock::new();
        let graph = OpGraph::build(*m, batch);
        let mut gpu = self.cluster.gpu;
        // Megatron's narrow (H/mp) GEMMs underutilize tensor cores;
        // calibrated to the paper's Fig. 13/15 deeps-mp results.
        if mp > 1 {
            gpu.gemm_flops *= 0.9 / (1.0 + 0.06 * (mp as f64).log2());
        }
        let bwd_mult = 2.0 + self.task.plan.recompute_factor();

        // FWD+BWD compute (MP divides GEMM work).
        for op in &graph.ops {
            let flops = (1.0 + bwd_mult) * op.fwd_flops / mp as f64;
            let kind = if op.kind == OpKind::Embedding {
                OpKind::ComputeIntensive
            } else {
                op.kind
            };
            clock.add(Phase::FwdBwd, gpu.op_time(kind, flops));
        }
        // Megatron activation all-reduces: 4 per layer (2 fwd + 2 bwd).
        if mp > 1 {
            let cc =
                CollectiveCost::new(self.cluster.net.nvlink, mp as usize);
            let act = 2 * batch * m.seq * m.hidden;
            let per_ar = 2.0 * cc.allgather_time(act);
            clock.add(Phase::AllGather, per_ar * 4.0 * m.layers as f64);
        }

        let n_tensors = (m.layers as u64 * 12 + 4).max(1);
        let pcie = self.cluster.net.pcie;
        if gpu_resident {
            // ADAM on GPU over the resident shard.
            clock.add(Phase::Adam, gpu.adam_time(16 * params_per_gpu));
            if dp > 1 {
                let cc = CollectiveCost::new(
                    self.cluster.net.nvlink, dp as usize);
                let avg_tensor_bytes = 2 * params_per_gpu / n_tensors;
                clock.add(
                    Phase::ReduceScatter,
                    2.0 * cc.allgather_time(avg_tensor_bytes)
                        * n_tensors as f64,
                );
            }
        } else {
            // Broadcast-based ZeRO-DP collectives at tensor granularity.
            if dp > 1 {
                let cc = CollectiveCost::new(
                    self.cluster.net.nvlink, dp as usize);
                let avg_tensor_bytes = 2 * params_per_gpu / n_tensors;
                clock.add(
                    Phase::AllGather,
                    2.0 * cc.broadcast_time(2 * params_per_gpu,
                                            avg_tensor_bytes),
                );
                clock.add(
                    Phase::ReduceScatter,
                    cc.allgather_time(avg_tensor_bytes) * n_tensors as f64,
                );
            }
            // CPU<->GPU streaming: grads down, params up — per tensor.
            let grad_bytes = 2 * params_per_gpu / dp;
            clock.add(Phase::GpuToCpu,
                      pcie.transfer_time_split(grad_bytes, n_tensors));
            clock.add(Phase::CpuToGpu,
                      pcie.transfer_time_split(grad_bytes, n_tensors));
            // ADAM on CPU over the rank's OS shard; host shared by all.
            let mut cpu = self.cluster.cpu;
            cpu.mem_bw /= self.nproc() as f64;
            let os_bytes = 16 * params_per_gpu / dp;
            clock.add(Phase::Adam, cpu.adam_time(os_bytes));
            clock.add(Phase::AdamMove,
                      cpu.cast_time(2 * params_per_gpu / dp));
        }

        if self.task.plan
            == crate::model::ActivationPlan::CheckpointingOffload
        {
            let bytes = 2 * batch * m.seq * m.hidden;
            clock.add(
                Phase::ActOffload,
                pcie.transfer_time(bytes) * 2.0 * m.layers as f64,
            );
        }

        let breakdown = IterBreakdown::from_clock(&clock);
        let total = breakdown.total();
        // Per-GPU useful flops: MP ranks share one model replica's flops.
        let flops_per_gpu = m.iter_flops(batch) / mp as f64;
        Ok(EngineReport {
            system: if mp > 1 {
                format!("deepspeed-mp{mp}")
            } else {
                "deepspeed-dp".into()
            },
            model: m.name.into(),
            n_gpus: self.task.n_gpus,
            batch_per_gpu: batch,
            chunk_elems: 0,
            breakdown,
            iter_time_s: total,
            tflops_per_gpu: flops_per_gpu / total / 1e12,
            placement: PlacementPlan {
                os_groups_on_gpu: 0,
                spilled_fp16_chunks: 0,
                total_fp16_chunks: 0,
                embedding_on_cpu: false,
            },
            move_stats: Default::default(),
            allgather_bytes: 0,
            reduce_scatter_bytes: 0,
            allgather_bw: 0.0,
            reduce_scatter_bw: 0.0,
            gather_prefetches: 0,
            gather_cancels: 0,
            adaptive_lookahead: false,
            avg_chunk_lookahead: 0.0,
            avg_group_lookahead: 0.0,
            gpu_peak: gpu_need,
            cpu_peak: cpu_need,
            nvme_peak: 0,
            non_model_peak: peak_nm,
            chaos: None,
            rescales: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::GptSpec;

    fn sim(model: &str, batch: u64, gpus: u32, mp: u32) -> DeepSpeedSim {
        DeepSpeedSim {
            cluster: ClusterPreset::yard(),
            task: TrainTask::new(GptSpec::by_name(model).unwrap(), batch,
                                 gpus),
            mp_degree: mp,
        }
    }

    #[test]
    fn small_model_runs() {
        let r = sim("1B", 16, 1, 1).run().unwrap();
        assert!(r.tflops_per_gpu > 10.0 && r.tflops_per_gpu < 70.0,
                "tflops {}", r.tflops_per_gpu);
    }

    #[test]
    fn cpu_limit_enforced() {
        // 6B: measured host footprint 2.1x(14x6e9)+80GB > 240 GB YARD —
        // the paper's "maximum model scale lowered to 4B" cliff (Sec. 4).
        let err = sim("6B", 8, 1, 1).run();
        assert!(err.is_err(), "6B must exceed YARD host memory");
        assert!(sim("4B", 8, 1, 1).run().is_ok(), "4B must fit");
    }

    #[test]
    fn mp_extends_scale() {
        // 8B infeasible at mp1 (host cliff), feasible at mp8 (GPU
        // resident: 18 x 8e9 / 8 x 1.25 = 22.5 GB < 32 GB).
        assert!(sim("8B", 4, 1, 1).run().is_err());
        assert!(sim("8B", 4, 8, 8).run().is_ok());
    }

    #[test]
    fn mp_gpu_limit_enforced() {
        // 15B mp8 needs 42 GB resident > 32 GB, and its offload fallback
        // exceeds the host: infeasible either way on YARD.
        assert!(sim("18B", 4, 8, 8).run().is_err());
    }

    #[test]
    fn patrickstar_faster_than_deepspeed_same_case() {
        // Paper Sec. 9.2.3: PatrickStar superior to DeepSpeed-DP in all
        // YARD cases (1.08-1.47x).
        use crate::engine::Engine;
        let task = TrainTask::new(GptSpec::by_name("1B").unwrap(), 16, 8);
        let ps = Engine::new(ClusterPreset::yard(), task).run().unwrap();
        let ds = sim("1B", 16, 8, 1).run().unwrap();
        assert!(
            ps.tflops_per_gpu > ds.tflops_per_gpu,
            "PatrickStar {} !> DeepSpeed {}",
            ps.tflops_per_gpu,
            ds.tflops_per_gpu
        );
    }

    #[test]
    fn mp_must_divide_gpus() {
        assert!(sim("1B", 8, 8, 3).run().is_err());
    }
}
