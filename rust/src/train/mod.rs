//! Real end-to-end training: the chunk manager orchestrates actual
//! parameter memory while JAX-lowered HLO (with the Pallas kernels
//! inside) executes on the PJRT CPU client.
//!
//! This is the proof that the three layers compose (DESIGN.md §5 E2E):
//! rust owns every byte of model data in chunks, streams them through the
//! same Access/Release protocol the simulator uses, reuses param fp16
//! chunks for gradients (paper Fig. 6), and updates parameters
//! chunk-by-chunk with the Pallas fused-ADAM executable.

pub mod data;
pub mod trainer;

pub use data::SyntheticCorpus;
pub use trainer::{Trainer, TrainerConfig, TrainReport};
