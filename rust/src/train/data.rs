//! Synthetic token corpus (DESIGN.md §1: substitution for the paper's
//! 3 TB private corpus).
//!
//! Zipf-distributed unigrams with an injected first-order structure: with
//! probability `coherence`, token t+1 is a deterministic function of
//! token t.  A language model can drive the loss well below the unigram
//! entropy by learning that structure, so the e2e loss curve is a real
//! learning signal, not noise.

use crate::util::rng::{Rng, ZipfTable};

pub struct SyntheticCorpus {
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    coherence: f64,
    zipf: ZipfTable,
    rng: Rng,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seq: usize, batch: usize, seed: u64) -> Self {
        SyntheticCorpus {
            vocab,
            seq,
            batch,
            coherence: 0.8,
            zipf: ZipfTable::new(vocab, 1.1),
            rng: Rng::new(seed),
        }
    }

    /// The deterministic successor rule learned by the model.
    fn successor(&self, t: usize) -> usize {
        (t.wrapping_mul(31).wrapping_add(7)) % self.vocab
    }

    fn sample_seq(&mut self, out: &mut Vec<i32>) {
        let mut t = self.zipf.sample(&mut self.rng);
        for _ in 0..self.seq {
            out.push(t as i32);
            t = if self.rng.chance(self.coherence) {
                self.successor(t)
            } else {
                self.zipf.sample(&mut self.rng)
            };
        }
    }

    /// One (tokens, targets) batch, both `batch*seq` long; targets are
    /// tokens shifted left with the final position wrapping to itself.
    pub fn next_batch(&mut self) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            self.sample_seq(&mut toks);
        }
        let mut tgts = Vec::with_capacity(toks.len());
        for b in 0..self.batch {
            let row = &toks[b * self.seq..(b + 1) * self.seq];
            tgts.extend_from_slice(&row[1..]);
            tgts.push(row[self.seq - 1]);
        }
        (toks, tgts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shape_and_range() {
        let mut c = SyntheticCorpus::new(128, 16, 4, 0);
        let (t, g) = c.next_batch();
        assert_eq!(t.len(), 64);
        assert_eq!(g.len(), 64);
        assert!(t.iter().all(|&x| (0..128).contains(&x)));
        assert!(g.iter().all(|&x| (0..128).contains(&x)));
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let mut c = SyntheticCorpus::new(64, 8, 2, 1);
        let (t, g) = c.next_batch();
        for b in 0..2 {
            for i in 0..7 {
                assert_eq!(g[b * 8 + i], t[b * 8 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = SyntheticCorpus::new(64, 8, 2, 42);
        let mut b = SyntheticCorpus::new(64, 8, 2, 42);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn coherent_structure_present() {
        // Most transitions follow the successor rule.
        let mut c = SyntheticCorpus::new(256, 64, 8, 7);
        let (t, _) = c.next_batch();
        let mut hits = 0;
        let mut total = 0;
        for b in 0..8 {
            for i in 0..63 {
                let cur = t[b * 64 + i] as usize;
                let nxt = t[b * 64 + i + 1] as usize;
                total += 1;
                if nxt == c.successor(cur) {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.6, "coherence {frac}");
    }
}
