//! The chunk-managed trainer over the PJRT runtime.
//!
//! Model data layout exactly follows the paper: four chunk lists (param
//! fp16 / param fp32 / momentum / variance) built from the manifest's
//! parameter order; gradients reuse the param fp16 chunks (Fig. 6);
//! embeddings live in dedicated CPU buffers (Sec. 8.2) updated with the
//! same Pallas ADAM executable.
//!
//! "GPU" here is a capacity-accounted pool (DESIGN.md §1): chunks must be
//! resident in it to feed the executable, evictions really happen (LRU)
//! and are really counted — the orchestration path is identical to a
//! CUDA deployment; only the arithmetic runs on the host through PJRT.
//!
//! Since ISSUE 5 the trainer drives the same backend-agnostic
//! [`TrainingSession`] the simulator uses, over a [`PjrtBackend`] that
//! records *measured* wall time per phase.  The session contributes the
//! policy the e2e path used to lack:
//!
//! * the **pinned staging pool** (`TrainerConfig::pinned_buffers`) — a
//!   staged chunk holds one buffer until its access consumes it, so the
//!   prefetch walk throttles to real staging capacity exactly as the
//!   simulator's does (`MoveStats::pinned_waits` counts the throttles);
//! * the **adaptive lookahead controller**
//!   (`TrainerConfig::adaptive_lookahead`) — the window is sized each
//!   access from the measured compute/copy ratio, with
//!   `prefetch_lookahead` acting as the cap, mirroring `--lookahead
//!   auto` in the simulator.  The chunk schedule is static here (the
//!   parameter order *is* the trace), so only the window depth adapts.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::chunk::{ChunkId, ChunkKind, ChunkManager, ChunkRegistry,
                   TensorSpec};
use crate::engine::{EvictKind, ExecutionBackend, IterBreakdown,
                    OptimizationPlan, PjrtBackend, StageOutcome,
                    TrainingSession};
use crate::mem::{Device, HeterogeneousSpace};
use crate::runtime::xla;
use crate::runtime::{lit_f32, lit_f32_shaped, lit_i32_shaped, scalar_f32,
                     to_f32, PjrtRuntime};
use crate::sim::{CopyDir, Phase};
use crate::tensor::TensorState;
use crate::train::data::SyntheticCorpus;
use crate::util::rng::Rng;

/// ADAM + memory-budget configuration for the e2e run.
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub artifacts_dir: String,
    /// Simulated GPU chunk capacity in bytes (small by default so chunk
    /// eviction actually happens on the e2e path).
    pub gpu_bytes: u64,
    pub cpu_bytes: u64,
    pub lr: f32,
    pub weight_decay: f32,
    pub seed: u64,
    /// Stage chunks up to `prefetch_lookahead` tensors ahead into the
    /// GPU pool while the current chunk streams through (0 = off).  The
    /// e2e analogue of the simulator's warm-up-guided prefetch: chunk
    /// order is static here, so the "trace" is the parameter order
    /// itself.  With `adaptive_lookahead` this becomes the *cap* the
    /// feedback-sized window never exceeds.
    pub prefetch_lookahead: usize,
    /// Size of the pinned staging pool the prefetch walk competes for
    /// (0 = unbounded staging, the pre-session behaviour).  Each staged
    /// chunk holds one buffer until consumed.
    pub pinned_buffers: u32,
    /// Size the prefetch window from the measured compute/transfer
    /// ratio (the simulator's `--lookahead auto`, fed by real per-step
    /// timings) instead of the static `prefetch_lookahead` count.
    pub adaptive_lookahead: bool,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig {
            artifacts_dir: "artifacts".into(),
            gpu_bytes: 6 << 20,
            cpu_bytes: 2 << 30,
            lr: 1e-3,
            weight_decay: 0.01,
            seed: 0,
            prefetch_lookahead: 0,
            pinned_buffers: 0,
            adaptive_lookahead: false,
        }
    }
}

/// Per-run telemetry.
#[derive(Clone, Debug, Default)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub step_secs: Vec<f64>,
    pub evictions: u64,
    pub cpu_to_gpu_bytes: u64,
    pub gpu_to_cpu_bytes: u64,
    pub prefetches: u64,
    /// Prefetch issues deferred because the staging pool was dry.
    pub pinned_waits: u64,
    /// Mean per-access staging window actually used (the static count,
    /// or the controller's feedback-sized window in adaptive mode).
    pub avg_prefetch_window: f64,
    /// Per-step phase breakdown (ISSUE 6 satellite): the measured
    /// backend's timeline accumulates across the run, so each entry is
    /// the before/after delta of one step
    /// ([`IterBreakdown::delta_since`]).
    pub step_breakdowns: Vec<IterBreakdown>,
}

/// Embedding parameter state (CPU-pinned, unmanaged by chunks).
struct EmbState {
    /// Kept for debugging/telemetry parity with the chunked tensors.
    #[allow(dead_code)]
    name: String,
    p32: Vec<f32>,
    m: Vec<f32>,
    v: Vec<f32>,
    grad: Vec<f32>,
    #[allow(dead_code)]
    shape: Vec<usize>,
}

pub struct Trainer {
    pub rt: PjrtRuntime,
    /// The shared orchestration core (chunk manager + staging pool +
    /// adaptive controller) over the measured-time backend.
    pub session: TrainingSession<PjrtBackend>,
    emb: Vec<EmbState>,
    /// manifest param index -> Some(non-embedding ordinal) or None (emb).
    param_map: Vec<Option<usize>>,
    step_count: u64,
    cfg: TrainerConfig,
}

impl Trainer {
    pub fn new(cfg: TrainerConfig) -> Result<Self> {
        let rt = PjrtRuntime::load(Path::new(&cfg.artifacts_dir))
            .context("loading artifacts")?;
        let man = rt.manifest.clone();

        // Chunk layout from the manifest (the python side guarantees
        // chunk_elems fits the largest non-embedding tensor).
        let specs: Vec<TensorSpec> = man
            .params
            .iter()
            .map(|p| TensorSpec {
                name: p.name.clone(),
                numel: p.numel as u64,
                embedding: p.embedding,
            })
            .collect();
        let reg = ChunkRegistry::build(&specs, man.chunk_elems as u64)?;
        let space = HeterogeneousSpace::new(cfg.gpu_bytes, cfg.cpu_bytes);
        let mut mgr = ChunkManager::new(reg, space).with_real_payloads();

        // Parameter initialization (GPT-2 style), chunk-resident on CPU.
        let mut rng = Rng::new(cfg.seed ^ 0x9ead);
        let mut param_map = Vec::with_capacity(man.params.len());
        let mut emb = Vec::new();
        let mut ordinal = 0usize;
        for p in &man.params {
            if p.embedding {
                let mut p32 = vec![0.0f32; p.numel];
                for x in &mut p32 {
                    *x = rng.normal_f32(0.02);
                }
                emb.push(EmbState {
                    name: p.name.clone(),
                    m: vec![0.0; p.numel],
                    v: vec![0.0; p.numel],
                    grad: vec![0.0; p.numel],
                    p32,
                    shape: p.shape.clone(),
                });
                param_map.push(None);
            } else {
                param_map.push(Some(ordinal));
                ordinal += 1;
            }
        }
        let n_model = ordinal;

        // Materialize all four lists on CPU and fill initial values.
        let residual_scale = 0.02 / (2.0 * man.layers as f32).sqrt();
        for kind in [ChunkKind::ParamFp16, ChunkKind::ParamFp32,
                     ChunkKind::Momentum, ChunkKind::Variance] {
            for id in mgr.reg.list(kind) {
                mgr.alloc_payload(id, Device::Cpu)?;
            }
        }
        for i in 0..n_model {
            let info = mgr.reg.tensor(ChunkKind::ParamFp32, i).clone();
            let chunk_id =
                crate::chunk::ChunkId(info.chunk as u32);
            let name = &info.name;
            let init: Box<dyn Fn(&mut Rng) -> f32> =
                if name.ends_with(".g") {
                    Box::new(|_| 1.0)
                } else if name.ends_with(".b")
                    || name.ends_with(".bqkv")
                    || name.ends_with(".bi")
                    || name.ends_with(".bo")
                {
                    Box::new(|_| 0.0)
                } else if name.ends_with("attn.wo")
                    || name.ends_with("mlp.wo")
                {
                    Box::new(move |r| r.normal_f32(residual_scale))
                } else {
                    Box::new(|r| r.normal_f32(0.02))
                };
            let (off, n) = (info.offset as usize, info.numel as usize);
            let buf = mgr
                .payload_mut(chunk_id)
                .ok_or_else(|| anyhow!("missing payload"))?;
            for x in &mut buf[off..off + n] {
                *x = init(&mut rng);
            }
            // fp32 master initialized -> HOLD.
            let ti = mgr.reg.tensor_index(ChunkKind::ParamFp32, i);
            mgr.reg.tensors[ti]
                .set_state(TensorState::Hold)
                .map_err(|e| anyhow!(e))?;
        }
        // Copy fp32 master -> fp16 working copy (same f32 storage; the
        // fp16-ness is accounting-only, DESIGN.md §1).
        for pos in 0..mgr.reg.list(ChunkKind::ParamFp16).len() {
            let p16 = mgr.reg.list(ChunkKind::ParamFp16)[pos];
            let p32 = mgr.reg.os_chunks_for(p16)[0];
            let src = mgr.payload(p32).unwrap().to_vec();
            mgr.payload_mut(p16).unwrap().copy_from_slice(&src);
        }
        for i in 0..n_model {
            for kind in [ChunkKind::ParamFp16, ChunkKind::Momentum,
                         ChunkKind::Variance] {
                let ti = mgr.reg.tensor_index(kind, i);
                mgr.reg.tensors[ti]
                    .set_state(TensorState::Hold)
                    .map_err(|e| anyhow!(e))?;
            }
        }

        // The e2e orchestration plan: LRU eviction (no tracer on the
        // real path), the prefetch cap, the staging pool, and the
        // adaptive controller when asked for.
        let opt = OptimizationPlan {
            eviction: EvictKind::Lru,
            lookahead: cfg.prefetch_lookahead as u32,
            pinned_buffers: cfg.pinned_buffers,
            adaptive_lookahead: cfg.adaptive_lookahead,
            ..Default::default()
        };
        let session =
            TrainingSession::new_real(opt, mgr, PjrtBackend::new());

        Ok(Trainer {
            rt,
            session,
            emb,
            param_map,
            step_count: 0,
            cfg,
        })
    }

    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.rt.manifest
    }

    /// The chunk manager (telemetry, payload inspection).
    pub fn mgr(&self) -> &ChunkManager {
        &self.session.mgr
    }

    pub fn corpus(&self, seed: u64) -> SyntheticCorpus {
        let m = self.manifest();
        SyntheticCorpus::new(m.vocab, m.seq, m.batch, seed)
    }

    // ------------------------------------------------------------ helpers

    /// Stage the chunks owning the next window of non-embedding tensors
    /// into the GPU pool (best-effort; the in-flight mark keeps a
    /// staged chunk safe from the LRU until its access consumes it).
    /// The window is the session's: static `prefetch_lookahead`, or the
    /// controller's measured-ratio window bounded by the free staging
    /// buffers.  Free pool space only — staging never evicts, so a
    /// tight pool simply stages nothing rather than thrashing the
    /// chunks the next few accesses are about to need.
    fn prefetch_ahead(&mut self, i: usize) -> Result<()> {
        if self.cfg.prefetch_lookahead == 0 {
            return Ok(());
        }
        let window = self.session.real_window() as usize;
        let limit =
            self.session.mgr.space.dev(Device::Gpu(0)).capacity;
        for d in 1..=window {
            let ahead = i + d;
            if ahead >= self.session.mgr.reg.n_model_tensors {
                break;
            }
            let info =
                self.session.mgr.reg.tensor(ChunkKind::ParamFp16, ahead);
            let chunk = ChunkId(info.chunk as u32);
            match self.session.stage_real(chunk, Device::Gpu(0), limit)? {
                StageOutcome::PoolDry => break,
                StageOutcome::Staged | StageOutcome::Skipped => {}
            }
        }
        Ok(())
    }

    /// Gather the flat parameter literal list (tokens first) for
    /// train_step / eval_loss.  Each fp16 chunk is fetched to the GPU
    /// pool through Algorithm 1, its tensor payload copied out to the
    /// executable's argument literal, then released to HOLD_AFTER_FWD so
    /// the chunk may be evicted while later chunks stream through — the
    /// paper's per-operator streaming, compressed around a monolithic
    /// AOT step function.  Fetch time is measured into the backend's
    /// H2D lane (the controller's transfer-rate signal).
    fn param_literals(&mut self) -> Result<Vec<xla::Literal>> {
        let man = self.rt.manifest.clone();
        let mut lits = Vec::with_capacity(man.params.len());
        let mut ei = 0usize;
        for (pi, p) in man.params.iter().enumerate() {
            match self.param_map[pi] {
                None => {
                    // Embedding: CPU-pinned buffer, no chunk traffic.
                    lits.push(lit_f32_shaped(&self.emb[ei].p32, &p.shape)?);
                    ei += 1;
                }
                Some(i) => {
                    self.prefetch_ahead(i)?;
                    let t0 = Instant::now();
                    self.session.access_real(
                        ChunkKind::ParamFp16, i, Device::Gpu(0))?;
                    let info = self
                        .session
                        .mgr
                        .reg
                        .tensor(ChunkKind::ParamFp16, i);
                    let (chunk, off, n) = (
                        crate::chunk::ChunkId(info.chunk as u32),
                        info.offset as usize,
                        info.numel as usize,
                    );
                    let buf = self
                        .session
                        .mgr
                        .payload(chunk)
                        .ok_or_else(|| anyhow!("no payload"))?;
                    lits.push(lit_f32_shaped(&buf[off..off + n], &p.shape)?);
                    self.session.mgr.release_tensor(
                        ChunkKind::ParamFp16, i, TensorState::HoldAfterFwd,
                    )?;
                    self.session.backend.demand_copy(
                        Phase::CpuToGpu,
                        t0.elapsed().as_secs_f64(),
                        CopyDir::H2D,
                        0.0,
                    );
                }
            }
        }
        Ok(lits)
    }

    /// One full training step: fwd+bwd via `train_step`, grads written
    /// into the param fp16 chunks, chunk-wise Pallas ADAM, fp32->fp16
    /// writeback.  Returns the loss.
    ///
    /// Set PS_TRACE=1 for a per-phase wall-time trace (perf pass,
    /// EXPERIMENTS.md §Perf).
    pub fn step(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let trace = std::env::var_os("PS_TRACE").is_some();
        let mut mark = std::time::Instant::now();
        let mut lap = |label: &str| {
            if trace {
                eprintln!("  [trace] {label}: {:.3}s",
                          mark.elapsed().as_secs_f64());
            }
            mark = std::time::Instant::now();
        };
        let man = self.rt.manifest.clone();
        let (b, s) = (man.batch, man.seq);
        if tokens.len() != b * s || targets.len() != b * s {
            bail!("batch shape mismatch: {} != {}", tokens.len(), b * s);
        }

        // ---- FWD+BWD --------------------------------------------------
        let mut args = vec![
            lit_i32_shaped(tokens, &[b, s])?,
            lit_i32_shaped(targets, &[b, s])?,
        ];
        args.extend(self.param_literals()?);
        lap("param literals");
        let t0 = Instant::now();
        let out = self.rt.run("train_step", &args)?;
        self.session.backend.execute_moment(
            Phase::FwdBwd, t0.elapsed().as_secs_f64());
        lap("train_step exec");
        if out.len() != 1 + man.params.len() {
            bail!("train_step returned {} values", out.len());
        }
        let loss = scalar_f32(&out[0])?;

        // ---- write grads: embeddings to their buffers, the rest into
        // the param fp16 chunks (grad reuses param chunk, Fig. 6).  Each
        // tensor is re-accessed (HOLD_AFTER_FWD -> COMPUTE, the BWD leg
        // of Fig. 7), the grad lands over the parameter payload, and the
        // tensor settles in HOLD_AFTER_BWD — chunks stream through the
        // GPU pool one group at a time.
        let mut ei = 0usize;
        for (pi, _p) in man.params.iter().enumerate() {
            let g = to_f32(&out[1 + pi])?;
            match self.param_map[pi] {
                None => {
                    self.emb[ei].grad.copy_from_slice(&g);
                    ei += 1;
                }
                Some(i) => {
                    self.prefetch_ahead(i)?;
                    let t0 = Instant::now();
                    self.session.access_real(
                        ChunkKind::ParamFp16, i, Device::Gpu(0))?;
                    let info = self
                        .session
                        .mgr
                        .reg
                        .tensor(ChunkKind::ParamFp16, i);
                    let (chunk, off, n) = (
                        crate::chunk::ChunkId(info.chunk as u32),
                        info.offset as usize,
                        info.numel as usize,
                    );
                    let buf = self
                        .session
                        .mgr
                        .payload_mut(chunk)
                        .ok_or_else(|| anyhow!("no payload"))?;
                    buf[off..off + n].copy_from_slice(&g);
                    self.session.mgr.release_tensor(
                        ChunkKind::ParamFp16, i, TensorState::HoldAfterBwd,
                    )?;
                    self.session.backend.demand_copy(
                        Phase::CpuToGpu,
                        t0.elapsed().as_secs_f64(),
                        CopyDir::H2D,
                        0.0,
                    );
                }
            }
        }

        lap("grad writeback");

        // ---- chunk-wise ADAM (Pallas kernel) ---------------------------
        self.step_count += 1;
        let hp = self.make_hp();
        let chunk_elems = man.chunk_elems;
        let fp16_list = self.session.mgr.reg.list(ChunkKind::ParamFp16);
        for p16 in fp16_list {
            let [p32, mom, var] = self.session.mgr.reg.os_chunks_for(p16);
            // ADAM runs on CPU: bring the grad chunk home (Sec. 8.2 OSC
            // default; the margin optimization lives in the simulator).
            // The D2H leg is measured into the backend's copy lane.
            let t0 = Instant::now();
            self.session.ensure_real(p16, Device::Cpu)?;
            self.session.backend.demand_copy(
                Phase::AdamMove,
                t0.elapsed().as_secs_f64(),
                CopyDir::D2H,
                0.0,
            );
            let getv = |mgrr: &ChunkManager, id| -> Result<Vec<f32>> {
                Ok(mgrr
                    .payload(id)
                    .ok_or_else(|| anyhow!("payload missing"))?
                    .to_vec())
            };
            let (pv, mv, vv, gv) = (
                getv(&self.session.mgr, p32)?,
                getv(&self.session.mgr, mom)?,
                getv(&self.session.mgr, var)?,
                getv(&self.session.mgr, p16)?,
            );
            debug_assert_eq!(pv.len(), chunk_elems);
            let t0 = Instant::now();
            let out = self.rt.run(
                "adam_step",
                &[lit_f32(&hp), lit_f32(&pv), lit_f32(&mv), lit_f32(&vv),
                  lit_f32(&gv)],
            )?;
            self.session.backend.execute_moment(
                Phase::Adam, t0.elapsed().as_secs_f64());
            if out.len() != 3 {
                bail!("adam_step returned {} values", out.len());
            }
            let (np, nm, nv) =
                (to_f32(&out[0])?, to_f32(&out[1])?, to_f32(&out[2])?);
            self.session.mgr.payload_mut(p32).unwrap()
                .copy_from_slice(&np);
            self.session.mgr.payload_mut(mom).unwrap()
                .copy_from_slice(&nm);
            self.session.mgr.payload_mut(var).unwrap()
                .copy_from_slice(&nv);
            // fp32 master -> fp16 working copy for the next iteration.
            self.session.mgr.payload_mut(p16).unwrap()
                .copy_from_slice(&np);
            // Grad consumed; params back to HOLD.
            let tensors = self.session.mgr.chunk(p16).tensors.clone();
            for t in tensors {
                let i = t.0 as usize
                    % self.session.mgr.reg.n_model_tensors;
                let ti = self
                    .session
                    .mgr
                    .reg
                    .tensor_index(ChunkKind::ParamFp16, i);
                if self.session.mgr.reg.tensors[ti].state
                    == TensorState::HoldAfterBwd
                {
                    self.session.mgr.reg.tensors[ti]
                        .set_state(TensorState::Hold)
                        .map_err(|e| anyhow!(e))?;
                }
            }
        }

        lap("chunk adam");

        // ---- embedding ADAM over padded chunk-size slices --------------
        let t0 = Instant::now();
        for e in 0..self.emb.len() {
            self.adam_embedding(e, &hp, chunk_elems)?;
        }
        self.session.backend.execute_moment(
            Phase::Adam, t0.elapsed().as_secs_f64());
        lap("embedding adam");
        self.session.mgr.drain_events();
        Ok(loss)
    }

    fn make_hp(&self) -> Vec<f32> {
        let mut hp = vec![0.0f32; self.rt.manifest.adam_hp_len];
        hp[0] = self.cfg.lr;
        hp[1] = 0.9;
        hp[2] = 0.999;
        hp[3] = 1e-8;
        hp[4] = self.cfg.weight_decay;
        hp[5] = self.step_count as f32;
        hp
    }

    fn adam_embedding(
        &mut self,
        e: usize,
        hp: &[f32],
        chunk_elems: usize,
    ) -> Result<()> {
        let n = self.emb[e].p32.len();
        let padded = n.div_ceil(chunk_elems) * chunk_elems;
        let slab = |src: &[f32]| {
            let mut v = src.to_vec();
            v.resize(padded, 0.0);
            v
        };
        let (p, m, v, g) = (
            slab(&self.emb[e].p32),
            slab(&self.emb[e].m),
            slab(&self.emb[e].v),
            slab(&self.emb[e].grad),
        );
        for c in 0..(padded / chunk_elems) {
            let r = c * chunk_elems..(c + 1) * chunk_elems;
            let out = self.rt.run(
                "adam_step",
                &[lit_f32(hp), lit_f32(&p[r.clone()]), lit_f32(&m[r.clone()]),
                  lit_f32(&v[r.clone()]), lit_f32(&g[r.clone()])],
            )?;
            let (np, nm, nv) =
                (to_f32(&out[0])?, to_f32(&out[1])?, to_f32(&out[2])?);
            let hi = ((c + 1) * chunk_elems).min(n);
            if c * chunk_elems < n {
                let w = hi - c * chunk_elems;
                self.emb[e].p32[c * chunk_elems..hi]
                    .copy_from_slice(&np[..w]);
                self.emb[e].m[c * chunk_elems..hi].copy_from_slice(&nm[..w]);
                self.emb[e].v[c * chunk_elems..hi].copy_from_slice(&nv[..w]);
            }
        }
        Ok(())
    }

    /// Held-out loss with the current parameters (no grads, no update).
    pub fn eval(&mut self, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let man = self.rt.manifest.clone();
        let (b, s) = (man.batch, man.seq);
        let mut args = vec![
            lit_i32_shaped(tokens, &[b, s])?,
            lit_i32_shaped(targets, &[b, s])?,
        ];
        args.extend(self.param_literals()?);
        let out = self.rt.run("eval_loss", &args)?;
        // param_literals left everything HOLD_AFTER_FWD; reset to HOLD
        // (the paper's end-of-FWD reset).
        self.session.mgr.reset_after_fwd(ChunkKind::ParamFp16)?;
        scalar_f32(&out[0])
    }

    /// Run `steps` steps over a fresh corpus; returns the loss curve.
    pub fn train(&mut self, steps: usize, log_every: usize)
        -> Result<TrainReport> {
        let mut corpus = self.corpus(self.cfg.seed);
        let mut report = TrainReport::default();
        for step in 0..steps {
            let (toks, tgts) = corpus.next_batch();
            // The backend's timeline accumulates across steps; snapshot
            // it around the step so the report carries a true per-step
            // phase breakdown.
            let before = self.session.backend.breakdown();
            let t0 = std::time::Instant::now();
            let loss = self.step(&toks, &tgts)?;
            report.step_secs.push(t0.elapsed().as_secs_f64());
            report
                .step_breakdowns
                .push(self.session.backend.breakdown()
                          .delta_since(&before));
            report.losses.push(loss);
            if log_every > 0 && step % log_every == 0 {
                eprintln!(
                    "step {step:4}  loss {loss:.4}  ({:.2}s)",
                    report.step_secs.last().unwrap()
                );
            }
        }
        report.evictions = self.session.mgr.stats.evictions;
        report.cpu_to_gpu_bytes = self.session.mgr.stats.cpu_to_gpu_bytes;
        report.gpu_to_cpu_bytes = self.session.mgr.stats.gpu_to_cpu_bytes;
        report.prefetches = self.session.mgr.stats.prefetches;
        report.pinned_waits = self.session.mgr.stats.pinned_waits;
        report.avg_prefetch_window = self.session.avg_window();
        Ok(report)
    }
}
