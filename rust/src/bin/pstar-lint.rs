//! `cargo run --bin pstar-lint` — the determinism & layering
//! static-analysis pass over `src/` (ISSUE 8/10).  Prints
//! `file:line: [rule] message` diagnostics and exits nonzero on any
//! finding, so CI can gate on it directly; `--json` emits the
//! machine-readable report CI archives as an artifact and diffs
//! against the Python port (`scripts/pstar_lint.py --json`).  The
//! same pass also runs under plain `cargo test` via
//! `tests/lint_clean.rs`; see `rust/docs/INVARIANTS.md` for the rules.

use std::path::Path;
use std::process::ExitCode;

use patrickstar::lint::{lint_tree, Rule};

fn main() -> ExitCode {
    let as_json = std::env::args().any(|a| a == "--json");
    // Lint the crate we were built from: src/ next to Cargo.toml.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = match lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("pstar-lint: cannot walk {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    if as_json {
        println!("{}", report.to_json());
        return if report.findings.is_empty() {
            ExitCode::SUCCESS
        } else {
            ExitCode::FAILURE
        };
    }
    if report.findings.is_empty() {
        println!(
            "pstar-lint: {} files clean ({})",
            report.files,
            Rule::ALL
                .iter()
                .map(|r| r.name())
                .collect::<Vec<_>>()
                .join(", "),
        );
        return ExitCode::SUCCESS;
    }
    for f in &report.findings {
        println!("{f}");
    }
    eprintln!(
        "pstar-lint: {} finding(s) in {} files scanned; waive a line \
         with `// lint:allow(<rule>): <reason>` only with a reviewed \
         justification",
        report.findings.len(),
        report.files,
    );
    ExitCode::FAILURE
}
