//! Stateful tensors (paper Sec. 6.2, Table 1, Fig. 7).
//!
//! Every model-data tensor carries a `TensorState`; a chunk's mobility is
//! derived from the states of its tensors.  `ps_attr` in the paper's
//! PyTorch implementation is `TensorInfo` here, owned by the
//! `ChunkRegistry` rather than hung off a framework tensor.

use thiserror::Error;

/// Dense id for a model-data tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub u32);

/// Paper Table 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorState {
    /// No payload space.
    Free,
    /// Participating in computing on a specific device.
    Compute,
    /// Payload must be kept, anywhere in heterogeneous memory.
    Hold,
    /// Hold, produced by a FWD release (distinguished so activation
    /// checkpointing's FWD-inside-BWD cannot be confused with first FWD).
    HoldAfterFwd,
    /// Hold, produced by a BWD release (gates reduce-scatter readiness).
    HoldAfterBwd,
}

impl TensorState {
    /// Any of the three HOLD-like states (paper: "HOLD-like").
    pub fn is_hold_like(&self) -> bool {
        matches!(
            self,
            TensorState::Hold
                | TensorState::HoldAfterFwd
                | TensorState::HoldAfterBwd
        )
    }
}

#[derive(Error, Debug, PartialEq)]
#[error("invalid tensor state transition {from:?} -> {to:?} for tensor {id:?}")]
pub struct BadTransition {
    pub id: TensorId,
    pub from: TensorState,
    pub to: TensorState,
}

/// The legal edges of the paper's Fig. 7 state diagram (param fp16), plus
/// the OS-tensor edges used by the ADAM stage (Sec. 6.2).
pub fn transition_allowed(from: TensorState, to: TensorState) -> bool {
    use TensorState::*;
    matches!(
        (from, to),
        // initialization / zero-init access
        (Free, Hold) | (Free, Compute)
            // operator access
            | (Hold, Compute) | (HoldAfterFwd, Compute) | (HoldAfterBwd, Compute)
            // operator release
            | (Compute, HoldAfterFwd) | (Compute, HoldAfterBwd) | (Compute, Hold)
            // end-of-FWD reset / end-of-ADAM reset
            | (HoldAfterFwd, Hold) | (HoldAfterBwd, Hold)
            // remote-chunk release / chunk reuse
            | (HoldAfterFwd, Free) | (HoldAfterBwd, Free) | (Hold, Free)
    )
}

/// Per-tensor bookkeeping (the paper's `ps_attr`).
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    pub numel: u64,
    /// Index of the owning chunk in the registry.
    pub chunk: usize,
    /// Element offset of this tensor inside the chunk.
    pub offset: u64,
    pub state: TensorState,
    /// Parameters may be shared by multiple operators; a tensor is only
    /// releasable when its access refcount drains (paper Sec. 6.2).
    pub ref_count: u32,
}

impl TensorInfo {
    /// Validated state transition; returns the previous state.
    pub fn set_state(
        &mut self,
        to: TensorState,
    ) -> Result<TensorState, BadTransition> {
        let from = self.state;
        if from == to {
            return Ok(from);
        }
        if !transition_allowed(from, to) {
            return Err(BadTransition { id: self.id, from, to });
        }
        self.state = to;
        Ok(from)
    }
}

#[cfg(test)]
mod tests {
    use super::TensorState::*;
    use super::*;

    fn info() -> TensorInfo {
        TensorInfo {
            id: TensorId(0),
            name: "t".into(),
            numel: 4,
            chunk: 0,
            offset: 0,
            state: Free,
            ref_count: 0,
        }
    }

    #[test]
    fn fig7_happy_path() {
        // init -> FWD access -> FWD release -> reset -> BWD access ->
        // BWD release -> post-reduce free.
        let mut t = info();
        for s in [Hold, Compute, HoldAfterFwd, Hold, Compute, HoldAfterBwd,
                  Free] {
            t.set_state(s).unwrap();
        }
    }

    #[test]
    fn checkpoint_recompute_path() {
        // During BWD, activation checkpointing re-runs FWD between two
        // checkpoints: HOLD_AFTER_FWD must be directly accessible.
        let mut t = info();
        t.set_state(Hold).unwrap();
        t.set_state(Compute).unwrap();
        t.set_state(HoldAfterFwd).unwrap();
        t.set_state(Compute).unwrap(); // recompute FWD inside BWD
        t.set_state(HoldAfterBwd).unwrap();
    }

    #[test]
    fn illegal_edges_rejected() {
        let mut t = info();
        t.set_state(Hold).unwrap();
        // HOLD cannot jump to HOLD_AFTER_BWD without computing.
        assert!(t.set_state(HoldAfterBwd).is_err());
        // FREE cannot go straight to HOLD_AFTER_FWD.
        let mut t2 = info();
        assert!(t2.set_state(HoldAfterFwd).is_err());
    }

    #[test]
    fn self_transition_is_noop() {
        let mut t = info();
        assert_eq!(t.set_state(Free).unwrap(), Free);
    }

    #[test]
    fn hold_like_classification() {
        assert!(Hold.is_hold_like());
        assert!(HoldAfterFwd.is_hold_like());
        assert!(HoldAfterBwd.is_hold_like());
        assert!(!Free.is_hold_like());
        assert!(!Compute.is_hold_like());
    }
}
