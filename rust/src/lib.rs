//! PatrickStar: parallel training of pre-trained models via chunk-based
//! memory management — a full-system reproduction of Fang et al. (TPDS
//! 2022) as a three-layer Rust + JAX + Pallas stack.
//!
//! * The **coordinator** (this crate) implements the paper's contribution:
//!   chunk-based heterogeneous memory management (Sec. 5–6), the runtime
//!   memory tracer (Sec. 8.1), device-aware operator placement (Sec. 8.2),
//!   OPT chunk eviction (Sec. 8.3) and ZeRO-symbiotic chunk collectives
//!   (Sec. 7), plus the DeepSpeed/PyTorch baselines and the calibrated
//!   cluster simulator that regenerates every table and figure of the
//!   paper's evaluation (DESIGN.md §5).
//! * The **compute** comes from JAX/Pallas, AOT-lowered to HLO text at
//!   build time and executed through the PJRT C API (`runtime::`); python
//!   is never on the training path.
//!
//! Start with [`train`] for the real end-to-end path or [`engine`] for
//! the simulator.

pub mod baselines;
pub mod chunk;
pub mod config;
pub mod dp;
pub mod engine;
pub mod evict;
pub mod lint;
pub mod mem;
pub mod model;
pub mod placement;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scale;
pub mod sim;
pub mod tensor;
pub mod tracer;
#[cfg(feature = "pjrt")]
pub mod train;
pub mod util;

/// Convenience prelude for examples and benches.
pub mod prelude {
    pub use crate::chunk::{Chunk, ChunkId, ChunkKind, ChunkManager,
                           ChunkRegistry, TensorSpec};
    pub use crate::config::{ClusterPreset, SystemKind, TrainTask};
    pub use crate::engine::{Engine, IterBreakdown, OptimizationPlan};
    pub use crate::evict::{EvictionPolicy, FifoPolicy, LfuPolicy, LruPolicy,
                           OptPolicy};
    pub use crate::mem::{Device, HeterogeneousSpace, Interconnect};
    pub use crate::model::{ActivationPlan, GptSpec};
    pub use crate::tensor::{TensorId, TensorState};
    pub use crate::tracer::MemTracer;
    pub use crate::util::{human_bytes, Json, Rng, Table};
}
