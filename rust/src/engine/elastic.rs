//! Elastic re-scaling plans (ISSUE 9 tentpole).
//!
//! An [`ElasticPlan`] schedules world-size changes at steady-iteration
//! boundaries: `--elastic shrink@iter=1:to=2,grow@iter=3:to=4`.  At
//! each named boundary the engine checkpoints the session state it
//! already holds (the session *is* the checkpoint — see
//! [`super::session::SessionState`]), re-partitions every chunk group
//! across the new comm world, prices the re-shard traffic on the real
//! collective curves, and remaps the warm-up carry-over state onto the
//! survivors instead of paying a fresh warm-up iteration
//! ([`super::session::TrainingSession::rescale`]).
//!
//! The second trigger is the chaos `rank-fail` lane
//! ([`super::chaos::ChaosPlan`]): when
//! [`super::ExecutionBackend::poll_rank_fail`] reports a lost rank at a
//! boundary with no planned event, the engine shrinks the world by one.
//! Both triggers produce a [`RescaleEvent`] row in the report, and both
//! are deterministic: the plan is static and the chaos lane draws from
//! its own seeded stream, so the same CLI invocation replays the same
//! rescale sequence byte-for-byte.
//!
//! Parsing is hardened the same way as `ChaosPlan::parse` (ISSUE 9
//! satellite): unknown kinds/parameters, duplicates, missing fields and
//! out-of-range values are *named* errors, never silent clamping or
//! last-write-wins.  Direction (shrink must decrease, grow must
//! increase) is validated at application time, when the current world
//! size is known.

use anyhow::{anyhow, bail, Result};

/// Which way one planned rescale moves the world size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElasticKind {
    Shrink,
    Grow,
}

impl ElasticKind {
    pub fn name(&self) -> &'static str {
        match self {
            ElasticKind::Shrink => "shrink",
            ElasticKind::Grow => "grow",
        }
    }
}

/// One planned world-size change: at the boundary *before* steady
/// iteration `at_iter`, rescale to `to` ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ElasticEvent {
    pub kind: ElasticKind,
    pub at_iter: usize,
    pub to: usize,
}

/// A schedule of world-size changes, at most one per iteration
/// boundary, sorted by iteration.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ElasticPlan {
    pub events: Vec<ElasticEvent>,
}

impl ElasticPlan {
    /// Parse an `--elastic` spec: comma-separated
    /// `<shrink|grow>@iter=K:to=P` events (see module docs).
    pub fn parse(spec: &str) -> Result<Self> {
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let (kind_s, params) = part.split_once('@').ok_or_else(|| {
                anyhow!(
                    "elastic event {part:?}: expected \
                     <shrink|grow>@iter=K:to=P"
                )
            })?;
            let kind = match kind_s {
                "shrink" => ElasticKind::Shrink,
                "grow" => ElasticKind::Grow,
                other => bail!(
                    "unknown elastic event kind {other:?} (want \
                     shrink or grow)"
                ),
            };
            let mut at_iter: Option<usize> = None;
            let mut to: Option<usize> = None;
            for kv in params.split(':') {
                let Some((k, v)) = kv.split_once('=') else {
                    bail!(
                        "malformed elastic parameter {kv:?} (want k=v)"
                    );
                };
                let n: usize = v.parse().map_err(|_| {
                    anyhow!(
                        "elastic parameter {k}={v:?} is not a number"
                    )
                })?;
                let slot = match k {
                    "iter" => &mut at_iter,
                    "to" => &mut to,
                    other => bail!(
                        "unknown elastic parameter {other:?} (want \
                         iter or to)"
                    ),
                };
                if slot.replace(n).is_some() {
                    bail!(
                        "duplicate elastic parameter {k:?} in {part:?} \
                         (each parameter may appear once)"
                    );
                }
            }
            let at_iter = at_iter.ok_or_else(|| {
                anyhow!("elastic event {part:?} is missing iter=K")
            })?;
            let to = to.ok_or_else(|| {
                anyhow!("elastic event {part:?} is missing to=P")
            })?;
            if to == 0 {
                bail!(
                    "elastic event {part:?}: the world cannot rescale \
                     to 0 ranks"
                );
            }
            events.push(ElasticEvent { kind, at_iter, to });
        }
        events.sort_by_key(|e| e.at_iter);
        if let Some(w) =
            events.windows(2).find(|w| w[0].at_iter == w[1].at_iter)
        {
            bail!(
                "two elastic events at iteration {} (at most one \
                 rescale per boundary)",
                w[0].at_iter
            );
        }
        Ok(ElasticPlan { events })
    }

    /// The planned event at the boundary before steady iteration `it`.
    pub fn event_at(&self, it: usize) -> Option<ElasticEvent> {
        self.events.iter().copied().find(|e| e.at_iter == it)
    }
}

/// What one applied rescale did — the report row and the replay
/// fingerprint of the elastic path.
#[derive(Clone, Debug, PartialEq)]
pub struct RescaleEvent {
    /// Boundary it fired at (before steady iteration `at_iter`).
    pub at_iter: usize,
    /// World size before / after.
    pub from: usize,
    pub to: usize,
    /// True when the chaos rank-fail lane triggered the shrink; false
    /// for planned `--elastic` events.
    pub rank_fail: bool,
    /// Chunk-list positions whose owner changed (each crosses the wire
    /// exactly once — the conservation invariant).
    pub moved_shards: usize,
    /// Owned state re-sharded: fp16 + fp32 param/momentum/variance,
    /// 14 B per moved parameter.  Wire bytes equal payload bytes — a
    /// re-shard is a permutation route, not a ring collective, so
    /// there is no (p-1)/p amplification.
    pub moved_bytes: u64,
    /// Wire time of the re-shard on the collective link's curves.
    pub reshard_secs: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_single_and_multi_event_specs() {
        let p = ElasticPlan::parse("shrink@iter=1:to=2").unwrap();
        assert_eq!(
            p.events,
            vec![ElasticEvent {
                kind: ElasticKind::Shrink,
                at_iter: 1,
                to: 2,
            }]
        );
        assert_eq!(p.event_at(1).unwrap().to, 2);
        assert_eq!(p.event_at(0), None);
        // Params in either order; events sorted by iteration.
        let p = ElasticPlan::parse(
            "grow@to=8:iter=3,shrink@iter=1:to=2",
        )
        .unwrap();
        assert_eq!(p.events.len(), 2);
        assert_eq!(p.events[0].at_iter, 1);
        assert_eq!(p.events[1].kind, ElasticKind::Grow);
        assert_eq!(p.events[1].to, 8);
    }

    #[test]
    fn parse_rejects_malformed_specs_with_named_errors() {
        let err = |s: &str| ElasticPlan::parse(s).unwrap_err().to_string();
        assert!(err("shrink").contains("expected"));
        assert!(err("explode@iter=1:to=2")
            .contains("unknown elastic event kind"));
        assert!(err("shrink@iter=1").contains("missing to=P"));
        assert!(err("shrink@to=2").contains("missing iter=K"));
        assert!(err("shrink@iter=1:to=x").contains("not a number"));
        assert!(err("shrink@iter=1:to=2:to=3")
            .contains("duplicate elastic parameter"));
        assert!(err("shrink@iter=1:depth=2")
            .contains("unknown elastic parameter"));
        assert!(err("shrink@iter=1:to=0").contains("0 ranks"));
        assert!(err("shrink@iter=1:to").contains("malformed"));
        assert!(err("shrink@iter=1:to=2,grow@iter=1:to=8")
            .contains("two elastic events at iteration 1"));
    }
}
