//! Engine output: the per-iteration breakdown and summary report.

use super::chaos::ChaosStats;
use super::elastic::RescaleEvent;
use crate::chunk::MoveStats;
use crate::placement::PlacementPlan;
use crate::sim::{Phase, SimClock};
use crate::util::fmt::human_time;
use crate::util::{human_bytes, Table};

/// Per-phase seconds of one measured iteration (paper Fig. 16 bars).
///
/// Phases carry *work* (serial-sum semantics): with the overlap pipeline
/// on, their sum exceeds `EngineReport::iter_time_s` by exactly
/// `overlapped_transfer_s` — the copy time hidden under compute on the
/// dual copy streams.  `exposed_transfer_s` is the copy time the compute
/// stream actually stalled for.  Serially both collapse: exposed = all
/// copy time, overlapped = 0, sum = iter time.
#[derive(Clone, Debug, Default)]
pub struct IterBreakdown {
    /// `pub(super)`: the backend layer (`backend.rs`) assembles this
    /// from its timeline; the report module itself never reads one
    /// (timeline-layering rule, ISSUE 8).
    pub(super) secs: Vec<(Phase, f64)>,
    /// Copy time on the compute critical path (stalls).
    pub exposed_transfer_s: f64,
    /// Copy time hidden under compute by the dual-stream pipeline.
    pub overlapped_transfer_s: f64,
    /// Collective time the compute stream stalled for.  Without the
    /// collective stream this is zero and the AllGather/ReduceScatter
    /// phases themselves are the (fully exposed) collective time.
    pub exposed_collective_s: f64,
    /// Collective time hidden under compute by the collective stream.
    pub overlapped_collective_s: f64,
    /// Copy time charged on the pageable PCIe curve — transfers that
    /// could not acquire a pinned staging buffer
    /// ([`crate::mem::PinnedPool`]).  Zero with the pool disabled.
    pub pageable_copy_s: f64,
}

impl IterBreakdown {
    pub fn from_clock(clock: &SimClock) -> Self {
        IterBreakdown {
            secs: Phase::ALL
                .iter()
                .map(|&p| (p, clock.get(p)))
                .collect(),
            exposed_transfer_s: 0.0,
            overlapped_transfer_s: 0.0,
            exposed_collective_s: 0.0,
            overlapped_collective_s: 0.0,
            pageable_copy_s: 0.0,
        }
    }

    // `from_timeline` lives in `backend.rs`: constructing a breakdown
    // from a `StreamTimeline` is the backend layer's job, and this
    // module stays a pure formatter (timeline-layering rule).

    /// Collective time on the compute critical path, in every mode:
    /// with the collective stream off, the phase clocks themselves;
    /// with it on, the measured stalls.
    pub fn critical_collective_s(&self) -> f64 {
        if self.overlapped_collective_s > 0.0
            || self.exposed_collective_s > 0.0
        {
            self.exposed_collective_s
        } else {
            self.get(Phase::AllGather) + self.get(Phase::ReduceScatter)
        }
    }

    pub fn get(&self, phase: Phase) -> f64 {
        self.secs
            .iter()
            .find(|(p, _)| *p == phase)
            .map(|(_, t)| *t)
            .unwrap_or(0.0)
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().map(|(_, t)| t).sum()
    }

    pub fn rows(&self) -> Vec<(Phase, f64)> {
        self.secs.iter().copied().filter(|&(_, t)| t > 0.0).collect()
    }

    /// The work done *since* `earlier` — both breakdowns must come from
    /// the same accumulating backend (e.g. before/after one trainer
    /// step, whose timeline never resets).  Every component is clamped
    /// at zero so a reset clock degrades to the full later breakdown
    /// instead of going negative.
    pub fn delta_since(&self, earlier: &IterBreakdown) -> IterBreakdown {
        let d = |a: f64, b: f64| (a - b).max(0.0);
        IterBreakdown {
            secs: self
                .secs
                .iter()
                .map(|&(p, t)| (p, d(t, earlier.get(p))))
                .collect(),
            exposed_transfer_s: d(self.exposed_transfer_s,
                                  earlier.exposed_transfer_s),
            overlapped_transfer_s: d(self.overlapped_transfer_s,
                                     earlier.overlapped_transfer_s),
            exposed_collective_s: d(self.exposed_collective_s,
                                    earlier.exposed_collective_s),
            overlapped_collective_s: d(self.overlapped_collective_s,
                                       earlier.overlapped_collective_s),
            pageable_copy_s: d(self.pageable_copy_s,
                               earlier.pageable_copy_s),
        }
    }
}

/// Everything one engine run reports.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub system: String,
    pub model: String,
    pub n_gpus: u32,
    pub batch_per_gpu: u64,
    pub chunk_elems: u64,
    pub breakdown: IterBreakdown,
    pub iter_time_s: f64,
    pub tflops_per_gpu: f64,
    pub placement: PlacementPlan,
    pub move_stats: MoveStats,
    pub allgather_bytes: u64,
    pub reduce_scatter_bytes: u64,
    /// Achieved collective bandwidths (Table 5).
    pub allgather_bw: f64,
    pub reduce_scatter_bw: f64,
    /// Lookahead group gathers issued on the collective stream.
    pub gather_prefetches: u64,
    /// Lookahead gathers reclaimed under memory pressure.
    pub gather_cancels: u64,
    /// The feedback controller sized the prefetch windows (ISSUE 4).
    pub adaptive_lookahead: bool,
    /// Mean per-moment chunk window of the measured iteration (the
    /// static `--lookahead` when `adaptive_lookahead` is false; 0 when
    /// the chunk prefetch lane was off).
    pub avg_chunk_lookahead: f64,
    /// Mean per-moment group-gather window (same conventions).
    pub avg_group_lookahead: f64,
    pub gpu_peak: u64,
    pub cpu_peak: u64,
    /// Peak bytes resident on the NVMe tier; 0 when the tier is off
    /// (`--nvme-gb 0`), in which case no NVMe line renders at all.
    pub nvme_peak: u64,
    pub non_model_peak: u64,
    /// Fault-injection counters when the run went through a
    /// [`super::chaos::ChaosBackend`]; None on a plain backend.
    pub chaos: Option<ChaosStats>,
    /// Elastic world-size changes applied at iteration boundaries
    /// (ISSUE 9): planned `--elastic` events and chaos rank failures,
    /// in firing order.  Empty on a fixed-world run.
    pub rescales: Vec<RescaleEvent>,
}

impl EngineReport {
    pub fn total_tflops(&self) -> f64 {
        self.tflops_per_gpu * self.n_gpus as f64
    }

    /// Human-readable dump (used by the CLI `breakdown` subcommand).
    pub fn render(&self) -> String {
        let mut out = format!(
            "{} | model {} | {} GPU(s) x batch {} | chunk {} elems\n\
             iter {} | {:.1} Tflops/GPU ({:.1} total)\n",
            self.system,
            self.model,
            self.n_gpus,
            self.batch_per_gpu,
            self.chunk_elems,
            human_time(self.iter_time_s),
            self.tflops_per_gpu,
            self.total_tflops(),
        );
        // Share of phase *work* (with the overlap pipeline on, work
        // exceeds wall time by the hidden transfer time, so dividing by
        // iter_time_s would sum past 100%).
        let work = self.breakdown.total().max(f64::MIN_POSITIVE);
        let mut t = Table::new(&["phase", "time", "share"]);
        for (p, secs) in self.breakdown.rows() {
            t.row(vec![
                p.name().into(),
                human_time(secs),
                format!("{:.1}%", 100.0 * secs / work),
            ]);
        }
        out.push_str(&t.render());
        if self.breakdown.overlapped_transfer_s > 0.0 {
            out.push_str(&format!(
                "transfers: {} exposed / {} overlapped (pipeline hid \
                 {:.0}% of copy time)\n",
                human_time(self.breakdown.exposed_transfer_s),
                human_time(self.breakdown.overlapped_transfer_s),
                100.0 * self.breakdown.overlapped_transfer_s
                    / (self.breakdown.exposed_transfer_s
                        + self.breakdown.overlapped_transfer_s),
            ));
        }
        if self.breakdown.pageable_copy_s > 0.0
            || self.move_stats.pinned_waits > 0
        {
            out.push_str(&format!(
                "pinned staging: {} of copy time fell to the pageable \
                 curve; {} prefetch issues throttled by the pool\n",
                human_time(self.breakdown.pageable_copy_s),
                self.move_stats.pinned_waits,
            ));
        }
        if self.adaptive_lookahead {
            out.push_str(&format!(
                "adaptive lookahead: avg chunk window {:.1} moments, \
                 avg group window {:.1}\n",
                self.avg_chunk_lookahead, self.avg_group_lookahead,
            ));
        }
        if let Some(c) = &self.chaos {
            out.push_str(&format!(
                "chaos: {} copy slowdowns, {} collective stretches, {} \
                 pressure spikes, {} aborts injected\n",
                c.copy_slowdowns,
                c.collective_stretches,
                c.pressure_spikes,
                c.aborts,
            ));
        }
        for r in &self.rescales {
            out.push_str(&format!(
                "rescale @ iter {}: {} -> {} ranks{} | {} shard \
                 moves, {} re-sharded in {}\n",
                r.at_iter,
                r.from,
                r.to,
                if r.rank_fail { " (rank-fail)" } else { "" },
                r.moved_shards,
                human_bytes(r.moved_bytes),
                human_time(r.reshard_secs),
            ));
        }
        if self.move_stats.lease_leaks > 0 {
            out.push_str(&format!(
                "WARNING: {} pinned staging lease(s) still held at \
                 iteration end (leak)\n",
                self.move_stats.lease_leaks,
            ));
        }
        if self.nvme_peak > 0
            || self.move_stats.to_nvme_bytes > 0
            || self.move_stats.from_nvme_bytes > 0
        {
            out.push_str(&format!(
                "nvme tier: peak {} | spilled down {} ({} moves) | \
                 staged up {} ({} moves)\n",
                human_bytes(self.nvme_peak),
                human_bytes(self.move_stats.to_nvme_bytes),
                self.move_stats.to_nvme_moves,
                human_bytes(self.move_stats.from_nvme_bytes),
                self.move_stats.from_nvme_moves,
            ));
        }
        if self.breakdown.overlapped_collective_s > 0.0 {
            out.push_str(&format!(
                "collectives: {} exposed / {} overlapped (stream hid \
                 {:.0}% of collective time; {} gathers ahead, {} \
                 cancelled)\n",
                human_time(self.breakdown.exposed_collective_s),
                human_time(self.breakdown.overlapped_collective_s),
                100.0 * self.breakdown.overlapped_collective_s
                    / (self.breakdown.exposed_collective_s
                        + self.breakdown.overlapped_collective_s),
                self.gather_prefetches,
                self.gather_cancels,
            ));
        }
        out.push_str(&format!(
            "margin/spill {:+} | moved c2g {} g2c {} | \
             allgather {} @ {:.1} GB/s | reduce-scatter {} @ {:.1} GB/s\n\
             peaks: gpu-chunk {} cpu-chunk {} non-model {}\n",
            self.placement.margin_or_spill(),
            human_bytes(self.move_stats.cpu_to_gpu_bytes),
            human_bytes(self.move_stats.gpu_to_cpu_bytes),
            human_bytes(self.allgather_bytes),
            self.allgather_bw / 1e9,
            human_bytes(self.reduce_scatter_bytes),
            self.reduce_scatter_bw / 1e9,
            human_bytes(self.gpu_peak),
            human_bytes(self.cpu_peak),
            human_bytes(self.non_model_peak),
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_is_sum() {
        let mut c = SimClock::new();
        c.add(Phase::FwdBwd, 1.0);
        c.add(Phase::Adam, 0.5);
        let b = IterBreakdown::from_clock(&c);
        assert!((b.total() - 1.5).abs() < 1e-12);
        assert_eq!(b.get(Phase::Adam), 0.5);
        assert_eq!(b.rows().len(), 2);
    }

    #[test]
    fn delta_since_isolates_one_step_of_an_accumulating_clock() {
        let mut c = SimClock::new();
        c.add(Phase::FwdBwd, 1.0);
        c.add(Phase::Adam, 0.5);
        let before = IterBreakdown::from_clock(&c);
        c.add(Phase::FwdBwd, 2.0);
        c.add(Phase::CpuToGpu, 0.25);
        let after = IterBreakdown::from_clock(&c);
        let d = after.delta_since(&before);
        assert!((d.get(Phase::FwdBwd) - 2.0).abs() < 1e-12);
        assert_eq!(d.get(Phase::Adam), 0.0);
        assert!((d.get(Phase::CpuToGpu) - 0.25).abs() < 1e-12);
        // A reset clock (earlier ahead of later) clamps at zero.
        let clamped = before.delta_since(&after);
        assert_eq!(clamped.get(Phase::FwdBwd), 0.0);
        assert_eq!(clamped.total(), 0.0);
    }
}
