//! Eviction-policy selection for the training session.
//!
//! The session holds one [`PolicySel`] for the run; [`with_policy`]
//! materializes the chosen [`EvictionPolicy`] (OPT borrows the tracer
//! per call — its future-use moment lists *are* the tracer statistics)
//! and hands it to the manager operation.  Backend-neutral: both the
//! simulator and the real trainer pick victims through this module.

use crate::evict::{EvictionPolicy, FifoPolicy, LfuPolicy, LruPolicy,
                   OptPolicy};
use crate::tracer::MemTracer;

use super::EvictKind;

/// The run's selected eviction policy.  Stateful policies (LRU, FIFO,
/// LFU) live here across the run; OPT is stateless and rebuilt per call
/// around a tracer borrow.
#[derive(Clone)]
pub(crate) enum PolicySel {
    Opt,
    Lru(LruPolicy),
    Fifo(FifoPolicy),
    Lfu(LfuPolicy),
}

impl PolicySel {
    pub(crate) fn new(kind: EvictKind) -> Self {
        match kind {
            EvictKind::Opt => PolicySel::Opt,
            EvictKind::Lru => PolicySel::Lru(LruPolicy::default()),
            EvictKind::Fifo => PolicySel::Fifo(FifoPolicy::default()),
            EvictKind::Lfu => PolicySel::Lfu(LfuPolicy::default()),
        }
    }
}

/// Construct the selected eviction policy (OPT borrows the tracer) and
/// run `f` with it.
pub(crate) fn with_policy<R>(
    sel: &mut PolicySel,
    tracer: &MemTracer,
    f: impl FnOnce(&mut dyn EvictionPolicy) -> R,
) -> R {
    match sel {
        PolicySel::Opt => {
            let mut p = OptPolicy { tracer };
            f(&mut p)
        }
        PolicySel::Lru(p) => f(p),
        PolicySel::Fifo(p) => f(p),
        PolicySel::Lfu(p) => f(p),
    }
}
