//! Adaptive lookahead controller + negotiated headroom ledger (ISSUE 4
//! tentpole).
//!
//! PR 1 and PR 2 gave the engine two prefetch windows — the chunk
//! window (`--lookahead`, moments) and the group-gather window
//! (`--group-lookahead`, communication groups) — as *static knobs*.
//! AutoHete (PAPERS.md) argues the right depth is a function of the
//! measured compute/transfer ratio, and PR 3's pinned pool already
//! showed the window must respect staging capacity.  This module closes
//! the loop: both windows are re-sized every moment from live feedback.
//!
//! # The feedback loop
//!
//! [`LookaheadController::observe`] differences three cumulative work
//! probes per moment tick — compute work, H2D copy work, collective
//! work, read from whatever [`crate::engine::ExecutionBackend`] is
//! executing the session (the simulator's stream timeline, or the real
//! trainer's measured wall-time accounting) — and folds each delta
//! into an exponential moving average (alpha [`EMA_ALPHA`]).  The EMAs
//! survive the iteration boundary (PTM iterations are structurally
//! identical, so last iteration's rates are this iteration's best
//! prior); only the cumulative baselines reset with the timeline.
//!
//! # Window sizing
//!
//! *Chunk window* ([`LookaheadController::chunk_window`]):
//!
//! ```text
//! want    = MIN_CHUNK_WINDOW + ceil(HEADSTART * h2d_ema / compute_ema)
//! window  = clamp(want - h2d_backlog_moments, 1, static cap)
//! window  = min(window, free_pinned_buffers * POOL_MOMENTS_PER_BUFFER)
//! ```
//!
//! The ratio term keeps the H2D engine fed: if every moment produces
//! `t` seconds of staging against `c` seconds of compute, a copy must
//! be issued ~`t/c` moments early to finish in time, and [`HEADSTART`]
//! doubles that for queueing slack.  The backlog term shrinks the walk
//! while the engine is already running ahead — copies enqueued behind a
//! deep backlog would land *later* than their use moments and be
//! evicted by the cap shrink before paying off.  The pool term bounds
//! the walk to what the free staging buffers could possibly issue
//! (chunk uses arrive at well under one per moment — 7 ops per layer
//! and multi-layer chunks — so [`POOL_MOMENTS_PER_BUFFER`] moments per
//! buffer is a generous over-approximation; a dry pool collapses the
//! window to zero instead of walking and throttling).
//!
//! *Group window* ([`LookaheadController::group_window`]): the same
//! shape on the fourth stream — `1 + ceil(coll_ema / compute_ema)`,
//! backlog-compressed, clamped to `[1, static cap]`.  The floor of 1
//! keeps the next demand gather always stageable.
//!
//! # The headroom ledger
//!
//! Before this PR the two prefetchers budgeted *independently* against
//! `MemTracer::min_chunkable_gpu`: a deep chunk walk could consume the
//! exact headroom the next moment's all-gather needed, forcing the
//! gather to retry while less urgent chunk copies occupied the space.
//! [`HeadroomLedger`] is the single negotiation point: every byte limit
//! either prefetcher uses comes from the ledger, and in adaptive mode
//! the engine *earmarks* the upcoming group gathers' absent bytes
//! before the chunk walk starts, so the chunk prefetcher sees
//! `grant - earmarks` and cannot starve the collective lane.  Demand
//! traffic always preempts — demand fetches and demand gathers never
//! consult the ledger at all.  With no earmarks the ledger's arithmetic
//! is exactly the pre-PR expressions, which is what keeps the
//! adaptive-off timelines bit-identical to PR 3.

use crate::tracer::{MemTracer, Moment, WARMUP_GPU_FRAC};

use super::prefetch::{DEFAULT_GROUP_LOOKAHEAD, DEFAULT_LOOKAHEAD};

/// Cap on the adaptive chunk window when the user asks for
/// `--lookahead auto` (the controller sizes *within* the cap; the cap
/// itself stays a static safety rail, which is what the window-bound
/// property test pins).
pub const DEFAULT_ADAPTIVE_MAX_LOOKAHEAD: u32 = 64;

/// Cap on the adaptive group-gather window in auto mode.
pub const DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD: u32 = 4;

/// EMA smoothing: ~4-moment memory, quick enough to track the
/// FWD->BWD->ADAM phase changes within one iteration.
const EMA_ALPHA: f64 = 0.25;

/// Floor of the ratio-derived chunk window: even a compute-bound phase
/// keeps about a layer of headstart (7 ops) so the first spill of the
/// next transfer-bound stretch is already hidden.
const MIN_CHUNK_WINDOW: u32 = 8;

/// Safety multiple on the measured transfer/compute ratio.  Generous on
/// purpose: chunk uses are sparse (one chunk spans a layer or more of
/// ops) and copies are not spaced uniformly, and an over-deep window is
/// cheap — the headroom budget, Belady guard and pool budget already
/// throttle it — while an under-deep one leaves the H2D engine idle.
const HEADSTART: f64 = 4.0;

/// Moments of window depth one free pinned buffer licenses (a generous
/// over-approximation: roughly one *distinct* chunk use per one-to-two
/// transformer layers of 7 ops each).
const POOL_MOMENTS_PER_BUFFER: u32 = 16;

/// Cap on the overlap-aware eviction tie-break margin (moments): a
/// near-equal droppable victim may jump at most this far ahead of the
/// OPT choice, however deep the D2H backlog grows.
const MAX_EVICT_MARGIN: u32 = 8;

/// One exponential moving average over per-moment deltas.
#[derive(Clone, Copy, Debug, Default)]
struct Ema(Option<f64>);

impl Ema {
    fn update(&mut self, x: f64) {
        self.0 = Some(match self.0 {
            None => x,
            Some(v) => EMA_ALPHA * x + (1.0 - EMA_ALPHA) * v,
        });
    }

    fn get(&self) -> Option<f64> {
        self.0
    }
}

/// Per-stream observations the controller sizes the windows from at one
/// moment tick.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowInputs {
    /// Free pinned staging buffers grantable to H2D copies right now
    /// (None: pool disabled, no staging-capacity bound).
    pub pool_free: Option<u32>,
    /// Seconds the H2D engine's frontier runs ahead of compute.
    pub h2d_backlog_secs: f64,
    /// Seconds the collective stream's frontier runs ahead of compute.
    pub coll_backlog_secs: f64,
}

/// Feedback-driven sizing of both prefetch windows.
#[derive(Clone, Debug)]
pub struct LookaheadController {
    /// Static caps the adaptive windows may never exceed.
    max_lookahead: u32,
    max_group_lookahead: u32,
    ema_compute: Ema,
    ema_h2d: Ema,
    ema_coll: Ema,
    /// Per-moment NVMe-lane work (ISSUE 7); stays `None` — and the NVMe
    /// window stays the plain chunk window — unless the engine feeds
    /// [`LookaheadController::observe_nvme`].
    ema_nvme: Ema,
    /// Cumulative-accumulator baselines from the previous tick.
    last_compute: f64,
    last_h2d: f64,
    last_coll: f64,
    /// Baselines for the NVMe probe (own compute baseline: the probe is
    /// fed by a separate call after `observe` has already re-based
    /// `last_compute`).
    last_nvme: f64,
    last_nvme_compute: f64,
}

impl LookaheadController {
    pub fn new(max_lookahead: u32, max_group_lookahead: u32) -> Self {
        LookaheadController {
            max_lookahead,
            max_group_lookahead,
            ema_compute: Ema::default(),
            ema_h2d: Ema::default(),
            ema_coll: Ema::default(),
            ema_nvme: Ema::default(),
            last_compute: 0.0,
            last_h2d: 0.0,
            last_coll: 0.0,
            last_nvme: 0.0,
            last_nvme_compute: 0.0,
        }
    }

    /// Fold this tick's per-stream work deltas into the EMAs.  The
    /// arguments are the backend's cumulative probes (`compute_work`,
    /// `copy_busy(H2D)`, `collective_work`) — raw values, not deltas,
    /// so the controller stays backend-agnostic.  Ticks that charged no
    /// compute (the iteration's first tick) are skipped so idle
    /// boundaries don't drag the rate estimates toward zero.
    pub fn observe(&mut self, compute_work: f64, h2d_busy: f64,
                   coll_work: f64) {
        let dc = compute_work - self.last_compute;
        let dh = h2d_busy - self.last_h2d;
        let dk = coll_work - self.last_coll;
        self.last_compute = compute_work;
        self.last_h2d = h2d_busy;
        self.last_coll = coll_work;
        if dc > 0.0 {
            self.ema_compute.update(dc);
            // Reclaims can drive a delta negative; the work physically
            // enqueued this tick is never less than zero.
            self.ema_h2d.update(dh.max(0.0));
            self.ema_coll.update(dk.max(0.0));
        }
    }

    /// Fold this tick's NVMe-lane work delta into its EMA (ISSUE 7).
    /// Same contract as [`Self::observe`]: `nvme_busy` is the backend's
    /// cumulative probe, and ticks that charged no compute are skipped.
    /// Carries its own compute baseline because the engine calls this
    /// *after* `observe` has re-based `last_compute` for the tick.
    pub fn observe_nvme(&mut self, compute_work: f64, nvme_busy: f64) {
        let dc = compute_work - self.last_nvme_compute;
        let dn = nvme_busy - self.last_nvme;
        self.last_nvme_compute = compute_work;
        self.last_nvme = nvme_busy;
        if dc > 0.0 {
            self.ema_nvme.update(dn.max(0.0));
        }
    }

    /// The timeline restarted at zero (iteration boundary): re-base the
    /// cumulative baselines, keep the learned rates.
    pub fn iteration_boundary(&mut self) {
        self.last_compute = 0.0;
        self.last_h2d = 0.0;
        self.last_coll = 0.0;
        self.last_nvme = 0.0;
        self.last_nvme_compute = 0.0;
    }

    fn pool_bound(w: u32, pool_free: Option<u32>) -> u32 {
        match pool_free {
            Some(f) => w.min(f.saturating_mul(POOL_MOMENTS_PER_BUFFER)),
            None => w,
        }
    }

    /// Chunk-prefetch window for this moment, in moments.
    pub fn chunk_window(&self, inp: WindowInputs) -> u32 {
        let cap = self.max_lookahead;
        if cap == 0 {
            return 0; // a zero cap disables the lane outright
        }
        let (c, t) = match (self.ema_compute.get(), self.ema_h2d.get()) {
            (Some(c), Some(t)) if c > 0.0 => (c, t),
            // Cold start (first ticks of the first steady iteration):
            // the static default, still pool-bounded.
            _ => {
                return Self::pool_bound(
                    DEFAULT_LOOKAHEAD.min(cap),
                    inp.pool_free,
                )
            }
        };
        let want = MIN_CHUNK_WINDOW as f64 + (HEADSTART * t / c).ceil();
        let backlog_moments = (inp.h2d_backlog_secs / c).floor();
        let w = (want - backlog_moments).clamp(1.0, cap as f64) as u32;
        Self::pool_bound(w, inp.pool_free)
    }

    /// Chunk-prefetch window for NVMe-resident chunks, in moments
    /// (ISSUE 7).  An NVMe fetch rides *two* sequenced hops — the NVMe
    /// link into the pinned stage, then PCIe onto the GPU — so its copy
    /// must be issued earlier than a CPU-resident chunk's by the extra
    /// NVMe-lane ratio.  Until `observe_nvme` has seen traffic this is
    /// exactly [`Self::chunk_window`], and it obeys the same static cap
    /// and pinned-pool bound (the stage buffer is held across both
    /// hops, so the pool is the binding resource either way).
    pub fn nvme_window(&self, inp: WindowInputs) -> u32 {
        let base = self.chunk_window(inp);
        if base == 0 {
            return 0;
        }
        let extra = match (self.ema_compute.get(), self.ema_nvme.get()) {
            (Some(c), Some(n)) if c > 0.0 => {
                (HEADSTART * n / c).ceil() as u32
            }
            _ => 0,
        };
        Self::pool_bound(
            base.saturating_add(extra).min(self.max_lookahead),
            inp.pool_free,
        )
    }

    /// Group-gather window for this moment, in communication groups.
    pub fn group_window(&self, inp: WindowInputs) -> u32 {
        if self.max_group_lookahead == 0 {
            return 0; // a zero cap disables the lane outright
        }
        let cap = self.max_group_lookahead;
        let (c, t) = match (self.ema_compute.get(), self.ema_coll.get()) {
            (Some(c), Some(t)) if c > 0.0 => (c, t),
            _ => return DEFAULT_GROUP_LOOKAHEAD.clamp(1, cap),
        };
        let want = 1.0 + (t / c).ceil();
        let backlog_groups = (inp.coll_backlog_secs / c).floor();
        (want - backlog_groups).clamp(1.0, cap as f64) as u32
    }

    /// Overlap-aware eviction tie-break margin, in moments: how much
    /// sooner a *droppable* (no-copy) victim's next use may be than the
    /// OPT choice's before we still prefer it.  Grows with the D2H
    /// backlog the spill copy would queue behind; zero while the spill
    /// engine is idle (plain OPT).
    pub fn evict_margin(&self, d2h_backlog_secs: f64) -> u32 {
        match self.ema_compute.get() {
            Some(c) if c > 0.0 && d2h_backlog_secs > 0.0 => {
                ((d2h_backlog_secs / c).floor() as u32)
                    .min(MAX_EVICT_MARGIN)
            }
            _ => 0,
        }
    }
}

// =====================================================================
// Headroom ledger
// =====================================================================

/// The single budgeting point both prefetchers draw GPU headroom from
/// during one moment tick.  Demand traffic preempts by construction —
/// it never consults the ledger.
#[derive(Clone, Debug)]
pub struct HeadroomLedger {
    now: Moment,
    gpu_cap: u64,
    /// False reproduces the "SP" plan's flat warm-up grant.
    use_tracer: bool,
    /// Bytes earmarked for upcoming lookahead group gathers, per group.
    earmarks: Vec<(usize, u64)>,
}

impl HeadroomLedger {
    pub fn new(now: Moment, gpu_cap: u64, use_tracer: bool) -> Self {
        HeadroomLedger { now, gpu_cap, use_tracer, earmarks: Vec::new() }
    }

    /// The tightest chunkable grant between now and `use_m` — the same
    /// forward-looking budget both prefetchers used before the ledger
    /// existed, now computed in exactly one place.
    fn grant(&self, tracer: &MemTracer, use_m: Moment) -> u64 {
        if self.use_tracer {
            tracer.min_chunkable_gpu(self.gpu_cap, self.now, use_m)
        } else {
            (self.gpu_cap as f64 * WARMUP_GPU_FRAC) as u64
        }
    }

    /// Reserve headroom for group `g`'s upcoming all-gather (adaptive
    /// mode; idempotent per group — re-earmarking replaces).
    pub fn earmark_group(&mut self, g: usize, bytes: u64) {
        self.earmarks.retain(|&(og, _)| og != g);
        self.earmarks.push((g, bytes));
    }

    /// Group `g`'s reservation was consumed (its gather issued and its
    /// bytes now show in the device's `used()`) or abandoned.
    pub fn consume_group(&mut self, g: usize) {
        self.earmarks.retain(|&(og, _)| og != g);
    }

    pub fn earmarked_total(&self) -> u64 {
        self.earmarks
            .iter()
            .fold(0u64, |a, &(_, b)| a.saturating_add(b))
    }

    fn earmarked_except(&self, g: usize) -> u64 {
        self.earmarks
            .iter()
            .filter(|&&(og, _)| og != g)
            .fold(0u64, |a, &(_, b)| a.saturating_add(b))
    }

    /// Byte limit for a chunk prefetch whose use moment is `use_m`: the
    /// tightest grant minus every gather reservation.  With no earmarks
    /// this IS `min_chunkable_gpu` — the pre-ledger budget, bit-for-bit.
    pub fn chunk_limit(&self, tracer: &MemTracer, use_m: Moment) -> u64 {
        self.grant(tracer, use_m).saturating_sub(self.earmarked_total())
    }

    /// Byte budget for group `g`'s lookahead gather at `use_m`: the
    /// tightest grant minus the *other* groups' reservations (its own
    /// earmark is exactly the headroom being spent).
    pub fn gather_budget(
        &self,
        tracer: &MemTracer,
        use_m: Moment,
        g: usize,
    ) -> u64 {
        self.grant(tracer, use_m)
            .saturating_sub(self.earmarked_except(g))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::ChunkId;
    use crate::sim::{CopyDir, Phase, StreamTimeline};
    use crate::util::quickcheck::forall;

    /// Feed a timeline's probes to the controller the way a backend
    /// would (the production path reads them off `ExecutionBackend`).
    fn observe_tl(ctl: &mut LookaheadController, tl: &StreamTimeline) {
        ctl.observe(
            tl.compute_work(),
            tl.copy_busy(CopyDir::H2D),
            tl.collective_work(),
        );
    }

    fn warmed(compute: f64, h2d: f64, coll: f64, ticks: u32)
        -> LookaheadController {
        let mut ctl = LookaheadController::new(
            DEFAULT_ADAPTIVE_MAX_LOOKAHEAD,
            DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD,
        );
        let mut tl = StreamTimeline::new(true);
        for _ in 0..ticks {
            tl.charge(Phase::FwdBwd, compute);
            if h2d > 0.0 {
                tl.async_copy(Phase::CpuToGpu, h2d, CopyDir::H2D, 0.0);
            }
            if coll > 0.0 {
                tl.async_collective(Phase::AllGather, coll);
            }
            observe_tl(&mut ctl, &tl);
        }
        ctl
    }

    #[test]
    fn cold_controller_falls_back_to_static_default() {
        let ctl = LookaheadController::new(16, 2);
        let w = ctl.chunk_window(WindowInputs::default());
        assert_eq!(w, DEFAULT_LOOKAHEAD.min(16));
        assert_eq!(ctl.group_window(WindowInputs::default()), 1);
        assert_eq!(ctl.evict_margin(10.0), 0);
    }

    #[test]
    fn transfer_bound_phases_deepen_the_window() {
        // Compute-bound: shallow (the MIN floor + ~ratio).
        let light = warmed(1.0, 0.05, 0.0, 16);
        let deep = warmed(1.0, 8.0, 0.0, 16);
        let wl = light.chunk_window(WindowInputs::default());
        let wd = deep.chunk_window(WindowInputs::default());
        assert!(
            wl >= MIN_CHUNK_WINDOW && wl <= MIN_CHUNK_WINDOW + 2,
            "light window {wl}"
        );
        assert!(wd > wl, "transfer-bound must deepen: {wd} <= {wl}");
        assert!(wd <= DEFAULT_ADAPTIVE_MAX_LOOKAHEAD);
    }

    #[test]
    fn backlog_compresses_the_window() {
        let ctl = warmed(1.0, 2.0, 0.0, 16);
        let free = ctl.chunk_window(WindowInputs::default());
        let jammed = ctl.chunk_window(WindowInputs {
            h2d_backlog_secs: 5.0,
            ..Default::default()
        });
        assert!(jammed < free, "backlog must shrink: {jammed} >= {free}");
        assert!(jammed >= 1, "window floor is 1 while the pool allows");
    }

    #[test]
    fn pool_bounds_the_window_and_a_dry_pool_closes_it() {
        let ctl = warmed(1.0, 8.0, 0.0, 16);
        let unbounded = ctl.chunk_window(WindowInputs::default());
        let one = ctl.chunk_window(WindowInputs {
            pool_free: Some(1),
            ..Default::default()
        });
        assert!(one <= POOL_MOMENTS_PER_BUFFER);
        assert!(one <= unbounded);
        let dry = ctl.chunk_window(WindowInputs {
            pool_free: Some(0),
            ..Default::default()
        });
        assert_eq!(dry, 0, "dry pool: skip the walk entirely");
    }

    #[test]
    fn collective_bound_phases_deepen_the_group_window() {
        let light = warmed(1.0, 0.0, 0.1, 16);
        let heavy = warmed(1.0, 0.0, 2.5, 16);
        assert_eq!(light.group_window(WindowInputs::default()), 2);
        let wg = heavy.group_window(WindowInputs::default());
        assert_eq!(wg, DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD);
        // Backlog compression floors at 1, never 0.
        let jammed = heavy.group_window(WindowInputs {
            coll_backlog_secs: 100.0,
            ..Default::default()
        });
        assert_eq!(jammed, 1);
    }

    #[test]
    fn evict_margin_scales_with_backlog_and_saturates() {
        let ctl = warmed(1.0, 1.0, 0.0, 16);
        assert_eq!(ctl.evict_margin(0.0), 0);
        assert_eq!(ctl.evict_margin(2.5), 2);
        assert_eq!(ctl.evict_margin(1e9), MAX_EVICT_MARGIN);
    }

    #[test]
    fn nvme_traffic_deepens_the_nvme_window_only() {
        // No NVMe observations: the NVMe window IS the chunk window
        // (tier-off identity at the controller level).
        let ctl = warmed(1.0, 2.0, 0.0, 16);
        let inp = WindowInputs::default();
        assert_eq!(ctl.nvme_window(inp), ctl.chunk_window(inp));
        // Feed a busy NVMe lane: the NVMe window deepens past the chunk
        // window by the measured lane ratio, the chunk window itself is
        // untouched, and both obey cap and pool bound.
        let mut ctl = warmed(1.0, 2.0, 0.0, 16);
        let before = ctl.chunk_window(inp);
        let mut tl = StreamTimeline::new(true);
        for _ in 0..16 {
            tl.charge(Phase::FwdBwd, 1.0);
            tl.async_copy(Phase::CpuToGpu, 2.0, CopyDir::H2D, 0.0);
            tl.async_copy_nvme(Phase::Nvme, 3.0, 0.0);
            observe_tl(&mut ctl, &tl);
            ctl.observe_nvme(tl.compute_work(), tl.nvme_busy());
        }
        assert_eq!(ctl.chunk_window(inp), before, "chunk window untouched");
        let wn = ctl.nvme_window(inp);
        assert!(wn > before, "nvme window must deepen: {wn} <= {before}");
        assert!(wn <= DEFAULT_ADAPTIVE_MAX_LOOKAHEAD);
        let dry = ctl.nvme_window(WindowInputs {
            pool_free: Some(0),
            ..Default::default()
        });
        assert_eq!(dry, 0, "dry pool closes the nvme walk too");
        // Boundary keeps the learned NVMe rate.
        ctl.iteration_boundary();
        assert_eq!(ctl.nvme_window(inp), wn);
    }

    #[test]
    fn emas_survive_the_iteration_boundary() {
        let mut ctl = warmed(1.0, 8.0, 0.0, 16);
        let before = ctl.chunk_window(WindowInputs::default());
        ctl.iteration_boundary();
        // Rates kept: the next iteration starts warm, not at the
        // static default.
        assert_eq!(ctl.chunk_window(WindowInputs::default()), before);
        // And a fresh timeline does not produce phantom negative
        // deltas.
        let tl = StreamTimeline::new(true);
        observe_tl(&mut ctl, &tl);
        assert_eq!(ctl.chunk_window(WindowInputs::default()), before);
    }

    /// ISSUE 4 property (a): whatever the feedback, the adaptive window
    /// never exceeds the static cap nor the pool-sized backlog bound,
    /// and the group window stays within [1, cap].
    #[test]
    fn property_windows_respect_caps_and_pool_bound() {
        forall(
            300,
            |rng| {
                (
                    rng.range(1, 65) as u32,          // chunk cap
                    rng.range(1, 9) as u32,           // group cap
                    rng.range(1, 1000) as f64 / 100.0, // compute/moment
                    rng.range(0, 5000) as f64 / 100.0, // h2d/moment
                    rng.range(0, 5000) as f64 / 100.0, // coll/moment
                    rng.range(0, 10000) as f64 / 10.0, // h2d backlog
                    rng.range(0, 10000) as f64 / 10.0, // coll backlog
                    rng.range(0, 10),                  // pool free (9=None)
                    rng.range(1, 30) as u32,           // warm ticks
                )
            },
            |&(cap, gcap, c, h, k, hb, kb, pf, ticks)| {
                let mut ctl = LookaheadController::new(cap, gcap);
                let mut tl = StreamTimeline::new(true);
                for _ in 0..ticks {
                    tl.charge(Phase::FwdBwd, c);
                    tl.async_copy(Phase::CpuToGpu, h, CopyDir::H2D, 0.0);
                    tl.async_collective(Phase::AllGather, k);
                    observe_tl(&mut ctl, &tl);
                }
                let pool_free =
                    if pf == 9 { None } else { Some(pf as u32) };
                let inp = WindowInputs {
                    pool_free,
                    h2d_backlog_secs: hb,
                    coll_backlog_secs: kb,
                };
                let w = ctl.chunk_window(inp);
                if w > cap {
                    return Err(format!("chunk window {w} > cap {cap}"));
                }
                if let Some(f) = pool_free {
                    let bound = f * POOL_MOMENTS_PER_BUFFER;
                    if w > bound {
                        return Err(format!(
                            "chunk window {w} > pool bound {bound}"
                        ));
                    }
                }
                let g = ctl.group_window(inp);
                if g < 1 || g > gcap.max(1) {
                    return Err(format!(
                        "group window {g} outside [1, {gcap}]"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ledger_without_earmarks_is_the_legacy_budget() {
        // The bit-identity anchor for adaptive-off mode: chunk_limit
        // and gather_budget reduce to the exact pre-ledger expressions.
        let mut t = MemTracer::new(1);
        for nm in [300u64, 500, 700, 100] {
            t.record_moment(nm);
        }
        t.record_chunk_use(ChunkId(0), 1);
        t.finish_warmup();
        let cap = 1000u64;
        for now in 0..4u32 {
            let ledger = HeadroomLedger::new(now, cap, true);
            for use_m in now..4u32 {
                assert_eq!(
                    ledger.chunk_limit(&t, use_m),
                    t.min_chunkable_gpu(cap, now, use_m)
                );
                assert_eq!(
                    ledger.gather_budget(&t, use_m, 3),
                    t.min_chunkable_gpu(cap, now, use_m)
                );
            }
        }
        // SP plan: the flat warm-up grant.
        let sp = HeadroomLedger::new(0, cap, false);
        let want = (cap as f64 * WARMUP_GPU_FRAC) as u64;
        assert_eq!(sp.chunk_limit(&t, 3), want);
        assert_eq!(sp.gather_budget(&t, 3, 0), want);
    }

    #[test]
    fn earmarks_reserve_headroom_for_the_collective_lane() {
        let mut t = MemTracer::new(1);
        for _ in 0..4 {
            t.record_moment(200);
        }
        t.finish_warmup();
        let mut ledger = HeadroomLedger::new(0, 1000, true);
        let grant = ledger.chunk_limit(&t, 3);
        ledger.earmark_group(7, 300);
        ledger.earmark_group(8, 100);
        assert_eq!(ledger.earmarked_total(), 400);
        // The chunk walk sees the grant minus every reservation...
        assert_eq!(ledger.chunk_limit(&t, 3), grant - 400);
        // ...each gather sees the grant minus the *other* groups'.
        assert_eq!(ledger.gather_budget(&t, 3, 7), grant - 100);
        assert_eq!(ledger.gather_budget(&t, 3, 8), grant - 300);
        // Re-earmarking replaces, consuming releases.
        ledger.earmark_group(7, 50);
        assert_eq!(ledger.earmarked_total(), 150);
        ledger.consume_group(8);
        assert_eq!(ledger.chunk_limit(&t, 3), grant - 50);
        // Over-earmarking saturates at zero, never wraps.
        ledger.earmark_group(9, u64::MAX);
        assert_eq!(ledger.chunk_limit(&t, 3), 0);
    }
}
