//! The backend-agnostic training session (ISSUE 5 tentpole).
//!
//! [`TrainingSession`] is the orchestration core extracted from the old
//! `Engine::run` monolith: one per-iteration driver holding the chunk
//! manager, tracer, prefetchers, pinned staging pool, adaptive
//! lookahead controller, headroom ledger and eviction policy — every
//! *policy* decision of a PatrickStar iteration — parameterized over an
//! [`ExecutionBackend`] that executes and prices the work.
//!
//! * Driven by the simulator ([`super::Engine`] over
//!   [`super::SimBackend`]): the cost-model methods (`iteration`,
//!   `exec_op`, `exec_adam`, …) replay the operator graph on the
//!   simulated clock.  These take a [`SimCost`] — the cluster/task cost
//!   context — as an explicit parameter, so the session itself stays
//!   free of simulation state.
//! * Driven by the real trainer (`train::Trainer` over
//!   `PjrtBackend`): the real-path methods (`real_window`,
//!   `stage_real`, `access_real`, `ensure_real`) give the e2e path the
//!   same pool-gated, feedback-sized staging the simulator uses, fed by
//!   measured wall time instead of modeled time.
//!
//! The split is behavior-preserving by construction: every backend call
//! is a 1:1 rename of the former inline `StreamTimeline`/cost-curve
//! call, in the same order with the same operands — locked by the
//! golden traces and `tests/session_equivalence.rs`.

use std::collections::{BTreeMap, BTreeSet};

use anyhow::{anyhow, bail, Result};

use crate::chunk::{ChunkId, ChunkKind, ChunkManager, MoveKind};
use crate::config::{ClusterPreset, TrainTask};
use crate::dp::{CollectivePipeline, CommGroups, InFlightGather};
use crate::evict::{BacklogAwareOpt, TierAwareOpt, TierPricing};
use crate::mem::{Device, PinnedLease, PinnedPool};
use crate::model::activation::{non_model_bytes, BASE_OVERHEAD};
use crate::model::{ActivationPlan, OpGraph, OpKind};
use crate::placement::{plan as placement_plan, PlacementPlan};
use crate::sim::{CopyDir, CopyRoute, DeviceProfile, Phase};
use crate::tensor::TensorState;
use crate::tracer::{MemTracer, Moment, WARMUP_GPU_FRAC};

use super::adaptive::{HeadroomLedger, LookaheadController, WindowInputs};
use super::backend::ExecutionBackend;
use super::elastic::RescaleEvent;
use super::policy::{with_policy, PolicySel};
use super::prefetch::{GroupPrefetcher, Prefetcher};
use super::OptimizationPlan;

/// The iteration phase the session is currently driving.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) enum Stage {
    Fwd,
    Bwd,
    Adam,
}

/// Bookkeeping for one in-flight prefetch copy: when it lands, what to
/// un-charge if it is cancelled before reaching the wire, which curve
/// it was charged on, and the pinned staging buffer it holds.  On the
/// real backend `done` is `f64::INFINITY` — there is no simulated
/// completion time; the lease frees when the staged chunk is consumed.
#[derive(Clone, Copy, Debug)]
struct PendingCopy {
    done: f64,
    secs: f64,
    /// NVMe-link hop time of a two-hop staged copy (GPU<->NVMe); 0 for
    /// plain PCIe copies.  `secs` is then the PCIe hop alone, so a
    /// cancel can reclaim each lane by its own share.
    nvme_secs: f64,
    dir: CopyDir,
    phase: Phase,
    route: CopyRoute,
    lease: Option<PinnedLease>,
}

/// A pinned-buffer lease held by a non-prefetch async copy (eviction,
/// activation offload).  Prefetch leases live in [`PendingCopy`] and
/// gather leases in [`InFlightGather`]; these need the same (stream,
/// completion) bookkeeping so queue compression after a cancelled
/// prefetch can shift their release times with the frontier — otherwise
/// the pool would look busier than the stream actually is.
#[derive(Clone, Copy, Debug)]
struct StreamLease {
    lease: PinnedLease,
    dir: CopyDir,
    done: f64,
}

/// Outcome of one real-path staging attempt
/// ([`TrainingSession::stage_real`]): the caller's walk continues over
/// `Skipped` chunks and stops on a dry pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageOutcome {
    /// The chunk is on its way to the target device.
    Staged,
    /// Nothing to do (already resident, in flight, or released).
    Skipped,
    /// No staging buffer free; the walk retries next tick.
    PoolDry,
}

/// The simulator's cost context: which cluster executes the work and
/// which task is being trained.  Only the simulation-driving methods
/// take it; the policy core never sees it.
#[derive(Clone, Copy, Debug)]
pub struct SimCost {
    pub cluster: ClusterPreset,
    pub task: TrainTask,
}

impl SimCost {
    fn nproc(&self) -> usize {
        self.task.n_gpus as usize
    }

    /// CPU profile with bandwidth shared across the node's nproc ranks.
    fn shared_cpu(&self) -> DeviceProfile {
        let mut p = self.cluster.cpu;
        p.mem_bw /= self.nproc() as f64;
        p.gemm_flops /= self.nproc() as f64;
        p
    }

    /// BWD ops cost 2x FWD plus checkpoint recompute.
    fn bwd_mult(&self, stage: Stage) -> f64 {
        match stage {
            Stage::Fwd => 1.0,
            Stage::Bwd => 2.0 + self.task.plan.recompute_factor(),
            Stage::Adam => 0.0,
        }
    }
}

/// One training process's per-iteration driver: chunk orchestration
/// state plus the policy that schedules it, over an execution backend.
///
/// `Clone` (with a cloneable backend) is the checkpoint/restore
/// primitive: every field — chunk-manager state, warm-up statistics,
/// controller EMAs, headroom earmarks, in-flight copies and pool
/// leases, even a chaos backend's mid-stream RNG positions — is plain
/// data, so a clone taken at iteration `k` replays a bit-exact tail
/// (see [`TrainingSession::checkpoint`]).
#[derive(Clone)]
pub struct TrainingSession<B: ExecutionBackend> {
    pub(crate) opt: OptimizationPlan,
    pub(crate) nproc: usize,
    pub(crate) backend: B,
    pub(crate) mgr: ChunkManager,
    pub(crate) tracer: MemTracer,
    pub(crate) groups: CommGroups,
    pub(crate) fp16_list: Vec<ChunkId>,
    pub(crate) policy: PolicySel,
    pub(crate) warmup: bool,
    pub(crate) moment: Moment,
    pub(crate) placement: PlacementPlan,
    stage: Stage,
    /// Inverted warm-up moment lists (built once after warm-up when the
    /// prefetch switch is on).
    pub(crate) prefetcher: Option<Prefetcher>,
    /// In-flight prefetch copies on the timeline, by chunk.
    inflight_done: BTreeMap<ChunkId, PendingCopy>,
    /// Groups already gathered in the current phase.
    gathered: BTreeSet<usize>,
    /// Wire-volume accounting (Table 5).
    pub(crate) allgather_bytes: u64,
    pub(crate) reduce_scatter_bytes: u64,
    pub(crate) allgather_time: f64,
    pub(crate) reduce_scatter_time: f64,
    /// Warm-up log of demand gathers: (moment, group), schedule order.
    gather_log: Vec<(Moment, usize)>,
    /// Group-gather schedule (built once after warm-up when the
    /// collective-stream switch is on).
    pub(crate) group_prefetcher: Option<GroupPrefetcher>,
    /// Collective-stream pipeline: in-flight lookahead gathers and
    /// draining reduce-scatters, by group.
    coll: CollectivePipeline,
    /// Pinned staging-buffer pool (capacity 0 = disabled: single-curve
    /// charging, the pre-pool numbers bit-for-bit).
    pub(crate) pool: PinnedPool,
    /// Leases held by eviction/offload copies still queued or on the
    /// wire (see [`StreamLease`]).  Pruned as they expire.
    stream_leases: Vec<StreamLease>,
    /// Lookahead gathers issued this iteration.
    pub(crate) gather_prefetches: u64,
    /// Lookahead gathers cancelled this iteration, counted per *group*
    /// (the same unit as `gather_prefetches`; the manager's
    /// `MoveStats::gather_cancels` counts reclaimed chunks).
    pub(crate) gather_cancelled_groups: u64,
    /// Feedback-driven window sizing (adaptive mode only; None keeps
    /// the static windows bit-identical to the static paths).
    pub(crate) ctl: Option<LookaheadController>,
    /// Window telemetry for the measured iteration: (sum, ticks) of
    /// the chunk and group windows actually used each moment.
    pub(crate) chunk_win: (u64, u64),
    pub(crate) group_win: (u64, u64),
    /// Per-moment backend snapshots (golden-trace tests).
    pub(crate) trace: Option<Vec<String>>,
}

/// A frozen copy of one session mid-run (ISSUE 6 tentpole): the full
/// orchestration state — chunk-manager residency/in-flight sets,
/// warm-up statistics and placement, controller EMAs, collective
/// pipeline, pool leases, wire-volume counters and the backend itself
/// (timeline position plus any chaos RNG streams).  Restoring it into
/// any session of the same shape resumes with a bit-exact tail versus
/// the uninterrupted run — the kill-and-resume golden test.
pub struct SessionState<B: ExecutionBackend>(TrainingSession<B>);

impl<B: ExecutionBackend + Clone> SessionState<B> {
    /// Unwrap into a live session (resume without a pre-built one).
    pub fn into_session(self) -> TrainingSession<B> {
        self.0
    }
}

impl<B: ExecutionBackend + Clone> TrainingSession<B> {
    /// Freeze the complete session state, e.g. at an iteration
    /// boundary before a (simulated) kill.
    pub fn checkpoint(&self) -> SessionState<B> {
        SessionState(self.clone())
    }

    /// Replace this session's state wholesale with a checkpoint's.
    /// The state is copied, so one checkpoint can seed many resumes.
    pub fn restore(&mut self, state: &SessionState<B>) {
        *self = state.0.clone();
    }
}

impl<B: ExecutionBackend> TrainingSession<B> {
    /// A fresh session at the start of warm-up.  `nproc` is the number
    /// of data-parallel processes this rank coordinates with.
    pub fn new(
        opt: OptimizationPlan,
        nproc: usize,
        mgr: ChunkManager,
        backend: B,
        traced: bool,
    ) -> Self {
        let fp16_list = mgr.reg.list(ChunkKind::ParamFp16);
        let n_chunks = mgr.reg.chunks.len();
        let list_len = fp16_list.len();
        TrainingSession {
            policy: PolicySel::new(opt.eviction),
            pool: {
                let p = PinnedPool::new(opt.pinned_buffers as usize);
                match opt.pinned_split {
                    Some((h, d)) => p.with_split(h as usize, d as usize),
                    None => p,
                }
            },
            opt,
            nproc,
            backend,
            mgr,
            tracer: MemTracer::new(n_chunks),
            groups: CommGroups::new(list_len, nproc),
            fp16_list,
            warmup: true,
            moment: 0,
            placement: PlacementPlan {
                os_groups_on_gpu: 0,
                spilled_fp16_chunks: 0,
                total_fp16_chunks: list_len,
                embedding_on_cpu: true,
            },
            stage: Stage::Fwd,
            prefetcher: None,
            inflight_done: BTreeMap::new(),
            gathered: BTreeSet::new(),
            allgather_bytes: 0,
            reduce_scatter_bytes: 0,
            allgather_time: 0.0,
            reduce_scatter_time: 0.0,
            gather_log: Vec::new(),
            group_prefetcher: None,
            coll: CollectivePipeline::default(),
            stream_leases: Vec::new(),
            gather_prefetches: 0,
            gather_cancelled_groups: 0,
            ctl: None,
            chunk_win: (0, 0),
            group_win: (0, 0),
            trace: if traced { Some(Vec::new()) } else { None },
        }
    }

    /// A session for the real trainer: no warm-up trace (the chunk
    /// schedule is the parameter order itself), single process, the
    /// adaptive controller built straight away when requested.  The
    /// simulation-driving methods are never called on such a session.
    pub fn new_real(opt: OptimizationPlan, mgr: ChunkManager, backend: B)
        -> Self {
        let mut s = Self::new(opt, 1, mgr, backend, false);
        s.warmup = false;
        if opt.adaptive_lookahead {
            s.ctl = Some(LookaheadController::new(
                opt.lookahead,
                opt.group_lookahead,
            ));
        }
        s
    }

    /// The collective stream is live: overlap timeline on, switch on,
    /// and there is actually more than one process to talk to.
    fn collectives_overlapped(&self) -> bool {
        self.opt.overlap && self.opt.overlap_collectives && self.nproc > 1
    }

    /// Push a marker line into the trace (iteration boundaries).
    pub(crate) fn trace_mark(&mut self, s: &str) {
        if let Some(tr) = self.trace.as_mut() {
            tr.push(s.into());
        }
    }

    /// Promote warm-up statistics into the steady-state plan: placement
    /// from the tracer, the prefetchers from the warm-up schedules, the
    /// adaptive controller when requested.  `prefetch_enabled` is the
    /// caller's `opt.prefetch && opt.use_tracer` (SP has no moment
    /// lists: the prefetcher is tracer-fed).
    pub(crate) fn finish_warmup(
        &mut self,
        cost: &SimCost,
        chunk_elems: u64,
        prefetch_enabled: bool,
    ) {
        self.tracer.finish_warmup();
        self.warmup = false;

        // Without the tracer ("SP" plan) the chunkable space stays at
        // the 20% warm-up grant forever, so the margin is computed
        // against that grant — and eviction must fall back to chunk-list
        // order (OPT's future-use moment lists ARE the tracer
        // statistics, paper Sec. 8.1/8.3).
        let (plan_gpu, plan_nm) = if self.opt.use_tracer {
            (cost.cluster.gpu_mem, self.tracer.peak_non_model())
        } else {
            self.policy = PolicySel::new(super::EvictKind::Fifo);
            (
                (cost.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64,
                0,
            )
        };
        self.placement = placement_plan(
            plan_gpu,
            plan_nm,
            chunk_elems,
            // Only the local share of fp16 chunks competes for this
            // rank's GPU during FWD/BWD residency planning.
            self.groups.owned_by(0).len(),
            self.opt.device_aware_os,
        );
        if prefetch_enabled {
            let n_chunks = self.mgr.reg.chunks.len();
            self.prefetcher =
                Some(Prefetcher::from_tracer(&self.tracer, n_chunks));
        }
        if self.collectives_overlapped() {
            self.group_prefetcher = Some(GroupPrefetcher::from_log(
                std::mem::take(&mut self.gather_log),
            ));
        }
        // The adaptive controller sizes whatever prefetch lanes are
        // live; with neither lane there is nothing to size and the
        // static path stays untouched.
        if self.opt.adaptive_lookahead
            && (self.prefetcher.is_some()
                || self.group_prefetcher.is_some())
        {
            self.ctl = Some(LookaheadController::new(
                self.opt.lookahead,
                self.opt.group_lookahead,
            ));
        }
        // Tier placement from warm-up statistics (tentpole): demote
        // the coldest CPU residents to NVMe so the steady iterations
        // start with CPU staging headroom instead of at the brink.
        if self.mgr.has_nvme() && self.opt.use_tracer {
            self.place_nvme_tier();
        }
    }

    /// Fraction of CPU capacity the post-warm-up placement keeps
    /// occupied; the rest is headroom for ADAM staging and eviction
    /// landings, bought by demoting cold chunks to NVMe.
    const CPU_TIER_HEADROOM: f64 = 0.875;

    /// Warm-up-driven NVMe residency: while the CPU tier sits above its
    /// headroom watermark, the CPU-resident chunks whose first steady
    /// use is farthest away (never-used coldest of all) move down to
    /// NVMe.  They return through the two-hop staged route when the
    /// prefetch window reaches them.  Boundary traffic is not part of
    /// any iteration's accounting, so the move events are discarded.
    fn place_nvme_tier(&mut self) {
        let cpu = self.mgr.space.dev(Device::Cpu);
        let target = (cpu.capacity as f64 * Self::CPU_TIER_HEADROOM)
            as u64;
        if cpu.used() <= target {
            return;
        }
        let mut cands: Vec<(u64, u32)> = self
            .mgr
            .reg
            .chunks
            .iter()
            .filter(|c| c.device == Some(Device::Cpu) && !c.embedding)
            .map(|c| {
                let key = match self.tracer.next_use(c.id, 0) {
                    Some(m) => m as u64,
                    None => u64::MAX,
                };
                (key, c.id.0)
            })
            .collect();
        // Farthest next use first; id breaks ties deterministically.
        cands.sort_unstable_by(|a, b| b.cmp(a));
        for (_, id) in cands {
            if self.mgr.space.dev(Device::Cpu).used() <= target {
                break;
            }
            let _ = self.mgr.demote(ChunkId(id), Device::Nvme);
        }
        let _ = self.mgr.drain_events();
    }

    /// Reset per-iteration state at a steady-iteration boundary.
    /// Settles copies still in flight from the previous iteration:
    /// their payloads are already resident, and the fresh timeline
    /// starts at zero, so stale completion times must not leak across
    /// the boundary.  Gathers settle the same way: anything issued is
    /// consumed by its group's fetch within the iteration, but
    /// belt-and-braces.
    pub(crate) fn begin_steady_iteration(&mut self, it: usize) {
        while let Some(c) = self.mgr.pending_prefetch_on(Device::Gpu(0)) {
            self.mgr.complete_prefetch(c);
        }
        for c in self.mgr.gathering_chunks() {
            self.mgr.finish_gather(c);
        }
        // Any staging lease still held past the finished iteration's
        // makespan is a leak (ISSUE 6 satellite): debug builds assert
        // inside the pool; release builds count it (the engine
        // re-checks after the final iteration, whose stats survive
        // into the report — the reset below wipes intermediate ones).
        self.check_lease_leaks();
        self.coll.clear();
        self.pool.clear();
        self.stream_leases.clear();
        self.inflight_done.clear();
        self.backend.reset();
        self.mgr.stats = Default::default();
        self.allgather_bytes = 0;
        self.reduce_scatter_bytes = 0;
        self.allgather_time = 0.0;
        self.reduce_scatter_time = 0.0;
        self.gather_prefetches = 0;
        self.gather_cancelled_groups = 0;
        self.chunk_win = (0, 0);
        self.group_win = (0, 0);
        if let Some(c) = self.ctl.as_mut() {
            // The timeline restarts at zero; the learned rates
            // carry over (iterations are structurally identical).
            c.iteration_boundary();
        }
        self.trace_mark(&format!("== iter {it} =="));
    }

    /// Elastic re-scale at an iteration boundary (ISSUE 9 tentpole):
    /// re-partition every chunk group across a `to`-rank comm world and
    /// carry the warm-up state over to the survivors.
    ///
    /// Four-step protocol:
    ///
    /// 1. **Settle the boundary** — land in-flight prefetches and
    ///    gathers, clear the collective pipeline (same discipline as
    ///    [`Self::begin_steady_iteration`], which runs right after).
    /// 2. **Plan and price the re-shard** — the moved positions are
    ///    exactly those whose owner changes (`pos % p != pos % p'`);
    ///    each carries its full owned state (fp16 + three fp32 lists,
    ///    14 B/param = 7x the fp16 chunk bytes) across the wire once.
    ///    A re-shard is a permutation route, so wire bytes equal
    ///    payload bytes — the conservation invariant the property
    ///    tests lock.
    /// 3. **Swap the comm world** — new [`CommGroups`], new ring cost
    ///    curve via [`ExecutionBackend::rescale_world`].
    /// 4. **Warm-up carry-over** — remap the group-gather log onto the
    ///    new groups, re-plan placement for the new per-rank owned set,
    ///    and re-split the shared CPU/NVMe tiers `to` ways.  The
    ///    chunk-indexed state (tracer moment lists, chunk prefetcher,
    ///    controller EMAs, tier residency) is world-size independent
    ///    and carries over untouched.
    ///
    /// Like `place_nvme_tier`, this is boundary traffic: the re-shard
    /// cost is reported in the returned [`RescaleEvent`], not charged
    /// to any iteration's timeline (`begin_steady_iteration` resets
    /// the backend clock anyway).
    pub(crate) fn rescale(
        &mut self,
        cost: &SimCost,
        chunk_elems: u64,
        to: usize,
        at_iter: usize,
        rank_fail: bool,
    ) -> Result<RescaleEvent> {
        let from = self.nproc;
        // The chunk grid was sized for the original world; a grown
        // world needs `to` chunks of a communication group resident at
        // once, which the warm-up GPU grant may no longer hold.
        let warmup_gpu =
            (cost.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64;
        let max_chunk = warmup_gpu / (2 * (to as u64 + 1));
        if chunk_elems > max_chunk {
            bail!(
                "elastic rescale to {to} ranks cannot hold a {to}-chunk \
                 communication group in the warm-up GPU grant: chunk \
                 {chunk_elems} elems > {max_chunk}"
            );
        }

        // (1) boundary settle.
        while let Some(c) = self.mgr.pending_prefetch_on(Device::Gpu(0)) {
            self.mgr.complete_prefetch(c);
        }
        for c in self.mgr.gathering_chunks() {
            self.mgr.finish_gather(c);
        }
        self.gathered.clear();
        self.coll.clear();

        // (2) re-shard plan: every position whose owner changes ships
        // its owned state exactly once.
        let new_groups = CommGroups::new(self.groups.list_len, to);
        let moves = self.groups.reshard_moves(&new_groups);
        let moved_bytes: u64 = moves
            .iter()
            .map(|mv| 7 * self.mgr.chunk(self.fp16_list[mv.pos]).bytes())
            .sum();
        let op = self.backend.reshard_cost(moved_bytes, moves.len());

        // (3) swap the comm world.
        let old_groups = std::mem::replace(&mut self.groups, new_groups);
        self.nproc = to;
        self.backend.rescale_world(to);

        // (4) warm-up carry-over.
        if let Some(gp) = self.group_prefetcher.take() {
            self.group_prefetcher =
                Some(gp.remap(&old_groups, &self.groups));
        }
        let (plan_gpu, plan_nm) = if self.opt.use_tracer {
            (cost.cluster.gpu_mem, self.tracer.peak_non_model())
        } else {
            (warmup_gpu, 0)
        };
        self.placement = placement_plan(
            plan_gpu,
            plan_nm,
            chunk_elems,
            self.groups.owned_by(0).len(),
            self.opt.device_aware_os,
        );
        let emb_bytes = 14 * cost.task.model.embedding_params();
        let cpu_share = (cost.cluster.cpu_mem / to as u64)
            .checked_sub(emb_bytes / to as u64)
            .ok_or_else(|| {
                anyhow!(
                    "elastic rescale to {to} ranks: the CPU share \
                     cannot hold the embedding slice"
                )
            })?;
        let nvme_share = if self.mgr.has_nvme() {
            Some((self.opt.nvme_gb << 30) / to as u64)
        } else {
            None
        };
        self.mgr.resize_shared_tiers(cpu_share, nvme_share);

        self.trace_mark(&format!(
            "== rescale @ iter {at_iter}: {from} -> {to} ({} shards, \
             {} B, {:.6}s){} ==",
            moves.len(),
            moved_bytes,
            op.secs,
            if rank_fail { " [rank-fail]" } else { "" },
        ));
        Ok(RescaleEvent {
            at_iter,
            from,
            to,
            rank_fail,
            moved_shards: moves.len(),
            moved_bytes,
            reshard_secs: op.secs,
        })
    }

    // ------------------------------------------------------------------
    // One iteration: FWD -> BWD -> ADAM.
    // ------------------------------------------------------------------

    pub(crate) fn iteration(&mut self, cost: &SimCost, graph: &OpGraph)
        -> Result<()> {
        self.moment = 0;
        let n_layer_ops = 7usize;
        let layer_of = |op_idx: usize| -> u32 {
            // ops: embed, L x 7, lnf, lm_head
            if op_idx == 0 {
                0
            } else {
                (((op_idx - 1) / n_layer_ops) as u32).min(
                    graph.spec.layers.saturating_sub(1),
                )
            }
        };

        // ---- FWD
        self.stage = Stage::Fwd;
        self.gathered.clear();
        for (i, op) in graph.ops.iter().enumerate() {
            let live = layer_of(i) + 1;
            self.moment_tick(cost, live)?;
            self.exec_op(cost, graph, i, op.params.clone())?;
        }
        self.mgr.reset_after_fwd(ChunkKind::ParamFp16)?;

        // ---- BWD (reverse op order)
        self.stage = Stage::Bwd;
        self.gathered.clear();
        for (i, op) in graph.ops.iter().enumerate().rev() {
            let live = layer_of(i) + 1;
            self.moment_tick(cost, live)?;
            self.exec_op(cost, graph, i, op.params.clone())?;
        }

        // ---- ADAM (rank-local chunk groups)
        self.stage = Stage::Adam;
        let local = self.groups.owned_by(0);
        for (li, pos) in local.iter().enumerate() {
            self.moment_tick(cost, 0)?;
            // Pipeline the optimizer sweep: while group `li` computes,
            // the next group's grad chunk rides the D2H stream home.
            if !self.warmup && self.prefetcher.is_some() {
                self.stage_next_adam_group(&local, li)?;
            }
            self.exec_adam(cost, *pos, li)?;
        }
        // Embedding ADAM runs on CPU over its own (unmanaged) buffers.
        let emb_os_bytes = 16 * graph.spec.embedding_params()
            / self.nproc as u64;
        if !self.warmup {
            let cpu = cost.shared_cpu();
            self.backend
                .execute_moment(Phase::Adam, cpu.adam_time(emb_os_bytes));
        }
        // The optimizer step is not done until every reduce-scatter has
        // drained off the collective stream (exec_adam waits per group;
        // this barrier catches any group whose drain no consumer hit).
        if !self.warmup && self.collectives_overlapped() {
            for t in self.coll.drain_rs() {
                self.backend.sync_collective(t);
            }
        }
        Ok(())
    }

    /// Advance one moment: record/evaluate non-model footprint, re-cap
    /// the chunkable GPU space, evict to fit, stage upcoming chunks.
    fn moment_tick(&mut self, cost: &SimCost, live_layers: u32)
        -> Result<()> {
        let nm = if live_layers == 0 {
            BASE_OVERHEAD
        } else {
            non_model_bytes(
                &cost.task.model,
                cost.task.batch_per_gpu,
                cost.task.plan,
                live_layers,
            )
        };
        let cap = if self.warmup || !self.opt.use_tracer {
            (cost.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64
        } else {
            cost.cluster.gpu_mem.saturating_sub(nm)
        };
        if self.warmup {
            let m = self.tracer.record_moment(nm);
            debug_assert_eq!(m, self.moment);
        }
        // A landed lookahead gather turns its chunks back into ordinary
        // residents *before* the cap shrink, so pressure prefers normal
        // eviction over cancelling still-queued gathers.
        if !self.warmup && self.collectives_overlapped() {
            self.complete_landed_gathers();
        }
        // Chaos abort poll: a fault-injecting backend may report that a
        // transient failure killed one in-flight transfer this moment.
        // Well-behaved backends always answer false (zero cost); the
        // guard keeps warm-up identical with and without chaos.
        if !self.warmup && self.backend.poll_abort() {
            self.inject_abort()?;
        }
        // Feedback first: the controller differences the backend's
        // per-stream work accumulators against the previous tick, so
        // this tick's window sizes reflect everything charged up to the
        // previous operator (self.ctl is only ever Some in adaptive
        // mode, after warm-up).
        let cw = self.backend.compute_work();
        let hb = self.backend.copy_busy(CopyDir::H2D);
        let kw = self.backend.collective_work();
        let nb = if self.mgr.has_nvme() {
            Some(self.backend.nvme_busy())
        } else {
            None
        };
        if let Some(c) = self.ctl.as_mut() {
            c.observe(cw, hb, kw);
            // The NVMe lane's own demand ratio (tier on only) sizes the
            // deeper window NVMe-resident chunks are staged from.
            if let Some(nb) = nb {
                c.observe_nvme(cw, nb);
            }
        }
        self.mgr.set_device_capacity(Device::Gpu(0), cap);
        // Cap-shrink eviction.  In adaptive mode with the OPT policy a
        // deep D2H backlog turns on the overlap-aware tie-break: a
        // near-equal victim that can be *dropped* (all tensors FREE)
        // beats one whose spill would queue behind the backlog.  Margin
        // 0 (static mode, idle engine, non-OPT policy) is plain OPT.
        let evict_margin = match (&self.ctl, &self.policy) {
            (Some(c), PolicySel::Opt) => {
                c.evict_margin(self.backend.copy_backlog(CopyDir::D2H))
            }
            _ => 0,
        };
        if evict_margin > 0 {
            let droppable: BTreeSet<ChunkId> = self
                .mgr
                .reg
                .chunks
                .iter()
                .filter(|c| c.device == Some(Device::Gpu(0)))
                .map(|c| c.id)
                .filter(|&id| self.mgr.all_free(id))
                .collect();
            // With the NVMe tier live, the tie-break also prices where
            // a spilled victim would land *right now*: behind a full
            // CPU the cascade pushes it all the way to NVMe, so a
            // near-tie victim whose round trip rides the slower curve
            // loses to a cheaper one.  Without the tier this is the
            // plain backlog-aware policy, decision for decision.
            if self.mgr.has_nvme() {
                let chunk_bytes =
                    self.mgr.chunk(self.fp16_list[0]).bytes();
                let spill_to = if self
                    .mgr
                    .space
                    .dev(Device::Cpu)
                    .can_fit(chunk_bytes)
                {
                    Device::Cpu
                } else {
                    Device::Nvme
                };
                let pricing = TierPricing::from_net(&cost.cluster.net);
                let TrainingSession { mgr, tracer, moment, .. } = self;
                let mut pol = TierAwareOpt {
                    tracer,
                    droppable,
                    margin: evict_margin,
                    pricing,
                    spill_to,
                };
                mgr.evict_to_fit(Device::Gpu(0), &mut pol, *moment)?;
            } else {
                let TrainingSession { mgr, tracer, moment, .. } = self;
                let mut pol = BacklogAwareOpt {
                    tracer,
                    droppable,
                    margin: evict_margin,
                };
                mgr.evict_to_fit(Device::Gpu(0), &mut pol, *moment)?;
            }
        } else {
            let TrainingSession { mgr, tracer, policy, moment, .. } = self;
            with_policy(policy, tracer, |pol| {
                mgr.evict_to_fit(Device::Gpu(0), pol, *moment)
            })?;
        }
        self.charge_moves()?;
        // Window sizing + the negotiated headroom ledger.  Static mode:
        // the configured knobs and a ledger with no earmarks — whose
        // arithmetic is exactly the pre-ledger budgets, bit-for-bit.
        let inputs = WindowInputs {
            pool_free: if self.pool.enabled() {
                Some(self.pool.available_at(self.backend.now(),
                                            CopyDir::H2D) as u32)
            } else {
                None
            },
            h2d_backlog_secs: self.backend.copy_backlog(CopyDir::H2D),
            coll_backlog_secs: self.backend.collective_backlog(),
        };
        let chunk_la = match &self.ctl {
            Some(c) => c.chunk_window(inputs),
            None => self.opt.lookahead,
        };
        // NVMe-resident chunks need more headstart than CPU-resident
        // ones (two hops on a slower curve): the controller learns how
        // much deeper their window must reach.  Tier off: the windows
        // coincide and the walk below is the two-tier walk exactly.
        let nvme_la = if self.mgr.has_nvme() {
            match &self.ctl {
                Some(c) => c.nvme_window(inputs),
                None => chunk_la,
            }
        } else {
            chunk_la
        };
        let group_la = match &self.ctl {
            Some(c) => c.group_window(inputs),
            None => self.opt.group_lookahead,
        };
        let mut ledger = HeadroomLedger::new(
            self.moment,
            cost.cluster.gpu_mem,
            self.opt.use_tracer,
        );
        if self.ctl.is_some() && self.group_prefetcher.is_some() {
            // Negotiation: reserve the upcoming all-gathers' bytes
            // before the chunk walk starts, so a deep chunk window
            // cannot starve the collective lane of headroom.  (Demand
            // traffic preempts both — it never consults the ledger.)
            self.earmark_upcoming_gathers(group_la, &mut ledger);
        }
        if !self.warmup && self.prefetcher.is_some() {
            self.chunk_win.0 += chunk_la as u64;
            self.chunk_win.1 += 1;
            self.issue_prefetches(chunk_la, nvme_la, &ledger)?;
            self.charge_moves()?;
        }
        if !self.warmup && self.group_prefetcher.is_some() {
            self.group_win.0 += group_la as u64;
            self.group_win.1 += 1;
            self.issue_group_gathers(group_la, &mut ledger)?;
            self.charge_moves()?;
        }
        self.moment += 1;
        if let Some(tr) = self.trace.as_mut() {
            tr.push(format!("m{:05} {}", self.moment - 1,
                            self.backend.snapshot()));
        }
        Ok(())
    }

    /// A gather whose collective has completed by the current compute
    /// time holds real data: its chunks become normal resident chunks
    /// (evictable under the usual rules — spilling landed data is
    /// honest, spilling a half-arrived payload is not).  The in-flight
    /// entry itself stays until the demand fetch consumes it, at zero
    /// stall.
    fn complete_landed_gathers(&mut self) {
        let now_t = self.backend.now();
        for g in self.coll.landed(now_t) {
            let members: Vec<usize> = self.groups.members(g).collect();
            for p in members {
                self.mgr.finish_gather(self.fp16_list[p]);
            }
        }
    }

    /// Deliver one injected abort (chaos backend, ISSUE 6): cancel the
    /// lowest-numbered group with a gather still on the wire, else the
    /// oldest prefetch copy still queued.  Everything downstream is the
    /// ordinary cancel machinery — the manager emits a
    /// `GatherCancel`/`PrefetchCancel` event and the next
    /// `charge_events` drain runs the same credit-back paths memory
    /// pressure uses, so an abort can never drift the accounting.
    /// Victim order is deterministic (sorted ids), so same-seed chaos
    /// replays cancel the same transfers.  With nothing in flight the
    /// abort hit a quiet wire and is a no-op.
    fn inject_abort(&mut self) -> Result<()> {
        // Landed gathers were completed just above (`is_gathering` is
        // already false for them): only gathers genuinely mid-wire can
        // be victims, so the demand re-gather re-charges exactly what
        // the cancel credited back.
        for g in self.coll.inflight_groups() {
            for p in self.groups.members(g) {
                let c = self.fp16_list[p];
                if self.mgr.is_gathering(c) {
                    self.mgr.cancel_gather(c)?;
                    return Ok(());
                }
            }
        }
        let now_t = self.backend.now();
        let mut queued: Vec<ChunkId> = self
            .inflight_done
            .iter()
            .filter(|(_, pc)| pc.done > now_t)
            .map(|(&c, _)| c)
            .collect();
        queued.sort_unstable_by_key(|c| c.0);
        for c in queued {
            if self.mgr.is_inflight(c) {
                self.mgr.cancel_prefetch(c)?;
                return Ok(());
            }
        }
        Ok(())
    }

    /// Pinned-lease leak guard (ISSUE 6 satellite): every sim-path
    /// lease must have expired by the iteration's makespan or been
    /// released by its cancel path; a holdout means a path forgot to
    /// release.  Debug builds assert (inside the pool); release builds
    /// count into `MoveStats::lease_leaks` for the report.
    pub(crate) fn check_lease_leaks(&mut self) {
        if !self.pool.enabled() {
            return;
        }
        let leaked = self.pool.leak_check(self.backend.makespan()) as u64;
        self.mgr.stats.lease_leaks += leaked;
    }

    /// Record the byte needs of the next `k` scheduled group gathers as
    /// ledger earmarks (adaptive mode).  Mirrors the walk of
    /// [`TrainingSession::issue_group_gathers`] up to (not including)
    /// its budget and pool checks, so exactly the groups that *could*
    /// issue this tick or soon after hold reservations against the
    /// chunk walk.
    fn earmark_upcoming_gathers(&self, k: u32, ledger: &mut HeadroomLedger) {
        let upcoming = match &self.group_prefetcher {
            Some(gp) => gp.upcoming(self.moment, k as usize),
            None => return,
        };
        let chunk_bytes = self.mgr.chunk(self.fp16_list[0]).bytes();
        for (_, g) in upcoming {
            if self.coll.gather_issued(g) {
                continue; // already staged; its bytes show in used()
            }
            if self.gathered.contains(&g) {
                break; // schedule-order FIFO, as in the issue walk
            }
            let absent = self
                .groups
                .members(g)
                .map(|p| self.fp16_list[p])
                .filter(|&c| self.mgr.chunk(c).device.is_none())
                .count() as u64;
            if absent == 0 {
                break;
            }
            ledger.earmark_group(g, absent * chunk_bytes);
        }
    }

    /// Issue all-gathers for the next `k` groups of the warm-up gather
    /// schedule onto the collective stream, drawing headroom from the
    /// negotiated ledger (statically `k = --group-lookahead`;
    /// adaptively the controller's collective/compute window).  Issue
    /// order strictly follows the schedule: if the next group cannot be
    /// staged (no absent members yet, or no headroom), later groups
    /// must not jump the queue — a demand gather must never find a
    /// less-urgent gather ahead of it on the stream.
    fn issue_group_gathers(
        &mut self,
        k: u32,
        ledger: &mut HeadroomLedger,
    ) -> Result<()> {
        let k = k as usize;
        if k == 0 {
            return Ok(());
        }
        let now = self.moment;
        let upcoming = match &self.group_prefetcher {
            Some(gp) => gp.upcoming(now, k),
            None => return Ok(()),
        };
        for (use_m, g) in upcoming {
            if self.coll.gather_issued(g) {
                continue; // already on the stream, in schedule order
            }
            if self.gathered.contains(&g) {
                break; // still held from the previous stage; retry later
            }
            let members: Vec<usize> = self.groups.members(g).collect();
            let absent: Vec<ChunkId> = members
                .iter()
                .map(|&p| self.fp16_list[p])
                .filter(|&c| self.mgr.chunk(c).device.is_none())
                .collect();
            if absent.is_empty() {
                break; // nothing to gather (yet); keep FIFO order
            }
            let chunk_bytes = self.mgr.chunk(self.fp16_list[0]).bytes();
            let new_bytes = absent.len() as u64 * chunk_bytes;
            // Headroom budget from the ledger: the tightest chunkable
            // cap between now and the use moment, minus the *other*
            // groups' reservations (this group's own earmark is the
            // headroom being spent), so staging never triggers the
            // evictions it is hiding from.
            let budget = ledger.gather_budget(&self.tracer, use_m, g);
            let gpu = self.mgr.space.dev(Device::Gpu(0));
            if gpu.used() + new_bytes > budget
                || !gpu.can_fit(new_bytes)
            {
                break; // no headroom; retry next moment
            }
            // A lookahead gather stages its local shard through one
            // pinned buffer held for the collective's lifetime; if
            // every buffer is leased out, the gather waits its turn
            // (FIFO: later groups must not jump the queue either).
            let lease = if self.pool.enabled() {
                match self.pool.try_acquire(self.backend.now(),
                                            CopyDir::H2D) {
                    Some(l) => Some(l),
                    None => {
                        self.mgr.stats.pinned_waits += 1;
                        break; // retry next moment
                    }
                }
            } else {
                None
            };
            for &c in &absent {
                self.mgr.alloc_payload(c, Device::Gpu(0))?;
                self.mgr.begin_gather(c)?;
                // Remote payloads arrive in HOLD (as in fetch_group).
                self.mgr.retag_tensors(
                    c, TensorState::Free, TensorState::Hold)?;
            }
            let op = self.backend.allgather_cost(chunk_bytes);
            let done =
                self.backend.issue_collective(Phase::AllGather, op.secs);
            if let Some(l) = lease {
                self.pool.set_release(l, done);
            }
            self.allgather_time += op.secs;
            self.allgather_bytes += op.bytes;
            self.coll.issue_gather(
                g,
                InFlightGather {
                    done,
                    secs: op.secs,
                    bytes: op.bytes,
                    use_moment: use_m,
                    lease,
                },
            );
            self.gather_prefetches += 1;
            // The reservation is spent: the staged bytes now show in
            // the device's used(), so keeping the earmark would charge
            // the remaining groups twice.
            ledger.consume_group(g);
        }
        Ok(())
    }

    /// Walk the lookahead window and stage CPU- and NVMe-resident
    /// chunks with an upcoming GPU use onto the H2D stream (statically
    /// `lookahead = --lookahead`; adaptively the controller's
    /// ratio-sized, backlog-compressed, pool-bounded window).  With the
    /// NVMe tier live the walk reaches `nvme_lookahead >= lookahead`
    /// moments ahead, but CPU-resident chunks still only stage within
    /// the shallower window — the extra depth exists to give two-hop
    /// copies their headstart, not to stage PCIe copies earlier.
    fn issue_prefetches(
        &mut self,
        lookahead: u32,
        nvme_lookahead: u32,
        ledger: &HeadroomLedger,
    ) -> Result<()> {
        let now = self.moment;
        let walk = lookahead.max(nvme_lookahead);
        let window = match &self.prefetcher {
            Some(pf) => pf.window(now, walk),
            None => return Ok(()),
        };
        // Staging-capacity budget (pool enabled only): each prefetch
        // issued this tick will lease one pinned buffer when its copy is
        // charged; once the free H2D buffers are spoken for, the rest of
        // the window waits for the next moment — the effective lookahead
        // is throttled to the pool-sized backlog.
        let mut pool_budget = if self.pool.enabled() {
            Some(self.pool.available_at(self.backend.now(), CopyDir::H2D))
        } else {
            None
        };
        for (use_moment, c) in window {
            match self.mgr.chunk(c).device {
                Some(Device::Cpu) => {
                    if use_moment.saturating_sub(now) > lookahead {
                        continue; // only in the NVMe window's tail
                    }
                }
                Some(Device::Nvme) => {}
                _ => continue, // resident, in flight, or released
            }
            if pool_budget == Some(0) {
                self.mgr.stats.pinned_waits += 1;
                break; // no staging buffer free; retry next moment
            }
            // Headroom budget from the ledger: staying under the
            // tightest chunkable cap between now and the use moment
            // (minus any bytes earmarked for the collective lane)
            // guarantees the staged bytes never cause a cap-shrink
            // eviction of their own nor starve an imminent all-gather.
            let limit = ledger.chunk_limit(&self.tracer, use_moment);
            let TrainingSession { mgr, tracer, policy, .. } = self;
            let issued = with_policy(policy, tracer, |pol| {
                mgr.prefetch_to(c, Device::Gpu(0), limit, pol, now, &|v| {
                    // Belady guard: spill only chunks OPT would spill at
                    // the use moment anyway — next use farther than the
                    // prefetched chunk's own use.
                    match tracer.next_use(v, now) {
                        None => true,
                        Some(next) => next > use_moment,
                    }
                })
            })?;
            if issued {
                if let Some(b) = pool_budget.as_mut() {
                    *b -= 1;
                }
            }
        }
        Ok(())
    }

    /// The ADAM-bound leg of the pipeline: stage the *next* local
    /// group's fp16 (grad) chunk onto the CPU over the async D2H stream
    /// while the current group's update computes.  Margin groups (ADAM
    /// on GPU) need no staging — their chunks are already resident.
    /// Conservative by construction: only free CPU space is used (no
    /// evictions for staging), so the transfer set matches the serial
    /// schedule exactly, just earlier and off the critical path.
    fn stage_next_adam_group(&mut self, local: &[usize], li: usize)
        -> Result<()> {
        let next = li + 1;
        if next >= local.len() {
            return Ok(());
        }
        let next_on_gpu = self.opt.device_aware_os
            && next < self.placement.os_groups_on_gpu;
        if next_on_gpu {
            return Ok(());
        }
        let c = self.fp16_list[local[next]];
        if self.mgr.chunk(c).device != Some(Device::Gpu(0)) {
            return Ok(()); // already home (or released)
        }
        // The D2H staging leg competes for the pinned pool's D2H
        // sub-pool: with no buffer free, the grad chunk waits and rides
        // home on the demand path instead.
        if self.pool.enabled()
            && self.pool.available_at(self.backend.now(), CopyDir::D2H)
                == 0
        {
            self.mgr.stats.pinned_waits += 1;
            return Ok(());
        }
        let limit = self.mgr.space.dev(Device::Cpu).capacity;
        let now = self.moment.saturating_sub(1);
        let TrainingSession { mgr, tracer, policy, .. } = self;
        with_policy(policy, tracer, |pol| {
            mgr.prefetch_to(c, Device::Cpu, limit, pol, now, &|_| false)
        })?;
        self.charge_adam_moves()?;
        Ok(())
    }

    /// If `chunk` has an in-flight prefetch, block the compute stream
    /// until the copy lands and mark it consumed.  On the real backend
    /// an in-flight copy has no completion time (`done` infinite); its
    /// staging lease frees here, at consumption.
    fn wait_chunk(&mut self, chunk: ChunkId) {
        if self.mgr.is_inflight(chunk) {
            if let Some(pc) = self.inflight_done.get(&chunk).copied() {
                if pc.done.is_finite() {
                    self.backend.sync_until(pc.done);
                }
            }
            self.mgr.complete_prefetch(chunk);
        }
        if let Some(pc) = self.inflight_done.remove(&chunk) {
            // Real-backend staging leases are open-ended (`done`
            // infinite): they free here, at consumption — also covering
            // a chunk whose prefetch a last-resort eviction already
            // force-completed (simulated leases expire on the clock
            // instead, so this arm never fires for finite `done`).
            if pc.done.is_infinite() {
                if let Some(l) = pc.lease {
                    self.pool.release(l);
                }
            }
        }
    }

    /// Chunk owning the `idx`-th tensor of `kind`.
    fn chunk_of(&self, kind: ChunkKind, idx: usize) -> ChunkId {
        let ti = self.mgr.reg.tensor_index(kind, idx);
        ChunkId(self.mgr.reg.tensors[ti].chunk as u32)
    }

    /// Execute one operator at the current moment (stage-dependent).
    fn exec_op(
        &mut self,
        cost: &SimCost,
        graph: &OpGraph,
        op_idx: usize,
        params: Vec<usize>,
    ) -> Result<()> {
        let op = &graph.ops[op_idx];
        let now = self.moment.saturating_sub(1);

        // Embedding ops: CPU lookup + activation traffic; LM head GEMM on
        // GPU with the fp16 embedding streamed up (Sec. 8.2).
        if op.kind == OpKind::Embedding {
            if !self.warmup {
                let cpu = cost.shared_cpu();
                let m = &graph.spec;
                let act_bytes =
                    2 * cost.task.batch_per_gpu * m.seq * m.hidden;
                if op.name == "embed" {
                    self.backend.execute_moment(
                        Phase::FwdBwd,
                        cpu.op_time(OpKind::Embedding, op.fwd_flops),
                    );
                    let (phase, dir) = if self.stage == Stage::Fwd {
                        (Phase::CpuToGpu, CopyDir::H2D)
                    } else {
                        (Phase::GpuToCpu, CopyDir::D2H)
                    };
                    let t = self
                        .backend
                        .copy_secs(act_bytes, CopyRoute::Pinned);
                    self.backend.demand_copy(phase, t, dir, 0.0);
                } else {
                    // lm_head: GEMM on GPU; wte fp16 up in FWD, its grad
                    // down in BWD.
                    let gpu = cost.cluster.gpu;
                    let mult = cost.bwd_mult(self.stage);
                    self.backend.execute_moment(
                        Phase::FwdBwd,
                        gpu.op_time(OpKind::ComputeIntensive,
                                    mult * op.fwd_flops),
                    );
                    let wte_bytes = 2 * m.vocab * m.hidden;
                    let (phase, dir) = if self.stage == Stage::Fwd {
                        (Phase::CpuToGpu, CopyDir::H2D)
                    } else {
                        (Phase::GpuToCpu, CopyDir::D2H)
                    };
                    let t = self
                        .backend
                        .copy_secs(wte_bytes, CopyRoute::Pinned);
                    self.backend.demand_copy(phase, t, dir, 0.0);
                }
            }
            return Ok(());
        }

        // Distributed: fetch the communication groups of every param.
        // BTreeSet throughout: group order must be deterministic —
        // unordered-set iteration varies per process, which would make
        // the multi-GPU stream timeline (and the golden traces locked
        // on it) run-to-run nondeterministic.
        if self.nproc > 1 {
            let positions: BTreeSet<usize> = params
                .iter()
                .map(|&t| {
                    let ti =
                        self.mgr.reg.tensor_index(ChunkKind::ParamFp16, t);
                    self.mgr.reg.chunks[self.mgr.reg.tensors[ti].chunk]
                        .list_pos as usize
                })
                .collect();
            let groups: BTreeSet<usize> = positions
                .iter()
                .map(|&p| self.groups.group_of(p))
                .collect();
            for g in groups {
                self.fetch_group(g, now)?;
            }
        }

        // Access parameters (Algorithm 1), run the op, release
        // (Algorithm 2).  A prefetched chunk's copy is waited out on the
        // timeline before the access consumes it.
        for &t in &params {
            let c = self.chunk_of(ChunkKind::ParamFp16, t);
            self.wait_chunk(c);
            let TrainingSession { mgr, tracer, policy, .. } = self;
            with_policy(policy, tracer, |pol| {
                mgr.access_tensor(ChunkKind::ParamFp16, t, Device::Gpu(0),
                                  pol, now)
            })?;
            if self.warmup {
                self.tracer.record_chunk_use_at(c, now, true);
            }
        }
        self.charge_moves()?;

        if !self.warmup {
            let gpu = cost.cluster.gpu;
            let mult = cost.bwd_mult(self.stage);
            self.backend.execute_moment(
                Phase::FwdBwd,
                gpu.op_time(op.kind, mult * op.fwd_flops),
            );
            // Activation offload traffic (ckpt+offload): one boundary per
            // layer crosses PCIe each way; charge at the layer's last op.
            // Down in FWD (async: nothing waits for it), up in BWD (the
            // boundary op needs it: demand).
            if cost.task.plan == ActivationPlan::CheckpointingOffload
                && op.name.ends_with(".fc2")
            {
                let m = &graph.spec;
                let bytes = 2 * cost.task.batch_per_gpu * m.seq * m.hidden;
                if self.stage == Stage::Fwd {
                    // Offload cannot wait for a buffer (the boundary is
                    // leaving the GPU now): pinned if one is free,
                    // pageable otherwise.
                    let (_, done, _, lease) = self.charge_async_routed(
                        Phase::ActOffload, CopyDir::D2H, 0.0, bytes);
                    if let Some(l) = lease {
                        self.stream_leases.push(StreamLease {
                            lease: l,
                            dir: CopyDir::D2H,
                            done,
                        });
                    }
                } else {
                    // Demand reload: preempts the pool, pinned rate.
                    let t =
                        self.backend.copy_secs(bytes, CopyRoute::Pinned);
                    self.backend.demand_copy(Phase::ActOffload, t,
                                             CopyDir::H2D, 0.0);
                }
            }
        }

        let target = if self.stage == Stage::Fwd {
            TensorState::HoldAfterFwd
        } else {
            TensorState::HoldAfterBwd
        };
        for &t in &params {
            self.mgr.release_tensor(ChunkKind::ParamFp16, t, target)?;
        }

        // Distributed: release/reduce groups that completed this stage
        // (deterministic order, as above).
        if self.nproc > 1 {
            let positions: BTreeSet<usize> = params
                .iter()
                .map(|&t| {
                    let ti =
                        self.mgr.reg.tensor_index(ChunkKind::ParamFp16, t);
                    self.mgr.reg.chunks[self.mgr.reg.tensors[ti].chunk]
                        .list_pos as usize
                })
                .collect();
            let groups: BTreeSet<usize> = positions
                .iter()
                .map(|&p| self.groups.group_of(p))
                .collect();
            for g in groups {
                self.release_group(g, target)?;
            }
        }
        Ok(())
    }

    /// FetchRemoteChunks (Algorithm 1, lines 1–20): all-gather the group
    /// if any member tensor is FREE.
    fn fetch_group(&mut self, g: usize, now: Moment) -> Result<()> {
        if self.gathered.contains(&g) {
            return Ok(());
        }
        // Consume an in-flight lookahead gather: block only for
        // whatever part of the collective compute hasn't already hidden.
        if let Some(gi) = self.coll.take_gather(g) {
            self.backend.sync_collective(gi.done);
            for p in self.groups.members(g) {
                self.mgr.finish_gather(self.fp16_list[p]);
            }
            self.gathered.insert(g);
            return Ok(());
        }
        let members: Vec<usize> = self.groups.members(g).collect();
        // Trigger only when some member chunk is absent (paper line 5:
        // a FREE tensor exists).
        let any_free = members.iter().any(|&p| {
            let c = self.fp16_list[p];
            self.mgr.chunk(c).device.is_none()
        });
        if !any_free {
            self.gathered.insert(g);
            return Ok(());
        }
        if self.warmup {
            // The gather log *is* the steady-state gather schedule
            // (iterations are structurally identical) — the group
            // prefetcher is built from it after warm-up.
            self.gather_log.push((now, g));
        }
        let chunk_bytes = self.mgr.chunk(self.fp16_list[0]).bytes();
        for &p in &members {
            let c = self.fp16_list[p];
            self.wait_chunk(c);
            let TrainingSession { mgr, tracer, policy, .. } = self;
            with_policy(policy, tracer, |pol| {
                mgr.ensure_on(c, Device::Gpu(0), pol, now)
            })?;
            self.mgr.pin(c);
            // Remote payloads arrive in HOLD.
            self.mgr
                .retag_tensors(c, TensorState::Free, TensorState::Hold)?;
            if self.warmup {
                self.tracer.record_chunk_use_at(c, now, true);
            }
        }
        if !self.warmup {
            let op = self.backend.allgather_cost(chunk_bytes);
            if self.collectives_overlapped() {
                // Demand gather on the collective stream: compute
                // stalls for queueing delay + wire time.
                self.backend.demand_collective(Phase::AllGather, op.secs);
            } else {
                self.backend.execute_moment(Phase::AllGather, op.secs);
            }
            self.allgather_time += op.secs;
            self.allgather_bytes += op.bytes;
        }
        for &p in &members {
            self.mgr.unpin(self.fp16_list[p]);
        }
        self.charge_moves()?;
        self.gathered.insert(g);
        Ok(())
    }

    /// ReleaseRemoteChunk (Algorithm 2, lines 1–30).
    fn release_group(&mut self, g: usize, target: TensorState)
        -> Result<()> {
        let members: Vec<usize> = self.groups.members(g).collect();
        // All tensors of all member chunks must have reached `target`.
        let done = members.iter().all(|&p| {
            let c = self.fp16_list[p];
            self.mgr.chunk(c).tensors.iter().all(|t| {
                self.mgr.reg.tensors[t.0 as usize].state == target
            })
        });
        if !done {
            return Ok(());
        }
        if target == TensorState::HoldAfterBwd && !self.warmup {
            // Reduce-scatter of the group's grad chunks (is_allreduce).
            let chunk_bytes = self.mgr.chunk(self.fp16_list[0]).bytes();
            let op = self.backend.reduce_scatter_cost(chunk_bytes);
            if self.collectives_overlapped() {
                // Drain behind compute (and behind queued gathers);
                // ADAM waits it out per group.
                let done = self
                    .backend
                    .issue_collective(Phase::ReduceScatter, op.secs);
                self.coll.set_rs_done(g, done);
            } else {
                self.backend
                    .execute_moment(Phase::ReduceScatter, op.secs);
            }
            self.reduce_scatter_time += op.secs;
            self.reduce_scatter_bytes += op.bytes;
        }
        // Release remote payloads; tensors -> FREE.
        for &p in &members {
            if self.groups.owner_of(p) == 0 {
                continue; // local chunk keeps its payload
            }
            let c = self.fp16_list[p];
            let chunk_tensors = self.mgr.chunk(c).tensors.clone();
            for t in chunk_tensors {
                self.mgr.reg.tensors[t.0 as usize]
                    .set_state(TensorState::Free)
                    .map_err(|e| anyhow!(e))?;
            }
            if self.mgr.chunk(c).device.is_some() {
                self.mgr.release_payload(c)?;
            }
        }
        self.gathered.remove(&g);
        Ok(())
    }

    /// ADAM over one local chunk group (Sec. 6.2 last paragraph + 8.2).
    fn exec_adam(&mut self, cost: &SimCost, pos: usize, local_index: usize)
        -> Result<()> {
        let now = self.moment.saturating_sub(1);
        let fp16 = self.fp16_list[pos];
        // The group's averaged gradient must be home before the update:
        // wait out whatever part of its reduce-scatter hasn't drained.
        if !self.warmup && self.collectives_overlapped() {
            let g = self.groups.group_of(pos);
            if let Some(t) = self.coll.take_rs_done(g) {
                self.backend.sync_collective(t);
            }
        }
        let os = self.mgr.reg.os_chunks_for(fp16);
        let on_gpu = !self.warmup
            && self.opt.device_aware_os
            && local_index < self.placement.os_groups_on_gpu;
        let device = if on_gpu { Device::Gpu(0) } else { Device::Cpu };

        // Bring the grad (fp16 chunk) and the OS chunks to the ADAM device.
        for c in std::iter::once(fp16).chain(os) {
            self.wait_chunk(c);
            let TrainingSession { mgr, tracer, policy, .. } = self;
            with_policy(policy, tracer, |pol| {
                mgr.ensure_on(c, device, pol, now)
            })?;
            if self.warmup {
                self.tracer.record_chunk_use_at(c, now, device.is_gpu());
            }
        }
        // OS tensors -> COMPUTE -> HOLD; fp16 tensors -> HOLD (updated
        // params overwrite the grads in place, Fig. 6 reversed).
        let n_tensors = self.mgr.chunk(fp16).tensors.len();
        for kind in [ChunkKind::ParamFp32, ChunkKind::Momentum,
                     ChunkKind::Variance] {
            for i in 0..n_tensors {
                let t = self.mgr.chunk(fp16).tensors[i];
                let idx = t.0 as usize % self.mgr.reg.n_model_tensors;
                let TrainingSession { mgr, tracer, policy, .. } = self;
                with_policy(policy, tracer, |pol| {
                    mgr.access_tensor(kind, idx, device, pol, now)
                })?;
                self.mgr.release_tensor(kind, idx, TensorState::Hold)?;
            }
        }
        for i in 0..n_tensors {
            let t = self.mgr.chunk(fp16).tensors[i];
            let idx = t.0 as usize % self.mgr.reg.n_model_tensors;
            let ti = self.mgr.reg.tensor_index(ChunkKind::ParamFp16, idx);
            let s = self.mgr.reg.tensors[ti].state;
            if s.is_hold_like() {
                self.mgr.reg.tensors[ti]
                    .set_state(TensorState::Hold)
                    .map_err(|e| anyhow!(e))?;
            }
        }

        if !self.warmup {
            let chunk_elems = self.mgr.reg.chunk_elems;
            let prof = if on_gpu {
                cost.cluster.gpu
            } else {
                cost.shared_cpu()
            };
            // grad fp16 -> fp32 conversion + fused update over
            // p32/m/v (+p16 writeback): ~16 B/elem of traffic.
            self.backend
                .execute_moment(Phase::Adam,
                                prof.cast_time(2 * chunk_elems));
            self.backend
                .execute_moment(Phase::Adam,
                                prof.adam_time(16 * chunk_elems));
        }
        self.charge_adam_moves()?;
        Ok(())
    }

    // ------------------------------------------------------------ helpers

    /// Pick the host-memory path for an async (non-demand) PCIe copy of
    /// `bytes` in direction `dir`: pinned while a staging buffer from
    /// `dir`'s sub-pool is held, pageable when the pool (total or
    /// sub-pool) is exhausted (pressure-driven copies cannot wait).
    /// With the pool disabled everything is pinned on the single curve
    /// — the pre-pool behaviour bit-for-bit.  The caller sets the
    /// returned lease's release time once the copy's completion time is
    /// known.
    fn route_async_copy(&mut self, dir: CopyDir, bytes: u64)
        -> (f64, CopyRoute, Option<PinnedLease>) {
        if !self.pool.enabled() {
            return (
                self.backend.copy_secs(bytes, CopyRoute::Pinned),
                CopyRoute::Pinned,
                None,
            );
        }
        match self.pool.try_acquire(self.backend.now(), dir) {
            Some(lease) => (
                self.backend.copy_secs(bytes, CopyRoute::Pinned),
                CopyRoute::Pinned,
                Some(lease),
            ),
            None => (
                self.backend.copy_secs(bytes, CopyRoute::Pageable),
                CopyRoute::Pageable,
                None,
            ),
        }
    }

    /// Route, charge and lease one async copy in a single step: pick
    /// the curve ([`TrainingSession::route_async_copy`]), enqueue on
    /// `dir`, and set the lease's release to the completion time.  The
    /// one place the async lease protocol lives — the Evict and
    /// Prefetch drain arms and the activation-offload path all charge
    /// through here.  Returns (wire secs, completion time, route,
    /// lease).
    fn charge_async_routed(
        &mut self,
        phase: Phase,
        dir: CopyDir,
        ready: f64,
        bytes: u64,
    ) -> (f64, f64, CopyRoute, Option<PinnedLease>) {
        let (t, route, lease) = self.route_async_copy(dir, bytes);
        let done = self.backend.issue_copy(phase, t, dir, ready, route);
        if let Some(l) = lease {
            self.pool.set_release(l, done);
        }
        (t, done, route, lease)
    }

    /// Drain chunk-move events and charge PCIe time (FWD/BWD phases).
    fn charge_moves(&mut self) -> Result<()> {
        self.charge_events(false)
    }

    /// Same, but attribute to the ADAM-move bar of Fig. 16.
    fn charge_adam_moves(&mut self) -> Result<()> {
        self.charge_events(true)
    }

    /// Drain chunk-move events onto the backend.  Evictions ride the
    /// async D2H stream; prefetches the async H2D stream (their
    /// completion time is remembered for `wait_chunk`); demand
    /// transfers block the compute stream.  An H2D fetch issued after an
    /// eviction in the same drain batch waits for that eviction — it is
    /// moving into the space the eviction frees.
    fn charge_events(&mut self, adam: bool) -> Result<()> {
        let events = self.mgr.drain_events();
        if self.warmup {
            return Ok(());
        }
        // Leases whose copies have completed need no more shifting;
        // drop them so the compression scan stays short.
        if self.pool.enabled() {
            let now_t = self.backend.now();
            self.stream_leases.retain(|sl| sl.done > now_t);
        }
        let mut dep = 0.0f64;
        let mut cancelled_groups: Vec<usize> = Vec::new();
        for ev in events {
            if ev.kind == MoveKind::GatherCancel {
                // Memory pressure reclaimed a mid-gather chunk: cancel
                // the whole group's collective.  The demand path will
                // re-gather (and re-charge) exactly once, so total
                // collective volume stays at the serial schedule's.
                let pos = self.mgr.reg.chunks[ev.chunk.0 as usize]
                    .list_pos as usize;
                let g = self.groups.group_of(pos);
                if let Some(gi) = self.coll.take_gather(g) {
                    self.allgather_bytes =
                        self.allgather_bytes.saturating_sub(gi.bytes);
                    self.allgather_time =
                        (self.allgather_time - gi.secs).max(0.0);
                    // The cancelled gather's staging buffer frees now.
                    if let Some(l) = gi.lease {
                        self.pool.release(l);
                    }
                    let now_t = self.backend.now();
                    if gi.done > now_t {
                        // Un-charge only the part of the collective
                        // that has not physically run yet: the full
                        // wire time while still queued, the remainder
                        // when cancelled mid-wire.  Followers compress
                        // forward by the same amount, so no completion
                        // time ever drops below elapsed time.
                        let remainder = (gi.done - now_t).min(gi.secs);
                        self.backend.reclaim_collective(
                            Phase::AllGather, remainder);
                        self.coll.compress_after(gi.done, remainder);
                        // Queue compression moved the surviving
                        // gathers' completion times; their buffer
                        // leases release at the new times.
                        let TrainingSession { coll, pool, .. } = self;
                        for g2 in coll.gathers_mut() {
                            if let Some(l) = g2.lease {
                                pool.set_release(l, g2.done);
                            }
                        }
                    }
                    self.gather_cancelled_groups += 1;
                    cancelled_groups.push(g);
                }
                continue;
            }
            if ev.kind == MoveKind::PrefetchCancel {
                if let Some(pc) = self.inflight_done.remove(&ev.chunk) {
                    // The staging buffer frees with the cancel (a no-op
                    // for an already-landed copy's expired lease).
                    if let Some(l) = pc.lease {
                        self.pool.release(l);
                    }
                    if pc.done > self.backend.now() {
                        // Still queued: un-charge its time so the
                        // timeline agrees with the credited-back
                        // MoveStats — otherwise the later demand fetch
                        // double-charges, and a cancel-heavy run could
                        // look slower than serial.
                        if pc.nvme_secs > 0.0 {
                            // Two-hop staged copy: pull both lane
                            // frontiers back by their own shares.
                            self.backend.reclaim_copy_staged(
                                Phase::Nvme, pc.nvme_secs, pc.phase,
                                pc.secs, pc.dir, pc.route);
                        } else {
                            self.backend.reclaim_copy(pc.phase, pc.secs,
                                                      pc.dir, pc.route);
                        }
                        // Queue compression: copies FIFO-queued behind
                        // the reclaimed one land earlier now; shift
                        // their recorded completion times too, so later
                        // waits and cancel classifications stay honest
                        // — and their buffer leases (prefetch AND
                        // eviction/offload) release earlier with them.
                        let TrainingSession {
                            inflight_done, stream_leases, pool, ..
                        } = self;
                        for other in inflight_done.values_mut() {
                            if other.dir == pc.dir && other.done > pc.done
                            {
                                other.done =
                                    (other.done - pc.secs).max(0.0);
                                if let Some(l) = other.lease {
                                    pool.set_release(l, other.done);
                                }
                            }
                        }
                        for sl in stream_leases.iter_mut() {
                            if sl.dir == pc.dir && sl.done > pc.done {
                                sl.done = (sl.done - pc.secs).max(0.0);
                                pool.set_release(sl.lease, sl.done);
                            }
                        }
                    } else {
                        // The copy had already landed when pressure
                        // reclaimed the chunk: the traffic was real, so
                        // undo the manager's byte credit (the cancel
                        // event's `from` is the staged-on device and
                        // `to` the source it restores to, i.e. the
                        // original copy's destination and origin).
                        match (ev.from, ev.to) {
                            (Some(Device::Gpu(_)), Some(Device::Nvme)) =>
                            {
                                self.mgr.stats.from_nvme_bytes +=
                                    ev.bytes;
                                self.mgr.stats.from_nvme_moves += 1;
                            }
                            (Some(Device::Gpu(_)), _) => {
                                self.mgr.stats.cpu_to_gpu_bytes +=
                                    ev.bytes;
                                self.mgr.stats.cpu_to_gpu_moves += 1;
                            }
                            _ => {
                                self.mgr.stats.gpu_to_cpu_bytes +=
                                    ev.bytes;
                                self.mgr.stats.gpu_to_cpu_moves += 1;
                            }
                        }
                    }
                }
                continue;
            }
            // NVMe-tier moves (tentpole): `copy_dir` only speaks PCIe,
            // so the third tier's pairs are classified here first.
            // GPU<->NVMe runs the two-hop staged route — the NVMe link
            // and the PCIe link each billed on its own lane, with the
            // pinned bounce buffer held across both hops.  CPU<->NVMe
            // is a single hop on the NVMe lane (host-local, no PCIe
            // staging, no pool lease).
            match (ev.from, ev.to) {
                (Some(Device::Nvme), Some(Device::Gpu(_)))
                | (Some(Device::Gpu(_)), Some(Device::Nvme)) => {
                    let dir = if matches!(ev.to, Some(Device::Gpu(_))) {
                        CopyDir::H2D
                    } else {
                        CopyDir::D2H
                    };
                    let pcie_phase = if adam {
                        Phase::AdamMove
                    } else {
                        match dir {
                            CopyDir::H2D => Phase::CpuToGpu,
                            CopyDir::D2H => Phase::GpuToCpu,
                        }
                    };
                    let nvme_t = self
                        .backend
                        .copy_secs(ev.bytes, CopyRoute::NvmeStaged);
                    match ev.kind {
                        MoveKind::Evict => {
                            let (pcie_t, route, lease) =
                                self.route_async_copy(dir, ev.bytes);
                            let done = self.backend.issue_copy_staged(
                                Phase::Nvme, nvme_t, pcie_phase, pcie_t,
                                dir, dep, route);
                            dep = done;
                            if let Some(l) = lease {
                                // Held for the full two-hop duration.
                                self.pool.set_release(l, done);
                                self.stream_leases.push(StreamLease {
                                    lease: l,
                                    dir,
                                    done,
                                });
                            }
                        }
                        MoveKind::Prefetch => {
                            let (pcie_t, route, lease) =
                                self.route_async_copy(dir, ev.bytes);
                            let done = self.backend.issue_copy_staged(
                                Phase::Nvme, nvme_t, pcie_phase, pcie_t,
                                dir, dep, route);
                            if let Some(l) = lease {
                                self.pool.set_release(l, done);
                            }
                            self.inflight_done.insert(
                                ev.chunk,
                                PendingCopy {
                                    done,
                                    secs: pcie_t,
                                    nvme_secs: nvme_t,
                                    dir,
                                    phase: pcie_phase,
                                    route,
                                    lease,
                                },
                            );
                        }
                        _ => {
                            // Demand: both hops block the compute
                            // stream, pinned rate on the PCIe hop.
                            let pcie_t = self
                                .backend
                                .copy_secs(ev.bytes, CopyRoute::Pinned);
                            self.backend.demand_copy_staged(
                                Phase::Nvme, nvme_t, pcie_phase, pcie_t,
                                dir, dep, CopyRoute::Pinned);
                        }
                    }
                    continue;
                }
                (Some(Device::Cpu), Some(Device::Nvme))
                | (Some(Device::Nvme), Some(Device::Cpu)) => {
                    let dir = if ev.to == Some(Device::Nvme) {
                        CopyDir::D2H
                    } else {
                        CopyDir::H2D
                    };
                    let t = self
                        .backend
                        .copy_secs(ev.bytes, CopyRoute::NvmeStaged);
                    match ev.kind {
                        MoveKind::Evict => {
                            // A cascade's inner spill frees the CPU
                            // space its outer eviction moves into:
                            // chain the dependency like PCIe evictions.
                            dep = self.backend.issue_copy_nvme(
                                Phase::Nvme, t, dir, dep);
                        }
                        _ => {
                            self.backend.demand_copy_nvme(
                                Phase::Nvme, t, dir, dep);
                        }
                    }
                    continue;
                }
                _ => {}
            }
            let dir = match ev.copy_dir() {
                Some(d) => d,
                None => continue, // allocs and releases are free
            };
            let phase = if adam {
                Phase::AdamMove
            } else {
                match dir {
                    CopyDir::H2D => Phase::CpuToGpu,
                    CopyDir::D2H => Phase::GpuToCpu,
                }
            };
            match ev.kind {
                MoveKind::Evict => {
                    // Pressure-driven: cannot wait for a buffer, so it
                    // downgrades to the pageable curve when the pool is
                    // dry.
                    let (_, done, _, lease) = self
                        .charge_async_routed(phase, dir, dep, ev.bytes);
                    dep = done;
                    if let Some(l) = lease {
                        self.stream_leases
                            .push(StreamLease { lease: l, dir, done });
                    }
                }
                MoveKind::Prefetch => {
                    // The issue paths reserve pool capacity before
                    // staging, so this normally lands a pinned lease;
                    // if an eviction in the same drain batch took the
                    // last buffer, the copy downgrades rather than
                    // un-staging the chunk.
                    let (t, done, route, lease) = self
                        .charge_async_routed(phase, dir, dep, ev.bytes);
                    self.inflight_done.insert(
                        ev.chunk,
                        PendingCopy { done, secs: t, nvme_secs: 0.0,
                                      dir, phase, route, lease },
                    );
                }
                _ => {
                    // Demand copies preempt the pool: always charged at
                    // the pinned rate, never queued on a buffer.
                    let t = self
                        .backend
                        .copy_secs(ev.bytes, CopyRoute::Pinned);
                    self.backend.demand_copy(phase, t, dir, dep);
                }
            }
        }
        // Finish cancelling each reclaimed group: drop the remaining
        // mid-gather member payloads and revert their tensors, so the
        // group is back in the released state the demand path expects.
        for g in cancelled_groups {
            let members: Vec<usize> = self.groups.members(g).collect();
            for p in members {
                if self.groups.owner_of(p) == 0 {
                    continue; // the local chunk was never gathering
                }
                let c = self.fp16_list[p];
                if self.mgr.is_gathering(c) {
                    // Emits another GatherCancel event; it finds the
                    // group already cancelled on the next drain.
                    self.mgr.cancel_gather(c)?;
                }
                if self.mgr.chunk(c).device.is_none() {
                    self.mgr.retag_tensors(
                        c, TensorState::Hold, TensorState::Free)?;
                }
            }
            self.gathered.remove(&g);
        }
        Ok(())
    }

    // ------------------------------------------------------------------
    // Real-backend surface (the e2e trainer's policy entry points).
    // ------------------------------------------------------------------

    /// Advance the real-path access clock by one moment and return it
    /// (the LRU timestamp the next manager operation is stamped with).
    pub fn bump_moment(&mut self) -> Moment {
        self.moment += 1;
        self.moment
    }

    /// Size this tick's staging window from the backend's measured
    /// compute/transfer feedback (adaptive mode) or the static knob.
    /// The e2e analogue of the window computation in `moment_tick`,
    /// including the window telemetry.
    pub fn real_window(&mut self) -> u32 {
        let cw = self.backend.compute_work();
        let hb = self.backend.copy_busy(CopyDir::H2D);
        let kw = self.backend.collective_work();
        if let Some(c) = self.ctl.as_mut() {
            c.observe(cw, hb, kw);
        }
        let inputs = WindowInputs {
            pool_free: if self.pool.enabled() {
                Some(self.pool.available_at(self.backend.now(),
                                            CopyDir::H2D) as u32)
            } else {
                None
            },
            h2d_backlog_secs: self.backend.copy_backlog(CopyDir::H2D),
            coll_backlog_secs: self.backend.collective_backlog(),
        };
        let w = match &self.ctl {
            Some(c) => c.chunk_window(inputs),
            None => self.opt.lookahead,
        };
        self.chunk_win.0 += w as u64;
        self.chunk_win.1 += 1;
        w
    }

    /// Mean per-tick staging window actually used (telemetry).
    pub fn avg_window(&self) -> f64 {
        if self.chunk_win.1 > 0 {
            self.chunk_win.0 as f64 / self.chunk_win.1 as f64
        } else {
            0.0
        }
    }

    /// Pool-gated staging of one chunk toward `device` (real backend):
    /// the e2e analogue of one `issue_prefetches` walk step.  A staged
    /// chunk holds a pinned buffer until its access consumes it
    /// (`wait_chunk` frees the open-ended lease); a dry pool throttles
    /// the caller's walk instead of issuing.
    pub fn stage_real(
        &mut self,
        chunk: ChunkId,
        device: Device,
        limit: u64,
    ) -> Result<StageOutcome> {
        if self.mgr.chunk(chunk).device != Some(Device::Cpu) {
            return Ok(StageOutcome::Skipped);
        }
        if self.pool.enabled()
            && self.pool.available_at(self.backend.now(), CopyDir::H2D)
                == 0
        {
            self.mgr.stats.pinned_waits += 1;
            return Ok(StageOutcome::PoolDry);
        }
        let now = self.bump_moment();
        let TrainingSession { mgr, tracer, policy, .. } = self;
        let issued = with_policy(policy, tracer, |pol| {
            mgr.prefetch_to(chunk, device, limit, pol, now, &|_| false)
        })?;
        if issued {
            let lease = if self.pool.enabled() {
                self.pool.try_acquire(self.backend.now(), CopyDir::H2D)
            } else {
                None
            };
            let old = self.inflight_done.insert(
                chunk,
                PendingCopy {
                    done: f64::INFINITY,
                    secs: 0.0,
                    nvme_secs: 0.0,
                    dir: CopyDir::H2D,
                    phase: Phase::CpuToGpu,
                    route: CopyRoute::Pinned,
                    lease,
                },
            );
            // A stale entry (the chunk's previous staging was
            // force-completed by a last-resort eviction, then the chunk
            // spilled home without being accessed) must not leak its
            // open-ended lease.
            if let Some(pc) = old {
                if pc.done.is_infinite() {
                    if let Some(l) = pc.lease {
                        self.pool.release(l);
                    }
                }
            }
            self.drain_events_real();
            Ok(StageOutcome::Staged)
        } else {
            self.drain_events_real();
            Ok(StageOutcome::Skipped)
        }
    }

    /// Access one tensor on `device` through Algorithm 1 (real
    /// backend): waits out (consumes) an in-flight staged copy first,
    /// then stamps the LRU clock and drains the move events.
    pub fn access_real(
        &mut self,
        kind: ChunkKind,
        idx: usize,
        device: Device,
    ) -> Result<()> {
        let c = self.chunk_of(kind, idx);
        self.wait_chunk(c);
        let now = self.bump_moment();
        let TrainingSession { mgr, tracer, policy, .. } = self;
        with_policy(policy, tracer, |pol| {
            mgr.access_tensor(kind, idx, device, pol, now)
        })?;
        self.drain_events_real();
        Ok(())
    }

    /// Bring one chunk to `device` through the eviction policy (real
    /// backend) — the ADAM staging leg of the e2e step.
    pub fn ensure_real(&mut self, c: ChunkId, device: Device)
        -> Result<()> {
        self.wait_chunk(c);
        let now = self.bump_moment();
        let TrainingSession { mgr, tracer, policy, .. } = self;
        with_policy(policy, tracer, |pol| {
            mgr.ensure_on(c, device, pol, now)
        })?;
        self.drain_events_real();
        Ok(())
    }

    /// Drain manager move events on the real backend.  The moves
    /// already happened (real memcpys, measured by the backend's
    /// recording wrappers); only the completion protocol runs here:
    /// a cancelled staged chunk frees its pinned buffer.
    fn drain_events_real(&mut self) {
        for ev in self.mgr.drain_events() {
            if ev.kind == MoveKind::PrefetchCancel {
                if let Some(pc) = self.inflight_done.remove(&ev.chunk) {
                    if let Some(l) = pc.lease {
                        self.pool.release(l);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::SimBackend;
    use super::*;
    use crate::chunk::ChunkRegistry;
    use crate::chunk::TensorSpec;
    use crate::mem::HeterogeneousSpace;

    fn tiny_mgr() -> ChunkManager {
        let specs: Vec<TensorSpec> = (0..6)
            .map(|i| TensorSpec {
                name: format!("w{i}"),
                numel: 64,
                embedding: false,
            })
            .collect();
        let reg = ChunkRegistry::build(&specs, 128).unwrap();
        let space = HeterogeneousSpace::new(2 << 10, 1 << 20);
        ChunkManager::new(reg, space)
    }

    fn real_session(pinned: u32, adaptive: bool)
        -> TrainingSession<SimBackend> {
        let opt = OptimizationPlan {
            eviction: super::super::EvictKind::Lru,
            lookahead: 4,
            pinned_buffers: pinned,
            adaptive_lookahead: adaptive,
            ..Default::default()
        };
        let net = crate::config::ClusterPreset::yard().net;
        TrainingSession::new_real(opt, tiny_mgr(),
                                  SimBackend::new(false, net, 1))
    }

    #[test]
    fn real_session_starts_steady_with_optional_controller() {
        let s = real_session(0, false);
        assert!(!s.warmup);
        assert!(s.ctl.is_none());
        assert!(!s.pool.enabled());
        let s = real_session(2, true);
        assert!(s.ctl.is_some());
        assert_eq!(s.pool.capacity(), 2);
    }

    #[test]
    fn real_window_static_and_telemetry() {
        let mut s = real_session(0, false);
        assert_eq!(s.real_window(), 4);
        assert_eq!(s.real_window(), 4);
        assert!((s.avg_window() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn stage_real_holds_a_lease_until_consumed() {
        let mut s = real_session(1, false);
        for id in s.mgr.reg.list(ChunkKind::ParamFp16) {
            s.mgr.alloc_payload(id, Device::Cpu).unwrap();
        }
        let c = s.fp16_list[0];
        let limit = s.mgr.space.dev(Device::Gpu(0)).capacity;
        assert_eq!(s.stage_real(c, Device::Gpu(0), limit).unwrap(),
                   StageOutcome::Staged);
        assert!(s.mgr.is_inflight(c));
        // The single buffer is held open-ended: a second stage attempt
        // finds the pool dry and counts a throttle.
        let c2 = s.fp16_list[1];
        assert_eq!(s.stage_real(c2, Device::Gpu(0), limit).unwrap(),
                   StageOutcome::PoolDry);
        assert_eq!(s.mgr.stats.pinned_waits, 1);
        // Consuming the staged chunk frees the buffer.
        s.access_real(ChunkKind::ParamFp16, 0, Device::Gpu(0)).unwrap();
        assert!(!s.mgr.is_inflight(c));
        assert_eq!(s.stage_real(c2, Device::Gpu(0), limit).unwrap(),
                   StageOutcome::Staged);
    }

    #[test]
    fn stage_real_skips_non_cpu_chunks() {
        let mut s = real_session(0, false);
        for id in s.mgr.reg.list(ChunkKind::ParamFp16) {
            s.mgr.alloc_payload(id, Device::Cpu).unwrap();
        }
        let c = s.fp16_list[0];
        let limit = s.mgr.space.dev(Device::Gpu(0)).capacity;
        assert_eq!(s.stage_real(c, Device::Gpu(0), limit).unwrap(),
                   StageOutcome::Staged);
        // Already in flight: skipped, not re-staged.
        assert_eq!(s.stage_real(c, Device::Gpu(0), limit).unwrap(),
                   StageOutcome::Skipped);
    }
}
