//! Deterministic fault injection at the execution-backend boundary
//! (ISSUE 6 tentpole).
//!
//! [`ChaosBackend`] decorates any [`ExecutionBackend`] and perturbs the
//! *pricing* side of the trait — never the execution side — so every
//! cancel/credit-back path in the session sees a coherent world: the
//! session prices a copy once, charges that duration, and reclaims the
//! same duration on cancel, whether or not chaos stretched it.  Four
//! fault lanes, each driven by its own forked [`Rng`] stream so a seed
//! replays bit-identically regardless of which other lanes are enabled:
//!
//! * **jitter** — PCIe bandwidth jitter and transient copy slowdowns:
//!   `copy_secs` is stretched per query, with independent streams for
//!   the pinned and pageable curves (the two host-copy directions the
//!   pricing boundary distinguishes).
//! * **straggler** — a slow rank stretches the ring: `allgather_cost` /
//!   `reduce_scatter_cost` wire *time* grows; the per-rank byte volume
//!   is never touched, so collective wire volume stays bit-for-bit
//!   serial under chaos (locked by `tests/chaos_resume.rs`).
//! * **pressure** — GPU memory-pressure spikes: the backlog probes the
//!   adaptive controller feeds on report a transient queue spike, which
//!   compresses the prefetch windows and inflates the overlap-aware
//!   eviction margin — eviction near-misses without fake bytes.
//! * **abort** — transient failures kill one in-flight transfer: the
//!   session polls [`ExecutionBackend::poll_abort`] once per steady
//!   moment and cancels its lowest-numbered in-flight gather (or
//!   oldest pending prefetch) mid-lease, exercising the
//!   `GatherCancel`/`PrefetchCancel` credit-back machinery.
//!
//! The decorator is an exact passthrough when a lane is disabled — it
//! draws *zero* random numbers, so a `ChaosBackend` over a disabled
//! [`ChaosPlan`] is bit-identical to the bare inner backend (locked by
//! `tests/session_equivalence.rs`).  All lane state lives in a
//! `RefCell` because the pricing methods take `&self`; the cell is
//! `Clone`, so checkpointing a session (`TrainingSession::checkpoint`)
//! captures the mid-stream RNG positions and a restored run replays
//! the exact fault tail of the uninterrupted one.

use std::cell::RefCell;

use anyhow::{bail, Result};

use crate::dp::CollectiveOp;
use crate::sim::{CopyDir, CopyRoute, Phase};
use crate::util::Rng;

use super::backend::{ExecutionBackend, SimBackend};
use super::report::IterBreakdown;

/// Default per-query fault probability.
pub const DEFAULT_CHAOS_RATE: f64 = 0.05;
/// Default fault magnitude scale (a slowdown factor of `1 + intensity
/// * u`, `u` uniform in `[0, 1)`).
pub const DEFAULT_CHAOS_INTENSITY: f64 = 1.0;
/// Synthetic queue-depth spike one pressure fault adds to a backlog
/// probe, in seconds per intensity unit.
const PRESSURE_SPIKE_SECS: f64 = 0.01;

// ---------------------------------------------------------------- plan

/// Which faults to inject, how often, how hard, and from which seed.
///
/// Parsed from `--chaos <spec>`: `all` or a `+`-separated subset of
/// `jitter`, `straggler`, `pressure`, `abort`, `burst`, `rank-fail`,
/// with optional `:rate=R,intensity=I,rank=N` parameters — e.g.
/// `--chaos jitter+abort:rate=0.2,intensity=3` or `--chaos
/// straggler:rank=2,intensity=1.5`.  Each kind and each parameter may
/// appear at most once; duplicates and out-of-range values are named
/// parse errors, never silent last-write-wins (ISSUE 9 satellite).
///
/// `all` deliberately remains the original four lanes: `burst` (a
/// correlated-window *shape* over jitter/straggler/pressure faults)
/// and `rank-fail` (a world-size-changing event) are opt-in, so every
/// pre-existing `--chaos all` trace replays unchanged.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosPlan {
    pub jitter: bool,
    pub straggler: bool,
    pub pressure: bool,
    pub abort: bool,
    /// Correlated burst windows (ISSUE 9): when a jitter/straggler/
    /// pressure fault fires, the same perturbation repeats for a
    /// window of consecutive pricings on that lane instead of fading
    /// immediately — one seed draw correlates several moments.
    pub burst: bool,
    /// Rank-failure lane (ISSUE 9): `poll_rank_fail` may report a lost
    /// rank at an iteration boundary, driving the engine's elastic
    /// shrink-and-re-shard path.
    pub rank_fail: bool,
    /// Per-query fault probability in `[0, 1]`.
    pub rate: f64,
    /// Fault magnitude scale (> 0).
    pub intensity: f64,
    /// Named straggler rank (`rank=N`): instead of uniform per-query
    /// collective jitter, rank N persistently stretches *every*
    /// collective it participates in; once an elastic shrink drops the
    /// world at or below N, the straggler leaves with it.
    pub straggler_rank: Option<u32>,
    /// Root seed; every lane forks its own stream from it.
    pub seed: u64,
}

impl ChaosPlan {
    /// The original four fault lanes at the default rate/intensity.
    /// Deliberately NOT every lane: burst and rank-fail are opt-in so
    /// `--chaos all` traces (and the wire-volume invariance tests,
    /// which a world-size change would void) replay unchanged.
    pub fn all(seed: u64) -> Self {
        ChaosPlan {
            jitter: true,
            straggler: true,
            pressure: true,
            abort: true,
            burst: false,
            rank_fail: false,
            rate: DEFAULT_CHAOS_RATE,
            intensity: DEFAULT_CHAOS_INTENSITY,
            straggler_rank: None,
            seed,
        }
    }

    /// No fault lane enabled: the decorator is an exact passthrough
    /// and draws zero random numbers (the chaos-off contract).
    pub fn disabled(seed: u64) -> Self {
        ChaosPlan {
            jitter: false,
            straggler: false,
            pressure: false,
            abort: false,
            burst: false,
            rank_fail: false,
            rate: DEFAULT_CHAOS_RATE,
            intensity: DEFAULT_CHAOS_INTENSITY,
            straggler_rank: None,
            seed,
        }
    }

    /// Whether any lane can ever fire.  A named straggler rank fires
    /// on every collective (no chance draw), so it activates the plan
    /// even at rate 0.
    pub fn is_active(&self) -> bool {
        let lanes = self.jitter
            || self.straggler
            || self.pressure
            || self.abort
            || self.rank_fail;
        lanes
            && (self.rate > 0.0
                || (self.straggler && self.straggler_rank.is_some()))
    }

    /// Parse a `--chaos` spec (see type docs for the grammar).
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        let (kinds, params) = match spec.split_once(':') {
            Some((k, p)) => (k, Some(p)),
            None => (spec, None),
        };
        let mut plan = ChaosPlan::disabled(seed);
        if kinds == "all" {
            plan = ChaosPlan::all(seed);
        } else {
            for kind in kinds.split('+') {
                let lane = match kind {
                    "jitter" => &mut plan.jitter,
                    "straggler" => &mut plan.straggler,
                    "pressure" => &mut plan.pressure,
                    "abort" => &mut plan.abort,
                    "burst" => &mut plan.burst,
                    "rank-fail" => &mut plan.rank_fail,
                    _ => bail!(
                        "unknown chaos fault kind {kind:?} (want all, \
                         or a + of jitter/straggler/pressure/abort/\
                         burst/rank-fail)"
                    ),
                };
                if *lane {
                    bail!(
                        "duplicate chaos fault kind {kind:?} (each \
                         lane may appear once)"
                    );
                }
                *lane = true;
            }
        }
        if let Some(params) = params {
            let mut seen: Vec<&str> = Vec::new();
            for kv in params.split(',') {
                let Some((k, v)) = kv.split_once('=') else {
                    bail!("malformed chaos parameter {kv:?} (want k=v)");
                };
                if seen.contains(&k) {
                    bail!(
                        "duplicate chaos parameter {k:?} (each \
                         parameter may appear once)"
                    );
                }
                seen.push(k);
                match k {
                    "rate" | "intensity" => {
                        let x: f64 = v.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "chaos parameter {k}={v:?} is not a \
                                 number"
                            )
                        })?;
                        if k == "rate" {
                            if !(0.0..=1.0).contains(&x) {
                                bail!("chaos rate {x} outside [0, 1]");
                            }
                            plan.rate = x;
                        } else {
                            if !(x.is_finite() && x > 0.0) {
                                bail!(
                                    "chaos intensity {x} must be a \
                                     finite number > 0"
                                );
                            }
                            plan.intensity = x;
                        }
                    }
                    "rank" => {
                        let r: u32 = v.parse().map_err(|_| {
                            anyhow::anyhow!(
                                "chaos parameter rank={v:?} is not a \
                                 rank index"
                            )
                        })?;
                        plan.straggler_rank = Some(r);
                    }
                    _ => bail!(
                        "unknown chaos parameter {k:?} (want rate, \
                         intensity, or rank)"
                    ),
                }
            }
        }
        if plan.straggler_rank.is_some() && !plan.straggler {
            bail!(
                "chaos parameter rank=N names a straggler rank; it \
                 needs the straggler lane enabled"
            );
        }
        if plan.burst
            && !(plan.jitter || plan.straggler || plan.pressure)
        {
            bail!(
                "chaos kind \"burst\" is a correlation shape over \
                 jitter/straggler/pressure; enable at least one of \
                 those lanes with it"
            );
        }
        Ok(plan)
    }
}

// --------------------------------------------------------------- stats

/// Cumulative fault/degradation counters, surfaced in the report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChaosStats {
    /// Copy pricings stretched by the jitter lane.
    pub copy_slowdowns: u64,
    /// Collective pricings stretched by the straggler lane.
    pub collective_stretches: u64,
    /// Backlog probes inflated by the pressure lane.
    pub pressure_spikes: u64,
    /// Abort events delivered to the session (each cancels at most one
    /// in-flight transfer; the cancel counters in `MoveStats` say what
    /// the session actually killed).
    pub aborts: u64,
}

/// Extra correlated pricings one burst window carries beyond the
/// fault that opened it: `2 + burst_lane.range(0, BURST_EXTRA_MAX)`.
const BURST_EXTRA_MAX: usize = 5;

/// One open burst window on a pricing lane: how many more pricings it
/// covers, and the frozen stretch factor they all repeat.
#[derive(Clone, Copy, Debug, Default)]
struct BurstWindow {
    left: u32,
    stretch: f64,
}

/// Per-lane RNG streams plus the counters — behind a `RefCell` because
/// the pricing methods take `&self`.
#[derive(Clone, Debug)]
struct ChaosState {
    copy_pinned: Rng,
    copy_pageable: Rng,
    coll: Rng,
    pressure: Rng,
    abort: Rng,
    /// Jitter stream for the NVMe pricing route (ISSUE 7).  Forked
    /// last so the first five lanes keep their pre-NVMe streams — a
    /// two-tier chaos run replays the exact same faults as before.
    copy_nvme: Rng,
    /// Burst-window lengths (ISSUE 9, lane 7): drawn only when a fault
    /// fires with the burst shape enabled, so burst-off runs draw zero
    /// numbers here and every earlier lane keeps its stream.
    burst: Rng,
    /// Named-straggler magnitudes (ISSUE 9, lane 8): one draw per
    /// collective the named rank stretches.
    straggler_profile: Rng,
    /// Rank-failure events (ISSUE 9, lane 9): one draw per iteration
    /// boundary poll when the rank-fail lane is enabled.
    rank_fail: Rng,
    /// Open burst windows per copy route (pinned, pageable, nvme).
    burst_copy: [BurstWindow; 3],
    /// Open burst window on the collective lane.
    burst_coll: BurstWindow,
    /// Remaining pressure-spike pricings in the open burst window.
    burst_pressure: u32,
    /// Current comm world size, updated by `rescale_world`; `None`
    /// until the first rescale (every configured rank present).
    world: Option<u32>,
    stats: ChaosStats,
}

impl ChaosState {
    fn new(seed: u64) -> Self {
        let mut root = Rng::new(seed);
        ChaosState {
            copy_pinned: root.fork(1),
            copy_pageable: root.fork(2),
            coll: root.fork(3),
            pressure: root.fork(4),
            abort: root.fork(5),
            copy_nvme: root.fork(6),
            burst: root.fork(7),
            straggler_profile: root.fork(8),
            rank_fail: root.fork(9),
            burst_copy: [BurstWindow::default(); 3],
            burst_coll: BurstWindow::default(),
            burst_pressure: 0,
            world: None,
            stats: ChaosStats::default(),
        }
    }

    /// Burst-window length for a fault that just fired (>= 2 extra
    /// pricings, so a burst is always observably correlated).
    fn draw_burst_len(&mut self) -> u32 {
        (2 + self.burst.range(0, BURST_EXTRA_MAX)) as u32
    }
}

// ------------------------------------------------------------- backend

/// Fault-injecting decorator over any execution backend (see module
/// docs for the fault model and determinism contract).
#[derive(Clone, Debug)]
pub struct ChaosBackend<B: ExecutionBackend = SimBackend> {
    inner: B,
    plan: ChaosPlan,
    state: RefCell<ChaosState>,
}

impl<B: ExecutionBackend> ChaosBackend<B> {
    pub fn new(inner: B, plan: ChaosPlan) -> Self {
        let state = RefCell::new(ChaosState::new(plan.seed));
        ChaosBackend { inner, plan, state }
    }

    /// The wrapped backend (report assembly, tests).
    pub fn inner(&self) -> &B {
        &self.inner
    }

    pub fn plan(&self) -> ChaosPlan {
        self.plan
    }

    /// Counters so far (also reachable through
    /// [`ExecutionBackend::chaos_stats`]).
    pub fn stats(&self) -> ChaosStats {
        self.state.borrow().stats
    }

    /// Stretch one copy pricing on its route's jitter lane.  With the
    /// burst shape, a firing fault freezes its stretch for a window of
    /// consecutive pricings on the same route — correlated slowdowns
    /// from one seed draw, no fresh chance draws inside the window.
    fn perturb_copy(&self, base: f64, route: CopyRoute) -> f64 {
        if !self.plan.jitter || base <= 0.0 {
            return base;
        }
        let st = &mut *self.state.borrow_mut();
        let idx = match route {
            CopyRoute::Pinned => 0,
            CopyRoute::Pageable => 1,
            CopyRoute::NvmeStaged => 2,
        };
        if self.plan.burst && st.burst_copy[idx].left > 0 {
            st.burst_copy[idx].left -= 1;
            st.stats.copy_slowdowns += 1;
            return base * st.burst_copy[idx].stretch;
        }
        let lane = match route {
            CopyRoute::Pinned => &mut st.copy_pinned,
            CopyRoute::Pageable => &mut st.copy_pageable,
            CopyRoute::NvmeStaged => &mut st.copy_nvme,
        };
        if lane.chance(self.plan.rate) {
            let stretch = 1.0 + self.plan.intensity * lane.f64();
            st.stats.copy_slowdowns += 1;
            if self.plan.burst {
                let left = st.draw_burst_len();
                st.burst_copy[idx] = BurstWindow { left, stretch };
            }
            base * stretch
        } else {
            base
        }
    }

    /// Stretch one collective pricing's wire time; the byte volume is
    /// untouched by construction (the wire-volume invariant).  A named
    /// straggler rank (`rank=N`) stretches *every* collective the rank
    /// participates in — no chance draw, magnitude from its own lane —
    /// until an elastic shrink drops the world at or below N.
    fn perturb_collective(&self, base: CollectiveOp) -> CollectiveOp {
        if !self.plan.straggler || base.secs <= 0.0 {
            return base;
        }
        let st = &mut *self.state.borrow_mut();
        if let Some(r) = self.plan.straggler_rank {
            if st.world.is_none_or(|w| r < w) {
                let stretch = 1.0
                    + self.plan.intensity * st.straggler_profile.f64();
                st.stats.collective_stretches += 1;
                return CollectiveOp {
                    secs: base.secs * stretch,
                    bytes: base.bytes,
                };
            }
            return base;
        }
        if self.plan.burst && st.burst_coll.left > 0 {
            st.burst_coll.left -= 1;
            st.stats.collective_stretches += 1;
            return CollectiveOp {
                secs: base.secs * st.burst_coll.stretch,
                bytes: base.bytes,
            };
        }
        if st.coll.chance(self.plan.rate) {
            let stretch = 1.0 + self.plan.intensity * st.coll.f64();
            st.stats.collective_stretches += 1;
            if self.plan.burst {
                let left = st.draw_burst_len();
                st.burst_coll = BurstWindow { left, stretch };
            }
            CollectiveOp { secs: base.secs * stretch, bytes: base.bytes }
        } else {
            base
        }
    }

    /// Inflate one backlog probe with a synthetic queue spike.
    fn perturb_backlog(&self, base: f64) -> f64 {
        if !self.plan.pressure {
            return base;
        }
        let st = &mut *self.state.borrow_mut();
        if self.plan.burst && st.burst_pressure > 0 {
            st.burst_pressure -= 1;
            st.stats.pressure_spikes += 1;
            return base + self.plan.intensity * PRESSURE_SPIKE_SECS;
        }
        if st.pressure.chance(self.plan.rate) {
            st.stats.pressure_spikes += 1;
            if self.plan.burst {
                st.burst_pressure = st.draw_burst_len();
            }
            base + self.plan.intensity * PRESSURE_SPIKE_SECS
        } else {
            base
        }
    }
}

impl<B: ExecutionBackend> ExecutionBackend for ChaosBackend<B> {
    // Execution: pure delegation.  Chaos never rewrites a duration the
    // session already holds — that would desynchronize the reclaim /
    // credit-back paths the faults exist to exercise.
    fn execute_moment(&mut self, phase: Phase, secs: f64) {
        self.inner.execute_moment(phase, secs);
    }

    fn demand_copy(&mut self, phase: Phase, secs: f64, dir: CopyDir,
                   ready: f64) {
        self.inner.demand_copy(phase, secs, dir, ready);
    }

    fn issue_copy(&mut self, phase: Phase, secs: f64, dir: CopyDir,
                  ready: f64, route: CopyRoute) -> f64 {
        self.inner.issue_copy(phase, secs, dir, ready, route)
    }

    fn reclaim_copy(&mut self, phase: Phase, secs: f64, dir: CopyDir,
                    route: CopyRoute) {
        self.inner.reclaim_copy(phase, secs, dir, route);
    }

    fn sync_until(&mut self, t: f64) {
        self.inner.sync_until(t);
    }

    fn demand_collective(&mut self, phase: Phase, secs: f64) {
        self.inner.demand_collective(phase, secs);
    }

    fn issue_collective(&mut self, phase: Phase, secs: f64) -> f64 {
        self.inner.issue_collective(phase, secs)
    }

    fn sync_collective(&mut self, t: f64) {
        self.inner.sync_collective(t);
    }

    fn reclaim_collective(&mut self, phase: Phase, secs: f64) {
        self.inner.reclaim_collective(phase, secs);
    }

    // NVMe tier: still pure delegation on the execution side.  These
    // must be explicit — the trait defaults decompose a staged copy
    // into `self.issue_copy` calls, which would route around the inner
    // backend's real NVMe lane.  Jitter on the NVMe route flows
    // through `copy_secs` per hop like every other fault.
    fn issue_copy_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        ready: f64,
        pcie_route: CopyRoute,
    ) -> f64 {
        self.inner.issue_copy_staged(
            nvme_phase, nvme_secs, pcie_phase, pcie_secs, dir, ready,
            pcie_route,
        )
    }

    fn demand_copy_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        ready: f64,
        pcie_route: CopyRoute,
    ) {
        self.inner.demand_copy_staged(
            nvme_phase, nvme_secs, pcie_phase, pcie_secs, dir, ready,
            pcie_route,
        );
    }

    fn reclaim_copy_staged(
        &mut self,
        nvme_phase: Phase,
        nvme_secs: f64,
        pcie_phase: Phase,
        pcie_secs: f64,
        dir: CopyDir,
        pcie_route: CopyRoute,
    ) {
        self.inner.reclaim_copy_staged(
            nvme_phase, nvme_secs, pcie_phase, pcie_secs, dir, pcie_route,
        );
    }

    fn issue_copy_nvme(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) -> f64 {
        self.inner.issue_copy_nvme(phase, secs, dir, ready)
    }

    fn demand_copy_nvme(
        &mut self,
        phase: Phase,
        secs: f64,
        dir: CopyDir,
        ready: f64,
    ) {
        self.inner.demand_copy_nvme(phase, secs, dir, ready);
    }

    fn reclaim_copy_nvme(&mut self, phase: Phase, secs: f64, dir: CopyDir) {
        self.inner.reclaim_copy_nvme(phase, secs, dir);
    }

    fn nvme_busy(&self) -> f64 {
        self.inner.nvme_busy()
    }

    // Pricing: the fault surface.
    fn copy_secs(&self, bytes: u64, route: CopyRoute) -> f64 {
        self.perturb_copy(self.inner.copy_secs(bytes, route), route)
    }

    fn allgather_cost(&self, chunk_bytes: u64) -> CollectiveOp {
        self.perturb_collective(self.inner.allgather_cost(chunk_bytes))
    }

    fn reduce_scatter_cost(&self, chunk_bytes: u64) -> CollectiveOp {
        self.perturb_collective(
            self.inner.reduce_scatter_cost(chunk_bytes),
        )
    }

    // Re-shard pricing is a pure delegation: the rescale event itself
    // is the fault — perturbing its pricing would entangle the
    // conservation property tests with the jitter lanes for no extra
    // coverage (time stretches elsewhere already exercise the paths).
    fn reshard_cost(&self, total_bytes: u64, n_shards: usize) -> CollectiveOp {
        self.inner.reshard_cost(total_bytes, n_shards)
    }

    // Probes: the work accumulators stay honest (the controller
    // differences them; a fake delta could go negative), only the
    // backlog signals spike.
    fn now(&self) -> f64 {
        self.inner.now()
    }

    fn compute_work(&self) -> f64 {
        self.inner.compute_work()
    }

    fn copy_busy(&self, dir: CopyDir) -> f64 {
        self.inner.copy_busy(dir)
    }

    fn copy_backlog(&self, dir: CopyDir) -> f64 {
        self.perturb_backlog(self.inner.copy_backlog(dir))
    }

    fn collective_work(&self) -> f64 {
        self.inner.collective_work()
    }

    fn collective_backlog(&self) -> f64 {
        self.perturb_backlog(self.inner.collective_backlog())
    }

    // Lifecycle: delegation.  `reset` deliberately does NOT rewind the
    // fault lanes — faults keep streaming across iteration boundaries,
    // and the counters are cumulative for the report.
    fn reset(&mut self) {
        self.inner.reset();
    }

    fn rescale_world(&mut self, nproc: usize) {
        // Track the world so a named straggler rank stops firing once
        // a shrink drops the world at or below it; the fault lanes are
        // deliberately NOT rewound (same contract as `reset`).
        self.state.get_mut().world = Some(nproc as u32);
        self.inner.rescale_world(nproc);
    }

    fn makespan(&self) -> f64 {
        self.inner.makespan()
    }

    fn breakdown(&self) -> IterBreakdown {
        self.inner.breakdown()
    }

    fn snapshot(&self) -> String {
        self.inner.snapshot()
    }

    fn poll_abort(&mut self) -> bool {
        if !self.plan.abort {
            return false;
        }
        let st = self.state.get_mut();
        if st.abort.chance(self.plan.rate) {
            st.stats.aborts += 1;
            true
        } else {
            false
        }
    }

    fn poll_rank_fail(&mut self) -> bool {
        if !self.plan.rank_fail {
            return false;
        }
        let st = self.state.get_mut();
        st.rank_fail.chance(self.plan.rate)
    }

    fn chaos_stats(&self) -> Option<ChaosStats> {
        Some(self.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterPreset;

    fn sim() -> SimBackend {
        SimBackend::new(true, ClusterPreset::yard().net, 4)
    }

    #[test]
    fn parse_spec_grammar() {
        let p = ChaosPlan::parse("all", 7).unwrap();
        assert_eq!(p, ChaosPlan::all(7));
        let p = ChaosPlan::parse("jitter+abort", 0).unwrap();
        assert!(p.jitter && p.abort && !p.straggler && !p.pressure);
        let p =
            ChaosPlan::parse("straggler:rate=0.5,intensity=3", 1).unwrap();
        assert!(p.straggler && p.rate == 0.5 && p.intensity == 3.0);
        assert!(ChaosPlan::parse("meteor", 0).is_err());
        assert!(ChaosPlan::parse("jitter:rate=2", 0).is_err());
        assert!(ChaosPlan::parse("jitter:intensity=0", 0).is_err());
        assert!(ChaosPlan::parse("jitter:rate", 0).is_err());
        assert!(ChaosPlan::parse("jitter:depth=1", 0).is_err());
    }

    #[test]
    fn parse_rejects_duplicates_with_named_errors() {
        // ISSUE 9 satellite: duplicate lanes and repeated parameters
        // are named errors, never silent last-write-wins.
        let e = ChaosPlan::parse("jitter+jitter", 0).unwrap_err();
        assert!(e.to_string().contains("duplicate chaos fault kind"),
                "{e}");
        let e = ChaosPlan::parse("jitter:rate=0.1,rate=0.9", 0)
            .unwrap_err();
        assert!(e.to_string().contains("duplicate chaos parameter"),
                "{e}");
        assert!(ChaosPlan::parse(
            "jitter:intensity=1,intensity=2", 0).is_err());
        // NaN/inf magnitudes are out-of-range, not accepted-and-weird.
        assert!(ChaosPlan::parse("jitter:rate=nan", 0).is_err());
        assert!(ChaosPlan::parse("jitter:intensity=nan", 0).is_err());
        assert!(ChaosPlan::parse("jitter:intensity=inf", 0).is_err());
    }

    #[test]
    fn parse_new_fault_shapes() {
        // burst and rank-fail are opt-in kinds; rank=N names the
        // straggler and requires its lane.
        let p = ChaosPlan::parse("jitter+burst", 0).unwrap();
        assert!(p.jitter && p.burst && !p.rank_fail);
        let p = ChaosPlan::parse("rank-fail:rate=0.3", 0).unwrap();
        assert!(p.rank_fail && !p.jitter && p.rate == 0.3);
        let p = ChaosPlan::parse("straggler:rank=2", 0).unwrap();
        assert_eq!(p.straggler_rank, Some(2));
        assert!(p.is_active(), "named straggler fires without rate");
        assert!(ChaosPlan::parse("jitter:rank=1", 0).is_err());
        assert!(ChaosPlan::parse("straggler:rank=-1", 0).is_err());
        assert!(ChaosPlan::parse("burst", 0).is_err());
        assert!(ChaosPlan::parse("burst+abort", 0).is_err());
        // `all` stays the original four lanes: pre-existing traces
        // must not grow new fault draws.
        let p = ChaosPlan::parse("all", 7).unwrap();
        assert!(!p.burst && !p.rank_fail && p.straggler_rank.is_none());
    }

    #[test]
    fn burst_correlates_consecutive_pricings() {
        // Once a jitter fault fires with the burst shape, the *same*
        // stretch factor repeats for >= 2 further pricings on that
        // route — a correlated window, not independent draws.
        let plan = ChaosPlan {
            jitter: true,
            burst: true,
            rate: 0.3,
            intensity: 2.0,
            ..ChaosPlan::disabled(13)
        };
        let be = ChaosBackend::new(sim(), plan);
        let base = sim().copy_secs(1 << 20, CopyRoute::Pinned);
        let ratios: Vec<f64> = (0..400)
            .map(|_| be.copy_secs(1 << 20, CopyRoute::Pinned) / base)
            .collect();
        let mut windows = 0;
        let mut i = 0;
        while i < ratios.len() {
            if ratios[i] > 1.0 {
                let mut run = 1;
                while i + run < ratios.len()
                    && ratios[i + run].to_bits() == ratios[i].to_bits()
                {
                    run += 1;
                }
                assert!(
                    run >= 3,
                    "burst window at {i} repeated only {run}x"
                );
                windows += 1;
                i += run;
            } else {
                i += 1;
            }
        }
        assert!(windows > 0, "no burst ever fired");
        // Same seed replays the same windows.
        let b2 = ChaosBackend::new(sim(), plan);
        for &r in &ratios {
            let got = b2.copy_secs(1 << 20, CopyRoute::Pinned) / base;
            assert_eq!(got.to_bits(), r.to_bits());
        }
    }

    #[test]
    fn named_straggler_rank_stretches_until_it_leaves() {
        // rank=2 stretches every collective (no chance draw) while
        // rank 2 is in the world; after a shrink to world size 2 the
        // straggler left, and collectives price clean again.
        let plan = ChaosPlan {
            straggler: true,
            straggler_rank: Some(2),
            rate: 0.0,
            intensity: 1.5,
            ..ChaosPlan::disabled(31)
        };
        let mut be = ChaosBackend::new(sim(), plan);
        let raw = sim();
        for i in 1..50u64 {
            let bytes = i << 12;
            let (g, g0) = (be.allgather_cost(bytes), raw.allgather_cost(bytes));
            assert!(g.secs > g0.secs, "straggler skipped a collective");
            assert_eq!(g.bytes, g0.bytes);
        }
        assert!(be.stats().collective_stretches >= 49);
        be.rescale_world(2);
        let raw2 = SimBackend::new(true, ClusterPreset::yard().net, 2);
        let before = be.stats().collective_stretches;
        for i in 1..50u64 {
            let bytes = i << 12;
            let (g, g0) =
                (be.allgather_cost(bytes), raw2.allgather_cost(bytes));
            assert_eq!(g.secs.to_bits(), g0.secs.to_bits());
        }
        assert_eq!(be.stats().collective_stretches, before);
    }

    #[test]
    fn rank_fail_lane_is_deterministic_and_opt_in() {
        // `all` never reports a rank failure; an enabled lane replays
        // the same failure sequence per seed.
        let mut all = ChaosBackend::new(
            sim(),
            ChaosPlan { rate: 1.0, ..ChaosPlan::all(5) },
        );
        for _ in 0..32 {
            assert!(!all.poll_rank_fail());
        }
        let plan = ChaosPlan {
            rank_fail: true,
            rate: 0.4,
            ..ChaosPlan::disabled(17)
        };
        let mut a = ChaosBackend::new(sim(), plan);
        let mut b = ChaosBackend::new(sim(), plan);
        let mut fails = 0;
        for _ in 0..64 {
            let fa = a.poll_rank_fail();
            assert_eq!(fa, b.poll_rank_fail());
            fails += fa as u32;
        }
        assert!(fails > 0, "rank-fail lane never fired at rate 0.4");
    }

    #[test]
    fn disabled_plan_is_an_exact_passthrough() {
        let raw = sim();
        let be = ChaosBackend::new(sim(), ChaosPlan::disabled(99));
        for bytes in [1u64 << 10, 1 << 20, 1 << 28] {
            for route in [
                CopyRoute::Pinned,
                CopyRoute::Pageable,
                CopyRoute::NvmeStaged,
            ] {
                assert_eq!(be.copy_secs(bytes, route).to_bits(),
                           raw.copy_secs(bytes, route).to_bits());
            }
            assert_eq!(be.allgather_cost(bytes), raw.allgather_cost(bytes));
            assert_eq!(be.reduce_scatter_cost(bytes),
                       raw.reduce_scatter_cost(bytes));
        }
        let mut be = be;
        for _ in 0..64 {
            assert!(!be.poll_abort());
        }
        assert_eq!(be.stats(), ChaosStats::default());
    }

    #[test]
    fn same_seed_replays_bit_identically() {
        let plan = ChaosPlan {
            rate: 0.7,
            intensity: 2.5,
            ..ChaosPlan::all(42)
        };
        let mut a = ChaosBackend::new(sim(), plan);
        let mut b = ChaosBackend::new(sim(), plan);
        for i in 0..200u64 {
            let bytes = 1 + (i * 977) % (1 << 22);
            assert_eq!(
                a.copy_secs(bytes, CopyRoute::Pinned).to_bits(),
                b.copy_secs(bytes, CopyRoute::Pinned).to_bits()
            );
            let (ga, gb) = (a.allgather_cost(bytes), b.allgather_cost(bytes));
            assert_eq!(ga.secs.to_bits(), gb.secs.to_bits());
            assert_eq!(ga.bytes, gb.bytes);
            assert_eq!(a.poll_abort(), b.poll_abort());
            assert_eq!(
                a.copy_backlog(CopyDir::H2D).to_bits(),
                b.copy_backlog(CopyDir::H2D).to_bits()
            );
        }
        assert_eq!(a.stats(), b.stats());
        assert!(a.stats().copy_slowdowns > 0);
        assert!(a.stats().aborts > 0);
    }

    #[test]
    fn faults_only_ever_stretch_time_and_never_bytes() {
        let plan = ChaosPlan { rate: 1.0, ..ChaosPlan::all(3) };
        let be = ChaosBackend::new(sim(), plan);
        let raw = sim();
        for bytes in [1u64 << 12, 1 << 20, 1 << 26] {
            let base = raw.copy_secs(bytes, CopyRoute::Pinned);
            assert!(be.copy_secs(bytes, CopyRoute::Pinned) >= base);
            let (g, g0) = (be.allgather_cost(bytes), raw.allgather_cost(bytes));
            assert!(g.secs >= g0.secs);
            assert_eq!(g.bytes, g0.bytes, "straggler touched wire volume");
            assert!(be.copy_backlog(CopyDir::D2H)
                        >= raw.copy_backlog(CopyDir::D2H));
        }
        let s = be.stats();
        assert!(s.copy_slowdowns > 0 && s.collective_stretches > 0
                    && s.pressure_spikes > 0);
    }

    #[test]
    fn nvme_route_jitter_replays_per_seed_on_its_own_lane() {
        // ISSUE 7: jitter on the NVMe pricing route is deterministic
        // per seed, and draws from its own forked stream — interleaving
        // NVMe queries must not shift the pinned lane's fault tail.
        let plan = ChaosPlan {
            jitter: true,
            rate: 0.6,
            intensity: 2.0,
            ..ChaosPlan::disabled(21)
        };
        let a = ChaosBackend::new(sim(), plan);
        let b = ChaosBackend::new(sim(), plan);
        let mut nvme_hits = 0;
        for i in 0..200u64 {
            let bytes = 1 + (i * 769) % (1 << 24);
            let (na, nb) = (
                a.copy_secs(bytes, CopyRoute::NvmeStaged),
                b.copy_secs(bytes, CopyRoute::NvmeStaged),
            );
            assert_eq!(na.to_bits(), nb.to_bits());
            if na > sim().copy_secs(bytes, CopyRoute::NvmeStaged) {
                nvme_hits += 1;
            }
        }
        assert!(nvme_hits > 0, "jitter never fired on the NVMe route");
        // Only b draws extra NVMe queries: the NVMe lane is its own
        // forked stream, so a's and b's *pinned* fault tails must stay
        // in lockstep regardless.
        for _ in 0..50 {
            b.copy_secs(1 << 20, CopyRoute::NvmeStaged);
        }
        for i in 0..50u64 {
            let bytes = 1 + (i * 331) % (1 << 22);
            assert_eq!(
                a.copy_secs(bytes, CopyRoute::Pinned).to_bits(),
                b.copy_secs(bytes, CopyRoute::Pinned).to_bits()
            );
        }
    }

    #[test]
    fn cloned_backend_replays_the_same_fault_tail() {
        // The checkpoint/restore primitive: a clone taken mid-stream
        // must produce the same future faults as the original.
        let plan =
            ChaosPlan { rate: 0.5, ..ChaosPlan::all(11) };
        let mut a = ChaosBackend::new(sim(), plan);
        for _ in 0..37 {
            a.copy_secs(1 << 20, CopyRoute::Pinned);
            a.poll_abort();
        }
        let mut b = a.clone();
        for _ in 0..100 {
            assert_eq!(
                a.copy_secs(1 << 18, CopyRoute::Pageable).to_bits(),
                b.copy_secs(1 << 18, CopyRoute::Pageable).to_bits()
            );
            assert_eq!(a.poll_abort(), b.poll_abort());
        }
        assert_eq!(a.stats(), b.stats());
    }
}
