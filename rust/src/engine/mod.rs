//! The PatrickStar training engine.
//!
//! Since ISSUE 5 the engine is split into a backend-agnostic
//! orchestration core and thin execution backends:
//!
//! * [`session::TrainingSession`] (`session.rs`) — the per-iteration
//!   driver.  It owns the chunk manager, tracer, eviction policy
//!   ([`policy`]), warm-up-guided prefetchers ([`prefetch`]), pinned
//!   staging pool, adaptive lookahead controller and headroom ledger
//!   ([`adaptive`]) — every *policy* decision of a training iteration.
//! * [`ExecutionBackend`] (`backend.rs`) — where work is executed and
//!   priced: `execute_moment`, demand/issued copies and collectives,
//!   sync points, reclaim, and the cumulative work/backlog probes the
//!   controller feeds on.  [`SimBackend`] wraps
//!   [`crate::sim::StreamTimeline`] plus the cluster's calibrated cost
//!   curves; `PjrtBackend` (feature `pjrt`) records measured wall time
//!   for the real trainer.
//! * [`Engine`] (this file) — the simulator driver: picks the chunk
//!   size, builds the manager and the session over a [`SimBackend`],
//!   replays warm-up + 2 steady iterations of the operator graph, and
//!   assembles the [`EngineReport`].
//!
//! The multi-GPU behaviour follows Sec. 7; the ablation switches
//! (paper Fig. 16) and the four pipeline layers stacked on top of the
//! paper's placement machinery — prefetch+overlap (PR 1), the
//! collective stream (PR 2), the pinned staging pool (PR 3), adaptive
//! lookahead (PR 4) — are all selected by [`OptimizationPlan`] and
//! documented in `engine/README.md`.  All switches default **off**:
//! the serial path reproduces the pre-pipeline numbers exactly, and
//! `SimBackend` reproduces the pre-split engine bit-for-bit (golden
//! traces + `tests/session_equivalence.rs`).

pub mod adaptive;
pub mod backend;
pub mod chaos;
pub mod elastic;
pub mod policy;
pub mod prefetch;
pub mod report;
pub mod session;

use anyhow::{anyhow, bail, Context, Result};

use crate::chunk::{ChunkManager, ChunkRegistry};
use crate::config::{ClusterPreset, TrainTask};
use crate::mem::{Device, HeterogeneousSpace, DEFAULT_PINNED_BUFFERS};
use crate::model::OpGraph;
use crate::tracer::WARMUP_GPU_FRAC;

pub use adaptive::{HeadroomLedger, LookaheadController, WindowInputs,
                   DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD,
                   DEFAULT_ADAPTIVE_MAX_LOOKAHEAD};
pub use backend::{ExecutionBackend, SimBackend};
#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use chaos::{ChaosBackend, ChaosPlan, ChaosStats};
pub use elastic::{ElasticEvent, ElasticKind, ElasticPlan, RescaleEvent};
pub use prefetch::{GroupPrefetcher, Prefetcher, DEFAULT_GROUP_LOOKAHEAD,
                   DEFAULT_LOOKAHEAD};
pub use report::{EngineReport, IterBreakdown};
pub use session::{SessionState, SimCost, StageOutcome, TrainingSession};

/// Eviction policy selection (paper Sec. 8.3 + DBMS baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictKind {
    Opt,
    Lru,
    Fifo,
    Lfu,
}

/// The optimization toggles of the Fig. 16 ablation, extended with the
/// prefetch/overlap pipeline switches.
#[derive(Clone, Copy, Debug)]
pub struct OptimizationPlan {
    /// Use warm-up tracer statistics for chunkable memory (false = "SP").
    pub use_tracer: bool,
    /// Device-aware OS placement in GPU margin space (false = "OSC").
    pub device_aware_os: bool,
    pub eviction: EvictKind,
    /// Stage chunks ahead of use from the warm-up moment lists
    /// (requires `use_tracer`; no-op without it).
    pub prefetch: bool,
    /// Run on the dual-copy-stream timeline: evictions/offload async,
    /// transfer time hidden under compute where possible.
    pub overlap: bool,
    /// Prefetch lookahead window, in moments.
    pub lookahead: u32,
    /// Run collectives on a dedicated fourth stream with group-level
    /// lookahead gathers and draining reduce-scatters (requires
    /// `overlap`; no-op on a single process).
    pub overlap_collectives: bool,
    /// Group-gather lookahead depth, in communication groups.
    pub group_lookahead: u32,
    /// Size of the pinned staging-buffer pool (ISSUE 3 tentpole).
    /// 0 disables the pool: every host transfer charges the single
    /// pinned PCIe curve, reproducing the pre-pool timelines
    /// bit-for-bit.  With a finite pool, async copies and lookahead
    /// gathers hold a buffer from issue to completion; prefetches that
    /// cannot acquire one wait (throttling the lookahead window),
    /// evictions and activation offload downgrade to the pageable
    /// curve, and demand copies preempt (always pinned, never queued
    /// on the pool).
    pub pinned_buffers: u32,
    /// Per-direction staging sub-pool caps `(h2d, d2h)` within
    /// `pinned_buffers` (ISSUE 4 satellite).  None = unsplit: either
    /// direction may lease the whole pool — bit-identical to the PR 3
    /// shared pool.  A split caps each direction's concurrent leases so
    /// a D2H eviction burst cannot starve H2D prefetch.
    pub pinned_split: Option<(u32, u32)>,
    /// Size both prefetch windows at runtime from measured
    /// compute/transfer and compute/collective ratios (ISSUE 4
    /// tentpole) instead of the static `lookahead`/`group_lookahead`
    /// knobs — which then act as *caps* the adaptive windows never
    /// exceed.  Off (default): the static windows, bit-identical to
    /// PR 3 timelines.
    pub adaptive_lookahead: bool,
    /// NVMe tier capacity in GiB, shared by the node's ranks (ISSUE 7
    /// tentpole).  0 (default) means **no third tier at all**: no
    /// `Device::Nvme` in the space, no NVMe lane traffic, and every
    /// report/trace byte identical to a two-tier run — locked by
    /// `tests/session_equivalence.rs`.
    pub nvme_gb: u64,
    /// NVMe link peak bandwidth override in GB/s; <= 0 keeps the
    /// cluster preset's curve.  Ignored entirely when `nvme_gb` is 0.
    pub nvme_gbps: f64,
}

impl Default for OptimizationPlan {
    fn default() -> Self {
        OptimizationPlan {
            use_tracer: true,
            device_aware_os: true,
            eviction: EvictKind::Opt,
            prefetch: false,
            overlap: false,
            lookahead: DEFAULT_LOOKAHEAD,
            overlap_collectives: false,
            group_lookahead: DEFAULT_GROUP_LOOKAHEAD,
            pinned_buffers: 0,
            pinned_split: None,
            adaptive_lookahead: false,
            nvme_gb: 0,
            nvme_gbps: 0.0,
        }
    }
}

impl OptimizationPlan {
    /// The "SP" ablation plan of Fig. 16.
    pub fn static_partition() -> Self {
        OptimizationPlan { use_tracer: false, ..Default::default() }
    }

    /// The "OSC" ablation plan of Fig. 16.
    pub fn os_on_cpu() -> Self {
        OptimizationPlan { device_aware_os: false, ..Default::default() }
    }

    /// The full transfer pipeline: prefetch + dual-stream overlap.
    pub fn pipelined() -> Self {
        OptimizationPlan { prefetch: true, overlap: true, ..Default::default() }
    }

    /// Overlap without prefetch: demand fetches still block, but
    /// evictions and activation offload leave the critical path.
    pub fn overlap_only() -> Self {
        OptimizationPlan { overlap: true, ..Default::default() }
    }

    /// The collective stream alone on top of overlap: chunk prefetch
    /// off, so the distributed win is measured in isolation.
    pub fn collectives_pipelined() -> Self {
        OptimizationPlan {
            overlap: true,
            overlap_collectives: true,
            ..Default::default()
        }
    }

    /// Everything on: chunk prefetch + dual copy streams + collective
    /// stream with group lookahead.
    pub fn fully_pipelined() -> Self {
        OptimizationPlan {
            overlap_collectives: true,
            ..Self::pipelined()
        }
    }

    /// The realistic transfer pipeline: everything on, plus a finite
    /// pinned staging pool ([`DEFAULT_PINNED_BUFFERS`] chunk-sized
    /// buffers) that the prefetchers compete for.
    pub fn pinned_pipeline() -> Self {
        OptimizationPlan {
            pinned_buffers: DEFAULT_PINNED_BUFFERS,
            ..Self::fully_pipelined()
        }
    }

    /// The ISSUE 4 tentpole cell: the full pinned pipeline with both
    /// prefetch windows sized by the feedback controller.  The static
    /// knobs become the adaptive caps (`--lookahead auto`).
    pub fn adaptive_pipeline() -> Self {
        OptimizationPlan {
            adaptive_lookahead: true,
            lookahead: DEFAULT_ADAPTIVE_MAX_LOOKAHEAD,
            group_lookahead: DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD,
            ..Self::pinned_pipeline()
        }
    }
}

/// The engine: one (cluster, task, optimization plan) triple, plus an
/// optional fault-injection plan (ISSUE 6).
pub struct Engine {
    pub cluster: ClusterPreset,
    pub task: TrainTask,
    pub opt: OptimizationPlan,
    /// When set, the session runs over a [`ChaosBackend`] wrapping the
    /// simulator: seeded deterministic faults at the backend boundary.
    /// None (default) runs the plain [`SimBackend`] — no wrapper in the
    /// dispatch path at all.
    pub chaos: Option<ChaosPlan>,
    /// When set, the drive loop rescales the comm world at the planned
    /// iteration boundaries (ISSUE 9).  None (default) keeps the world
    /// fixed; the chaos `rank-fail` lane can still shrink it.
    pub elastic: Option<ElasticPlan>,
}

impl Engine {
    pub fn new(cluster: ClusterPreset, task: TrainTask) -> Self {
        Engine {
            cluster,
            task,
            opt: OptimizationPlan::default(),
            chaos: None,
            elastic: None,
        }
    }

    pub fn with_opt(mut self, opt: OptimizationPlan) -> Self {
        self.opt = opt;
        self
    }

    pub fn with_chaos(mut self, plan: ChaosPlan) -> Self {
        self.chaos = Some(plan);
        self
    }

    pub fn with_elastic(mut self, plan: ElasticPlan) -> Self {
        self.elastic = Some(plan);
        self
    }

    fn nproc(&self) -> usize {
        self.task.n_gpus as usize
    }

    fn prefetch_enabled(&self) -> bool {
        // SP has no moment lists: the prefetcher is tracer-fed.
        self.opt.prefetch && self.opt.use_tracer
    }

    /// Pick the chunk size: task override or the paper-grid search
    /// against the per-process heterogeneous budget.
    ///
    /// Besides the paper's host-capacity constraint, a whole
    /// communication group (`nproc` fp16 chunks) must fit the warm-up
    /// GPU grant (20% of GPU memory, Sec. 8.1) — all group members are
    /// pinned simultaneously during an all-gather.
    pub fn chunk_elems(&self) -> Result<u64> {
        if self.task.chunk_elems > 0 {
            return Ok(self.task.chunk_elems);
        }
        let specs = self.task.model.tensor_specs();
        let budget = self.cluster.cpu_mem
            + self.cluster.n_gpus as u64 * self.cluster.gpu_mem
            + (self.opt.nvme_gb << 30);
        let warmup_gpu =
            (self.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64;
        // fp16 group bytes = 2 * chunk_elems * nproc; leave one chunk of
        // headroom for the working set.
        let max_chunk_elems =
            warmup_gpu / (2 * (self.nproc() as u64 + 1));
        let grid: Vec<u64> = (128..=512u64)
            .step_by(32)
            .map(|q| q << 20)
            .filter(|&c| c <= max_chunk_elems)
            .collect();
        if grid.is_empty() {
            bail!(
                "no chunk size candidate fits a {}-chunk group in the \
                 warm-up GPU grant ({} B)",
                self.nproc(),
                warmup_gpu
            );
        }
        let res = crate::chunk::search::search_grid(&specs, &grid, budget)
            .ok_or_else(|| {
                anyhow!(
                    "no feasible chunk size for {} within {} bytes",
                    self.task.model.name,
                    budget
                )
            })?;
        Ok(res.best.chunk_elems)
    }

    /// Run warm-up + 2 steady iterations; report the final iteration.
    pub fn run(&self) -> Result<EngineReport> {
        self.run_inner(false).map(|(r, _)| r)
    }

    /// `run`, capturing a per-moment bit-exact timeline snapshot trace
    /// (one line per moment, plus iteration markers) for the
    /// golden-trace regression tests.
    pub fn run_traced(&self) -> Result<(EngineReport, Vec<String>)> {
        self.run_inner(true)
            .map(|(r, t)| (r, t.unwrap_or_default()))
    }

    fn run_inner(
        &self,
        traced: bool,
    ) -> Result<(EngineReport, Option<Vec<String>>)> {
        let parts = self.sim_parts()?;
        let SimParts { mgr, cost, graph, chunk_elems } = parts;
        let nproc = self.nproc();
        let backend = SimBackend::new(self.opt.overlap, cost.cluster.net,
                                      nproc);
        match self.chaos {
            Some(plan) => {
                let s = TrainingSession::new(
                    self.opt,
                    nproc,
                    mgr,
                    ChaosBackend::new(backend, plan),
                    traced,
                );
                self.drive(s, &cost, &graph, chunk_elems)
            }
            None => {
                let s = TrainingSession::new(self.opt, nproc, mgr,
                                             backend, traced);
                self.drive(s, &cost, &graph, chunk_elems)
            }
        }
    }

    /// Everything `run_inner` builds *before* choosing a backend: the
    /// chunk manager over the per-process heterogeneous budget, the cost
    /// model and the operator graph.  Split out so the checkpoint/resume
    /// tests (and any external driver) can construct sessions over
    /// arbitrary backends from the same deterministic starting state.
    pub(crate) fn sim_parts(&self) -> Result<SimParts> {
        let m = &self.task.model;
        let nproc = self.nproc();
        let chunk_elems = self.chunk_elems()?;
        let specs = m.tensor_specs();
        let reg = ChunkRegistry::build(&specs, chunk_elems)
            .context("chunk layout")?;

        // Per-process CPU share, minus this process's slice of the
        // CPU-pinned embedding data (p32+m+v+p16 ≈ 14 B/param).
        let emb_bytes = 14 * m.embedding_params();
        let cpu_total = self.cluster.cpu_mem;
        let cpu_share = (cpu_total / nproc as u64)
            .checked_sub(emb_bytes / nproc as u64)
            .ok_or_else(|| {
                anyhow!(
                    "CPU memory cannot hold embeddings: {} < {}",
                    cpu_total / nproc as u64,
                    emb_bytes / nproc as u64
                )
            })?;
        // The third tier: per-process NVMe share, present iff the plan
        // grants capacity (`with_nvme(0)` leaves the space two-tier).
        let space =
            HeterogeneousSpace::new(self.cluster.gpu_mem, cpu_share)
                .with_nvme((self.opt.nvme_gb << 30) / nproc as u64);
        let mgr = ChunkManager::new(reg, space);

        // The cost context carries the (possibly overridden) NVMe
        // curve: backend pricing and tier-aware victim pricing must
        // agree on it.
        let mut cluster = self.cluster;
        cluster.net = cluster.net.with_nvme_gbps(self.opt.nvme_gbps);
        let cost = SimCost { cluster, task: self.task };
        let graph = OpGraph::build(*m, self.task.batch_per_gpu);
        Ok(SimParts { mgr, cost, graph, chunk_elems })
    }

    /// Drive one session to a report: warm-up iteration, placement +
    /// prefetch schedules, 2 steady iterations (measure the last).
    /// Generic over the backend so the same loop runs the plain
    /// simulator and its chaos-wrapped variant.
    fn drive<B: ExecutionBackend>(
        &self,
        mut s: TrainingSession<B>,
        cost: &SimCost,
        graph: &OpGraph,
        chunk_elems: u64,
    ) -> Result<(EngineReport, Option<Vec<String>>)> {
        let m = &self.task.model;

        // ---- warm-up iteration (conservative 20% GPU, FIFO eviction).
        s.trace_mark("== warmup ==");
        s.iteration(cost, graph).context("warm-up iteration")?;

        // ---- placement + prefetch schedules from warm-up statistics.
        s.finish_warmup(cost, chunk_elems, self.prefetch_enabled());

        // ---- steady state: 2 iterations, measure the last.  The cost
        // context is a local copy: an elastic rescale changes the world
        // size mid-run, and everything downstream (shared-CPU split,
        // collective sizing, per-rank ADAM share) prices on it.
        let mut cost = *cost;
        let mut rescales: Vec<RescaleEvent> = Vec::new();
        if let Some(plan) = &self.elastic {
            if let Some(ev) =
                plan.events.iter().find(|e| e.at_iter >= 2)
            {
                bail!(
                    "elastic {} at iter {} is past the run: the engine \
                     drives 2 steady iterations (boundaries 0 and 1)",
                    ev.kind.name(),
                    ev.at_iter
                );
            }
        }
        let mut breakdown = IterBreakdown::default();
        let mut iter_time = 0.0f64;
        for it in 0..2 {
            // Boundary rescale triggers, in precedence order: the
            // planned elastic event, else a chaos rank failure (the
            // poll is a no-op drawing zero randoms unless the
            // rank-fail lane is armed).
            let failed = s.backend.poll_rank_fail();
            let planned =
                self.elastic.as_ref().and_then(|p| p.event_at(it));
            let target = if let Some(ev) = planned {
                match ev.kind {
                    ElasticKind::Shrink if ev.to >= s.nproc => bail!(
                        "elastic shrink at iter {it} targets {} ranks \
                         but the world is already {}",
                        ev.to,
                        s.nproc
                    ),
                    ElasticKind::Grow if ev.to <= s.nproc => bail!(
                        "elastic grow at iter {it} targets {} ranks \
                         but the world is already {}",
                        ev.to,
                        s.nproc
                    ),
                    _ => Some(ev.to),
                }
            } else if failed && s.nproc > 1 {
                Some(s.nproc - 1)
            } else {
                None
            };
            if let Some(to) = target {
                rescales.push(s.rescale(
                    &cost,
                    chunk_elems,
                    to,
                    it,
                    planned.is_none(),
                )?);
                cost.task.n_gpus = to as u32;
            }
            s.begin_steady_iteration(it);
            s.iteration(&cost, graph)
                .with_context(|| format!("steady iteration {it}"))?;
            breakdown = s.backend.breakdown();
            iter_time = s.backend.makespan();
        }
        // `begin_steady_iteration` audits lease leaks for every
        // iteration but the last (the audit runs before the stats
        // reset); audit the final iteration here so its count reaches
        // the report.
        s.check_lease_leaks();

        let iter_flops = m.iter_flops(self.task.batch_per_gpu);
        let trace = s.trace.take();
        let report = EngineReport {
            system: "patrickstar".into(),
            model: m.name.into(),
            n_gpus: self.task.n_gpus,
            batch_per_gpu: self.task.batch_per_gpu,
            chunk_elems,
            breakdown,
            iter_time_s: iter_time,
            tflops_per_gpu: iter_flops / iter_time / 1e12,
            placement: s.placement,
            move_stats: s.mgr.stats,
            allgather_bytes: s.allgather_bytes,
            reduce_scatter_bytes: s.reduce_scatter_bytes,
            allgather_bw: if s.allgather_time > 0.0 {
                s.allgather_bytes as f64 / s.allgather_time
            } else {
                0.0
            },
            reduce_scatter_bw: if s.reduce_scatter_time > 0.0 {
                s.reduce_scatter_bytes as f64 / s.reduce_scatter_time
            } else {
                0.0
            },
            gather_prefetches: s.gather_prefetches,
            gather_cancels: s.gather_cancelled_groups,
            adaptive_lookahead: s.ctl.is_some(),
            avg_chunk_lookahead: if s.chunk_win.1 > 0 {
                s.chunk_win.0 as f64 / s.chunk_win.1 as f64
            } else {
                0.0
            },
            avg_group_lookahead: if s.group_win.1 > 0 {
                s.group_win.0 as f64 / s.group_win.1 as f64
            } else {
                0.0
            },
            gpu_peak: s.mgr.space.dev(Device::Gpu(0)).peak(),
            cpu_peak: s.mgr.space.dev(Device::Cpu).peak(),
            nvme_peak: if s.mgr.has_nvme() {
                s.mgr.space.dev(Device::Nvme).peak()
            } else {
                0
            },
            non_model_peak: s.tracer.peak_non_model(),
            chaos: s.backend.chaos_stats(),
            rescales,
        };
        Ok((report, trace))
    }
}

/// Backend-independent session ingredients (see [`Engine::sim_parts`]).
pub(crate) struct SimParts {
    pub mgr: ChunkManager,
    pub cost: SimCost,
    pub graph: OpGraph,
    pub chunk_elems: u64,
}

/// Compile-time `Send` audit of the planner core (ISSUE 8).
///
/// A future multi-rank driver will move whole sessions across worker
/// threads, so the planner state must never grow an `Rc`, raw pointer
/// or other `!Send` member — this function fails to *compile* the day
/// one appears, which is a much earlier tripwire than a runtime test.
///
/// Deliberate exception: [`ChaosBackend`] keeps its fault-arrival
/// state in a `RefCell` (interior mutability behind `&self` probe
/// methods).  `RefCell<T: Send>` is still `Send` — sessions migrate
/// between threads fine — but it is **not** `Sync`: a chaos-wrapped
/// session must not be *shared* across threads, and nothing here
/// asserts `Sync` for exactly that reason.
#[allow(dead_code)]
fn assert_planner_core_is_send() {
    fn assert_send<T: Send>() {}
    assert_send::<ChunkManager>();
    assert_send::<OptimizationPlan>();
    assert_send::<ChaosPlan>();
    assert_send::<crate::placement::PlacementPlan>();
    assert_send::<SimBackend>();
    assert_send::<TrainingSession<SimBackend>>();
    assert_send::<TrainingSession<ChaosBackend<SimBackend>>>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterPreset;
    use crate::model::GptSpec;
    use crate::sim::Phase;

    fn run(model: &str, batch: u64, gpus: u32) -> EngineReport {
        let task =
            TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
        Engine::new(ClusterPreset::yard(), task).run().unwrap()
    }

    #[test]
    fn one_gpu_1b_runs_and_is_plausible() {
        let r = run("1B", 16, 1);
        assert!(r.iter_time_s > 0.1 && r.iter_time_s < 120.0,
                "iter {}", r.iter_time_s);
        // Paper band: tens of Tflops on V100.
        assert!(r.tflops_per_gpu > 20.0 && r.tflops_per_gpu < 80.0,
                "tflops {}", r.tflops_per_gpu);
    }

    #[test]
    fn eight_gpu_has_collectives() {
        let r = run("4B", 8, 8);
        assert!(r.breakdown.get(Phase::AllGather) > 0.0);
        assert!(r.breakdown.get(Phase::ReduceScatter) > 0.0);
        assert!(r.allgather_bytes > 0);
    }

    #[test]
    fn single_gpu_has_no_collectives() {
        let r = run("1B", 16, 1);
        assert_eq!(r.breakdown.get(Phase::AllGather), 0.0);
        assert_eq!(r.allgather_bytes, 0);
    }

    #[test]
    fn tracer_beats_static_partition() {
        // Fig. 16: Base vs SP — the tracer must cut chunk traffic.
        let task =
            TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 1);
        let base = Engine::new(ClusterPreset::yard(), task).run().unwrap();
        let sp = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::static_partition())
            .run()
            .unwrap();
        assert!(
            base.iter_time_s < sp.iter_time_s,
            "base {} !< sp {}",
            base.iter_time_s,
            sp.iter_time_s
        );
    }

    #[test]
    fn infeasible_when_model_too_big_for_node() {
        // 68B on YARD-120GB single GPU cannot hold OS in 120 GB.
        let task =
            TrainTask::new(GptSpec::by_name("68B").unwrap(), 8, 1);
        let r = Engine::new(ClusterPreset::yard_120gb(), task).run();
        assert!(r.is_err());
    }

    // The serial flat-clock contract and the full pipelined-vs-serial
    // comparison (volume, never-slower, overlap shares) live in
    // tests/prefetch_overlap.rs — not duplicated here.  The
    // session/backend-split equivalence properties live in
    // tests/session_equivalence.rs.

    #[test]
    fn overlap_without_prefetch_still_valid() {
        let task =
            TrainTask::new(GptSpec::by_name("8B").unwrap(), 8, 1);
        let serial =
            Engine::new(ClusterPreset::yard(), task).run().unwrap();
        let ov = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::overlap_only())
            .run()
            .unwrap();
        assert!(ov.iter_time_s <= serial.iter_time_s * (1.0 + 1e-9));
        assert_eq!(ov.move_stats.prefetches, 0);
        // Work accounting is identical either way — only concurrency
        // differs.
        let sum = |r: &EngineReport| -> f64 {
            Phase::ALL.iter().map(|&p| r.breakdown.get(p)).sum()
        };
        assert!((sum(&serial) - sum(&ov)).abs() < 1e-6 * sum(&serial));
    }

    // ---- ISSUE 6: kill-and-resume golden tests.  A session check-
    // pointed after steady iteration 0, dropped ("killed"), restored
    // and driven through iteration 1 must land bit-exactly where the
    // uninterrupted run lands — with and without fault injection.

    fn drive_steps<B: ExecutionBackend>(
        e: &Engine,
        s: &mut TrainingSession<B>,
        parts: &SimParts,
        iters: std::ops::Range<usize>,
        warm: bool,
    ) {
        if warm {
            s.trace_mark("== warmup ==");
            s.iteration(&parts.cost, &parts.graph).unwrap();
            s.finish_warmup(&parts.cost, parts.chunk_elems,
                            e.prefetch_enabled());
        }
        for it in iters {
            s.begin_steady_iteration(it);
            s.iteration(&parts.cost, &parts.graph).unwrap();
        }
    }

    /// Full per-run state digest: makespan bits, phase breakdown, move
    /// stats, and the per-moment trace — byte-compared via Debug.
    fn fingerprint<B: ExecutionBackend>(
        s: &TrainingSession<B>,
    ) -> (u64, String, String, Option<Vec<String>>) {
        (
            s.backend.makespan().to_bits(),
            format!("{:?}", s.backend.breakdown()),
            format!("{:?}", s.mgr.stats),
            s.trace.clone(),
        )
    }

    fn kill_resume_bit_exact<B, F>(mk: F) -> TrainingSession<B>
    where
        B: ExecutionBackend + Clone,
        F: Fn() -> B,
    {
        let task =
            TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 4);
        let e = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::pinned_pipeline());

        // Reference: uninterrupted warm-up + 2 steady iterations.
        let parts = e.sim_parts().unwrap();
        let mut full =
            TrainingSession::new(e.opt, e.nproc(), parts.mgr, mk(), true);
        drive_steps(&e, &mut full, &parts, 0..2, true);

        // Kill at k = 0: checkpoint after steady iteration 0, drop the
        // live session, restore from the checkpoint, run iteration 1.
        let parts2 = e.sim_parts().unwrap();
        let mut live = TrainingSession::new(e.opt, e.nproc(), parts2.mgr,
                                            mk(), true);
        drive_steps(&e, &mut live, &parts2, 0..1, true);
        let ckpt = live.checkpoint();
        drop(live); // the "kill"
        let mut resumed = ckpt.into_session();
        drive_steps(&e, &mut resumed, &parts2, 1..2, false);

        assert_eq!(fingerprint(&full), fingerprint(&resumed));
        resumed
    }

    #[test]
    fn kill_and_resume_is_bit_exact_without_chaos() {
        // nproc/overlap below must match the 4-GPU pinned_pipeline task
        // inside the helper.
        kill_resume_bit_exact(|| {
            SimBackend::new(true, ClusterPreset::yard().net, 4)
        });
    }

    #[test]
    fn kill_and_resume_is_bit_exact_under_chaos() {
        let s = kill_resume_bit_exact(|| {
            ChaosBackend::new(
                SimBackend::new(true, ClusterPreset::yard().net, 4),
                ChaosPlan::all(0xC0FFEE),
            )
        });
        // The run must actually have injected something, or the test
        // proves nothing about replaying fault state.
        let st = s.backend.chaos_stats().unwrap();
        assert!(
            st.copy_slowdowns
                + st.collective_stretches
                + st.pressure_spikes
                + st.aborts
                > 0,
            "chaos run injected no faults: {st:?}"
        );
    }

    // ---- ISSUE 9: elastic re-scaling.

    /// Small chunks so the fp16 list has enough positions for a
    /// shrink's re-shard set to be non-empty (list_len >= 3).
    fn elastic_engine(gpus: u32, spec: &str) -> Engine {
        let task =
            TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, gpus)
                .with_chunk_elems(32 << 20);
        Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::pinned_pipeline())
            .with_elastic(ElasticPlan::parse(spec).unwrap())
    }

    #[test]
    fn elastic_shrink_completes_and_replays_byte_identically() {
        let e = elastic_engine(4, "shrink@iter=1:to=2");
        let (r1, t1) = e.run_traced().unwrap();
        let (r2, t2) = e.run_traced().unwrap();
        assert_eq!(t1, t2, "elastic replay diverged");
        assert_eq!(format!("{r1:?}"), format!("{r2:?}"));
        assert_eq!(r1.rescales.len(), 1);
        let rs = &r1.rescales[0];
        assert_eq!((rs.at_iter, rs.from, rs.to), (1, 4, 2));
        assert!(!rs.rank_fail);
        assert!(rs.moved_shards > 0, "shrink moved no shards");
        assert!(rs.moved_bytes > 0 && rs.reshard_secs > 0.0);
        // Every moved shard ships its full owned state (7x its fp16
        // chunk bytes) exactly once — conservation at the report level.
        assert_eq!(
            rs.moved_bytes,
            rs.moved_shards as u64 * 7 * 2 * (32 << 20),
        );
        assert!(t1.iter().any(|l| l.contains("rescale @ iter 1: 4 -> 2")),
                "trace has no rescale marker");
        assert!(r1.render().contains("rescale @ iter 1: 4 -> 2 ranks"));
        assert!(r1.iter_time_s > 0.0);
    }

    #[test]
    fn elastic_grow_completes_and_direction_errors_are_named() {
        let (r, t) = elastic_engine(2, "grow@iter=1:to=4")
            .run_traced()
            .unwrap();
        assert_eq!(r.rescales.len(), 1);
        assert_eq!((r.rescales[0].from, r.rescales[0].to), (2, 4));
        assert!(t.iter().any(|l| l.contains("rescale @ iter 1: 2 -> 4")));
        // Wrong-direction and out-of-run events fail loudly.
        let err = elastic_engine(4, "shrink@iter=0:to=8")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("already"), "{err}");
        let err = elastic_engine(4, "grow@iter=0:to=2")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("already"), "{err}");
        let err = elastic_engine(4, "shrink@iter=2:to=2")
            .run()
            .unwrap_err()
            .to_string();
        assert!(err.contains("past the run"), "{err}");
    }

    #[test]
    fn elastic_kill_and_resume_is_bit_exact() {
        // The elastic path must compose with ISSUE 6 checkpoint/
        // restore: checkpoint right before the rescale boundary, kill,
        // restore, rescale, run iteration 1 — bit-identical to the
        // uninterrupted elastic run.
        let task =
            TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 4)
                .with_chunk_elems(32 << 20);
        let e = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::pinned_pipeline());
        let mk = || SimBackend::new(true, ClusterPreset::yard().net, 4);

        let run_tail =
            |s: &mut TrainingSession<SimBackend>, parts: &SimParts| {
                let mut cost = parts.cost;
                let ev = s
                    .rescale(&cost, parts.chunk_elems, 2, 1, false)
                    .unwrap();
                cost.task.n_gpus = 2;
                s.begin_steady_iteration(1);
                s.iteration(&cost, &parts.graph).unwrap();
                ev
            };

        // Reference: uninterrupted warm-up + iter 0 + rescale + iter 1.
        let parts = e.sim_parts().unwrap();
        let mut full =
            TrainingSession::new(e.opt, e.nproc(), parts.mgr, mk(), true);
        drive_steps(&e, &mut full, &parts, 0..1, true);
        let ev_full = run_tail(&mut full, &parts);

        // Kill at the boundary, restore, rescale, iter 1.
        let parts2 = e.sim_parts().unwrap();
        let mut live = TrainingSession::new(e.opt, e.nproc(), parts2.mgr,
                                            mk(), true);
        drive_steps(&e, &mut live, &parts2, 0..1, true);
        let ckpt = live.checkpoint();
        drop(live);
        let mut resumed = ckpt.into_session();
        let ev_resumed = run_tail(&mut resumed, &parts2);

        assert_eq!(fingerprint(&full), fingerprint(&resumed));
        assert_eq!(ev_full, ev_resumed);
    }

    #[test]
    fn rank_fail_chaos_lane_drives_shrinks_deterministically() {
        let mk = |gpus: u32| {
            let task =
                TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, gpus)
                    .with_chunk_elems(32 << 20);
            Engine::new(ClusterPreset::yard(), task)
                .with_opt(OptimizationPlan::pinned_pipeline())
                .with_chaos(
                    ChaosPlan::parse("rank-fail:rate=1", 7).unwrap(),
                )
        };
        // rate=1 fires at every boundary: 4 -> 3 at iter 0, 3 -> 2 at
        // iter 1, all flagged as rank failures, and the whole run
        // replays byte-identically.
        let (r1, t1) = mk(4).run_traced().unwrap();
        let (r2, t2) = mk(4).run_traced().unwrap();
        assert_eq!(t1, t2);
        assert_eq!(format!("{r1:?}"), format!("{r2:?}"));
        let shape: Vec<_> = r1
            .rescales
            .iter()
            .map(|r| (r.at_iter, r.from, r.to, r.rank_fail))
            .collect();
        assert_eq!(shape, vec![(0, 4, 3, true), (1, 3, 2, true)]);
        // A single-rank world has no one to lose: the poll may fire
        // but the engine never shrinks below 1.
        let (r, _) = mk(1).run_traced().unwrap();
        assert!(r.rescales.is_empty());
    }

    // ---- ISSUE 9 satellite: PinnedPool::leak_check on the restore
    // path.  Restoring a checkpoint and driving on must never leave a
    // dangling staging lease, even when hostile chaos aborts copies
    // mid-flight and the NVMe tier routes them through the two-hop
    // staged path (each hop holds the lease until the second lands).

    #[test]
    fn property_restore_path_never_leaks_leases_under_nvme_chaos() {
        use crate::util::quickcheck::forall;
        let task =
            TrainTask::new(GptSpec::by_name("1B").unwrap(), 4, 2)
                .with_chunk_elems(32 << 20);
        let opt = OptimizationPlan {
            nvme_gb: 64,
            ..OptimizationPlan::pinned_pipeline()
        };
        let e = Engine::new(ClusterPreset::nvme_lab(), task).with_opt(opt);
        forall(
            6,
            |rng| rng.next_u64(),
            |&seed| {
                let plan = ChaosPlan {
                    rate: 0.5,
                    intensity: 2.0,
                    ..ChaosPlan::all(seed)
                };
                let mk = || {
                    ChaosBackend::new(
                        SimBackend::new(
                            true,
                            ClusterPreset::nvme_lab().net,
                            2,
                        ),
                        plan,
                    )
                };
                let parts = e.sim_parts().unwrap();
                let mut live = TrainingSession::new(
                    e.opt, e.nproc(), parts.mgr, mk(), true,
                );
                drive_steps(&e, &mut live, &parts, 0..1, true);
                let ckpt = live.checkpoint();
                drop(live);
                let mut resumed = ckpt.into_session();
                drive_steps(&e, &mut resumed, &parts, 1..2, false);
                // The boundary audits counted every iteration but the
                // last; audit it too, then the whole run's count must
                // be zero.
                resumed.check_lease_leaks();
                if resumed.mgr.stats.lease_leaks != 0 {
                    return Err(format!(
                        "seed {seed}: restore path leaked {} pinned \
                         lease(s)",
                        resumed.mgr.stats.lease_leaks
                    ));
                }
                if resumed.mgr.stats.from_nvme_bytes == 0 {
                    return Err(format!(
                        "seed {seed}: run never exercised the two-hop \
                         staged NVMe route"
                    ));
                }
                Ok(())
            },
        );
    }
}
