//! The PatrickStar training engine (simulation backend).
//!
//! Drives one training process (rank 0's view) through warm-up and
//! steady-state iterations over the operator graph, using the *real*
//! chunk manager, tensor state machine, tracer, eviction and placement
//! code — only operator execution and data transfer are replaced by the
//! calibrated cost model.  The multi-GPU behaviour follows Sec. 7: chunks
//! at list position `p` belong to rank `p mod nproc`; remote chunks are
//! all-gathered per communication group and released after use;
//! reduce-scatter averages gradients; ADAM is rank-local.
//!
//! Ablation switches (paper Fig. 16): `use_tracer=false` reproduces the
//! "SP" static-partition plan (20% of GPU for chunks, forever);
//! `device_aware_os=false` reproduces "OSC" (optimizer states pinned to
//! CPU).
//!
//! # The prefetch + overlap pipeline
//!
//! On top of the paper's placement machinery sits a warm-up-guided
//! transfer pipeline (`prefetch`/`overlap` in [`OptimizationPlan`]):
//!
//! * **overlap** runs the iteration on a three-stream timeline
//!   ([`crate::sim::StreamTimeline`]): compute, H2D copy and D2H copy.
//!   Evictions and activation offload ride the async D2H stream; demand
//!   fetches still block, but only the compute stream's *stall* —
//!   `exposed_transfer_s` in the [`IterBreakdown`] — costs wall time,
//!   while `overlapped_transfer_s` is hidden under compute.
//! * **prefetch** walks the tracer's inverted moment lists
//!   ([`prefetch::Prefetcher`]) with a lookahead window each moment and
//!   stages upcoming chunks on the H2D stream ahead of use, guarded by
//!   the forward-looking `chunkable_gpu` headroom budget and a Belady
//!   victim guard (see `ChunkManager::prefetch_to`).  The optimizer
//!   sweep is pipelined the same way in the other direction: while
//!   group *k* updates on the CPU, group *k+1*'s grad chunk rides the
//!   D2H stream home.  A staged chunk is *in flight* — never evicted,
//!   only cancelled — until its first access waits out the copy.
//! * **overlap_collectives** extends the same pipeline to the
//!   data-parallel layer (ISSUE 2 tentpole): a fourth **collective
//!   stream** carries all-gather/reduce-scatter, and a group-level
//!   prefetcher ([`prefetch::GroupPrefetcher`], fed by the warm-up's
//!   gather log) issues the all-gather for group *g+1*'s remote chunks
//!   while group *g* computes (`group_lookahead` groups deep), with
//!   group *g-1*'s reduce-scatter draining behind it.  Chunks being
//!   filled by an in-flight gather are invisible to eviction and only
//!   ever *cancelled* whole under memory pressure, with the collective's
//!   time and bytes credited back — so total collective volume is
//!   bit-for-bit the serial schedule's volume, only its placement on
//!   the clock changes.
//!
//! * **pinned_buffers** (ISSUE 3 tentpole) prices the pipeline's host
//!   copies honestly: a finite pool of chunk-sized pinned staging
//!   buffers ([`crate::mem::PinnedPool`]) is leased per staged copy
//!   (issue to completion).  Demand copies preempt (always the pinned
//!   PCIe curve); prefetches and lookahead gathers that find the pool
//!   dry wait until the next moment (the lookahead window throttles to
//!   the pool-sized backlog); evictions and activation offload
//!   downgrade to the pageable (~0.5x-peak) curve.  Pool size 0
//!   disables the model: the single-curve timelines of PR 1/PR 2,
//!   bit-for-bit.
//!
//! * **adaptive_lookahead** (ISSUE 4 tentpole) replaces both static
//!   windows with a feedback controller
//!   ([`adaptive::LookaheadController`]): the chunk window is sized
//!   each moment from the EMA compute/H2D-transfer ratio, compressed by
//!   the live H2D backlog and bounded by the free pinned buffers; the
//!   group window from the collective/compute ratio on the fourth
//!   stream.  The two prefetchers stop budgeting independently against
//!   `min_chunkable_gpu` and draw from one negotiated
//!   [`adaptive::HeadroomLedger`] (upcoming gathers earmark their bytes
//!   before the chunk walk; demand traffic preempts both).  The static
//!   `lookahead`/`group_lookahead` knobs become the caps the adaptive
//!   windows never exceed.
//!
//! All switches default **off**: the serial path reproduces the
//! pre-pipeline numbers exactly; the pipelined paths are ablation cells
//! measured by `cargo bench -- prefetch_overlap collective_overlap
//! pinned_pool adaptive_lookahead`.

pub mod adaptive;
pub mod prefetch;
pub mod report;

use std::collections::{BTreeSet, HashMap, HashSet};

use anyhow::{anyhow, bail, Context, Result};

use crate::chunk::{ChunkId, ChunkKind, ChunkManager, ChunkRegistry,
                   MoveKind};
use crate::config::{ClusterPreset, TrainTask};
use crate::dp::{CollectiveCost, CollectivePipeline, CommGroups,
                InFlightGather};
use crate::evict::{BacklogAwareOpt, EvictionPolicy, FifoPolicy,
                   LfuPolicy, LruPolicy, OptPolicy};
use crate::mem::{Device, HeterogeneousSpace, PinnedLease, PinnedPool,
                 DEFAULT_PINNED_BUFFERS};
use crate::model::activation::{non_model_bytes, BASE_OVERHEAD};
use crate::model::{ActivationPlan, OpGraph, OpKind};
use crate::placement::{plan as placement_plan, PlacementPlan};
use crate::sim::{CopyDir, CopyRoute, Phase, StreamTimeline};
use crate::tensor::TensorState;
use crate::tracer::{MemTracer, Moment, WARMUP_GPU_FRAC};

pub use adaptive::{HeadroomLedger, LookaheadController, WindowInputs,
                   DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD,
                   DEFAULT_ADAPTIVE_MAX_LOOKAHEAD};
pub use prefetch::{GroupPrefetcher, Prefetcher, DEFAULT_GROUP_LOOKAHEAD,
                   DEFAULT_LOOKAHEAD};
pub use report::{EngineReport, IterBreakdown};

/// Eviction policy selection (paper Sec. 8.3 + DBMS baselines).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictKind {
    Opt,
    Lru,
    Fifo,
    Lfu,
}

/// The optimization toggles of the Fig. 16 ablation, extended with the
/// prefetch/overlap pipeline switches.
#[derive(Clone, Copy, Debug)]
pub struct OptimizationPlan {
    /// Use warm-up tracer statistics for chunkable memory (false = "SP").
    pub use_tracer: bool,
    /// Device-aware OS placement in GPU margin space (false = "OSC").
    pub device_aware_os: bool,
    pub eviction: EvictKind,
    /// Stage chunks ahead of use from the warm-up moment lists
    /// (requires `use_tracer`; no-op without it).
    pub prefetch: bool,
    /// Run on the dual-copy-stream timeline: evictions/offload async,
    /// transfer time hidden under compute where possible.
    pub overlap: bool,
    /// Prefetch lookahead window, in moments.
    pub lookahead: u32,
    /// Run collectives on a dedicated fourth stream with group-level
    /// lookahead gathers and draining reduce-scatters (requires
    /// `overlap`; no-op on a single process).
    pub overlap_collectives: bool,
    /// Group-gather lookahead depth, in communication groups.
    pub group_lookahead: u32,
    /// Size of the pinned staging-buffer pool (ISSUE 3 tentpole).
    /// 0 disables the pool: every host transfer charges the single
    /// pinned PCIe curve, reproducing the pre-pool timelines
    /// bit-for-bit.  With a finite pool, async copies and lookahead
    /// gathers hold a buffer from issue to completion; prefetches that
    /// cannot acquire one wait (throttling the lookahead window),
    /// evictions and activation offload downgrade to the pageable
    /// curve, and demand copies preempt (always pinned, never queued
    /// on the pool).
    pub pinned_buffers: u32,
    /// Per-direction staging sub-pool caps `(h2d, d2h)` within
    /// `pinned_buffers` (ISSUE 4 satellite).  None = unsplit: either
    /// direction may lease the whole pool — bit-identical to the PR 3
    /// shared pool.  A split caps each direction's concurrent leases so
    /// a D2H eviction burst cannot starve H2D prefetch.
    pub pinned_split: Option<(u32, u32)>,
    /// Size both prefetch windows at runtime from measured
    /// compute/transfer and compute/collective ratios (ISSUE 4
    /// tentpole) instead of the static `lookahead`/`group_lookahead`
    /// knobs — which then act as *caps* the adaptive windows never
    /// exceed.  Off (default): the static windows, bit-identical to
    /// PR 3 timelines.
    pub adaptive_lookahead: bool,
}

impl Default for OptimizationPlan {
    fn default() -> Self {
        OptimizationPlan {
            use_tracer: true,
            device_aware_os: true,
            eviction: EvictKind::Opt,
            prefetch: false,
            overlap: false,
            lookahead: DEFAULT_LOOKAHEAD,
            overlap_collectives: false,
            group_lookahead: DEFAULT_GROUP_LOOKAHEAD,
            pinned_buffers: 0,
            pinned_split: None,
            adaptive_lookahead: false,
        }
    }
}

impl OptimizationPlan {
    /// The "SP" ablation plan of Fig. 16.
    pub fn static_partition() -> Self {
        OptimizationPlan { use_tracer: false, ..Default::default() }
    }

    /// The "OSC" ablation plan of Fig. 16.
    pub fn os_on_cpu() -> Self {
        OptimizationPlan { device_aware_os: false, ..Default::default() }
    }

    /// The full transfer pipeline: prefetch + dual-stream overlap.
    pub fn pipelined() -> Self {
        OptimizationPlan { prefetch: true, overlap: true, ..Default::default() }
    }

    /// Overlap without prefetch: demand fetches still block, but
    /// evictions and activation offload leave the critical path.
    pub fn overlap_only() -> Self {
        OptimizationPlan { overlap: true, ..Default::default() }
    }

    /// The collective stream alone on top of overlap: chunk prefetch
    /// off, so the distributed win is measured in isolation.
    pub fn collectives_pipelined() -> Self {
        OptimizationPlan {
            overlap: true,
            overlap_collectives: true,
            ..Default::default()
        }
    }

    /// Everything on: chunk prefetch + dual copy streams + collective
    /// stream with group lookahead.
    pub fn fully_pipelined() -> Self {
        OptimizationPlan {
            overlap_collectives: true,
            ..Self::pipelined()
        }
    }

    /// The realistic transfer pipeline: everything on, plus a finite
    /// pinned staging pool ([`DEFAULT_PINNED_BUFFERS`] chunk-sized
    /// buffers) that the prefetchers compete for.
    pub fn pinned_pipeline() -> Self {
        OptimizationPlan {
            pinned_buffers: DEFAULT_PINNED_BUFFERS,
            ..Self::fully_pipelined()
        }
    }

    /// The ISSUE 4 tentpole cell: the full pinned pipeline with both
    /// prefetch windows sized by the feedback controller.  The static
    /// knobs become the adaptive caps (`--lookahead auto`).
    pub fn adaptive_pipeline() -> Self {
        OptimizationPlan {
            adaptive_lookahead: true,
            lookahead: DEFAULT_ADAPTIVE_MAX_LOOKAHEAD,
            group_lookahead: DEFAULT_ADAPTIVE_MAX_GROUP_LOOKAHEAD,
            ..Self::pinned_pipeline()
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Stage {
    Fwd,
    Bwd,
    Adam,
}

/// Timeline bookkeeping for one in-flight prefetch copy: when it lands,
/// what to un-charge if it is cancelled before reaching the wire, which
/// curve it was charged on, and the pinned staging buffer it holds.
#[derive(Clone, Copy, Debug)]
struct PendingCopy {
    done: f64,
    secs: f64,
    dir: CopyDir,
    phase: Phase,
    route: CopyRoute,
    lease: Option<PinnedLease>,
}

/// A pinned-buffer lease held by a non-prefetch async copy (eviction,
/// activation offload).  Prefetch leases live in [`PendingCopy`] and
/// gather leases in [`InFlightGather`]; these need the same (stream,
/// completion) bookkeeping so queue compression after a cancelled
/// prefetch can shift their release times with the frontier — otherwise
/// the pool would look busier than the stream actually is.
#[derive(Clone, Copy, Debug)]
struct StreamLease {
    lease: PinnedLease,
    dir: CopyDir,
    done: f64,
}

enum PolicySel {
    Opt,
    Lru(LruPolicy),
    Fifo(FifoPolicy),
    Lfu(LfuPolicy),
}

struct RunState {
    mgr: ChunkManager,
    tracer: MemTracer,
    tl: StreamTimeline,
    groups: CommGroups,
    fp16_list: Vec<ChunkId>,
    policy: PolicySel,
    warmup: bool,
    moment: Moment,
    placement: PlacementPlan,
    stage: Stage,
    /// Inverted warm-up moment lists (built once after warm-up when the
    /// prefetch switch is on).
    prefetcher: Option<Prefetcher>,
    /// In-flight prefetch copies on the timeline, by chunk.
    inflight_done: HashMap<ChunkId, PendingCopy>,
    /// Groups already gathered in the current phase.
    gathered: HashSet<usize>,
    /// Wire-volume accounting (Table 5).
    allgather_bytes: u64,
    reduce_scatter_bytes: u64,
    allgather_time: f64,
    reduce_scatter_time: f64,
    /// Warm-up log of demand gathers: (moment, group), schedule order.
    gather_log: Vec<(Moment, usize)>,
    /// Group-gather schedule (built once after warm-up when the
    /// collective-stream switch is on).
    group_prefetcher: Option<GroupPrefetcher>,
    /// Collective-stream pipeline: in-flight lookahead gathers and
    /// draining reduce-scatters, by group.
    coll: CollectivePipeline,
    /// Pinned staging-buffer pool (capacity 0 = disabled: single-curve
    /// charging, the pre-pool numbers bit-for-bit).
    pool: PinnedPool,
    /// Leases held by eviction/offload copies still queued or on the
    /// wire (see [`StreamLease`]).  Pruned as they expire.
    stream_leases: Vec<StreamLease>,
    /// Lookahead gathers issued this iteration.
    gather_prefetches: u64,
    /// Lookahead gathers cancelled this iteration, counted per *group*
    /// (the same unit as `gather_prefetches`; the manager's
    /// `MoveStats::gather_cancels` counts reclaimed chunks).
    gather_cancelled_groups: u64,
    /// Feedback-driven window sizing (adaptive mode only; None keeps
    /// the static windows bit-identical to PR 3).
    ctl: Option<LookaheadController>,
    /// Window telemetry for the measured iteration: (sum, ticks) of
    /// the chunk and group windows actually used each moment.
    chunk_win: (u64, u64),
    group_win: (u64, u64),
    /// Per-moment timeline snapshots (golden-trace tests).
    trace: Option<Vec<String>>,
}

/// The engine: one (cluster, task, optimization plan) triple.
pub struct Engine {
    pub cluster: ClusterPreset,
    pub task: TrainTask,
    pub opt: OptimizationPlan,
}

impl Engine {
    pub fn new(cluster: ClusterPreset, task: TrainTask) -> Self {
        Engine { cluster, task, opt: OptimizationPlan::default() }
    }

    pub fn with_opt(mut self, opt: OptimizationPlan) -> Self {
        self.opt = opt;
        self
    }

    fn nproc(&self) -> usize {
        self.task.n_gpus as usize
    }

    fn prefetch_enabled(&self) -> bool {
        // SP has no moment lists: the prefetcher is tracer-fed.
        self.opt.prefetch && self.opt.use_tracer
    }

    /// The collective stream is live: overlap timeline on, switch on,
    /// and there is actually more than one process to talk to.
    fn collectives_overlapped(&self) -> bool {
        self.opt.overlap && self.opt.overlap_collectives && self.nproc() > 1
    }

    /// Pick the chunk size: task override or the paper-grid search
    /// against the per-process heterogeneous budget.
    ///
    /// Besides the paper's host-capacity constraint, a whole
    /// communication group (`nproc` fp16 chunks) must fit the warm-up
    /// GPU grant (20% of GPU memory, Sec. 8.1) — all group members are
    /// pinned simultaneously during an all-gather.
    pub fn chunk_elems(&self) -> Result<u64> {
        if self.task.chunk_elems > 0 {
            return Ok(self.task.chunk_elems);
        }
        let specs = self.task.model.tensor_specs();
        let budget = self.cluster.cpu_mem
            + self.cluster.n_gpus as u64 * self.cluster.gpu_mem;
        let warmup_gpu =
            (self.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64;
        // fp16 group bytes = 2 * chunk_elems * nproc; leave one chunk of
        // headroom for the working set.
        let max_chunk_elems =
            warmup_gpu / (2 * (self.nproc() as u64 + 1));
        let grid: Vec<u64> = (128..=512u64)
            .step_by(32)
            .map(|q| q << 20)
            .filter(|&c| c <= max_chunk_elems)
            .collect();
        if grid.is_empty() {
            bail!(
                "no chunk size candidate fits a {}-chunk group in the \
                 warm-up GPU grant ({} B)",
                self.nproc(),
                warmup_gpu
            );
        }
        let res = crate::chunk::search::search_grid(&specs, &grid, budget)
            .ok_or_else(|| {
                anyhow!(
                    "no feasible chunk size for {} within {} bytes",
                    self.task.model.name,
                    budget
                )
            })?;
        Ok(res.best.chunk_elems)
    }

    /// Run warm-up + 2 steady iterations; report the final iteration.
    pub fn run(&self) -> Result<EngineReport> {
        self.run_inner(false).map(|(r, _)| r)
    }

    /// `run`, capturing a per-moment bit-exact timeline snapshot trace
    /// (one line per moment, plus iteration markers) for the
    /// golden-trace regression tests.
    pub fn run_traced(&self) -> Result<(EngineReport, Vec<String>)> {
        self.run_inner(true)
            .map(|(r, t)| (r, t.unwrap_or_default()))
    }

    fn run_inner(
        &self,
        traced: bool,
    ) -> Result<(EngineReport, Option<Vec<String>>)> {
        let m = &self.task.model;
        let nproc = self.nproc();
        let chunk_elems = self.chunk_elems()?;
        let specs = m.tensor_specs();
        let reg = ChunkRegistry::build(&specs, chunk_elems)
            .context("chunk layout")?;

        // Per-process CPU share, minus this process's slice of the
        // CPU-pinned embedding data (p32+m+v+p16 ≈ 14 B/param).
        let emb_bytes = 14 * m.embedding_params();
        let cpu_total = self.cluster.cpu_mem;
        let cpu_share = (cpu_total / nproc as u64)
            .checked_sub(emb_bytes / nproc as u64)
            .ok_or_else(|| {
                anyhow!(
                    "CPU memory cannot hold embeddings: {} < {}",
                    cpu_total / nproc as u64,
                    emb_bytes / nproc as u64
                )
            })?;
        let space =
            HeterogeneousSpace::new(self.cluster.gpu_mem, cpu_share);
        let mgr = ChunkManager::new(reg, space);
        let fp16_list = mgr.reg.list(ChunkKind::ParamFp16);
        let n_chunks = mgr.reg.chunks.len();
        let list_len = fp16_list.len();

        let mut st = RunState {
            mgr,
            tracer: MemTracer::new(n_chunks),
            tl: StreamTimeline::new(self.opt.overlap),
            groups: CommGroups::new(list_len, nproc),
            fp16_list,
            policy: match self.opt.eviction {
                EvictKind::Opt => PolicySel::Opt,
                EvictKind::Lru => PolicySel::Lru(LruPolicy::default()),
                EvictKind::Fifo => PolicySel::Fifo(FifoPolicy::default()),
                EvictKind::Lfu => PolicySel::Lfu(LfuPolicy::default()),
            },
            warmup: true,
            moment: 0,
            placement: PlacementPlan {
                os_groups_on_gpu: 0,
                spilled_fp16_chunks: 0,
                total_fp16_chunks: list_len,
                embedding_on_cpu: true,
            },
            stage: Stage::Fwd,
            prefetcher: None,
            inflight_done: HashMap::new(),
            gathered: HashSet::new(),
            allgather_bytes: 0,
            reduce_scatter_bytes: 0,
            allgather_time: 0.0,
            reduce_scatter_time: 0.0,
            gather_log: Vec::new(),
            group_prefetcher: None,
            coll: CollectivePipeline::default(),
            pool: {
                let p = PinnedPool::new(self.opt.pinned_buffers as usize);
                match self.opt.pinned_split {
                    Some((h, d)) => p.with_split(h as usize, d as usize),
                    None => p,
                }
            },
            stream_leases: Vec::new(),
            gather_prefetches: 0,
            gather_cancelled_groups: 0,
            ctl: None,
            chunk_win: (0, 0),
            group_win: (0, 0),
            trace: if traced { Some(Vec::new()) } else { None },
        };

        let graph = OpGraph::build(*m, self.task.batch_per_gpu);

        // ---- warm-up iteration (conservative 20% GPU, FIFO eviction).
        if let Some(tr) = st.trace.as_mut() {
            tr.push("== warmup ==".into());
        }
        self.iteration(&mut st, &graph).context("warm-up iteration")?;
        st.tracer.finish_warmup();
        st.warmup = false;

        // ---- placement from warm-up statistics.
        // Without the tracer ("SP" plan) the chunkable space stays at
        // the 20% warm-up grant forever, so the margin is computed
        // against that grant — and eviction must fall back to chunk-list
        // order (OPT's future-use moment lists ARE the tracer
        // statistics, paper Sec. 8.1/8.3).
        let (plan_gpu, plan_nm) = if self.opt.use_tracer {
            (self.cluster.gpu_mem, st.tracer.peak_non_model())
        } else {
            st.policy = PolicySel::Fifo(FifoPolicy::default());
            (
                (self.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64,
                0,
            )
        };
        st.placement = placement_plan(
            plan_gpu,
            plan_nm,
            chunk_elems,
            // Only the local share of fp16 chunks competes for this
            // rank's GPU during FWD/BWD residency planning.
            st.groups.owned_by(0).len(),
            self.opt.device_aware_os,
        );
        if self.prefetch_enabled() {
            st.prefetcher =
                Some(Prefetcher::from_tracer(&st.tracer, n_chunks));
        }
        if self.collectives_overlapped() {
            st.group_prefetcher = Some(GroupPrefetcher::from_log(
                std::mem::take(&mut st.gather_log),
            ));
        }
        // The adaptive controller sizes whatever prefetch lanes are
        // live; with neither lane there is nothing to size and the
        // static path stays untouched.
        if self.opt.adaptive_lookahead
            && (st.prefetcher.is_some() || st.group_prefetcher.is_some())
        {
            st.ctl = Some(LookaheadController::new(
                self.opt.lookahead,
                self.opt.group_lookahead,
            ));
        }

        // ---- steady state: 2 iterations, measure the last.
        let mut breakdown = IterBreakdown::default();
        let mut iter_time = 0.0f64;
        for it in 0..2 {
            // Settle copies still in flight from the previous iteration:
            // their payloads are already resident, and the fresh
            // timeline starts at zero, so stale completion times must
            // not leak across the boundary.  Gathers settle the same
            // way: anything issued is consumed by its group's fetch
            // within the iteration, but belt-and-braces.
            while let Some(c) = st.mgr.pending_prefetch_on(Device::Gpu(0)) {
                st.mgr.complete_prefetch(c);
            }
            for c in st.mgr.gathering_chunks() {
                st.mgr.finish_gather(c);
            }
            st.coll.clear();
            st.pool.clear();
            st.stream_leases.clear();
            st.inflight_done.clear();
            st.tl.reset();
            st.mgr.stats = Default::default();
            st.allgather_bytes = 0;
            st.reduce_scatter_bytes = 0;
            st.allgather_time = 0.0;
            st.reduce_scatter_time = 0.0;
            st.gather_prefetches = 0;
            st.gather_cancelled_groups = 0;
            st.chunk_win = (0, 0);
            st.group_win = (0, 0);
            if let Some(c) = st.ctl.as_mut() {
                // The timeline restarts at zero; the learned rates
                // carry over (iterations are structurally identical).
                c.iteration_boundary();
            }
            if let Some(tr) = st.trace.as_mut() {
                tr.push(format!("== iter {it} =="));
            }
            self.iteration(&mut st, &graph)
                .with_context(|| format!("steady iteration {it}"))?;
            breakdown = IterBreakdown::from_timeline(&st.tl);
            iter_time = st.tl.makespan();
        }

        let iter_flops = m.iter_flops(self.task.batch_per_gpu);
        let trace = st.trace.take();
        let report = EngineReport {
            system: "patrickstar".into(),
            model: m.name.into(),
            n_gpus: self.task.n_gpus,
            batch_per_gpu: self.task.batch_per_gpu,
            chunk_elems,
            breakdown,
            iter_time_s: iter_time,
            tflops_per_gpu: iter_flops / iter_time / 1e12,
            placement: st.placement,
            move_stats: st.mgr.stats,
            allgather_bytes: st.allgather_bytes,
            reduce_scatter_bytes: st.reduce_scatter_bytes,
            allgather_bw: if st.allgather_time > 0.0 {
                st.allgather_bytes as f64 / st.allgather_time
            } else {
                0.0
            },
            reduce_scatter_bw: if st.reduce_scatter_time > 0.0 {
                st.reduce_scatter_bytes as f64 / st.reduce_scatter_time
            } else {
                0.0
            },
            gather_prefetches: st.gather_prefetches,
            gather_cancels: st.gather_cancelled_groups,
            adaptive_lookahead: st.ctl.is_some(),
            avg_chunk_lookahead: if st.chunk_win.1 > 0 {
                st.chunk_win.0 as f64 / st.chunk_win.1 as f64
            } else {
                0.0
            },
            avg_group_lookahead: if st.group_win.1 > 0 {
                st.group_win.0 as f64 / st.group_win.1 as f64
            } else {
                0.0
            },
            gpu_peak: st.mgr.space.dev(Device::Gpu(0)).peak(),
            cpu_peak: st.mgr.space.dev(Device::Cpu).peak(),
            non_model_peak: st.tracer.peak_non_model(),
        };
        Ok((report, trace))
    }

    // ------------------------------------------------------------------
    // One iteration: FWD -> BWD -> ADAM.
    // ------------------------------------------------------------------

    fn iteration(&self, st: &mut RunState, graph: &OpGraph) -> Result<()> {
        st.moment = 0;
        let n_layer_ops = 7usize;
        let layer_of = |op_idx: usize| -> u32 {
            // ops: embed, L x 7, lnf, lm_head
            if op_idx == 0 {
                0
            } else {
                (((op_idx - 1) / n_layer_ops) as u32).min(
                    graph.spec.layers.saturating_sub(1),
                )
            }
        };

        // ---- FWD
        st.stage = Stage::Fwd;
        st.gathered.clear();
        for (i, op) in graph.ops.iter().enumerate() {
            let live = layer_of(i) + 1;
            self.moment_tick(st, live)?;
            self.exec_op(st, graph, i, op.params.clone())?;
        }
        st.mgr.reset_after_fwd(ChunkKind::ParamFp16)?;

        // ---- BWD (reverse op order)
        st.stage = Stage::Bwd;
        st.gathered.clear();
        for (i, op) in graph.ops.iter().enumerate().rev() {
            let live = layer_of(i) + 1;
            self.moment_tick(st, live)?;
            self.exec_op(st, graph, i, op.params.clone())?;
        }

        // ---- ADAM (rank-local chunk groups)
        st.stage = Stage::Adam;
        let local = st.groups.owned_by(0);
        for (li, pos) in local.iter().enumerate() {
            self.moment_tick(st, 0)?;
            // Pipeline the optimizer sweep: while group `li` computes,
            // the next group's grad chunk rides the D2H stream home.
            if !st.warmup && st.prefetcher.is_some() {
                self.stage_next_adam_group(st, &local, li)?;
            }
            self.exec_adam(st, *pos, li)?;
        }
        // Embedding ADAM runs on CPU over its own (unmanaged) buffers.
        let emb_os_bytes = 16 * graph.spec.embedding_params()
            / self.nproc() as u64;
        if !st.warmup {
            let cpu = self.shared_cpu();
            st.tl.charge(Phase::Adam, cpu.adam_time(emb_os_bytes));
        }
        // The optimizer step is not done until every reduce-scatter has
        // drained off the collective stream (exec_adam waits per group;
        // this barrier catches any group whose drain no consumer hit).
        if !st.warmup && self.collectives_overlapped() {
            for t in st.coll.drain_rs() {
                st.tl.wait_collective(t);
            }
        }
        Ok(())
    }

    /// Advance one moment: record/evaluate non-model footprint, re-cap the
    /// chunkable GPU space, evict to fit, stage upcoming chunks.
    fn moment_tick(&self, st: &mut RunState, live_layers: u32) -> Result<()> {
        let nm = if live_layers == 0 {
            BASE_OVERHEAD
        } else {
            non_model_bytes(
                &self.task.model,
                self.task.batch_per_gpu,
                self.task.plan,
                live_layers,
            )
        };
        let cap = if st.warmup || !self.opt.use_tracer {
            (self.cluster.gpu_mem as f64 * WARMUP_GPU_FRAC) as u64
        } else {
            self.cluster.gpu_mem.saturating_sub(nm)
        };
        if st.warmup {
            let m = st.tracer.record_moment(nm);
            debug_assert_eq!(m, st.moment);
        }
        // A landed lookahead gather turns its chunks back into ordinary
        // residents *before* the cap shrink, so pressure prefers normal
        // eviction over cancelling still-queued gathers.
        if !st.warmup && self.collectives_overlapped() {
            self.complete_landed_gathers(st);
        }
        // Feedback first: the controller differences the timeline's
        // per-stream work accumulators against the previous tick, so
        // this tick's window sizes reflect everything charged up to the
        // previous operator (st.ctl is only ever Some in adaptive mode,
        // after warm-up).
        if let Some(c) = st.ctl.as_mut() {
            c.observe(&st.tl);
        }
        st.mgr.space.dev_mut(Device::Gpu(0)).set_capacity(cap);
        // Cap-shrink eviction.  In adaptive mode with the OPT policy a
        // deep D2H backlog turns on the overlap-aware tie-break: a
        // near-equal victim that can be *dropped* (all tensors FREE)
        // beats one whose spill would queue behind the backlog.  Margin
        // 0 (static mode, idle engine, non-OPT policy) is plain OPT.
        let evict_margin = match (&st.ctl, &st.policy) {
            (Some(c), PolicySel::Opt) => {
                c.evict_margin(st.tl.copy_backlog(CopyDir::D2H))
            }
            _ => 0,
        };
        if evict_margin > 0 {
            let droppable: HashSet<ChunkId> = st
                .mgr
                .reg
                .chunks
                .iter()
                .filter(|c| c.device == Some(Device::Gpu(0)))
                .map(|c| c.id)
                .filter(|&id| st.mgr.all_free(id))
                .collect();
            let RunState { mgr, tracer, moment, .. } = st;
            let mut pol = BacklogAwareOpt {
                tracer,
                droppable,
                margin: evict_margin,
            };
            mgr.evict_to_fit(Device::Gpu(0), &mut pol, *moment)?;
        } else {
            let RunState { mgr, tracer, policy, moment, .. } = st;
            with_policy(policy, tracer, |pol| {
                mgr.evict_to_fit(Device::Gpu(0), pol, *moment)
            })?;
        }
        self.charge_moves(st)?;
        // Window sizing + the negotiated headroom ledger.  Static mode:
        // the configured knobs and a ledger with no earmarks — whose
        // arithmetic is exactly the PR 3 budgets, bit-for-bit.
        let inputs = WindowInputs {
            pool_free: if st.pool.enabled() {
                Some(st.pool.available_at(st.tl.now(), CopyDir::H2D)
                     as u32)
            } else {
                None
            },
            h2d_backlog_secs: st.tl.copy_backlog(CopyDir::H2D),
            coll_backlog_secs: st.tl.collective_backlog(),
        };
        let chunk_la = match &st.ctl {
            Some(c) => c.chunk_window(inputs),
            None => self.opt.lookahead,
        };
        let group_la = match &st.ctl {
            Some(c) => c.group_window(inputs),
            None => self.opt.group_lookahead,
        };
        let mut ledger = HeadroomLedger::new(
            st.moment,
            self.cluster.gpu_mem,
            self.opt.use_tracer,
        );
        if st.ctl.is_some() && st.group_prefetcher.is_some() {
            // Negotiation: reserve the upcoming all-gathers' bytes
            // before the chunk walk starts, so a deep chunk window
            // cannot starve the collective lane of headroom.  (Demand
            // traffic preempts both — it never consults the ledger.)
            self.earmark_upcoming_gathers(st, group_la, &mut ledger);
        }
        if !st.warmup && st.prefetcher.is_some() {
            st.chunk_win.0 += chunk_la as u64;
            st.chunk_win.1 += 1;
            self.issue_prefetches(st, chunk_la, &ledger)?;
            self.charge_moves(st)?;
        }
        if !st.warmup && st.group_prefetcher.is_some() {
            st.group_win.0 += group_la as u64;
            st.group_win.1 += 1;
            self.issue_group_gathers(st, group_la, &mut ledger)?;
            self.charge_moves(st)?;
        }
        st.moment += 1;
        if let Some(tr) = st.trace.as_mut() {
            tr.push(format!("m{:05} {}", st.moment - 1, st.tl.snapshot()));
        }
        Ok(())
    }

    /// A gather whose collective has completed by the current compute
    /// time holds real data: its chunks become normal resident chunks
    /// (evictable under the usual rules — spilling landed data is
    /// honest, spilling a half-arrived payload is not).  The in-flight
    /// entry itself stays until the demand fetch consumes it, at zero
    /// stall.
    fn complete_landed_gathers(&self, st: &mut RunState) {
        let now_t = st.tl.now();
        for g in st.coll.landed(now_t) {
            let members: Vec<usize> = st.groups.members(g).collect();
            for p in members {
                st.mgr.finish_gather(st.fp16_list[p]);
            }
        }
    }

    /// Record the byte needs of the next `k` scheduled group gathers as
    /// ledger earmarks (adaptive mode).  Mirrors the walk of
    /// [`Engine::issue_group_gathers`] up to (not including) its budget
    /// and pool checks, so exactly the groups that *could* issue this
    /// tick or soon after hold reservations against the chunk walk.
    fn earmark_upcoming_gathers(
        &self,
        st: &RunState,
        k: u32,
        ledger: &mut HeadroomLedger,
    ) {
        let upcoming = match &st.group_prefetcher {
            Some(gp) => gp.upcoming(st.moment, k as usize),
            None => return,
        };
        let chunk_bytes = st.mgr.chunk(st.fp16_list[0]).bytes();
        for (_, g) in upcoming {
            if st.coll.gather_issued(g) {
                continue; // already staged; its bytes show in used()
            }
            if st.gathered.contains(&g) {
                break; // schedule-order FIFO, as in the issue walk
            }
            let absent = st
                .groups
                .members(g)
                .map(|p| st.fp16_list[p])
                .filter(|&c| st.mgr.chunk(c).device.is_none())
                .count() as u64;
            if absent == 0 {
                break;
            }
            ledger.earmark_group(g, absent * chunk_bytes);
        }
    }

    /// Issue all-gathers for the next `k` groups of the warm-up gather
    /// schedule onto the collective stream, drawing headroom from the
    /// negotiated ledger (statically `k = --group-lookahead`;
    /// adaptively the controller's collective/compute window).  Issue
    /// order strictly follows the schedule: if the next group cannot be
    /// staged (no absent members yet, or no headroom), later groups
    /// must not jump the queue — a demand gather must never find a
    /// less-urgent gather ahead of it on the stream.
    fn issue_group_gathers(
        &self,
        st: &mut RunState,
        k: u32,
        ledger: &mut HeadroomLedger,
    ) -> Result<()> {
        let k = k as usize;
        if k == 0 {
            return Ok(());
        }
        let now = st.moment;
        let upcoming = match &st.group_prefetcher {
            Some(gp) => gp.upcoming(now, k),
            None => return Ok(()),
        };
        let cc = CollectiveCost::new(self.cluster.net.nvlink, self.nproc());
        for (use_m, g) in upcoming {
            if st.coll.gather_issued(g) {
                continue; // already on the stream, in schedule order
            }
            if st.gathered.contains(&g) {
                break; // still held from the previous stage; retry later
            }
            let members: Vec<usize> = st.groups.members(g).collect();
            let absent: Vec<ChunkId> = members
                .iter()
                .map(|&p| st.fp16_list[p])
                .filter(|&c| st.mgr.chunk(c).device.is_none())
                .collect();
            if absent.is_empty() {
                break; // nothing to gather (yet); keep FIFO order
            }
            let chunk_bytes = st.mgr.chunk(st.fp16_list[0]).bytes();
            let new_bytes = absent.len() as u64 * chunk_bytes;
            // Headroom budget from the ledger: the tightest chunkable
            // cap between now and the use moment, minus the *other*
            // groups' reservations (this group's own earmark is the
            // headroom being spent), so staging never triggers the
            // evictions it is hiding from.
            let budget = ledger.gather_budget(&st.tracer, use_m, g);
            let gpu = st.mgr.space.dev(Device::Gpu(0));
            if gpu.used() + new_bytes > budget
                || !gpu.can_fit(new_bytes)
            {
                break; // no headroom; retry next moment
            }
            // A lookahead gather stages its local shard through one
            // pinned buffer held for the collective's lifetime; if
            // every buffer is leased out, the gather waits its turn
            // (FIFO: later groups must not jump the queue either).
            let lease = if st.pool.enabled() {
                match st.pool.try_acquire(st.tl.now(), CopyDir::H2D) {
                    Some(l) => Some(l),
                    None => {
                        st.mgr.stats.pinned_waits += 1;
                        break; // retry next moment
                    }
                }
            } else {
                None
            };
            for &c in &absent {
                st.mgr.alloc_payload(c, Device::Gpu(0))?;
                st.mgr.begin_gather(c)?;
                // Remote payloads arrive in HOLD (as in fetch_group).
                st.mgr.retag_tensors(
                    c, TensorState::Free, TensorState::Hold)?;
            }
            let op = cc.allgather_op(chunk_bytes);
            let done = st.tl.async_collective(Phase::AllGather, op.secs);
            if let Some(l) = lease {
                st.pool.set_release(l, done);
            }
            st.allgather_time += op.secs;
            st.allgather_bytes += op.bytes;
            st.coll.issue_gather(
                g,
                InFlightGather {
                    done,
                    secs: op.secs,
                    bytes: op.bytes,
                    use_moment: use_m,
                    lease,
                },
            );
            st.gather_prefetches += 1;
            // The reservation is spent: the staged bytes now show in
            // the device's used(), so keeping the earmark would charge
            // the remaining groups twice.
            ledger.consume_group(g);
        }
        Ok(())
    }

    /// Walk the lookahead window and stage CPU-resident chunks with an
    /// upcoming GPU use onto the H2D stream (statically `lookahead =
    /// --lookahead`; adaptively the controller's ratio-sized,
    /// backlog-compressed, pool-bounded window).
    fn issue_prefetches(
        &self,
        st: &mut RunState,
        lookahead: u32,
        ledger: &HeadroomLedger,
    ) -> Result<()> {
        let now = st.moment;
        let window = match &st.prefetcher {
            Some(pf) => pf.window(now, lookahead),
            None => return Ok(()),
        };
        // Staging-capacity budget (pool enabled only): each prefetch
        // issued this tick will lease one pinned buffer when its copy is
        // charged; once the free H2D buffers are spoken for, the rest of
        // the window waits for the next moment — the effective lookahead
        // is throttled to the pool-sized backlog.
        let mut pool_budget = if st.pool.enabled() {
            Some(st.pool.available_at(st.tl.now(), CopyDir::H2D))
        } else {
            None
        };
        for (use_moment, c) in window {
            if st.mgr.chunk(c).device != Some(Device::Cpu) {
                continue; // resident, in flight, or released
            }
            if pool_budget == Some(0) {
                st.mgr.stats.pinned_waits += 1;
                break; // no staging buffer free; retry next moment
            }
            // Headroom budget from the ledger: staying under the
            // tightest chunkable cap between now and the use moment
            // (minus any bytes earmarked for the collective lane)
            // guarantees the staged bytes never cause a cap-shrink
            // eviction of their own nor starve an imminent all-gather.
            let limit = ledger.chunk_limit(&st.tracer, use_moment);
            let RunState { mgr, tracer, policy, .. } = st;
            let issued = with_policy(policy, tracer, |pol| {
                mgr.prefetch_to(c, Device::Gpu(0), limit, pol, now, &|v| {
                    // Belady guard: spill only chunks OPT would spill at
                    // the use moment anyway — next use farther than the
                    // prefetched chunk's own use.
                    match tracer.next_use(v, now) {
                        None => true,
                        Some(next) => next > use_moment,
                    }
                })
            })?;
            if issued {
                if let Some(b) = pool_budget.as_mut() {
                    *b -= 1;
                }
            }
        }
        Ok(())
    }

    /// The ADAM-bound leg of the pipeline: stage the *next* local
    /// group's fp16 (grad) chunk onto the CPU over the async D2H stream
    /// while the current group's update computes.  Margin groups (ADAM
    /// on GPU) need no staging — their chunks are already resident.
    /// Conservative by construction: only free CPU space is used (no
    /// evictions for staging), so the transfer set matches the serial
    /// schedule exactly, just earlier and off the critical path.
    fn stage_next_adam_group(
        &self,
        st: &mut RunState,
        local: &[usize],
        li: usize,
    ) -> Result<()> {
        let next = li + 1;
        if next >= local.len() {
            return Ok(());
        }
        let next_on_gpu = self.opt.device_aware_os
            && next < st.placement.os_groups_on_gpu;
        if next_on_gpu {
            return Ok(());
        }
        let c = st.fp16_list[local[next]];
        if st.mgr.chunk(c).device != Some(Device::Gpu(0)) {
            return Ok(()); // already home (or released)
        }
        // The D2H staging leg competes for the pinned pool's D2H
        // sub-pool: with no buffer free, the grad chunk waits and rides
        // home on the demand path instead.
        if st.pool.enabled()
            && st.pool.available_at(st.tl.now(), CopyDir::D2H) == 0
        {
            st.mgr.stats.pinned_waits += 1;
            return Ok(());
        }
        let limit = st.mgr.space.dev(Device::Cpu).capacity;
        let now = st.moment.saturating_sub(1);
        let RunState { mgr, tracer, policy, .. } = st;
        with_policy(policy, tracer, |pol| {
            mgr.prefetch_to(c, Device::Cpu, limit, pol, now, &|_| false)
        })?;
        self.charge_adam_moves(st)?;
        Ok(())
    }

    /// If `chunk` has an in-flight prefetch, block the compute stream
    /// until the copy lands and mark it consumed.
    fn wait_chunk(&self, st: &mut RunState, chunk: ChunkId) {
        if st.mgr.is_inflight(chunk) {
            if let Some(pc) = st.inflight_done.get(&chunk).copied() {
                st.tl.wait_until(pc.done);
            }
            st.mgr.complete_prefetch(chunk);
        }
        st.inflight_done.remove(&chunk);
    }

    /// Chunk owning the `idx`-th tensor of `kind`.
    fn chunk_of(&self, st: &RunState, kind: ChunkKind, idx: usize)
        -> ChunkId {
        let ti = st.mgr.reg.tensor_index(kind, idx);
        ChunkId(st.mgr.reg.tensors[ti].chunk as u32)
    }

    /// Execute one operator at the current moment (stage-dependent).
    fn exec_op(
        &self,
        st: &mut RunState,
        graph: &OpGraph,
        op_idx: usize,
        params: Vec<usize>,
    ) -> Result<()> {
        let op = &graph.ops[op_idx];
        let now = st.moment.saturating_sub(1);

        // Embedding ops: CPU lookup + activation traffic; LM head GEMM on
        // GPU with the fp16 embedding streamed up (Sec. 8.2).
        if op.kind == OpKind::Embedding {
            if !st.warmup {
                let cpu = self.shared_cpu();
                let m = &graph.spec;
                let act_bytes = 2 * self.task.batch_per_gpu * m.seq * m.hidden;
                let pcie = self.cluster.net.pcie;
                if op.name == "embed" {
                    st.tl.charge(
                        Phase::FwdBwd,
                        cpu.op_time(OpKind::Embedding, op.fwd_flops),
                    );
                    let (phase, dir) = if st.stage == Stage::Fwd {
                        (Phase::CpuToGpu, CopyDir::H2D)
                    } else {
                        (Phase::GpuToCpu, CopyDir::D2H)
                    };
                    st.tl.demand_copy(
                        phase, pcie.transfer_time(act_bytes), dir, 0.0);
                } else {
                    // lm_head: GEMM on GPU; wte fp16 up in FWD, its grad
                    // down in BWD.
                    let gpu = self.cluster.gpu;
                    let mult = self.bwd_mult(st.stage);
                    st.tl.charge(
                        Phase::FwdBwd,
                        gpu.op_time(OpKind::ComputeIntensive,
                                    mult * op.fwd_flops),
                    );
                    let wte_bytes = 2 * m.vocab * m.hidden;
                    let (phase, dir) = if st.stage == Stage::Fwd {
                        (Phase::CpuToGpu, CopyDir::H2D)
                    } else {
                        (Phase::GpuToCpu, CopyDir::D2H)
                    };
                    st.tl.demand_copy(
                        phase, pcie.transfer_time(wte_bytes), dir, 0.0);
                }
            }
            return Ok(());
        }

        // Distributed: fetch the communication groups of every param.
        // BTreeSet: group order must be deterministic — HashSet
        // iteration order varies per process, which would make the
        // multi-GPU stream timeline (and the golden traces locked on
        // it) run-to-run nondeterministic.
        if self.nproc() > 1 {
            let positions: HashSet<usize> = params
                .iter()
                .map(|&t| {
                    let ti = st.mgr.reg.tensor_index(ChunkKind::ParamFp16, t);
                    st.mgr.reg.chunks[st.mgr.reg.tensors[ti].chunk]
                        .list_pos as usize
                })
                .collect();
            let groups: BTreeSet<usize> =
                positions.iter().map(|&p| st.groups.group_of(p)).collect();
            for g in groups {
                self.fetch_group(st, g, now)?;
            }
        }

        // Access parameters (Algorithm 1), run the op, release
        // (Algorithm 2).  A prefetched chunk's copy is waited out on the
        // timeline before the access consumes it.
        for &t in &params {
            let c = self.chunk_of(st, ChunkKind::ParamFp16, t);
            self.wait_chunk(st, c);
            let RunState { mgr, tracer, policy, .. } = st;
            with_policy(policy, tracer, |pol| {
                mgr.access_tensor(ChunkKind::ParamFp16, t, Device::Gpu(0),
                                  pol, now)
            })?;
            if st.warmup {
                st.tracer.record_chunk_use_at(c, now, true);
            }
        }
        self.charge_moves(st)?;

        if !st.warmup {
            let gpu = self.cluster.gpu;
            let mult = self.bwd_mult(st.stage);
            st.tl.charge(Phase::FwdBwd, gpu.op_time(op.kind,
                                                    mult * op.fwd_flops));
            // Activation offload traffic (ckpt+offload): one boundary per
            // layer crosses PCIe each way; charge at the layer's last op.
            // Down in FWD (async: nothing waits for it), up in BWD (the
            // boundary op needs it: demand).
            if self.task.plan == ActivationPlan::CheckpointingOffload
                && op.name.ends_with(".fc2")
            {
                let m = &graph.spec;
                let bytes = 2 * self.task.batch_per_gpu * m.seq * m.hidden;
                if st.stage == Stage::Fwd {
                    // Offload cannot wait for a buffer (the boundary is
                    // leaving the GPU now): pinned if one is free,
                    // pageable otherwise.
                    let (_, done, _, lease) = self.charge_async_routed(
                        st, Phase::ActOffload, CopyDir::D2H, 0.0, bytes);
                    if let Some(l) = lease {
                        st.stream_leases.push(StreamLease {
                            lease: l,
                            dir: CopyDir::D2H,
                            done,
                        });
                    }
                } else {
                    // Demand reload: preempts the pool, pinned rate.
                    let t = self.cluster.net.pcie.transfer_time(bytes);
                    st.tl.demand_copy(Phase::ActOffload, t, CopyDir::H2D, 0.0);
                }
            }
        }

        let target = if st.stage == Stage::Fwd {
            TensorState::HoldAfterFwd
        } else {
            TensorState::HoldAfterBwd
        };
        for &t in &params {
            st.mgr.release_tensor(ChunkKind::ParamFp16, t, target)?;
        }

        // Distributed: release/reduce groups that completed this stage
        // (deterministic order, as above).
        if self.nproc() > 1 {
            let positions: HashSet<usize> = params
                .iter()
                .map(|&t| {
                    let ti = st.mgr.reg.tensor_index(ChunkKind::ParamFp16, t);
                    st.mgr.reg.chunks[st.mgr.reg.tensors[ti].chunk]
                        .list_pos as usize
                })
                .collect();
            let groups: BTreeSet<usize> =
                positions.iter().map(|&p| st.groups.group_of(p)).collect();
            for g in groups {
                self.release_group(st, g, target)?;
            }
        }
        Ok(())
    }

    /// FetchRemoteChunks (Algorithm 1, lines 1–20): all-gather the group
    /// if any member tensor is FREE.
    fn fetch_group(&self, st: &mut RunState, g: usize, now: Moment)
        -> Result<()> {
        if st.gathered.contains(&g) {
            return Ok(());
        }
        // Consume an in-flight lookahead gather: block only for
        // whatever part of the collective compute hasn't already hidden.
        if let Some(gi) = st.coll.take_gather(g) {
            st.tl.wait_collective(gi.done);
            for p in st.groups.members(g) {
                st.mgr.finish_gather(st.fp16_list[p]);
            }
            st.gathered.insert(g);
            return Ok(());
        }
        let members: Vec<usize> = st.groups.members(g).collect();
        // Trigger only when some member chunk is absent (paper line 5:
        // a FREE tensor exists).
        let any_free = members.iter().any(|&p| {
            let c = st.fp16_list[p];
            st.mgr.chunk(c).device.is_none()
        });
        if !any_free {
            st.gathered.insert(g);
            return Ok(());
        }
        if st.warmup {
            // The gather log *is* the steady-state gather schedule
            // (iterations are structurally identical) — the group
            // prefetcher is built from it after warm-up.
            st.gather_log.push((now, g));
        }
        let chunk_bytes = st.mgr.chunk(st.fp16_list[0]).bytes();
        for &p in &members {
            let c = st.fp16_list[p];
            self.wait_chunk(st, c);
            let RunState { mgr, tracer, policy, .. } = st;
            with_policy(policy, tracer, |pol| {
                mgr.ensure_on(c, Device::Gpu(0), pol, now)
            })?;
            st.mgr.pin(c);
            // Remote payloads arrive in HOLD.
            st.mgr.retag_tensors(c, TensorState::Free, TensorState::Hold)?;
            if st.warmup {
                st.tracer.record_chunk_use_at(c, now, true);
            }
        }
        if !st.warmup {
            let cc = CollectiveCost::new(self.cluster.net.nvlink,
                                         self.nproc());
            let op = cc.allgather_op(chunk_bytes);
            if self.collectives_overlapped() {
                // Demand gather on the collective stream: compute
                // stalls for queueing delay + wire time.
                st.tl.demand_collective(Phase::AllGather, op.secs);
            } else {
                st.tl.charge(Phase::AllGather, op.secs);
            }
            st.allgather_time += op.secs;
            st.allgather_bytes += op.bytes;
        }
        for &p in &members {
            st.mgr.unpin(st.fp16_list[p]);
        }
        self.charge_moves(st)?;
        st.gathered.insert(g);
        Ok(())
    }

    /// ReleaseRemoteChunk (Algorithm 2, lines 1–30).
    fn release_group(
        &self,
        st: &mut RunState,
        g: usize,
        target: TensorState,
    ) -> Result<()> {
        let members: Vec<usize> = st.groups.members(g).collect();
        // All tensors of all member chunks must have reached `target`.
        let done = members.iter().all(|&p| {
            let c = st.fp16_list[p];
            st.mgr.chunk(c).tensors.iter().all(|t| {
                st.mgr.reg.tensors[t.0 as usize].state == target
            })
        });
        if !done {
            return Ok(());
        }
        if target == TensorState::HoldAfterBwd && !st.warmup {
            // Reduce-scatter of the group's grad chunks (is_allreduce).
            let chunk_bytes = st.mgr.chunk(st.fp16_list[0]).bytes();
            let cc =
                CollectiveCost::new(self.cluster.net.nvlink, self.nproc());
            let op = cc.reduce_scatter_op(chunk_bytes);
            if self.collectives_overlapped() {
                // Drain behind compute (and behind queued gathers);
                // ADAM waits it out per group.
                let done =
                    st.tl.async_collective(Phase::ReduceScatter, op.secs);
                st.coll.set_rs_done(g, done);
            } else {
                st.tl.charge(Phase::ReduceScatter, op.secs);
            }
            st.reduce_scatter_time += op.secs;
            st.reduce_scatter_bytes += op.bytes;
        }
        // Release remote payloads; tensors -> FREE.
        for &p in &members {
            if st.groups.owner_of(p) == 0 {
                continue; // local chunk keeps its payload
            }
            let c = st.fp16_list[p];
            let chunk_tensors = st.mgr.chunk(c).tensors.clone();
            for t in chunk_tensors {
                st.mgr.reg.tensors[t.0 as usize]
                    .set_state(TensorState::Free)
                    .map_err(|e| anyhow!(e))?;
            }
            if st.mgr.chunk(c).device.is_some() {
                st.mgr.release_payload(c)?;
            }
        }
        st.gathered.remove(&g);
        Ok(())
    }

    /// ADAM over one local chunk group (Sec. 6.2 last paragraph + 8.2).
    fn exec_adam(
        &self,
        st: &mut RunState,
        pos: usize,
        local_index: usize,
    ) -> Result<()> {
        let now = st.moment.saturating_sub(1);
        let fp16 = st.fp16_list[pos];
        // The group's averaged gradient must be home before the update:
        // wait out whatever part of its reduce-scatter hasn't drained.
        if !st.warmup && self.collectives_overlapped() {
            let g = st.groups.group_of(pos);
            if let Some(t) = st.coll.take_rs_done(g) {
                st.tl.wait_collective(t);
            }
        }
        let os = st.mgr.reg.os_chunks_for(fp16);
        let on_gpu = !st.warmup
            && self.opt.device_aware_os
            && local_index < st.placement.os_groups_on_gpu;
        let device = if on_gpu { Device::Gpu(0) } else { Device::Cpu };

        // Bring the grad (fp16 chunk) and the OS chunks to the ADAM device.
        for c in std::iter::once(fp16).chain(os) {
            self.wait_chunk(st, c);
            let RunState { mgr, tracer, policy, .. } = st;
            with_policy(policy, tracer, |pol| {
                mgr.ensure_on(c, device, pol, now)
            })?;
            if st.warmup {
                st.tracer.record_chunk_use_at(c, now, device.is_gpu());
            }
        }
        // OS tensors -> COMPUTE -> HOLD; fp16 tensors -> HOLD (updated
        // params overwrite the grads in place, Fig. 6 reversed).
        let n_tensors = st.mgr.chunk(fp16).tensors.len();
        for kind in [ChunkKind::ParamFp32, ChunkKind::Momentum,
                     ChunkKind::Variance] {
            for i in 0..n_tensors {
                let t = st.mgr.chunk(fp16).tensors[i];
                let idx = t.0 as usize % st.mgr.reg.n_model_tensors;
                let RunState { mgr, tracer, policy, .. } = st;
                with_policy(policy, tracer, |pol| {
                    mgr.access_tensor(kind, idx, device, pol, now)
                })?;
                st.mgr.release_tensor(kind, idx, TensorState::Hold)?;
            }
        }
        for i in 0..n_tensors {
            let t = st.mgr.chunk(fp16).tensors[i];
            let idx = t.0 as usize % st.mgr.reg.n_model_tensors;
            let ti = st.mgr.reg.tensor_index(ChunkKind::ParamFp16, idx);
            let s = st.mgr.reg.tensors[ti].state;
            if s.is_hold_like() {
                st.mgr.reg.tensors[ti]
                    .set_state(TensorState::Hold)
                    .map_err(|e| anyhow!(e))?;
            }
        }

        if !st.warmup {
            let chunk_elems = st.mgr.reg.chunk_elems;
            let prof = if on_gpu { self.cluster.gpu } else {
                self.shared_cpu()
            };
            // grad fp16 -> fp32 conversion + fused update over
            // p32/m/v (+p16 writeback): ~16 B/elem of traffic.
            st.tl.charge(Phase::Adam, prof.cast_time(2 * chunk_elems));
            st.tl.charge(Phase::Adam, prof.adam_time(16 * chunk_elems));
        }
        self.charge_adam_moves(st)?;
        Ok(())
    }

    // ------------------------------------------------------------ helpers

    /// BWD ops cost 2x FWD plus checkpoint recompute.
    fn bwd_mult(&self, stage: Stage) -> f64 {
        match stage {
            Stage::Fwd => 1.0,
            Stage::Bwd => 2.0 + self.task.plan.recompute_factor(),
            Stage::Adam => 0.0,
        }
    }

    /// Pick the host-memory path for an async (non-demand) PCIe copy of
    /// `bytes` in direction `dir`: pinned while a staging buffer from
    /// `dir`'s sub-pool is held, pageable when the pool (total or
    /// sub-pool) is exhausted (pressure-driven copies cannot wait).
    /// With the pool disabled everything is pinned on the single curve
    /// — the pre-pool behaviour bit-for-bit.  The caller sets the
    /// returned lease's release time once the copy's completion time is
    /// known.
    fn route_async_copy(
        &self,
        st: &mut RunState,
        dir: CopyDir,
        bytes: u64,
    ) -> (f64, CopyRoute, Option<PinnedLease>) {
        if !st.pool.enabled() {
            return (
                self.cluster.net.pcie.transfer_time(bytes),
                CopyRoute::Pinned,
                None,
            );
        }
        match st.pool.try_acquire(st.tl.now(), dir) {
            Some(lease) => (
                self.cluster.net.pcie.transfer_time(bytes),
                CopyRoute::Pinned,
                Some(lease),
            ),
            None => (
                self.cluster.net.pcie_pageable.transfer_time(bytes),
                CopyRoute::Pageable,
                None,
            ),
        }
    }

    /// Route, charge and lease one async copy in a single step: pick
    /// the curve ([`Engine::route_async_copy`]), enqueue on `dir`, and
    /// set the lease's release to the completion time.  The one place
    /// the async lease protocol lives — the Evict and Prefetch drain
    /// arms and the activation-offload path all charge through here.
    /// Returns (wire secs, completion time, route, lease).
    fn charge_async_routed(
        &self,
        st: &mut RunState,
        phase: Phase,
        dir: CopyDir,
        ready: f64,
        bytes: u64,
    ) -> (f64, f64, CopyRoute, Option<PinnedLease>) {
        let (t, route, lease) = self.route_async_copy(st, dir, bytes);
        let done = st.tl.async_copy_on(phase, t, dir, ready, route);
        if let Some(l) = lease {
            st.pool.set_release(l, done);
        }
        (t, done, route, lease)
    }

    /// CPU profile with bandwidth shared across the node's nproc ranks.
    fn shared_cpu(&self) -> crate::sim::DeviceProfile {
        let mut p = self.cluster.cpu;
        p.mem_bw /= self.nproc() as f64;
        p.gemm_flops /= self.nproc() as f64;
        p
    }

    /// Drain chunk-move events and charge PCIe time (FWD/BWD phases).
    fn charge_moves(&self, st: &mut RunState) -> Result<()> {
        self.charge_events(st, false)
    }

    /// Same, but attribute to the ADAM-move bar of Fig. 16.
    fn charge_adam_moves(&self, st: &mut RunState) -> Result<()> {
        self.charge_events(st, true)
    }

    /// Drain chunk-move events onto the timeline.  Evictions ride the
    /// async D2H stream; prefetches the async H2D stream (their
    /// completion time is remembered for `wait_chunk`); demand
    /// transfers block the compute stream.  An H2D fetch issued after an
    /// eviction in the same drain batch waits for that eviction — it is
    /// moving into the space the eviction frees.
    fn charge_events(&self, st: &mut RunState, adam: bool) -> Result<()> {
        let events = st.mgr.drain_events();
        if st.warmup {
            return Ok(());
        }
        let pcie = self.cluster.net.pcie;
        // Leases whose copies have completed need no more shifting;
        // drop them so the compression scan stays short.
        if st.pool.enabled() {
            let now_t = st.tl.now();
            st.stream_leases.retain(|sl| sl.done > now_t);
        }
        let mut dep = 0.0f64;
        let mut cancelled_groups: Vec<usize> = Vec::new();
        for ev in events {
            if ev.kind == MoveKind::GatherCancel {
                // Memory pressure reclaimed a mid-gather chunk: cancel
                // the whole group's collective.  The demand path will
                // re-gather (and re-charge) exactly once, so total
                // collective volume stays at the serial schedule's.
                let pos = st.mgr.reg.chunks[ev.chunk.0 as usize].list_pos
                    as usize;
                let g = st.groups.group_of(pos);
                if let Some(gi) = st.coll.take_gather(g) {
                    st.allgather_bytes =
                        st.allgather_bytes.saturating_sub(gi.bytes);
                    st.allgather_time =
                        (st.allgather_time - gi.secs).max(0.0);
                    // The cancelled gather's staging buffer frees now.
                    if let Some(l) = gi.lease {
                        st.pool.release(l);
                    }
                    let now_t = st.tl.now();
                    if gi.done > now_t {
                        // Un-charge only the part of the collective
                        // that has not physically run yet: the full
                        // wire time while still queued, the remainder
                        // when cancelled mid-wire.  Followers compress
                        // forward by the same amount, so no completion
                        // time ever drops below elapsed time.
                        let remainder = (gi.done - now_t).min(gi.secs);
                        st.tl.reclaim_collective(
                            Phase::AllGather, remainder);
                        st.coll.compress_after(gi.done, remainder);
                        // Queue compression moved the surviving
                        // gathers' completion times; their buffer
                        // leases release at the new times.
                        let RunState { coll, pool, .. } = st;
                        for g2 in coll.gathers_mut() {
                            if let Some(l) = g2.lease {
                                pool.set_release(l, g2.done);
                            }
                        }
                    }
                    st.gather_cancelled_groups += 1;
                    cancelled_groups.push(g);
                }
                continue;
            }
            if ev.kind == MoveKind::PrefetchCancel {
                if let Some(pc) = st.inflight_done.remove(&ev.chunk) {
                    // The staging buffer frees with the cancel (a no-op
                    // for an already-landed copy's expired lease).
                    if let Some(l) = pc.lease {
                        st.pool.release(l);
                    }
                    if pc.done > st.tl.now() {
                        // Still queued: un-charge its time so the
                        // timeline agrees with the credited-back
                        // MoveStats — otherwise the later demand fetch
                        // double-charges, and a cancel-heavy run could
                        // look slower than serial.
                        st.tl.reclaim_on(pc.phase, pc.secs, pc.dir,
                                         pc.route);
                        // Queue compression: copies FIFO-queued behind
                        // the reclaimed one land earlier now; shift
                        // their recorded completion times too, so later
                        // waits and cancel classifications stay honest
                        // — and their buffer leases (prefetch AND
                        // eviction/offload) release earlier with them.
                        let RunState {
                            inflight_done, stream_leases, pool, ..
                        } = st;
                        for other in inflight_done.values_mut() {
                            if other.dir == pc.dir && other.done > pc.done
                            {
                                other.done =
                                    (other.done - pc.secs).max(0.0);
                                if let Some(l) = other.lease {
                                    pool.set_release(l, other.done);
                                }
                            }
                        }
                        for sl in stream_leases.iter_mut() {
                            if sl.dir == pc.dir && sl.done > pc.done {
                                sl.done = (sl.done - pc.secs).max(0.0);
                                pool.set_release(sl.lease, sl.done);
                            }
                        }
                    } else {
                        // The copy had already landed when pressure
                        // reclaimed the chunk: the traffic was real, so
                        // undo the manager's byte credit (the cancel
                        // event's `from` is the staged-on device, i.e.
                        // the original copy's destination).
                        match ev.from {
                            Some(Device::Gpu(_)) => {
                                st.mgr.stats.cpu_to_gpu_bytes += ev.bytes;
                                st.mgr.stats.cpu_to_gpu_moves += 1;
                            }
                            _ => {
                                st.mgr.stats.gpu_to_cpu_bytes += ev.bytes;
                                st.mgr.stats.gpu_to_cpu_moves += 1;
                            }
                        }
                    }
                }
                continue;
            }
            let dir = match (ev.from, ev.to) {
                (Some(Device::Cpu), Some(Device::Gpu(_))) => CopyDir::H2D,
                (Some(Device::Gpu(_)), Some(Device::Cpu)) => CopyDir::D2H,
                _ => continue, // allocs and releases are free
            };
            let phase = if adam {
                Phase::AdamMove
            } else {
                match dir {
                    CopyDir::H2D => Phase::CpuToGpu,
                    CopyDir::D2H => Phase::GpuToCpu,
                }
            };
            match ev.kind {
                MoveKind::Evict => {
                    // Pressure-driven: cannot wait for a buffer, so it
                    // downgrades to the pageable curve when the pool is
                    // dry.
                    let (_, done, _, lease) = self
                        .charge_async_routed(st, phase, dir, dep,
                                             ev.bytes);
                    dep = done;
                    if let Some(l) = lease {
                        st.stream_leases
                            .push(StreamLease { lease: l, dir, done });
                    }
                }
                MoveKind::Prefetch => {
                    // The issue paths reserve pool capacity before
                    // staging, so this normally lands a pinned lease;
                    // if an eviction in the same drain batch took the
                    // last buffer, the copy downgrades rather than
                    // un-staging the chunk.
                    let (t, done, route, lease) = self
                        .charge_async_routed(st, phase, dir, dep,
                                             ev.bytes);
                    st.inflight_done.insert(
                        ev.chunk,
                        PendingCopy { done, secs: t, dir, phase, route,
                                      lease },
                    );
                }
                _ => {
                    // Demand copies preempt the pool: always charged at
                    // the pinned rate, never queued on a buffer.
                    st.tl.demand_copy(phase, pcie.transfer_time(ev.bytes),
                                      dir, dep);
                }
            }
        }
        // Finish cancelling each reclaimed group: drop the remaining
        // mid-gather member payloads and revert their tensors, so the
        // group is back in the released state the demand path expects.
        for g in cancelled_groups {
            let members: Vec<usize> = st.groups.members(g).collect();
            for p in members {
                if st.groups.owner_of(p) == 0 {
                    continue; // the local chunk was never gathering
                }
                let c = st.fp16_list[p];
                if st.mgr.is_gathering(c) {
                    // Emits another GatherCancel event; it finds the
                    // group already cancelled on the next drain.
                    st.mgr.cancel_gather(c)?;
                }
                if st.mgr.chunk(c).device.is_none() {
                    st.mgr.retag_tensors(
                        c, TensorState::Hold, TensorState::Free)?;
                }
            }
            st.gathered.remove(&g);
        }
        Ok(())
    }
}

/// Construct the selected eviction policy (OPT borrows the tracer) and
/// run `f` with it.
fn with_policy<R>(
    sel: &mut PolicySel,
    tracer: &MemTracer,
    f: impl FnOnce(&mut dyn EvictionPolicy) -> R,
) -> R {
    match sel {
        PolicySel::Opt => {
            let mut p = OptPolicy { tracer };
            f(&mut p)
        }
        PolicySel::Lru(p) => f(p),
        PolicySel::Fifo(p) => f(p),
        PolicySel::Lfu(p) => f(p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterPreset;
    use crate::model::GptSpec;

    fn run(model: &str, batch: u64, gpus: u32) -> EngineReport {
        let task =
            TrainTask::new(GptSpec::by_name(model).unwrap(), batch, gpus);
        Engine::new(ClusterPreset::yard(), task).run().unwrap()
    }

    #[test]
    fn one_gpu_1b_runs_and_is_plausible() {
        let r = run("1B", 16, 1);
        assert!(r.iter_time_s > 0.1 && r.iter_time_s < 120.0,
                "iter {}", r.iter_time_s);
        // Paper band: tens of Tflops on V100.
        assert!(r.tflops_per_gpu > 20.0 && r.tflops_per_gpu < 80.0,
                "tflops {}", r.tflops_per_gpu);
    }

    #[test]
    fn eight_gpu_has_collectives() {
        let r = run("4B", 8, 8);
        assert!(r.breakdown.get(Phase::AllGather) > 0.0);
        assert!(r.breakdown.get(Phase::ReduceScatter) > 0.0);
        assert!(r.allgather_bytes > 0);
    }

    #[test]
    fn single_gpu_has_no_collectives() {
        let r = run("1B", 16, 1);
        assert_eq!(r.breakdown.get(Phase::AllGather), 0.0);
        assert_eq!(r.allgather_bytes, 0);
    }

    #[test]
    fn tracer_beats_static_partition() {
        // Fig. 16: Base vs SP — the tracer must cut chunk traffic.
        let task =
            TrainTask::new(GptSpec::by_name("4B").unwrap(), 8, 1);
        let base = Engine::new(ClusterPreset::yard(), task).run().unwrap();
        let sp = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::static_partition())
            .run()
            .unwrap();
        assert!(
            base.iter_time_s < sp.iter_time_s,
            "base {} !< sp {}",
            base.iter_time_s,
            sp.iter_time_s
        );
    }

    #[test]
    fn infeasible_when_model_too_big_for_node() {
        // 68B on YARD-120GB single GPU cannot hold OS in 120 GB.
        let task =
            TrainTask::new(GptSpec::by_name("68B").unwrap(), 8, 1);
        let r = Engine::new(ClusterPreset::yard_120gb(), task).run();
        assert!(r.is_err());
    }

    // The serial flat-clock contract and the full pipelined-vs-serial
    // comparison (volume, never-slower, overlap shares) live in
    // tests/prefetch_overlap.rs — not duplicated here.

    #[test]
    fn overlap_without_prefetch_still_valid() {
        let task =
            TrainTask::new(GptSpec::by_name("8B").unwrap(), 8, 1);
        let serial =
            Engine::new(ClusterPreset::yard(), task).run().unwrap();
        let ov = Engine::new(ClusterPreset::yard(), task)
            .with_opt(OptimizationPlan::overlap_only())
            .run()
            .unwrap();
        assert!(ov.iter_time_s <= serial.iter_time_s * (1.0 + 1e-9));
        assert_eq!(ov.move_stats.prefetches, 0);
        // Work accounting is identical either way — only concurrency
        // differs.
        let sum = |r: &EngineReport| -> f64 {
            Phase::ALL.iter().map(|&p| r.breakdown.get(p)).sum()
        };
        assert!((sum(&serial) - sum(&ov)).abs() < 1e-6 * sum(&serial));
    }
}
